"""Serve a model with batched requests over pluggable KV-cache codecs.

Trains briefly (so generations are non-trivial), then serves batched
prompts comparing cache policies through the KeyCodec/CachePolicy API:
fp16, KIVI-4, PolarQuant_44 (+2-bit values) — the paper's Table 4 setting
in miniature — plus a KVTuner-style *mixed* per-layer policy (int8 on the
first layer, polar 4+4 elsewhere) with per-layer cache bytes.

Finishes with a shared-system-prompt demo on the continuous-batching
engine: every request carries the same system prefix, and the prefix
cache adopts the donor's encoded pages instead of re-prefilling them
(DESIGN.md §12) — printing the hit rate and the pool bytes shared —
then reruns the same engine through the **streaming front door**
(DESIGN.md §13): tokens print the step they are sampled, and one request
is cancelled mid-flight, its pages decref'd and its slot reused while
the other requests keep decoding.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.core import CachePolicy
from repro.data import SyntheticLMDataset
from repro.models import get_model
from repro.serve import (
    ContinuousBatchingEngine, GenerationConfig, Request, ServeEngine,
    StreamingEngine,
)
from repro.train.train_step import StepConfig, init_train_state, make_train_step


def main():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"),
                           num_layers=4, d_model=256, num_heads=4,
                           head_dim=64, vocab_size=2048)
    model = get_model(cfg)
    ds = SyntheticLMDataset(cfg, global_batch=16, seq_len=128, seed=0)
    step = make_train_step(model, None, StepConfig(peak_lr=3e-3,
                                                   warmup_steps=10,
                                                   total_steps=120))
    state = init_train_state(model, jax.random.PRNGKey(0))
    for i in range(120):
        batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
        state, metrics = step(state, batch)
    print(f"trained 120 steps, loss {float(metrics['loss']):.3f}")

    q = cfg.quant
    int8 = dataclasses.replace(q, method="int", key_bits=8)
    polar44 = dataclasses.replace(q, method="polar", rho_bits=4, theta_bits=4)
    policies = [
        ("fp16", CachePolicy.uniform(dataclasses.replace(q, method="none"))),
        ("kivi4", CachePolicy.uniform(
            dataclasses.replace(q, method="kivi", key_bits=4))),
        ("polar44", CachePolicy.uniform(polar44)),
        ("polar44+v2", CachePolicy.uniform(
            dataclasses.replace(polar44, value_bits=2))),
        # KVTuner-style mix: the sensitive first layer at int8, rest polar
        ("int8x1+polar44", CachePolicy.first_k(1, int8, polar44)),
    ]

    prompts = {"tokens": np.asarray(ds.local_batch_np(777)["tokens"])[:8, :64]}
    rows = []
    for name, policy in policies:
        eng = ServeEngine(get_model(dataclasses.replace(
            cfg, cache_policy=policy)), state.params, max_len=256)
        out = eng.generate(prompts, GenerationConfig(max_new_tokens=24))
        rows.append((name, out))
        bits = policy.avg_key_bits(cfg.num_layers, cfg.head_dim)
        print(f"{name:16s} {out['tokens_per_s']:8.1f} tok/s  "
              f"cache {out['cache_bytes'] / 2**20:6.2f} MiB  "
              f"avg {bits:.2f} key-bits/elem  "
              f"first-gen {out['tokens'][0][:10].tolist()}")
    fp = rows[0][1]["tokens"]
    for name, out in rows[1:]:
        agree = (out["tokens"] == fp).mean()
        print(f"{name:16s} token agreement vs fp16: {agree * 100:.1f}%")
    mixed = rows[-1][1]
    per_layer = [f"{b / 2**20:.2f}" for b in mixed["cache_bytes_per_layer"]]
    print(f"mixed policy per-layer cache MiB: {per_layer} "
          "(layer 0 = int8, layers 1-3 = polar 4+4)")

    # --- shared-system-prompt serving: prefix-cache page reuse -----------
    g = cfg.quant.group_size
    model = get_model(dataclasses.replace(
        cfg, cache_policy=CachePolicy.uniform(polar44)))
    all_tokens = np.asarray(ds.local_batch_np(123)["tokens"])
    system_prompt = all_tokens[0, : 3 * g].astype(np.int32)
    reqs = []
    for i in range(6):
        user = all_tokens[i + 1, : 10 + 3 * i].astype(np.int32)
        # the first request arrives alone so its prefill can register the
        # system prompt's pages before the rest admit
        reqs.append(Request(rid=i,
                            prompt=np.concatenate([system_prompt, user]),
                            max_new_tokens=8,
                            arrival_time=0.0 if i == 0 else 100.0 + 0.01 * i))
    eng = ContinuousBatchingEngine(model, state.params, max_slots=3,
                                   max_len=256, prefix_cache=True,
                                   prefill_chunk=g)
    out = eng.run(reqs, GenerationConfig(max_new_tokens=8))
    saved = out["prefix_pool_bytes_saved"]
    print(f"shared-prefix serving: {len(out['requests'])} requests, "
          f"{out['prefix_hit_rate'] * 100:.1f}% of prefill tokens served "
          f"from adopted pages ({out['prefill_tokens_skipped']} tokens, "
          f"{out['adopted_pages']} pages, {saved / 2**10:.1f} KiB of pool "
          "shared instead of re-encoded)")

    # --- streaming: tokens as they arrive, one mid-flight cancel ---------
    # Same engine (same compiled functions), new session through the
    # open-loop front door: requests are added while the step loop runs,
    # tokens surface as TokenEvents, and cancellation frees the victim's
    # pages (never the index-pinned prefix) + slot for the next admission.
    stream = StreamingEngine(eng, GenerationConfig(max_new_tokens=12))
    rids = [stream.add_request(
        np.concatenate([system_prompt,
                        all_tokens[i + 1, : 8 + 2 * i].astype(np.int32)]),
        max_new_tokens=12) for i in range(3)]
    victim = rids[1]
    got = {rid: [] for rid in rids}
    cancelled = False
    print("streaming serve (3 requests; cancelling the 2nd mid-flight):")
    while stream.has_work:
        for ev in stream.step():
            if ev.kind in ("first_token", "token"):
                got[ev.rid].append(ev.token)
                print(f"  t={ev.t * 1e3:7.1f}ms rid={ev.rid} "
                      f"slot={ev.slot} +{ev.token}")
            else:
                if ev.kind == "preempt" and got[ev.rid]:
                    got[ev.rid].pop()   # preempt retracts the last token
                print(f"  t={ev.t * 1e3:7.1f}ms rid={ev.rid} "
                      f"slot={ev.slot} {ev.kind}")
            if (not cancelled and ev.rid == victim
                    and len(got[victim]) >= 3):
                stream.cancel(victim)
                cancelled = True
    res = stream.result()
    assert cancelled and res["n_cancelled"] == 1
    print(f"streamed {res['total_tokens']} tokens from "
          f"{len(res['requests'])} finished requests; rid={victim} "
          f"cancelled after {len(got[victim])} tokens, its pages back in "
          f"the pool ({stream.core.sched.alloc.free_pages} pages free)")


if __name__ == "__main__":
    main()
