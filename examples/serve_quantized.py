"""Serve a model with batched requests over the PolarQuant KV cache.

Trains briefly (so generations are non-trivial), then serves batched
prompts comparing cache policies: fp16, KIVI-4, PolarQuant_44 (+2-bit
values) — the paper's Table 4 setting in miniature.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.data import SyntheticLMDataset
from repro.models import get_model
from repro.serve import GenerationConfig, ServeEngine
from repro.train.train_step import StepConfig, init_train_state, make_train_step


def main():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"),
                           num_layers=4, d_model=256, num_heads=4,
                           head_dim=64, vocab_size=2048)
    model = get_model(cfg)
    ds = SyntheticLMDataset(cfg, global_batch=16, seq_len=128, seed=0)
    step = make_train_step(model, None, StepConfig(peak_lr=3e-3,
                                                   warmup_steps=10,
                                                   total_steps=120))
    state = init_train_state(model, jax.random.PRNGKey(0))
    for i in range(120):
        batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
        state, metrics = step(state, batch)
    print(f"trained 120 steps, loss {float(metrics['loss']):.3f}")

    prompts = {"tokens": np.asarray(ds.local_batch_np(777)["tokens"])[:8, :64]}
    rows = []
    for name, method, vbits in [("fp16", "none", 0), ("kivi4", "kivi", 0),
                                ("polar44", "polar", 0),
                                ("polar44+v2", "polar", 2)]:
        qc = dataclasses.replace(cfg.quant, method=method, value_bits=vbits)
        eng = ServeEngine(get_model(dataclasses.replace(cfg, quant=qc)),
                          state.params, max_len=256)
        out = eng.generate(prompts, GenerationConfig(max_new_tokens=24))
        rows.append((name, out))
        print(f"{name:12s} {out['tokens_per_s']:8.1f} tok/s  "
              f"cache {out['cache_bytes'] / 2**20:6.2f} MiB  "
              f"first-gen {out['tokens'][0][:10].tolist()}")
    fp = rows[0][1]["tokens"]
    for name, out in rows[1:]:
        agree = (out["tokens"] == fp).mean()
        print(f"{name:12s} token agreement vs fp16: {agree * 100:.1f}%")


if __name__ == "__main__":
    main()
