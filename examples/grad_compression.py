"""Cross-boundary gradient compression demo (int8 + error feedback).

Simulates the cross-pod (DCN) reduction on an 8-device CPU mesh: per-pod
partial gradients are int8-compressed before the all-reduce (4x fewer wire
bytes than fp32), with the quantization error carried forward so SGD
convergence is preserved (EF-SGD).

    PYTHONPATH=src python examples/grad_compression.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.distributed.collectives import ef_allreduce_mean  # noqa: E402


def main():
    mesh = jax.make_mesh((8,), ("dp",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    dim = 512
    key = jax.random.PRNGKey(0)
    w_true = jax.random.normal(key, (dim,))

    def per_worker_grads(w, key):
        """8 workers, each with its own minibatch of a quadratic loss."""
        xs = jax.random.normal(key, (8, 64, dim))
        err = xs @ w - xs @ w_true
        return jnp.einsum("wbd,wb->wd", xs, err) / 64.0

    for compressed in (False, True):
        w = jnp.zeros((dim,))
        ef = {"g": jnp.zeros((8, dim), jnp.float32)}
        k = key
        wire_bytes = 0
        for step in range(150):
            k, sub = jax.random.split(k)
            g = per_worker_grads(w, sub)
            if compressed:
                mean, ef = ef_allreduce_mean({"g": g}, ef, mesh, "dp")
                g_mean = mean["g"]
                wire_bytes += g.shape[0] * dim * 1  # int8 payload
            else:
                g_mean = jnp.mean(g, 0)
                wire_bytes += g.shape[0] * dim * 4  # fp32 payload
            w = w - 0.05 * g_mean
        final = float(jnp.linalg.norm(w - w_true) / jnp.linalg.norm(w_true))
        print(f"{'int8+EF' if compressed else 'fp32   '}: final rel err "
              f"{final:.5f}, wire {wire_bytes / 2**20:.1f} MiB")


if __name__ == "__main__":
    main()
