"""Quickstart: PolarQuant in 60 seconds.

Quantize a key cache in polar coordinates, decode with the LUT fast path,
and compare against the fp oracle + baselines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (QuantConfig, decode_attention, init_cache,
                        lut_qk_scores, dequant_qk_scores, prefill)
from repro.core.quantizers import encode_polar_keys, decode_polar_keys
from repro.models.layers import apply_rope


def main():
    key = jax.random.PRNGKey(0)
    B, Hkv, T, d = 1, 4, 1024, 128

    # Post-RoPE keys with channel-wise outliers (the hard case, Fig. 1a).
    half = d // 2
    mean = jnp.zeros((d,)).at[jnp.array([52, 55, 60])].set(10.0)
    pre_rope = jax.random.normal(key, (B, Hkv, T, d)) + mean
    k = apply_rope(pre_rope, jnp.arange(T), 10000.0)
    v = jax.random.normal(jax.random.PRNGKey(1), (B, Hkv, T, d))

    # 1. PolarQuant_44: 4-bit radius + 4-bit angle = 4.25 bits/element
    cfg = QuantConfig(method="polar", rho_bits=4, theta_bits=4, group_size=128)
    pk = encode_polar_keys(k, cfg)
    k_tilde = decode_polar_keys(pk)
    rel = jnp.linalg.norm(k - k_tilde) / jnp.linalg.norm(k)
    print(f"[1] key reconstruction, {cfg.key_bits_per_element(d):.2f} bits/elem: "
          f"rel err {float(rel):.4f}")
    print(f"    codes: {pk.codes.shape} {pk.codes.dtype} = "
          f"{pk.codes.nbytes * 8 / (T * d * Hkv * B):.1f} bits/elem payload "
          f"(vs 16 for bf16) + 32/g bits group stats")

    # 2. LUT decode: matmul -> table lookup, no dequantization
    q = jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, d))
    s_lut = lut_qk_scores(q, pk)
    s_deq = dequant_qk_scores(q, pk)
    print(f"[2] LUT scores == dequant-then-matmul: "
          f"max diff {float(jnp.abs(s_lut - s_deq).max()):.2e}")

    # 3. Full serving cache: prefill -> quantized decode attention
    cache = prefill(init_cache(cfg, B, Hkv, d, max_len=T), k, v)
    cache_fp = prefill(init_cache(QuantConfig(method="none"), B, Hkv, d,
                                  max_len=T), k, v)
    q_full = jax.random.normal(jax.random.PRNGKey(3), (B, Hkv * 8, d))
    o_pq = decode_attention(cache, q_full)
    o_fp = decode_attention(cache_fp, q_full)
    rel_o = jnp.linalg.norm(o_pq - o_fp) / jnp.linalg.norm(o_fp)
    print(f"[3] decode attention vs fp cache: rel err {float(rel_o):.4f}")

    # 4. Baselines at the same bit budget
    for method in ("kivi", "int", "zipcache"):
        c = prefill(init_cache(QuantConfig(method=method, key_bits=4,
                                           group_size=128), B, Hkv, d, T), k, v)
        o = decode_attention(c, q_full)
        r = jnp.linalg.norm(o - o_fp) / jnp.linalg.norm(o_fp)
        print(f"[4] {method:8s} 4-bit decode rel err {float(r):.4f}")


if __name__ == "__main__":
    main()
