"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on the synthetic pipeline, with checkpointing + restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--resume]

On this CPU container the default config is ~25M params to keep step time
reasonable; pass --full100m for the ~100M-parameter configuration (same
code path, just slower per step).
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.data import SyntheticLMDataset
from repro.models import get_model
from repro.train import Trainer, TrainerConfig
from repro.train.train_step import StepConfig


def small_lm(d_model=256, layers=8, vocab=8192) -> ModelConfig:
    base = get_config("tinyllama-1.1b")
    return dataclasses.replace(
        base, name=f"llama-{d_model}x{layers}", num_layers=layers,
        d_model=d_model, num_heads=d_model // 64, num_kv_heads=2,
        head_dim=64, d_ff=d_model * 3, vocab_size=vocab, max_seq_len=1024,
        dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full100m", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = small_lm(768, 12, 32000) if args.full100m else small_lm()
    model = get_model(cfg)
    print(f"model {cfg.name}: {cfg.param_count() / 1e6:.1f}M params")

    ds = SyntheticLMDataset(cfg, global_batch=args.batch, seq_len=args.seq,
                            seed=0)
    trainer = Trainer(
        model, ds,
        TrainerConfig(total_steps=args.steps, checkpoint_every=100,
                      checkpoint_dir=args.ckpt, log_every=20),
        StepConfig(peak_lr=3e-3, warmup_steps=30, total_steps=args.steps,
                   microbatches=2))
    res = trainer.run()
    print(f"first-20 loss {sum(res['losses'][:20]) / 20:.4f} -> "
          f"last-20 loss {sum(res['losses'][-20:]) / 20:.4f}")
    print(f"stragglers flagged: {res['stragglers']}")


if __name__ == "__main__":
    main()
