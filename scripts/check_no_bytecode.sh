#!/usr/bin/env bash
# No compiled-Python artifacts in the index. Stray __pycache__/ trees and
# .pyc files shadow source edits (a stale .pyc can mask a syntax error or
# resurrect deleted code at import time) and bloat diffs; .gitignore keeps
# them out of `git add .`, and this check catches the force-add path.
set -u
cd "$(dirname "$0")/.."

tracked=$(git ls-files | grep -E '(^|/)__pycache__(/|$)|\.py[co]$' || true)
if [ -n "$tracked" ]; then
    echo "ERROR: compiled Python artifacts tracked in git — remove with" >&2
    echo "'git rm -r --cached <path>' (they are .gitignore'd):" >&2
    echo "$tracked" >&2
    exit 1
fi
echo "bytecode check OK (no __pycache__/.pyc tracked)"
