"""§Perf hillclimb driver: A/B lowerings for the three chosen cells.

Each variant is lowered+compiled on the production 16x16 mesh and costed
with the loop-aware HLO model; results land in artifacts/perf/ for
EXPERIMENTS.md §Perf. Run:

    PYTHONPATH=src python scripts/hillclimb.py [cell...]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.launch import dryrun_lib as lib  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.train.train_step import StepConfig  # noqa: E402

OUT = "artifacts/perf"

# (cell, variant, kwargs) — baselines first; each later variant is the
# hypothesis -> change of one §Perf iteration.
CELLS = {
    # Most representative of the paper's technique: quantized-KV decode.
    "yi_decode": [
        ("yi-9b", "decode_32k", "v0_paper_gather_lut",
         dict(quant_override={"lut_impl": "gather"})),
        ("yi-9b", "decode_32k", "v1_select_lut",
         dict(quant_override={"lut_impl": "select"})),
        ("yi-9b", "decode_32k", "v2_select_v2bit",
         dict(quant_override={"lut_impl": "select", "value_bits": 2})),
        ("yi-9b", "decode_32k", "ref_fp16_cache",
         dict(quant_override={"method": "none"})),
        ("yi-9b", "decode_32k", "ref_kivi4_cache",
         dict(quant_override={"method": "kivi"})),
    ],
    # Most collective-bound cell (largest all-reduce/all-gather volume).
    "dbrx_train": [
        ("dbrx-132b", "train_4k", "v0_mb4_fp32",
         dict(step_cfg=StepConfig(microbatches=4))),
        ("dbrx-132b", "train_4k", "v1_mb8",
         dict(step_cfg=StepConfig(microbatches=8))),
        ("dbrx-132b", "train_4k", "v2_mb8_bf16params",
         dict(step_cfg=StepConfig(microbatches=8, param_dtype="bfloat16"))),
        ("dbrx-132b", "train_4k", "v3_mb8_bf16_noseqshard",
         dict(step_cfg=StepConfig(microbatches=8, param_dtype="bfloat16",
                                  seq_shard=False))),
    ],
    # Worst roofline fraction: attention-free SSM had no model parallelism.
    "mamba_train": [
        ("mamba2-2.7b", "train_4k", "v0_no_ssm_shard",
         dict(step_cfg=StepConfig(microbatches=4),
              rules_override={"ssm_heads": None, "ssm_conv": None,
                              "ssm_inner": None})),
        ("mamba2-2.7b", "train_4k", "v1_ssm_head_shard",
         dict(step_cfg=StepConfig(microbatches=4))),
        ("mamba2-2.7b", "train_4k", "v2_chunk128",
         dict(step_cfg=StepConfig(microbatches=4),
              cfg_override={"ssm_chunk": 128})),
        ("mamba2-2.7b", "train_4k", "v3_chunk512",
         dict(step_cfg=StepConfig(microbatches=4),
              cfg_override={"ssm_chunk": 512})),
    ],
}


def main():
    assert jax.device_count() == 512
    mesh = make_production_mesh(multi_pod=False)
    wanted = sys.argv[1:] or list(CELLS)
    for cell in wanted:
        for arch, shape, variant, kw in CELLS[cell]:
            step_cfg = kw.pop("step_cfg", StepConfig(microbatches=4))
            t0 = time.monotonic()
            try:
                rec = lib.run_cell(arch, shape, mesh, OUT, cell,
                                   step_cfg, variant=variant, **kw)
            except Exception as e:  # noqa: BLE001
                print(f"[hillclimb] {cell}/{variant}: FAIL {repr(e)[:200]}",
                      flush=True)
                continue
            c = rec["cost"]
            terms = lib.roofline_terms(rec, 256)
            print(f"[hillclimb] {cell}/{variant}: "
                  f"flops={c['flops']:.3g} bytes={c['bytes accessed']:.3g} "
                  f"coll={rec['collectives']['total_bytes']:.3g} | "
                  f"compute={terms['compute_s']:.3g}s "
                  f"mem={terms['memory_s']:.3g}s "
                  f"coll={terms['collective_s']:.3g}s "
                  f"peak={rec['memory']['peak_per_device'] / 2**30:.2f}GiB "
                  f"({time.monotonic() - t0:.0f}s)", flush=True)


if __name__ == "__main__":
    main()
