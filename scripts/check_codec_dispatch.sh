#!/usr/bin/env bash
# Guard the KeyCodec registry as the single quantization-method dispatch
# point: fail on any new string dispatch over QuantConfig.method (`x.method
# ==`, `x.method in (...)`, `x.method != ...`) in library code outside
# core/codecs.py. Cache/model code must branch on codec capabilities
# (codec.grouped, codec.quantizes, codec.supports_fused_decode) or call
# codec methods instead.
set -u
cd "$(dirname "$0")/.."

matches=$(grep -rnE '\.method *(==|!=| in )' src/repro --include='*.py' \
    | grep -v 'src/repro/core/codecs.py' || true)

if [ -n "$matches" ]; then
    echo "ERROR: string dispatch on the quantization method outside" >&2
    echo "src/repro/core/codecs.py — route through the codec registry:" >&2
    echo "$matches" >&2
    exit 1
fi
echo "codec dispatch check OK (registry is the single dispatch point)"
