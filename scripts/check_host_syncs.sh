#!/usr/bin/env bash
# Guard the host-sync budget of the serving stack (DESIGN.md §18): the
# run-ahead decode work only stays won if new per-token blocking fetches
# don't creep back in. Every host<->device synchronization point in
# src/repro/serve must be *declared*:
#
#   1. Any line that blocks on the device — block_until_ready,
#      jax.device_get, or .item() — must carry a trailing
#      `# sync: <reason>` marker on the same line. np.asarray(<device
#      array>) also syncs, but only the explicit blockers are
#      grep-enforceable; the reviewed np.asarray fetch sites carry the
#      same marker by convention.
#   2. Sync sites (marked or not) are allowed only in serve/core.py —
#      the device-dispatch layer. The front doors (api.py, engine.py),
#      scheduler, QoS, and chaos modules must never block on the device
#      (they are jax-free per check_engine_layering.sh; this rule keeps
#      it that way even for objects passed in).
#
# Adding a sync: put it in core.py, give it a `# sync:` reason, and
# account for it in DESIGN.md §18's sync-site inventory.
set -u
cd "$(dirname "$0")/.."

fail=0

unmarked=$(grep -rnE '(block_until_ready|jax\.device_get|\.item\(\))' \
    src/repro/serve --include='*.py' \
    | grep -v '# sync:' || true)
if [ -n "$unmarked" ]; then
    echo "ERROR: undeclared host sync in src/repro/serve — every" >&2
    echo "blocking fetch must carry a trailing '# sync: <reason>'" >&2
    echo "marker (DESIGN.md §18):" >&2
    echo "$unmarked" >&2
    fail=1
fi

outside=$(grep -rnE '(block_until_ready|jax\.device_get|\.item\(\)|# sync:)' \
    src/repro/serve --include='*.py' \
    | grep -v 'src/repro/serve/core.py' || true)
if [ -n "$outside" ]; then
    echo "ERROR: host sync outside serve/core.py — the device-dispatch" >&2
    echo "layer is the only place the serving stack may block on the" >&2
    echo "device:" >&2
    echo "$outside" >&2
    fail=1
fi

[ "$fail" -eq 0 ] || exit 1
n=$(grep -cE '# sync:' src/repro/serve/core.py || true)
echo "host-sync check OK ($n declared sync sites, all in serve/core.py)"
