"""Per-op traffic/collective breakdown of one dry-run cell (§Perf tooling).

    PYTHONPATH=src python scripts/perf_breakdown.py <arch> <shape> \
        [--key hbm_bytes|collective_bytes|flops] [--mb 4] [...]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402

import jax  # noqa: E402

from repro.launch import dryrun_lib as lib  # noqa: E402
from repro.launch import hlo_cost  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.train.train_step import StepConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--key", default="hbm_bytes")
    ap.add_argument("--mb", type=int, default=4)
    ap.add_argument("--depth", type=int, default=4)
    ap.add_argument("--seq-shard", type=int, default=1)
    ap.add_argument("--param-dtype", default="float32")
    ap.add_argument("--quant", default="{}", help="json quant override")
    ap.add_argument("--cfg", default="{}", help="json cfg override")
    args = ap.parse_args()

    mesh = make_production_mesh()
    step_cfg = StepConfig(microbatches=args.mb, seq_shard=bool(args.seq_shard),
                          param_dtype=args.param_dtype)
    lowered = lib.lower_cell(args.arch, args.shape, mesh, step_cfg,
                             quant_override=json.loads(args.quant) or None,
                             cfg_override=json.loads(args.cfg) or None)
    txt = lowered.compile().as_text()
    rows = hlo_cost.breakdown(txt, key=args.key, depth=args.depth, top=25)
    total = sum(v for _, v in rows) or 1.0
    print(f"# {args.arch} x {args.shape} — top {args.key} contributors")
    for name, val in rows:
        print(f"{val:12.3e}  {val / total * 100:5.1f}%  {name}")


if __name__ == "__main__":
    main()
