"""Small-mesh shakeout of the dry-run across all archs (dev helper)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import time

import jax

from repro.launch import dryrun_lib as lib
from repro.train.train_step import StepConfig
from repro.configs.base import ShapeConfig
from repro.configs import ARCH_IDS

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
shapes = {
    "train_4k": ShapeConfig("train_4k", 256, 8, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 1024, 8, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 1024, 8, "decode"),
    "long_500k": ShapeConfig("long_500k", 8192, 1, "decode"),
}
archs = sys.argv[1:] or ARCH_IDS
fails = 0
for arch in archs:
    for sname, so in shapes.items():
        t0 = time.monotonic()
        try:
            rec = lib.run_cell(arch, sname, mesh, "/tmp/dry_small", "test",
                               StepConfig(), shape_override=so)
            if rec["status"] == "skip":
                print(f"{arch:22s} {sname:12s} SKIP", flush=True)
            else:
                print(f"{arch:22s} {sname:12s} ok {rec['compile_s']:.1f}s "
                      f"peak={rec['memory']['peak_per_device']/2**30:.2f}GiB "
                      f"flops={rec['cost'].get('flops', 0):.3g} "
                      f"coll={rec['collectives'].get('total_bytes', 0):.3g}",
                      flush=True)
        except Exception as e:  # noqa
            fails += 1
            import traceback
            traceback.print_exc()
            print(f"{arch:22s} {sname:12s} FAIL {repr(e)[:200]}", flush=True)
print("FAILURES:", fails)
