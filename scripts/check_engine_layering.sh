#!/usr/bin/env bash
# Guard the serve-layer decomposition (DESIGN.md §13): EngineCore
# (serve/core.py) is the ONLY place the serving stack may dispatch to the
# device, and the PageAllocator may only be mutated by its owners. This
# keeps the front door (api.py streaming, engine.py batch adapter) and
# every launcher/benchmark/example host-side-only — cancellation, request
# intake, and event plumbing can never race a device call or corrupt page
# refcounts from outside the core.
#
#   1. jax/jnp usage inside src/repro/serve/ is allowed only in core.py
#      (the step loop + the static ServeEngine live there).
#   2. PageAllocator mutating calls (alloc/adopt/incref/decref/cow/
#      free_slot) are allowed only in serve/scheduler.py (the allocator's
#      host-side owner), serve/core.py (the COW guard), and core/ (the
#      allocator + PrefixIndex themselves). bench_kernel_latency.py is
#      exempt: it microbenchmarks the paged layout directly, below the
#      serve stack.
#   3. jax/jnp usage inside src/repro/spec/ is allowed only in verify.py
#      (the paged span verifier) and draft.py (the draft-model proposer's
#      forwards). Proposer bookkeeping (ngram index, registry, config)
#      stays host-side so proposing never blocks on the device.
#   4. The chaos seam is duck-typed (DESIGN.md §16): serve/core.py must
#      NOT import serve.chaos — fault injection reaches the engine only
#      as an opaque object, so production code carries zero test-harness
#      imports. serve.chaos imports are allowed only in the front doors
#      (serve/__init__.py), launchers, benchmarks, and tests. Note rule 1
#      already keeps qos.py and chaos.py jax-free: SLA policy and fault
#      schedules are host-side decisions, never device work.
set -u
cd "$(dirname "$0")/.."

fail=0

jaxuse=$(grep -rnE '(import[[:space:]]+jax|from[[:space:]]+jax|jax\.|jnp\.)' \
    src/repro/serve --include='*.py' \
    | grep -v 'src/repro/serve/core.py' || true)
if [ -n "$jaxuse" ]; then
    echo "ERROR: device dispatch outside serve/core.py — the streaming" >&2
    echo "front door and batch adapter must stay host-side-only; route" >&2
    echo "device work through EngineCore.step():" >&2
    echo "$jaxuse" >&2
    fail=1
fi

specjax=$(grep -rnE '(import[[:space:]]+jax|from[[:space:]]+jax|jax\.|jnp\.)' \
    src/repro/spec --include='*.py' \
    | grep -vE 'src/repro/spec/(verify|draft)\.py' || true)
if [ -n "$specjax" ]; then
    echo "ERROR: device dispatch in src/repro/spec outside verify.py /" >&2
    echo "draft.py — proposers and the registry must stay host-side so" >&2
    echo "drafting never blocks on the device:" >&2
    echo "$specjax" >&2
    fail=1
fi

mut=$(grep -rnE '\.(alloc|adopt|incref|decref|cow|free_slot)\(' \
    src/repro benchmarks examples --include='*.py' \
    | grep -vE 'src/repro/serve/(scheduler|core)\.py' \
    | grep -v 'src/repro/core/' \
    | grep -v 'benchmarks/bench_kernel_latency.py' || true)
if [ -n "$mut" ]; then
    echo "ERROR: direct PageAllocator mutation outside its owners" >&2
    echo "(serve/scheduler.py, serve/core.py, core/) — page refcounts" >&2
    echo "must only change under the scheduler/core invariants:" >&2
    echo "$mut" >&2
    fail=1
fi

chaosimp=$(grep -rnE '(from[[:space:]]+(repro\.serve\.chaos|\.chaos)[[:space:]]+import|import[[:space:]]+repro\.serve\.chaos|from[[:space:]]+\.[[:space:]]*import[^\n]*chaos)' \
    src/repro benchmarks examples --include='*.py' \
    | grep -vE 'src/repro/(serve/(chaos|__init__)\.py|launch/)' \
    | grep -v 'benchmarks/' || true)
if [ -n "$chaosimp" ]; then
    echo "ERROR: serve.chaos imported outside the front doors — the" >&2
    echo "engine's chaos seam is duck-typed; core code must never" >&2
    echo "import the fault-injection harness:" >&2
    echo "$chaosimp" >&2
    fail=1
fi

[ "$fail" -eq 0 ] || exit 1
echo "engine layering check OK (device dispatch + allocator mutation contained)"
