"""Generate the EXPERIMENTS.md data tables from dry-run/perf artifacts."""
import json
import os
import sys

sys.path.insert(0, "src")

from repro.configs import ARCH_IDS, SHAPES  # noqa: E402
from benchmarks.roofline import analyze, model_flops  # noqa: E402


def dryrun_table(mesh_tag: str, devices: int) -> str:
    rows = analyze(mesh_tag, devices)
    out = [f"| arch | shape | compile | peak/dev | fits 16G | HLO flops/dev | "
           f"HBM bytes/dev | coll bytes/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_IDS:
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            path = f"artifacts/dryrun/{mesh_tag}/{arch}/{shape}.json"
            if not os.path.exists(path):
                continue
            d = json.load(open(path))
            if d["status"] == "skip":
                out.append(f"| {arch} | {shape} | SKIP | — | — | — | — | — |")
                continue
            m = d["memory"]
            out.append(
                f"| {arch} | {shape} | {d['compile_s']:.1f}s "
                f"| {m['peak_per_device']/2**30:.2f}GiB "
                f"| {'yes' if m['fits_16g_hbm'] else '**NO**'} "
                f"| {d['cost']['flops']:.3g} "
                f"| {d['cost']['bytes accessed']:.3g} "
                f"| {d['collectives']['total_bytes']:.3g} |")
    return "\n".join(out)


def roofline_table(mesh_tag: str, devices: int) -> str:
    rows = analyze(mesh_tag, devices)
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant | "
           "useful ratio | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} "
            f"| {r['memory_s']:.2e} | {r['collective_s']:.2e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} |")
    return "\n".join(out)


def perf_table(cell: str) -> str:
    d = f"artifacts/perf/{cell}"
    out = ["| variant | compute_s | memory_s | collective_s | peak/dev | fits |",
           "|---|---|---|---|---|---|"]
    rows = []
    for arch in os.listdir(d):
        for f in sorted(os.listdir(os.path.join(d, arch))):
            rec = json.load(open(os.path.join(d, arch, f)))
            from repro.launch.dryrun_lib import roofline_terms
            t = roofline_terms(rec, 256)
            rows.append((rec.get("variant", f),
                         t["compute_s"], t["memory_s"], t["collective_s"],
                         rec["memory"]["peak_per_device"] / 2 ** 30,
                         rec["memory"]["fits_16g_hbm"]))
    for v, c, m, co, p, fit in sorted(rows):
        out.append(f"| {v} | {c:.3g} | {m:.3g} | {co:.3g} | {p:.2f}GiB "
                   f"| {'yes' if fit else 'no'} |")
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("## single-pod 16x16\n")
        print(dryrun_table("single_16x16", 256))
        print("\n## multi-pod 2x16x16\n")
        print(dryrun_table("multi_2x16x16", 512))
    if which in ("all", "roofline"):
        print("\n## roofline single-pod\n")
        print(roofline_table("single_16x16", 256))
    if which in ("all", "perf"):
        for cell in ("yi_decode", "dbrx_train", "mamba_train"):
            print(f"\n## {cell}\n")
            print(perf_table(cell))
