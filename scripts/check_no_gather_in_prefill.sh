#!/usr/bin/env bash
# Guard the serve chunked-prefill hot path against regressing to the
# gathering formulation. `chunk_prefill_attention` materializes
# `pool[page_row]` — a dense O(capacity) copy of the slot's entire page
# table — every chunk. It is kept ONLY as the parity reference and the
# fallback for codecs without a page-native prefill kernel
# (KeyCodec.paged_prefill's base implementation). The hot path must go
# through paged_prefill_attention (kernels/paged_prefill.py walks the
# page table in place), so:
#
#   * kernels/, models/, serve/, launch/ must not call
#     chunk_prefill_attention directly (they dispatch through
#     paged_prefill_attention, which routes per cfg.prefill_backend and
#     codec capability);
#   * inside core/, chunk_prefill_attention may only be *called* from its
#     own definition, the dispatcher (paged_prefill_attention), or the
#     codec-default fallback (KeyCodec.paged_prefill in codecs.py).
set -u
cd "$(dirname "$0")/.."

fail=0

hot=$(grep -rn 'chunk_prefill_attention(' src/repro/kernels \
      src/repro/models src/repro/serve src/repro/launch \
      --include='*.py' 2>/dev/null || true)
if [ -n "$hot" ]; then
    echo "ERROR: serve prefill hot path calls chunk_prefill_attention —" >&2
    echo "route through paged_prefill_attention instead:" >&2
    echo "$hot" >&2
    fail=1
fi

core=$(awk '
    FNR == 1 { fn = "" }
    /^[ \t]*def [A-Za-z_]+/ { fn = $2; sub(/\(.*/, "", fn) }
    /chunk_prefill_attention\(/ {
        if (fn !~ /^(chunk_prefill_attention|paged_prefill_attention|paged_prefill)$/)
            print FILENAME ":" FNR ": " $0
    }
' src/repro/core/*.py)
if [ -n "$core" ]; then
    echo "ERROR: chunk_prefill_attention called outside its definition," >&2
    echo "the paged_prefill_attention dispatcher, or the codec-default" >&2
    echo "KeyCodec.paged_prefill fallback:" >&2
    echo "$core" >&2
    fail=1
fi

[ "$fail" -eq 0 ] || exit 1
echo "no-gather prefill hot path check OK (page-native dispatch intact)"
