#!/usr/bin/env bash
# Guard the serve decode hot path against regressing to the gathered
# formulation. `gather_view` re-materializes a dense O(capacity) copy of
# every slot's cache — it is kept ONLY as the parity reference and the
# fallback for codecs without a page-native kernel. The hot path must go
# through codec.paged_decode (kernels/paged_decode.py walks the page
# table in place), so:
#
#   * kernels/, models/, serve/, launch/ must not reference gather_view
#     at all (they dispatch through paged_decode_attention);
#   * inside core/, gather_view may only be *called* from its own
#     definition or the explicitly-named gathered fallback
#     (gathered_decode_attention).
set -u
cd "$(dirname "$0")/.."

fail=0

hot=$(grep -rn 'gather_view(' src/repro/kernels src/repro/models \
      src/repro/serve src/repro/launch --include='*.py' 2>/dev/null || true)
if [ -n "$hot" ]; then
    echo "ERROR: serve decode hot path references gather_view — route" >&2
    echo "through paged_decode_attention / codec.paged_decode instead:" >&2
    echo "$hot" >&2
    fail=1
fi

core=$(awk '
    FNR == 1 { fn = "" }
    /^[ \t]*def [A-Za-z_]+/ { fn = $2; sub(/\(.*/, "", fn) }
    /gather_view\(/ {
        if (fn !~ /^(gather_view|gathered_decode_attention)$/)
            print FILENAME ":" FNR ": " $0
    }
' src/repro/core/*.py)
if [ -n "$core" ]; then
    echo "ERROR: gather_view called outside its definition or the" >&2
    echo "designated gathered_decode_attention fallback:" >&2
    echo "$core" >&2
    fail=1
fi

[ "$fail" -eq 0 ] || exit 1
echo "no-gather decode hot path check OK (page-native dispatch intact)"
