"""KV-cache quantizer math: PolarQuant + the paper's baselines (Int-N, KIVI,
ZipCache).

This module holds the *numerics* — affine helpers, the per-method
encode/decode functions and their quantized-key containers, plus the
serializable :class:`QuantConfig` description. Method *dispatch* lives in
:mod:`repro.core.codecs`: ``QuantConfig.codec`` resolves the ``method``
string once to a registered :class:`~repro.core.codecs.KeyCodec`, and the
cache layers call codec methods instead of branching on method names.

All quantizers operate on tensors shaped ``(..., T, d)`` — arbitrary leading
batch/head dims, a token axis ``T`` and a head dim ``d``. Group-wise methods
require ``T % group_size == 0`` (the cache layer owns the fp residual buffer
for remainder tokens, per the paper's "residual length").

Conventions (see DESIGN.md §8):

* PolarQuant uses a *mid-rise* uniform quantizer: ``s = (max-min)/2^b``,
  ``code = floor((x-z)/s)``, ``x~ = (code + 1/2) * s + z`` — exactly the
  appendix PyTorch code. The paper's printed zero-point formula is a typo
  (it repeats the scale); we use ``z = min`` like every other quantizer in
  the paper.
* Int-N / KIVI / ZipCache / value quantization use the *mid-tread* form:
  ``s = (max-min)/(2^b - 1)``, ``code = round((x-z)/s)``, ``x~ = code*s + z``.
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass, static_field
from repro.core import polar

Array = jax.Array
_EPS = 1e-8

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@pytree_dataclass
class QuantConfig:
    """Cache-quantization policy. A pure-static pytree (safe to close over)."""

    method: str = static_field(default="polar")  # none|int|kivi|zipcache|polar
    rho_bits: int = static_field(default=4)      # polar radius bits (r)
    theta_bits: int = static_field(default=4)    # polar angle bits (t)
    key_bits: int = static_field(default=4)      # int/kivi/zipcache key bits
    value_bits: int = static_field(default=0)    # 0 => values stay fp
    group_size: int = static_field(default=128)  # tokens per quantization group
    pairing: str = static_field(default="half")  # RoPE pairing convention
    scale_dtype: str = static_field(default="float32")
    theta_stats: str = static_field(default="group")  # group|fixed (beyond-paper)
    residual_dtype: str = static_field(default="bfloat16")
    lut_impl: str = static_field(default="select")    # select|gather (§Perf A/B)

    @property
    def codec(self):
        """The registered :class:`~repro.core.codecs.KeyCodec` for
        ``method`` — the one resolution point from string to behavior."""
        from repro.core.codecs import get_codec  # codecs imports this module
        return get_codec(self.method)

    @property
    def quantizes_keys(self) -> bool:
        return self.codec.quantizes

    def key_bits_per_element(self, head_dim: int) -> float:
        """Logical key bits/element incl. quantization-parameter overhead,
        at the cache's actual ``head_dim`` (token-wise stats amortize over
        it; the codec owns the accounting)."""
        return self.codec.bits_per_element(self, head_dim)

    @property
    def lut_states(self) -> int:
        return 1 << (self.rho_bits + self.theta_bits)


# ---------------------------------------------------------------------------
# Generic affine helpers
# ---------------------------------------------------------------------------


def affine_encode(
    x: Array,
    bits: int,
    axis: int | tuple[int, ...],
    mode: Literal["midrise", "midtread"],
    scale_dtype: jnp.dtype = jnp.float32,
) -> tuple[Array, Array, Array]:
    """Quantize ``x`` along ``axis`` (stats reduced over it, keepdims).

    Returns (codes uint8, scale, zero).
    """
    x32 = x.astype(jnp.float32)
    mn = jnp.min(x32, axis=axis, keepdims=True)
    mx = jnp.max(x32, axis=axis, keepdims=True)
    levels = (1 << bits) if mode == "midrise" else (1 << bits) - 1
    scale = jnp.maximum((mx - mn) / levels, _EPS)
    if mode == "midrise":
        q = jnp.floor((x32 - mn) / scale)
    else:
        q = jnp.round((x32 - mn) / scale)
    codes = jnp.clip(q, 0, (1 << bits) - 1).astype(jnp.uint8)
    return codes, scale.astype(scale_dtype), mn.astype(scale_dtype)


def affine_decode(
    codes: Array, scale: Array, zero: Array, mode: Literal["midrise", "midtread"]
) -> Array:
    c = codes.astype(jnp.float32)
    if mode == "midrise":
        c = c + 0.5
    return c * scale.astype(jnp.float32) + zero.astype(jnp.float32)


def _group(x: Array, g: int) -> Array:
    """(..., T, d) -> (..., G, g, d). Requires T % g == 0."""
    *lead, t, d = x.shape
    if t % g:
        raise ValueError(f"token count {t} not divisible by group size {g}")
    return x.reshape(*lead, t // g, g, d)


def _ungroup(x: Array) -> Array:
    *lead, gcount, g, d = x.shape
    return x.reshape(*lead, gcount * g, d)


# ---------------------------------------------------------------------------
# PolarQuant keys
# ---------------------------------------------------------------------------


@pytree_dataclass
class PolarKeys:
    """Quantized key groups in polar representation.

    ``codes`` packs the pair (rho_code << theta_bits) | theta_code into one
    uint8 per channel pair — requires rho_bits + theta_bits <= 8 (all paper
    configs satisfy this), giving (r+t)/2 physical bits per key element.
    """

    codes: Array        # (..., G, g, P) uint8
    rho_scale: Array    # (..., G, 1, P)
    rho_zero: Array     # (..., G, 1, P)
    theta_scale: Array  # (..., G, 1, P)
    theta_zero: Array   # (..., G, 1, P)
    rho_bits: int = static_field(default=4)
    theta_bits: int = static_field(default=4)
    pairing: str = static_field(default="half")

    @property
    def num_tokens(self) -> int:
        return self.codes.shape[-3] * self.codes.shape[-2]

    @property
    def head_dim(self) -> int:
        return 2 * self.codes.shape[-1]

    def rho_codes(self) -> Array:
        return (self.codes >> self.theta_bits).astype(jnp.uint8)

    def theta_codes(self) -> Array:
        return (self.codes & ((1 << self.theta_bits) - 1)).astype(jnp.uint8)


def encode_polar_keys(k: Array, cfg: QuantConfig) -> PolarKeys:
    """Quantize post-RoPE keys ``(..., T, d)`` into :class:`PolarKeys`."""
    if cfg.rho_bits + cfg.theta_bits > 8:
        raise ValueError("rho_bits + theta_bits must be <= 8 for packed codes")
    scale_dtype = jnp.dtype(cfg.scale_dtype)
    rho, theta = polar.to_polar(k, cfg.pairing)  # (..., T, P)
    rho_g = _group(rho, cfg.group_size)          # (..., G, g, P)
    theta_g = _group(theta, cfg.group_size)
    rc, rs, rz = affine_encode(rho_g, cfg.rho_bits, axis=-2, mode="midrise",
                               scale_dtype=scale_dtype)
    if cfg.theta_stats == "fixed":
        # Beyond-paper variant: theta has known support (0, 2pi] — use a
        # fixed grid, saving the per-group theta stats (and their overhead).
        ts = jnp.full_like(rs, 2.0 * jnp.pi / (1 << cfg.theta_bits))
        tz = jnp.zeros_like(rz)
        q = jnp.floor(theta_g / (2.0 * jnp.pi / (1 << cfg.theta_bits)))
        tc = jnp.clip(q, 0, (1 << cfg.theta_bits) - 1).astype(jnp.uint8)
    else:
        tc, ts, tz = affine_encode(theta_g, cfg.theta_bits, axis=-2,
                                   mode="midrise", scale_dtype=scale_dtype)
    codes = ((rc << cfg.theta_bits) | tc).astype(jnp.uint8)
    return PolarKeys(codes=codes, rho_scale=rs, rho_zero=rz, theta_scale=ts,
                     theta_zero=tz, rho_bits=cfg.rho_bits,
                     theta_bits=cfg.theta_bits, pairing=cfg.pairing)


def decode_polar_keys(pk: PolarKeys, dtype: jnp.dtype = jnp.float32) -> Array:
    """Dequantize back to Cartesian keys ``(..., T, d)``."""
    rho = affine_decode(pk.rho_codes(), pk.rho_scale, pk.rho_zero, "midrise")
    theta = affine_decode(pk.theta_codes(), pk.theta_scale, pk.theta_zero, "midrise")
    k = polar.from_polar(rho, theta, pk.pairing)
    return _ungroup(k).astype(dtype)


# ---------------------------------------------------------------------------
# KIVI keys (channel-wise over token groups)
# ---------------------------------------------------------------------------


@pytree_dataclass
class ChannelKeys:
    codes: Array   # (..., G, g, d) uint8
    scale: Array   # (..., G, 1, d)
    zero: Array    # (..., G, 1, d)
    bits: int = static_field(default=4)


def encode_kivi_keys(k: Array, cfg: QuantConfig) -> ChannelKeys:
    kg = _group(k, cfg.group_size)
    c, s, z = affine_encode(kg, cfg.key_bits, axis=-2, mode="midtread",
                            scale_dtype=jnp.dtype(cfg.scale_dtype))
    return ChannelKeys(codes=c, scale=s, zero=z, bits=cfg.key_bits)


def decode_channel_keys(ck: ChannelKeys, dtype: jnp.dtype = jnp.float32) -> Array:
    return _ungroup(affine_decode(ck.codes, ck.scale, ck.zero, "midtread")).astype(dtype)


# ---------------------------------------------------------------------------
# Int-N keys (token-wise)
# ---------------------------------------------------------------------------


@pytree_dataclass
class TokenKeys:
    codes: Array   # (..., T, d) uint8
    scale: Array   # (..., T, 1)
    zero: Array    # (..., T, 1)
    bits: int = static_field(default=4)


def encode_int_keys(k: Array, cfg: QuantConfig) -> TokenKeys:
    c, s, z = affine_encode(k, cfg.key_bits, axis=-1, mode="midtread",
                            scale_dtype=jnp.dtype(cfg.scale_dtype))
    return TokenKeys(codes=c, scale=s, zero=z, bits=cfg.key_bits)


def decode_token_keys(tk: TokenKeys, dtype: jnp.dtype = jnp.float32) -> Array:
    return affine_decode(tk.codes, tk.scale, tk.zero, "midtread").astype(dtype)


# ---------------------------------------------------------------------------
# ZipCache keys (channel-separable token-wise)
# ---------------------------------------------------------------------------


@pytree_dataclass
class ZipKeys:
    codes: Array         # (..., G, g, d) uint8
    token_scale: Array   # (..., G, g, 1)
    token_zero: Array    # (..., G, g, 1)
    channel_norm: Array  # (..., G, 1, d)   sqrt(max |K_channel|) per group
    bits: int = static_field(default=4)


def encode_zipcache_keys(k: Array, cfg: QuantConfig) -> ZipKeys:
    kg = _group(k, cfg.group_size).astype(jnp.float32)
    norm = jnp.sqrt(jnp.maximum(jnp.max(jnp.abs(kg), axis=-2, keepdims=True), _EPS))
    normalized = kg / norm
    c, s, z = affine_encode(normalized, cfg.key_bits, axis=-1, mode="midtread",
                            scale_dtype=jnp.dtype(cfg.scale_dtype))
    return ZipKeys(codes=c, token_scale=s, token_zero=z,
                   channel_norm=norm.astype(jnp.dtype(cfg.scale_dtype)),
                   bits=cfg.key_bits)


def decode_zipcache_keys(zk: ZipKeys, dtype: jnp.dtype = jnp.float32) -> Array:
    normalized = affine_decode(zk.codes, zk.token_scale, zk.token_zero, "midtread")
    return _ungroup(normalized * zk.channel_norm.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Values (token-wise, KIVI §2) — shared by all methods
# ---------------------------------------------------------------------------


@pytree_dataclass
class QuantizedValues:
    codes: Array   # (..., T, d) uint8
    scale: Array   # (..., T, 1)
    zero: Array    # (..., T, 1)
    bits: int = static_field(default=4)


def encode_values(v: Array, bits: int, scale_dtype: str = "float32") -> QuantizedValues:
    c, s, z = affine_encode(v, bits, axis=-1, mode="midtread",
                            scale_dtype=jnp.dtype(scale_dtype))
    return QuantizedValues(codes=c, scale=s, zero=z, bits=bits)


def decode_values(qv: QuantizedValues, dtype: jnp.dtype = jnp.float32) -> Array:
    return affine_decode(qv.codes, qv.scale, qv.zero, "midtread").astype(dtype)


# ---------------------------------------------------------------------------
# Generic entry points (dispatch via the codec registry / container type)
# ---------------------------------------------------------------------------

KEY_DECODERS = {
    PolarKeys: decode_polar_keys,
    ChannelKeys: decode_channel_keys,
    TokenKeys: decode_token_keys,
    ZipKeys: decode_zipcache_keys,
}


def encode_keys(k: Array, cfg: QuantConfig):
    """Quantize keys via the registered codec; returns the method-specific
    container (or ``k`` unchanged for the fp passthrough)."""
    codec = cfg.codec
    return codec.container(cfg, *codec.encode(cfg, k))


def decode_keys(qk, dtype: jnp.dtype = jnp.float32) -> Array:
    if isinstance(qk, jax.Array):
        return qk.astype(dtype)
    decoder = KEY_DECODERS.get(type(qk))
    if decoder is not None:
        return decoder(qk, dtype)
    # generic container of a third-party codec (see codecs.CodecKeys)
    return qk.decode(dtype)
