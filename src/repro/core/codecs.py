"""KeyCodec registry + per-layer CachePolicy: the single dispatch point for
key-cache quantization methods.

A :class:`KeyCodec` owns everything method-specific about cached keys:

=====================  ======================================================
Responsibility          Codec method
=====================  ======================================================
buffer allocation       :meth:`KeyCodec.init_buffers` (codes + scale dict)
encode                  :meth:`KeyCodec.encode`  -> ``(codes, scales)``
decode                  :meth:`KeyCodec.decode`  -> fp keys ``(..., T, d)``
score path              :meth:`KeyCodec.scores` (LUT for polar, dequant
                        matmul otherwise)
bits accounting         :meth:`KeyCodec.bits_per_element` (payload + stats
                        overhead at the *actual* head_dim)
fused decode kernel     :meth:`KeyCodec.fused_decode` where
                        ``supports_fused_decode`` is True
paged fused decode      :meth:`KeyCodec.paged_decode` — page-native kernel
                        where ``supports_paged_decode`` is True, gathered
                        fallback otherwise
paged fused prefill     :meth:`KeyCodec.paged_prefill` — page-native chunk
                        prefill kernel where ``supports_paged_prefill`` is
                        True, ``chunk_prefill_attention`` jnp fallback
                        otherwise
=====================  ======================================================

The cache layers (``kv_cache.py`` dense/ring, ``paged_cache.py`` pools) own
only the method-agnostic machinery: token/group placement, the fp residual
buffer for grouped codecs, value quantization, masks and softmax. They
branch on two structural codec *capabilities* (``grouped``, ``quantizes``) —
never on method names; ``scripts/check_codec_dispatch.sh`` enforces that
this module stays the only string dispatch point.

Buffer-layout contract (``lead`` is the cache's leading dims, e.g. ``(B, H)``
for dense caches or ``(PP, H)`` for page pools):

* grouped codecs (``grouped = True``): tokens are quantized ``group_size``
  at a time; ``codes`` is ``(*lead, G, g, ·)`` and every scale array is
  ``(*lead, G, 1|g, ·)``. The cache owns an fp residual for the trailing
  partial group.
* token-wise codecs (``grouped = False``): ``codes`` is ``(*lead, T, ·)``
  and every scale array is ``(*lead, T, ·)``; each token encodes
  independently (appends never re-encode old tokens).
* the fp passthrough ("none") is a token-wise codec whose "codes" buffer
  simply stores keys in the model dtype with an empty scale dict.

Third-party codecs subclass :class:`KeyCodec` and call
:func:`register_codec`; ``QuantConfig(method=<name>)`` then works through
``make_cache`` / paged serving / benchmarks with no further changes.

:class:`CachePolicy` maps layer index -> :class:`QuantConfig` so a model
can run e.g. its most sensitive layers at int8 and the rest at polar 4+4
(KVTuner-style mixed precision). Contiguous layers sharing a config form a
*segment*; model code scans each segment's layers with one stacked cache.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import quantizers as qz
from repro.core.quantizers import QuantConfig
from repro.utils import pytree_dataclass, static_field

Array = jax.Array


@pytree_dataclass
class CodecKeys:
    """Generic quantized-keys container: raw codec buffers + their config.

    The default :meth:`KeyCodec.container` wraps ``(codes, scales)`` in
    this, so third-party codecs work through the generic
    ``quantizers.encode_keys`` / ``decode_keys`` entry points (and every
    benchmark built on them) without defining a bespoke container pytree.
    Built-in codecs keep their legacy containers (PolarKeys & co.).
    """

    codes: Array
    scales: dict
    cfg: QuantConfig = static_field(default=None)

    def decode(self, dtype=jnp.float32) -> Array:
        return self.cfg.codec.decode(self.cfg, self.codes, self.scales,
                                     dtype)


# ---------------------------------------------------------------------------
# Codec protocol
# ---------------------------------------------------------------------------


class KeyCodec:
    """Base class for key-cache codecs. Subclasses are stateless singletons
    (all per-run parameters live in :class:`QuantConfig`)."""

    name: str = ""
    grouped: bool = False            # codes carry (G, g) axes + fp residual
    quantizes: bool = True           # False => fp passthrough
    supports_fused_decode: bool = False
    supports_paged_decode: bool = False   # page-native fused decode kernel
    supports_paged_prefill: bool = False  # page-native chunk-prefill kernel

    # -- accounting ---------------------------------------------------------

    def bits_per_element(self, cfg: QuantConfig, head_dim: int) -> float:
        """Logical key bits/element including quantization-stat overhead."""
        raise NotImplementedError

    # -- allocation ---------------------------------------------------------

    def init_buffers(self, cfg: QuantConfig, lead: tuple[int, ...],
                     tokens: int, head_dim: int, dtype
                     ) -> tuple[Array, dict[str, Array]]:
        """Zero-filled ``(codes, scales)`` buffers for ``tokens`` tokens.

        ``dtype`` is the model compute dtype (quantized codecs ignore it
        and use uint8 codes + ``cfg.scale_dtype`` stats)."""
        raise NotImplementedError

    # -- transform ----------------------------------------------------------

    def encode(self, cfg: QuantConfig, k: Array
               ) -> tuple[Array, dict[str, Array]]:
        """Quantize post-RoPE keys ``(*lead, T, d)`` -> ``(codes, scales)``
        following the buffer-layout contract (grouped codecs require
        ``T % cfg.group_size == 0``)."""
        raise NotImplementedError

    def decode(self, cfg: QuantConfig, codes: Array,
               scales: dict[str, Array], dtype=jnp.float32) -> Array:
        """Dequantize buffers back to Cartesian keys ``(*lead, T, d)``."""
        raise NotImplementedError

    def container(self, cfg: QuantConfig, codes: Array,
                  scales: dict[str, Array]):
        """Rebuild the quantized-keys pytree from raw cache buffers.

        Built-in codecs return their method-specific
        ``repro.core.quantizers`` container; the default wraps the raw
        buffers in :class:`CodecKeys`, which is all ``decode_keys`` needs."""
        return CodecKeys(codes=codes, scales=scales, cfg=cfg)

    # -- score path ---------------------------------------------------------

    def scores(self, cfg: QuantConfig, q: Array, codes: Array,
               scales: dict[str, Array], *, use_lut: bool = True) -> Array:
        """``q . K~`` for every cached token.

        q: ``(*lead, Qh, d)``; returns ``(*lead, Qh, T)`` fp32. The default
        is dequantize-then-matmul; codecs with a structured decode (polar's
        angle LUT) override this."""
        k_tilde = self.decode(cfg, codes, scales)
        return jnp.einsum("...qd,...td->...qt", q.astype(jnp.float32),
                          k_tilde)

    # -- fused decode kernel (optional capability) --------------------------

    def fused_decode(self, cache, q: Array, *, scale: Optional[float],
                     backend: str) -> Array:
        raise NotImplementedError(
            f"codec {self.name!r} has no fused decode kernel")

    # -- paged fused decode (optional capability) ---------------------------

    def paged_decode(self, cache, q: Array, page_table: Array, *,
                     scale: Optional[float], backend: str) -> Array:
        """Decode attention of q (S, Hq, d) straight off a paged cache.

        Codecs with a page-table-walking kernel (``supports_paged_decode``)
        override this to read quantized pages in place. The default is the
        gathered fallback: materialize the dense per-slot view and reuse
        the dense decode path (the pre-page-native formulation, kept as
        the reference)."""
        from repro.core import paged_cache as pgc  # cache layer; no cycle
        return pgc.gathered_decode_attention(cache, q, page_table,
                                             scale=scale, backend=backend)

    # -- paged fused prefill (optional capability) ---------------------------

    def paged_prefill(self, cache, q: Array, k_chunk: Array, v_chunk: Array,
                      page_row: Array, start: Array, chunk_len: Array, *,
                      scale: Optional[float], backend: str) -> Array:
        """One prefill chunk's attention straight off a paged cache.

        Codecs with a page-walking prefill kernel
        (``supports_paged_prefill``) override this to score the quantized
        prefix pages in place. The default is the jnp fallback:
        ``chunk_prefill_attention`` gathers the page pool and runs the
        codec score path densely (the pre-page-native formulation, kept as
        the reference)."""
        from repro.core import paged_cache as pgc  # cache layer; no cycle
        return pgc.chunk_prefill_attention(cache, q, k_chunk, v_chunk,
                                           page_row, start, chunk_len,
                                           scale=scale)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_CODECS: dict[str, KeyCodec] = {}


def register_codec(codec: KeyCodec, *, overwrite: bool = False) -> KeyCodec:
    """Register ``codec`` under ``codec.name``. Returns the codec so the
    call composes as a decorator-style one-liner."""
    if not codec.name:
        raise ValueError("codec must set a non-empty .name")
    if codec.name in _CODECS and not overwrite:
        raise ValueError(f"codec {codec.name!r} already registered "
                         "(pass overwrite=True to replace)")
    _CODECS[codec.name] = codec
    return codec


def get_codec(name: str) -> KeyCodec:
    try:
        return _CODECS[name]
    except KeyError:
        raise KeyError(f"unknown key codec {name!r}; registered: "
                       f"{sorted(_CODECS)}") from None


def registered_codecs() -> dict[str, KeyCodec]:
    """Snapshot of the registry (name -> codec)."""
    return dict(_CODECS)


# ---------------------------------------------------------------------------
# Built-in codecs
# ---------------------------------------------------------------------------


class NoneCodec(KeyCodec):
    """fp passthrough: keys stored unquantized in the model dtype."""

    name = "none"
    quantizes = False

    def bits_per_element(self, cfg, head_dim):
        return 16.0

    def init_buffers(self, cfg, lead, tokens, head_dim, dtype):
        return jnp.zeros((*lead, tokens, head_dim), dtype), {}

    def encode(self, cfg, k):
        return k, {}

    def decode(self, cfg, codes, scales, dtype=jnp.float32):
        return codes.astype(dtype)

    def container(self, cfg, codes, scales):
        return codes


class IntCodec(KeyCodec):
    """Int-N token-wise affine quantization (per-token z, s over d)."""

    name = "int"

    def bits_per_element(self, cfg, head_dim):
        # per-token fp16 (z, s) amortized over the actual head_dim
        return float(cfg.key_bits) + 32.0 / head_dim

    def init_buffers(self, cfg, lead, tokens, head_dim, dtype):
        sdt = jnp.dtype(cfg.scale_dtype)
        return (jnp.zeros((*lead, tokens, head_dim), jnp.uint8),
                {"scale": jnp.zeros((*lead, tokens, 1), sdt),
                 "zero": jnp.zeros((*lead, tokens, 1), sdt)})

    def encode(self, cfg, k):
        tk = qz.encode_int_keys(k, cfg)
        return tk.codes, {"scale": tk.scale, "zero": tk.zero}

    def decode(self, cfg, codes, scales, dtype=jnp.float32):
        return qz.decode_token_keys(self.container(cfg, codes, scales), dtype)

    def container(self, cfg, codes, scales):
        return qz.TokenKeys(codes=codes, bits=cfg.key_bits, **scales)


class _GroupedCodec(KeyCodec):
    grouped = True

    def _gcount(self, cfg, tokens: int) -> int:
        if tokens % cfg.group_size:
            raise ValueError(f"token capacity {tokens} not a multiple of "
                             f"group size {cfg.group_size}")
        return tokens // cfg.group_size


class KiviCodec(_GroupedCodec):
    """KIVI channel-wise quantization over token groups."""

    name = "kivi"

    def bits_per_element(self, cfg, head_dim):
        # per-channel fp16 (z, s) per group -> 32 bits / g tokens
        return float(cfg.key_bits) + 32.0 / cfg.group_size

    def init_buffers(self, cfg, lead, tokens, head_dim, dtype):
        gc, g, d = self._gcount(cfg, tokens), cfg.group_size, head_dim
        sdt = jnp.dtype(cfg.scale_dtype)
        stat = lambda: jnp.zeros((*lead, gc, 1, d), sdt)
        return (jnp.zeros((*lead, gc, g, d), jnp.uint8),
                {"scale": stat(), "zero": stat()})

    def encode(self, cfg, k):
        ck = qz.encode_kivi_keys(k, cfg)
        return ck.codes, {"scale": ck.scale, "zero": ck.zero}

    def decode(self, cfg, codes, scales, dtype=jnp.float32):
        return qz.decode_channel_keys(self.container(cfg, codes, scales),
                                      dtype)

    def container(self, cfg, codes, scales):
        return qz.ChannelKeys(codes=codes, bits=cfg.key_bits, **scales)


class ZipCacheCodec(_GroupedCodec):
    """ZipCache channel-separable token-wise quantization."""

    name = "zipcache"

    def bits_per_element(self, cfg, head_dim):
        # per-token fp16 (z, s) over d channels + fp16 channel_norm per group
        return (float(cfg.key_bits) + 32.0 / head_dim
                + 16.0 / cfg.group_size)

    def init_buffers(self, cfg, lead, tokens, head_dim, dtype):
        gc, g, d = self._gcount(cfg, tokens), cfg.group_size, head_dim
        sdt = jnp.dtype(cfg.scale_dtype)
        return (jnp.zeros((*lead, gc, g, d), jnp.uint8),
                {"token_scale": jnp.zeros((*lead, gc, g, 1), sdt),
                 "token_zero": jnp.zeros((*lead, gc, g, 1), sdt),
                 "channel_norm": jnp.zeros((*lead, gc, 1, d), sdt)})

    def encode(self, cfg, k):
        zk = qz.encode_zipcache_keys(k, cfg)
        return zk.codes, {"token_scale": zk.token_scale,
                          "token_zero": zk.token_zero,
                          "channel_norm": zk.channel_norm}

    def decode(self, cfg, codes, scales, dtype=jnp.float32):
        return qz.decode_zipcache_keys(self.container(cfg, codes, scales),
                                       dtype)

    def container(self, cfg, codes, scales):
        return qz.ZipKeys(codes=codes, bits=cfg.key_bits, **scales)


class PolarCodec(_GroupedCodec):
    """PolarQuant radius/angle quantization with the LUT score path."""

    name = "polar"
    supports_fused_decode = True
    supports_paged_decode = True
    supports_paged_prefill = True

    def bits_per_element(self, cfg, head_dim):
        payload = (cfg.rho_bits + cfg.theta_bits) / 2.0
        # rho (z, s) [+ theta (z, s) unless the fixed grid is used]: fp16
        # stats per channel pair per group over 2*g elements.
        stats = 2 if cfg.theta_stats == "fixed" else 4
        return payload + stats * 16.0 / (2.0 * cfg.group_size)

    def init_buffers(self, cfg, lead, tokens, head_dim, dtype):
        gc, g, p = self._gcount(cfg, tokens), cfg.group_size, head_dim // 2
        sdt = jnp.dtype(cfg.scale_dtype)
        stat = lambda: jnp.zeros((*lead, gc, 1, p), sdt)
        return (jnp.zeros((*lead, gc, g, p), jnp.uint8),
                {"rho_scale": stat(), "rho_zero": stat(),
                 "theta_scale": stat(), "theta_zero": stat()})

    def encode(self, cfg, k):
        pk = qz.encode_polar_keys(k, cfg)
        return pk.codes, {"rho_scale": pk.rho_scale,
                          "rho_zero": pk.rho_zero,
                          "theta_scale": pk.theta_scale,
                          "theta_zero": pk.theta_zero}

    def decode(self, cfg, codes, scales, dtype=jnp.float32):
        return qz.decode_polar_keys(self.container(cfg, codes, scales), dtype)

    def container(self, cfg, codes, scales):
        return qz.PolarKeys(codes=codes, rho_bits=cfg.rho_bits,
                            theta_bits=cfg.theta_bits, pairing=cfg.pairing,
                            **scales)

    def scores(self, cfg, q, codes, scales, *, use_lut=True):
        if not use_lut:
            return super().scores(cfg, q, codes, scales)
        from repro.core import lut as lut_mod  # lut imports quantizers only
        pk = self.container(cfg, codes, scales)
        # (B, H, G, g, P) -> (B, H, 1, G, g, P): broadcast over the query
        # heads axis of q (B, H, Qh, d)
        pk_exp = jax.tree_util.tree_map(lambda a: a[:, :, None], pk)
        return lut_mod.lut_qk_scores(q, pk_exp, impl=cfg.lut_impl)

    def fused_decode(self, cache, q, *, scale, backend):
        # function-local import: core is imported by kernels.ref at package
        # init; importing ops at module scope would cycle.
        from repro.kernels import ops
        cfg = cache.cfg
        sc = cache.key_scales
        quant_v = cfg.value_bits > 0
        return ops.polar_decode_attention_full(
            q, cache.key_codes, sc["rho_scale"], sc["rho_zero"],
            sc["theta_scale"], sc["theta_zero"], cache.key_residual,
            cache.value_codes if quant_v else cache.value_fp,
            cache.value_scale if quant_v else None,
            cache.value_zero if quant_v else None,
            cache.length, r_bits=cfg.rho_bits, t_bits=cfg.theta_bits,
            softmax_scale=scale, backend=backend)

    def paged_decode(self, cache, q, page_table, *, scale, backend):
        # page-native hot path: the kernel walks the page table and reads
        # codes/stats/values in place — no gathered dense copy
        from repro.kernels import ops
        cfg = cache.cfg
        sc = cache.key_scales
        quant_v = cfg.value_bits > 0
        return ops.polar_paged_decode_attention_full(
            q, cache.key_codes, sc["rho_scale"], sc["rho_zero"],
            sc["theta_scale"], sc["theta_zero"], cache.key_residual,
            cache.value_codes if quant_v else cache.value_fp,
            cache.value_scale if quant_v else None,
            cache.value_zero if quant_v else None,
            page_table, cache.lengths, r_bits=cfg.rho_bits,
            t_bits=cfg.theta_bits, softmax_scale=scale, backend=backend)

    def paged_prefill(self, cache, q, k_chunk, v_chunk, page_row, start,
                      chunk_len, *, scale, backend):
        # page-native chunk prefill: LUT scores + online softmax walk the
        # prefix pages in place; the chunk's fp causal tile shares the
        # same flash carry — no full-pool gather, no dense score spill
        from repro.kernels import ops
        cfg = cache.cfg
        sc = cache.key_scales
        quant_v = cfg.value_bits > 0
        return ops.polar_paged_prefill_attention(
            q, k_chunk, v_chunk, cache.key_codes, sc["rho_scale"],
            sc["rho_zero"], sc["theta_scale"], sc["theta_zero"],
            cache.value_codes if quant_v else cache.value_fp,
            cache.value_scale if quant_v else None,
            cache.value_zero if quant_v else None,
            page_row, start, chunk_len, r_bits=cfg.rho_bits,
            t_bits=cfg.theta_bits, softmax_scale=scale, backend=backend)


register_codec(NoneCodec())
register_codec(IntCodec())
register_codec(KiviCodec())
register_codec(ZipCacheCodec())
register_codec(PolarCodec())


# ---------------------------------------------------------------------------
# Per-layer cache policy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CachePolicy:
    """Layer index -> :class:`QuantConfig` map (hashable, pure-static).

    ``overrides`` lists ``(layer, config)`` pairs; unlisted layers use
    ``default``. Contiguous layers sharing a config form a *segment* —
    model code allocates one stacked cache per segment and scans its
    layers together, so a uniform policy compiles exactly like the
    pre-policy single-scan path.
    """

    default: QuantConfig = QuantConfig()
    overrides: tuple[tuple[int, QuantConfig], ...] = ()

    # -- constructors -------------------------------------------------------

    @classmethod
    def uniform(cls, cfg: QuantConfig) -> "CachePolicy":
        return cls(default=cfg)

    @classmethod
    def per_layer(cls, overrides: dict[int, QuantConfig],
                  default: QuantConfig) -> "CachePolicy":
        return cls(default=default,
                   overrides=tuple(sorted(overrides.items())))

    @classmethod
    def first_k(cls, k: int, first: QuantConfig,
                rest: QuantConfig) -> "CachePolicy":
        """KVTuner-style split: layers ``[0, k)`` use ``first`` (e.g. int8
        for the sensitive early layers), the rest use ``rest``."""
        return cls(default=rest,
                   overrides=tuple((i, first) for i in range(k)))

    # -- queries ------------------------------------------------------------

    def layer_config(self, layer: int) -> QuantConfig:
        for i, q in self.overrides:
            if i == layer:
                return q
        return self.default

    @property
    def is_uniform(self) -> bool:
        return all(q == self.default for _, q in self.overrides)

    def segments(self, num_layers: int
                 ) -> tuple[tuple[int, int, QuantConfig], ...]:
        """Contiguous ``(lo, hi, config)`` runs covering ``[0, num_layers)``."""
        segs: list[tuple[int, int, QuantConfig]] = []
        for i in range(num_layers):
            q = self.layer_config(i)
            if segs and segs[-1][2] == q:
                segs[-1] = (segs[-1][0], i + 1, q)
            else:
                segs.append((i, i + 1, q))
        return tuple(segs)

    def avg_key_bits(self, num_layers: int, head_dim: int) -> float:
        """Mean logical key bits/element across the layer stack."""
        return sum(
            self.layer_config(i).key_bits_per_element(head_dim)
            for i in range(num_layers)) / max(num_layers, 1)

    def max_group_size(self) -> int:
        """Largest group size across layers — a bucketing multiple for the
        dense (non-paged) serving path, which allows mixed group sizes."""
        return max({self.default.group_size}
                   | {q.group_size for _, q in self.overrides})

    def page_group_size(self) -> int:
        """The single group size shared by every layer — required by the
        paged cache, whose page size equals the quantization group size."""
        sizes = {self.default.group_size} | {
            q.group_size for _, q in self.overrides}
        if len(sizes) != 1:
            raise ValueError(
                "paged serving requires one group size across all layers "
                f"(page == group); policy has {sorted(sizes)}")
        return sizes.pop()

    def map(self, fn: Callable[[QuantConfig], QuantConfig]) -> "CachePolicy":
        """Apply ``fn`` to every per-layer config (smoke-size reductions)."""
        return CachePolicy(
            default=fn(self.default),
            overrides=tuple((i, fn(q)) for i, q in self.overrides))
