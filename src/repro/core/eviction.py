"""SnapKV-style token eviction (paper §5.2 / Table 8 compatibility).

Selects the prompt tokens that receive the most attention from an
observation window at the end of the prompt, keeps those plus the window,
and drops the rest — composable with any cache quantization policy (the
kept keys are quantized as usual).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def snapkv_scores(q_obs: Array, k: Array, scale: float | None = None,
                  kernel: int = 5) -> Array:
    """Accumulated attention from the observation queries to every key.

    q_obs: (B, H, W, d) last-window queries; k: (B, Hkv, T, d).
    Returns (B, Hkv, T) pooled importance scores (max-pooled over a small
    window along T, as SnapKV does, to keep local context clusters)."""
    b, h, w, d = q_obs.shape
    hkv = k.shape[1]
    scale = d ** -0.5 if scale is None else scale
    q5 = (q_obs * scale).reshape(b, hkv, h // hkv, w, d).astype(jnp.float32)
    s = jnp.einsum("bhqwd,bhtd->bhqwt", q5, k.astype(jnp.float32))
    # causal-ish: observation window attends to all prompt tokens
    p = jax.nn.softmax(s, axis=-1)
    imp = p.sum(axis=(2, 3))                       # (B, Hkv, T)
    # local max-pool along T
    pooled = imp
    for off in range(1, kernel // 2 + 1):
        pooled = jnp.maximum(pooled, jnp.roll(imp, off, axis=-1))
        pooled = jnp.maximum(pooled, jnp.roll(imp, -off, axis=-1))
    return pooled


def snapkv_select(q_obs: Array, k: Array, budget: int,
                  obs_window: int) -> Array:
    """Boolean keep-mask (B, Hkv, T): top-(budget - obs_window) scored
    prompt tokens plus the observation window itself."""
    b, hkv, t, _ = k.shape
    scores = snapkv_scores(q_obs, k)
    scores = scores.at[:, :, t - obs_window :].set(jnp.inf)  # always keep
    k_keep = min(budget, t)
    _, idx = jax.lax.top_k(scores, k_keep)
    mask = jnp.zeros((b, hkv, t), bool)
    return mask.at[jnp.arange(b)[:, None, None],
                   jnp.arange(hkv)[None, :, None], idx].set(True)
