"""Quantized KV cache over pluggable key codecs (see ``core/codecs.py``).

The cache owns placement and the method-agnostic machinery; the resolved
:class:`~repro.core.codecs.KeyCodec` owns buffer shapes, encode/decode and
the score path. Layout (all shapes static; ``length`` is the only traced
scalar):

* grouped codecs (polar / kivi / zipcache / any ``codec.grouped``):
    - ``key_codes``   ``(B, Hkv, G, g, ·)`` uint8 (codec-specific last dim,
      e.g. packed rho<<t|theta pairs for polar)
    - ``key_scales``  dict of per-group stat arrays (codec-specific)
    - ``key_residual``(B, Hkv, g, d) fp — tokens of the not-yet-full group
* token-wise codecs (int, the fp passthrough "none", third-party):
    - ``key_codes`` (B, Hkv, T, ·) + per-token ``key_scales`` (``{}`` and a
      model-dtype codes buffer for the fp passthrough)
* values: token-wise quantized (``value_bits>0``) or fp, token-major
  (B, Hkv, T, d) — independent of key codec.

Absolute-position bookkeeping: ``flushed = (length // g) * g`` tokens live in
quantized groups; positions ``[flushed, length)`` live in the residual. The
decode-attention score assembly exploits ``pos - flushed == pos % g`` inside
the residual window, so residual scores scatter into the absolute score
vector with a tile+reshape — no dynamic slicing (see ``assemble_scores``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass, static_field
from repro.core import quantizers as qz
from repro.core.cache_layout import (
    LinearLayout, RingLayout, ring_segments as _ring_segments,
)
from repro.core.quantizers import QuantConfig

Array = jax.Array
NEG_INF = -1e30


@pytree_dataclass
class KVCache:
    key_codes: Array        # codec codes (fp keys for the passthrough codec)
    key_scales: Any         # dict[str, Array] (codec-specific; may be {})
    key_residual: Any       # Array or None (grouped codecs only)
    value_codes: Any        # Array or None
    value_scale: Any
    value_zero: Any
    value_fp: Any           # Array or None
    length: Array           # () int32 — or (B,) for gathered paged views
    cfg: QuantConfig = static_field(default=QuantConfig())
    max_len: int = static_field(default=0)
    layout: Any = static_field(default=None)   # LinearLayout | RingLayout

    @property
    def batch(self) -> int:
        return self.key_codes.shape[0]

    @property
    def num_kv_heads(self) -> int:
        return self.key_codes.shape[1]

    @property
    def head_dim(self) -> int:
        v = self.value_codes if self.value_codes is not None else self.value_fp
        return v.shape[-1]

    @property
    def codec(self):
        return self.cfg.codec

    @property
    def grouped(self) -> bool:
        return self.cfg.codec.grouped

    @property
    def lay(self):
        """Placement layout; pre-layout caches default to ring arithmetic
        (slot = pos % capacity), of which linear is the degenerate case."""
        return self.layout if self.layout is not None else RingLayout(self.max_len)


def init_cache(cfg: QuantConfig, batch: int, num_kv_heads: int, head_dim: int,
               max_len: int, dtype=jnp.bfloat16, layout=None) -> KVCache:
    """Allocate an empty cache of capacity ``max_len`` tokens.

    ``layout`` picks the placement policy (default: ring arithmetic over
    ``max_len`` slots, which is also correct for linear use since positions
    then never wrap). Quantization policy and placement are independent —
    any registered codec composes with any layout."""
    b, h, d = batch, num_kv_heads, head_dim
    codec = cfg.codec
    key_codes, key_scales = codec.init_buffers(cfg, (b, h), max_len, d, dtype)
    key_residual = None
    if codec.grouped:
        key_residual = jnp.zeros((b, h, cfg.group_size, d),
                                 jnp.dtype(cfg.residual_dtype))

    sdt = jnp.dtype(cfg.scale_dtype)
    value_codes = value_scale = value_zero = value_fp = None
    if cfg.value_bits > 0:
        value_codes = jnp.zeros((b, h, max_len, d), jnp.uint8)
        value_scale = jnp.zeros((b, h, max_len, 1), sdt)
        value_zero = jnp.zeros((b, h, max_len, 1), sdt)
    else:
        value_fp = jnp.zeros((b, h, max_len, d), dtype)

    return KVCache(key_codes=key_codes, key_scales=key_scales,
                   key_residual=key_residual,
                   value_codes=value_codes, value_scale=value_scale,
                   value_zero=value_zero, value_fp=value_fp,
                   length=jnp.zeros((), jnp.int32), cfg=cfg, max_len=max_len,
                   layout=layout)


# ---------------------------------------------------------------------------
# Update helpers shared by append/prefill
# ---------------------------------------------------------------------------


def _dus(buf: Array, update: Array, axis: int, index: Array) -> Array:
    idx = [jnp.zeros((), jnp.int32)] * buf.ndim
    idx[axis] = index.astype(jnp.int32)
    return jax.lax.dynamic_update_slice(buf, update.astype(buf.dtype), idx)


# ---------------------------------------------------------------------------
# Append (decode step: one token)
# ---------------------------------------------------------------------------


def append(cache: KVCache, k_new: Array, v_new: Array) -> KVCache:
    """Append one token. ``k_new``/``v_new``: (B, Hkv, 1, d) post-RoPE.

    Token-major slots are written modulo capacity, so the same code path
    serves unbounded (linear) caches and ring (local-window) caches.
    """
    cfg = cache.cfg
    codec = cache.codec
    lay = cache.lay
    pos = cache.length
    tok_slot = lay.token_slot(pos)
    updates: dict[str, Any] = {}

    # --- values (token-major) ---
    if cfg.value_bits > 0:
        qv = qz.encode_values(v_new, cfg.value_bits, cfg.scale_dtype)
        updates["value_codes"] = _dus(cache.value_codes, qv.codes, 2, tok_slot)
        updates["value_scale"] = _dus(cache.value_scale, qv.scale, 2, tok_slot)
        updates["value_zero"] = _dus(cache.value_zero, qv.zero, 2, tok_slot)
    else:
        updates["value_fp"] = _dus(cache.value_fp, v_new, 2, tok_slot)

    # --- keys ---
    if not codec.grouped:
        codes, scales = codec.encode(cfg, k_new)
        updates["key_codes"] = _dus(cache.key_codes, codes, 2, tok_slot)
        updates["key_scales"] = {
            k: _dus(cache.key_scales[k], scales[k], 2, tok_slot)
            for k in cache.key_scales}
    else:
        g = cfg.group_size
        slot = pos % g
        residual = _dus(cache.key_residual, k_new, 2, slot)

        def flush(args):
            codes_buf, scales_buf, res = args
            # res (B,H,g,d) -> codes (B,H,1,g,*) / scales (B,H,1,1|g,*)
            codes, scales = codec.encode(cfg, res)
            gidx = lay.group_slot(pos // g, codes_buf.shape[2])
            codes_buf = _dus(codes_buf, codes, 2, gidx)
            scales_buf = {k: _dus(scales_buf[k], scales[k], 2, gidx)
                          for k in scales_buf}
            return codes_buf, scales_buf

        def no_flush(args):
            codes_buf, scales_buf, _ = args
            return codes_buf, scales_buf

        codes_buf, scales_buf = jax.lax.cond(
            slot == g - 1, flush, no_flush,
            (cache.key_codes, cache.key_scales, residual))
        updates["key_codes"] = codes_buf
        updates["key_scales"] = scales_buf
        updates["key_residual"] = residual

    return dataclasses.replace(cache, length=pos + 1, **updates)


# ---------------------------------------------------------------------------
# Prefill (bulk insert of T tokens into an empty cache)
# ---------------------------------------------------------------------------


def prefill(cache: KVCache, k: Array, v: Array) -> KVCache:
    """Fill an empty cache with ``T`` tokens at once. k/v: (B, Hkv, T, d).

    T may exceed capacity for ring (local-window) caches: only the last
    ``max_len`` tokens are stored token-major at slots ``pos % max_len``;
    key groups (absolute-aligned) keep the last ``max_len/g`` groups — the
    few grouped keys older than the window are masked out at attention time
    (see ``position_masks``).
    """
    cfg = cache.cfg
    codec = cache.codec
    lay = cache.lay
    b, h, t, d = k.shape
    cap = cache.max_len
    off = lay.prefill_offset(t)    # tokens before `off` fall out of the ring
    segs = lay.copy_segments(t)
    updates: dict[str, Any] = {}

    def write_tok(buf, src):
        for lo, hi, dst in segs:
            buf = buf.at[:, :, dst : dst + (hi - lo)].set(
                src[:, :, lo - off : hi - off].astype(buf.dtype))
        return buf

    if cfg.value_bits > 0:
        qv = qz.encode_values(v[:, :, off:], cfg.value_bits, cfg.scale_dtype)
        updates["value_codes"] = write_tok(cache.value_codes, qv.codes)
        updates["value_scale"] = write_tok(cache.value_scale, qv.scale)
        updates["value_zero"] = write_tok(cache.value_zero, qv.zero)
    else:
        updates["value_fp"] = write_tok(cache.value_fp, v[:, :, off:])

    if not codec.grouped:
        codes, scales = codec.encode(cfg, k[:, :, off:])
        updates["key_codes"] = write_tok(cache.key_codes, codes)
        updates["key_scales"] = {
            key: write_tok(cache.key_scales[key], scales[key])
            for key in cache.key_scales}
    else:
        g = cfg.group_size
        nfull = t // g
        goff = max(0, nfull - cap // g)   # group ring offset (group units)
        rem = t - nfull * g
        scales_buf = dict(cache.key_scales)
        codes_buf = cache.key_codes
        # Round through the residual dtype so bulk prefill and token-by-token
        # append produce bit-identical codes (streaming parity invariant).
        k_rdt = k[:, :, goff * g :].astype(jnp.dtype(cfg.residual_dtype))
        if nfull > goff:
            codes, scales = codec.encode(cfg, k_rdt[:, :, : (nfull - goff) * g])
            for lo, hi, dst in _ring_segments(nfull, cap // g):
                n = hi - lo
                codes_buf = codes_buf.at[:, :, dst : dst + n].set(
                    codes[:, :, lo - goff : hi - goff])
                scales_buf = {key: scales_buf[key].at[:, :, dst : dst + n].set(
                    scales[key][:, :, lo - goff : hi - goff].astype(
                        scales_buf[key].dtype)) for key in scales_buf}
        residual = cache.key_residual
        if rem:
            residual = residual.at[:, :, :rem].set(
                k_rdt[:, :, (nfull - goff) * g :])
        updates["key_codes"] = codes_buf
        updates["key_scales"] = scales_buf
        updates["key_residual"] = residual

    return dataclasses.replace(
        cache, length=jnp.asarray(t, jnp.int32), **updates)


# ---------------------------------------------------------------------------
# Score computation over the cache
# ---------------------------------------------------------------------------


def key_scores(cache: KVCache, q: Array, use_lut: bool = True) -> Array:
    """Scores of q against all stored keys via the codec's score path.
    q: (B, Hkv, Qh, d) -> (B, Hkv, Qh, max_len)."""
    return cache.codec.scores(cache.cfg, q, cache.key_codes,
                              cache.key_scales, use_lut=use_lut)


def position_masks(t_cap: int, g: int, length: Array, window: int):
    """Validity masks over buffer slots, for linear AND ring caches.

    Ring semantics (capacity == window): slot ``i`` of the token-major value
    buffer holds absolute position ``i + floor((length-1-i)/t_cap)*t_cap``;
    key-group slots wrap by ``flushed`` instead. A slot's key expires from
    the window exactly when its value slot is overwritten (capacity ==
    window), so grouped-validity and residual-membership never overlap.
    Linear caches are the degenerate case (positions == slot index).

    ``length`` may be () — one shared length — or (B,) per-sequence lengths
    (gathered paged views under continuous batching, where every slot sits
    at its own position).

    Returns (valid_grouped, in_residual, flushed): (t_cap,) bools + scalar,
    or (B, t_cap) bools + (B,) for batched lengths.
    """
    length = jnp.asarray(length, jnp.int32)
    i = jnp.arange(t_cap, dtype=jnp.int32)
    if length.ndim:
        i = i[None, :]
        length = length[:, None]
    flushed = (length // g) * g
    abs_k = i + ((flushed - 1 - i) // t_cap) * t_cap
    abs_v = i + ((length - 1 - i) // t_cap) * t_cap
    valid_g = (abs_k >= 0) & (abs_k < flushed)
    if window > 0:
        valid_g = valid_g & (abs_k >= length - window)
    in_res = (abs_v >= flushed) & (abs_v < length)
    return valid_g, in_res, (flushed if flushed.ndim == 0 else flushed[:, 0])


def decode_attention(cache: KVCache, q: Array, scale: float | None = None,
                     use_lut: bool = True, window: int = 0) -> Array:
    """Single-step attention of query q (B, Hq, d) over the cache.

    Returns (B, Hq, d) in q.dtype. Handles GQA by folding query heads onto
    their KV head. Scores over stored keys come from the codec's score path
    (angle LUT for polar); residual tokens are attended at full precision.
    ``window > 0`` applies ring-buffer local-attention semantics (capacity
    must equal window).
    """
    cfg = cache.cfg
    b, hq, d = q.shape
    hkv = cache.num_kv_heads
    qpk = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    q4 = (q.astype(jnp.float32) * scale).reshape(b, hkv, qpk, d)
    t_cap = cache.max_len
    length = cache.length

    def bc(mask):  # (T,) or (B,T) -> broadcastable against (B,Hkv,Qh,T)
        return mask if mask.ndim == 1 else mask[:, None, None, :]

    if cache.grouped:
        g = cfg.group_size
        valid_g, in_res, _ = position_masks(t_cap, g, length, window)
        s_grouped = key_scores(cache, q4, use_lut)                 # (B,Hkv,Qh,T)
        res = cache.key_residual.astype(jnp.float32)               # (B,Hkv,g,d)
        s_res = jnp.einsum("bhqd,bhgd->bhqg", q4, res)             # (B,Hkv,Qh,g)
        s_res_tiled = jnp.tile(s_res, (1, 1, 1, t_cap // g))       # slot % g trick
        scores = jnp.where(bc(in_res), s_res_tiled,
                           jnp.where(bc(valid_g), s_grouped, NEG_INF))
    else:
        valid_g, in_res, _ = position_masks(t_cap, 1, length, window)
        scores = key_scores(cache, q4, use_lut)
        scores = jnp.where(bc(valid_g | in_res), scores, NEG_INF)

    probs = jax.nn.softmax(scores, axis=-1)                        # fp32
    if cfg.value_bits > 0:
        v_tilde = qz.decode_values(qz.QuantizedValues(
            codes=cache.value_codes, scale=cache.value_scale,
            zero=cache.value_zero, bits=cfg.value_bits))
    else:
        v_tilde = cache.value_fp.astype(jnp.float32)
    out = jnp.einsum("bhqt,bhtd->bhqd", probs, v_tilde)
    return out.reshape(b, hq, d).astype(q.dtype)


def fused_decode_attention(cache: KVCache, q: Array,
                           scale: float | None = None,
                           backend: str = "ref") -> Array:
    """Single-step decode attention via the codec's fused flash-decode
    kernel (polar: :func:`repro.kernels.ops.polar_decode_attention_full`).

    Semantically equivalent to :func:`decode_attention` for a *linear*
    cache (no ring wrap, no window) — the kernel consumes the cache
    buffers directly: LUT scores over quantized groups fused with the
    value matmul, exact online-softmax merge with the fp residual.
    ``cache.length`` may be () or (B,) (heterogeneous slot lengths).
    ``backend``: ref | interpret | pallas (see kernels.ops).
    """
    codec = cache.codec
    if not codec.supports_fused_decode:
        raise ValueError("fused decode path requires a codec with a fused "
                         f"kernel, got {codec.name!r}")
    if not isinstance(cache.layout, LinearLayout):
        # ring (and layout-less, which defaults to ring arithmetic) caches
        # can wrap: the kernel's pos < flushed mask would validate
        # overwritten slots
        raise ValueError("fused decode path requires a linear layout")
    return codec.fused_decode(cache, q, scale=scale, backend=backend)


def cache_logical_bits(cache: KVCache) -> float:
    """Logical bits/key-element of this cache's policy (paper's accounting)."""
    return cache.cfg.key_bits_per_element(cache.head_dim)
