"""Cache layouts: WHERE cached tokens live, independent of HOW they are
quantized (DESIGN.md §9).

The quantization *policy* (:class:`~repro.core.quantizers.QuantConfig`)
decides the bit layout of each stored token/group; the *layout* decides
which physical buffer slot a logical position maps to:

* :class:`LinearLayout` — slot == absolute position; capacity bounds the
  sequence length. The dense serving default.
* :class:`RingLayout`   — slot == position % capacity; capacity equals the
  local-attention window, so a key expires exactly when its value slot is
  overwritten.
* :class:`PagedLayout`  — tokens live in fixed-size pages drawn from a
  shared pool; a per-slot page table maps group index -> pool page. Page
  size equals the quantization group size, so one page holds exactly one
  key group plus its token-major value rows, and admission/eviction of
  whole requests becomes free-list bookkeeping instead of buffer copies.

All layout objects are pure-static (hashable frozen dataclasses): they ride
on pytree dataclasses as aux data and jit retraces only when the layout
itself changes, never per step.

:class:`PageAllocator` is the host-side free-list companion of
``PagedLayout``: the scheduler allocates/reclaims pages between jitted
steps and ships the updated page table to the device as a plain int32
array. Unassigned entries point at the pool's *scratch page* (index
``num_pages``) so masked-out lanes of batched scatters land harmlessly
there — no -1 special-casing inside kernels.

Pages are *refcounted* (DESIGN.md §12): a page may be mapped into several
slots' table rows at once (shared-prefix reuse — the encoded bytes are
shared verbatim, never re-encoded) and additionally referenced by the
:class:`PrefixIndex`, which keeps reclaimed prompt pages alive for future
admissions. A page returns to the free list only when its last reference
drops; writers must go through :meth:`PageAllocator.cow` (copy-on-write)
before mutating a page whose refcount exceeds one.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict, deque

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LinearLayout:
    """Dense layout: absolute position == buffer slot. Requires
    ``length <= capacity`` at all times."""

    capacity: int

    def token_slot(self, pos):
        return pos

    def group_slot(self, gidx, ngroups: int):
        return gidx

    def prefill_offset(self, t: int) -> int:
        if t > self.capacity:
            raise ValueError(
                f"prompt length {t} exceeds linear capacity {self.capacity}")
        return 0

    def copy_segments(self, t: int) -> list[tuple[int, int, int]]:
        self.prefill_offset(t)
        return [(0, t, 0)]


@dataclasses.dataclass(frozen=True)
class RingLayout:
    """Sliding-window layout: slot ``pos % capacity``; capacity == window."""

    capacity: int

    def token_slot(self, pos):
        return pos % self.capacity

    def group_slot(self, gidx, ngroups: int):
        return gidx % ngroups

    def prefill_offset(self, t: int) -> int:
        return max(0, t - self.capacity)

    def copy_segments(self, t: int) -> list[tuple[int, int, int]]:
        return ring_segments(t, self.capacity)


def ring_segments(t: int, cap: int) -> list[tuple[int, int, int]]:
    """Static (src_lo, src_hi, dst_lo) copy segments mapping positions
    [max(0, t-cap), t) onto slots pos % cap. At most two segments."""
    start = max(0, t - cap)
    if start == 0:
        return [(0, t, 0)]
    p0 = -(-start // cap) * cap  # first position mapping to slot 0
    segs = []
    if p0 > start:
        segs.append((start, min(p0, t), start % cap))
    if t > p0:
        segs.append((p0, t, 0))
    return segs


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Paged layout: a pool of ``num_pages`` fixed-size pages shared by up
    to ``slots`` concurrent sequences, each owning at most
    ``pages_per_slot`` pages via its page-table row.

    ``page_size`` must equal the quantization group size: page == group is
    what lets the paged cache reuse the grouped encode/decode machinery
    (and the fused LUT decode kernel) unchanged on gathered views.
    """

    page_size: int
    num_pages: int       # allocatable pages (scratch page excluded)
    slots: int
    pages_per_slot: int

    @property
    def scratch_page(self) -> int:
        """Write target for masked-out lanes; readers never see it because
        every read is masked by per-slot lengths."""
        return self.num_pages

    @property
    def pool_pages(self) -> int:
        """Physical pages to allocate: pool + one scratch page."""
        return self.num_pages + 1

    @property
    def tokens_per_slot(self) -> int:
        return self.pages_per_slot * self.page_size

    def pages_for(self, num_tokens: int) -> int:
        """Pages needed to hold ``num_tokens`` tokens of one sequence."""
        return -(-num_tokens // self.page_size)


class PageAllocator:
    """Host-side refcounting free-list allocator over a :class:`PagedLayout`.

    Not a pytree: lives in the serving scheduler, mutates numpy state
    between jitted steps, and exposes the device-ready ``table``.

    Reference semantics (DESIGN.md §12): every mapping of a page into a
    slot's table row holds one reference, and external holders (the
    :class:`PrefixIndex`) take references through :meth:`incref`. A page is
    free iff its refcount is zero — :meth:`free_slot` *decrefs* rather than
    frees, so pages shared with other slots or pinned by the prefix index
    survive slot reclamation with their encoded bytes intact.
    """

    def __init__(self, layout: PagedLayout):
        self.layout = layout
        self._free: deque[int] = deque(range(layout.num_pages))
        self._ref = np.zeros((layout.num_pages,), np.int32)
        self._table = np.full((layout.slots, layout.pages_per_slot),
                              layout.scratch_page, np.int32)
        self._owned: list[list[int]] = [[] for _ in range(layout.slots)]
        self._quarantined: list[int] = []

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.layout.num_pages - len(self._free)

    def utilization(self) -> float:
        return self.used_pages / max(self.layout.num_pages, 1)

    def slot_pages(self, slot: int) -> int:
        return len(self._owned[slot])

    def slot_page_ids(self, slot: int) -> list[int]:
        """The slot's owned pages in table-row order (copy)."""
        return list(self._owned[slot])

    def page_at(self, slot: int, idx: int) -> int:
        return self._owned[slot][idx]

    def can_alloc(self, count: int) -> bool:
        return len(self._free) >= count

    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    def incref(self, page: int) -> int:
        """Take an external reference on an *allocated* page (prefix-index
        pin). Returns the new count."""
        if not 0 <= page < self.layout.num_pages:
            raise ValueError(f"page {page} out of pool range")
        if self._ref[page] == 0:
            raise ValueError(f"incref on free page {page}")
        self._ref[page] += 1
        return int(self._ref[page])

    def decref(self, page: int) -> int:
        """Drop one reference; the page returns to the free list when the
        count reaches zero. Returns the new count."""
        if self._ref[page] <= 0:
            raise ValueError(f"decref on free page {page} (double free)")
        self._ref[page] -= 1
        if self._ref[page] == 0:
            self._free.append(page)
        return int(self._ref[page])

    def alloc(self, slot: int, count: int = 1) -> bool:
        """Append ``count`` fresh pages (refcount 1) to ``slot``'s table
        row. All-or-nothing: returns False (state unchanged) when the pool
        or the slot's row can't fit them."""
        owned = self._owned[slot]
        if count > len(self._free):
            return False
        if len(owned) + count > self.layout.pages_per_slot:
            return False
        for _ in range(count):
            page = self._free.popleft()
            self._ref[page] = 1
            self._table[slot, len(owned)] = page
            owned.append(page)
        return True

    def adopt(self, slot: int, pages: list[int]) -> bool:
        """Map already-allocated ``pages`` into ``slot``'s table row at
        refcount+1 (shared-prefix hit: the encoded bytes are shared
        verbatim). All-or-nothing on row capacity; the pages must be live."""
        owned = self._owned[slot]
        if len(owned) + len(pages) > self.layout.pages_per_slot:
            return False
        for page in pages:
            self.incref(page)
            self._table[slot, len(owned)] = page
            owned.append(page)
        return True

    def cow(self, slot: int, idx: int) -> tuple[int, int] | None:
        """Copy-on-write split of ``slot``'s ``idx``-th page.

        If the page is shared (refcount > 1), remap the row entry to a
        fresh page and drop the old reference, returning ``(old, new)`` so
        the caller can copy the pool bytes device-side before writing.
        Returns None when the page is exclusively owned (no split needed).
        Raises when the pool is dry — callers must check :meth:`can_alloc`
        / reclaim first."""
        page = self._owned[slot][idx]
        if self._ref[page] <= 1:
            return None
        if not self._free:
            raise RuntimeError("COW split with an empty pool")
        new = self._free.popleft()
        self._ref[new] = 1
        self._owned[slot][idx] = new
        self._table[slot, idx] = new
        self.decref(page)
        return page, new

    def free_slot(self, slot: int) -> int:
        """Drop ``slot``'s references; returns the number of pages whose
        last reference this was (i.e. actually reclaimed)."""
        owned = self._owned[slot]
        n = 0
        for page in owned:
            if self.decref(page) == 0:
                n += 1
        self._owned[slot] = []
        self._table[slot, :] = self.layout.scratch_page
        return n

    def quarantine(self, count: int) -> int:
        """Pull up to ``count`` pages off the free list and pin them
        (refcount 1, mapped into no slot) — the fault-injection form of
        pool exhaustion (DESIGN.md §16). Quarantined pages are external
        pins exactly like prefix-index pins, so every allocator invariant
        (conservation, free iff ref 0) holds while they are held. Returns
        the number actually quarantined."""
        n = min(int(count), len(self._free))
        for _ in range(n):
            page = self._free.popleft()
            self._ref[page] = 1
            self._quarantined.append(page)
        return n

    def release_quarantine(self) -> int:
        """Return every quarantined page to the free list; returns the
        number released."""
        n = len(self._quarantined)
        for page in self._quarantined:
            self.decref(page)
        self._quarantined = []
        return n

    @property
    def quarantined_pages(self) -> int:
        return len(self._quarantined)

    def table(self) -> jnp.ndarray:
        """Device-ready (slots, pages_per_slot) int32 page table."""
        return jnp.asarray(self._table)

    def table_np(self) -> np.ndarray:
        return self._table.copy()


# ---------------------------------------------------------------------------
# Shared-prefix page index
# ---------------------------------------------------------------------------


def token_page_hashes(tokens: np.ndarray, page_size: int) -> list[bytes]:
    """Chain hashes of ``tokens``, one per *full* page.

    ``h[i]`` digests every token in ``[0, (i+1)*page_size)`` — not just
    page ``i``'s own tokens — because a page's encoded bytes depend on the
    whole token prefix through the transformer (causal attention below the
    key projection). Two prompts may share page ``i`` only when they agree
    on all tokens up to the end of that page, which the chain encodes.
    """
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
    out: list[bytes] = []
    h = hashlib.sha1(str(page_size).encode())
    for i in range(len(toks) // page_size):
        h = h.copy()
        h.update(toks[i * page_size:(i + 1) * page_size].tobytes())
        out.append(h.digest())
    return out


class PrefixIndex:
    """Content-hash index over encoded prompt pages (DESIGN.md §12).

    Maps the chain hash of a token prefix (page granularity, see
    :func:`token_page_hashes`) to the pool page holding that group's
    encoded keys and value rows. Entries form a trie over chains: each
    entry records its parent hash so eviction can stay *leaf-first* and
    never strand reachable descendants.

    The index holds one allocator reference per entry (taken via
    ``alloc.incref`` at :meth:`register`), which is what keeps a finished
    request's prompt pages alive for future admissions. Under pool
    pressure :meth:`evict` drops least-recently-used leaf entries whose
    page has no other holder (refcount == 1).

    Page bytes are deterministic in (token prefix, group size, prefill
    chunking): the index is built per engine run for one
    ``(page_size, chunk_tokens)`` pair, so entries never mix encodings
    from different chunk schedules or group sizes.
    """

    def __init__(self, layout: PagedLayout, chunk_tokens: int = 0):
        self.layout = layout
        self.chunk_tokens = int(chunk_tokens)
        # hash -> (page, parent_hash | None); order == LRU (oldest first)
        self._entries: "OrderedDict[bytes, tuple[int, bytes | None]]" = \
            OrderedDict()
        self._children: dict[bytes, int] = {}   # hash -> live child count
        self.hits = 0
        self.queries = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def pages(self) -> list[int]:
        return [p for p, _ in self._entries.values()]

    def match(self, tokens: np.ndarray, count: bool = True) -> list[int]:
        """Pages of the longest indexed prefix of ``tokens`` (whole pages,
        in position order; empty on a first-page miss). Touches matched
        entries for LRU recency. ``count=False`` skips the query/hit
        stats (repeated admission polls of the same queue head)."""
        return self.match_hashes(
            token_page_hashes(tokens, self.layout.page_size), count=count)

    def match_hashes(self, hashes: list[bytes],
                     count: bool = True) -> list[int]:
        """:meth:`match` on precomputed chain hashes — callers that poll
        repeatedly (the scheduler's admission loop) memoize the hashes,
        which are pure in the tokens, while the page walk itself always
        runs against the live index (eviction may drop entries between
        polls)."""
        if count:
            self.queries += 1
        pages: list[int] = []
        for h in hashes:
            ent = self._entries.get(h)
            if ent is None:
                break
            pages.append(ent[0])
            self._entries.move_to_end(h)
        if count:
            self.hits += bool(pages)
        return pages

    def register(self, tokens: np.ndarray, pages: list[int],
                 alloc: PageAllocator) -> int:
        """Index ``pages`` (the slot's table row prefix) under the chain
        hashes of ``tokens``; takes one allocator reference per *newly*
        indexed page. Existing entries win (first writer keeps the page —
        equal chain hash means bit-identical bytes, so either copy serves).
        Returns the number of new entries."""
        new = 0
        parent: bytes | None = None
        for h, page in zip(token_page_hashes(tokens, self.layout.page_size),
                           pages):
            if h not in self._entries:
                alloc.incref(page)
                self._entries[h] = (page, parent)
                self._children.setdefault(h, 0)
                if parent is not None:
                    self._children[parent] += 1
                new += 1
            self._entries.move_to_end(h)
            parent = h
        return new

    def _drop(self, h: bytes, alloc: PageAllocator) -> None:
        page, parent = self._entries.pop(h)
        del self._children[h]
        if parent is not None and parent in self._children:
            self._children[parent] -= 1
        alloc.decref(page)
        self.evictions += 1

    def drop_all(self, alloc: PageAllocator) -> None:
        for h in list(self._entries):
            self._drop(h, alloc)

    def evict(self, alloc: PageAllocator, need: int,
              keep: set[int] | None = None) -> int:
        """Free up to ``need`` pages by dropping LRU *leaf* entries whose
        page has no holder besides the index (refcount == 1) and is not in
        ``keep`` (pages about to be adopted). Returns pages freed."""
        keep = keep or set()
        freed = 0
        while freed < need:
            victim = None
            for h, (page, _) in self._entries.items():   # oldest first
                if (self._children.get(h, 0) == 0 and page not in keep
                        and alloc.refcount(page) == 1):
                    victim = h
                    break
            if victim is None:
                break
            self._drop(victim, alloc)
            freed += 1
        return freed
