"""Cache layouts: WHERE cached tokens live, independent of HOW they are
quantized (DESIGN.md §9).

The quantization *policy* (:class:`~repro.core.quantizers.QuantConfig`)
decides the bit layout of each stored token/group; the *layout* decides
which physical buffer slot a logical position maps to:

* :class:`LinearLayout` — slot == absolute position; capacity bounds the
  sequence length. The dense serving default.
* :class:`RingLayout`   — slot == position % capacity; capacity equals the
  local-attention window, so a key expires exactly when its value slot is
  overwritten.
* :class:`PagedLayout`  — tokens live in fixed-size pages drawn from a
  shared pool; a per-slot page table maps group index -> pool page. Page
  size equals the quantization group size, so one page holds exactly one
  key group plus its token-major value rows, and admission/eviction of
  whole requests becomes free-list bookkeeping instead of buffer copies.

All layout objects are pure-static (hashable frozen dataclasses): they ride
on pytree dataclasses as aux data and jit retraces only when the layout
itself changes, never per step.

:class:`PageAllocator` is the host-side free-list companion of
``PagedLayout``: the scheduler allocates/reclaims pages between jitted
steps and ships the updated page table to the device as a plain int32
array. Unassigned entries point at the pool's *scratch page* (index
``num_pages``) so masked-out lanes of batched scatters land harmlessly
there — no -1 special-casing inside kernels.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LinearLayout:
    """Dense layout: absolute position == buffer slot. Requires
    ``length <= capacity`` at all times."""

    capacity: int

    def token_slot(self, pos):
        return pos

    def group_slot(self, gidx, ngroups: int):
        return gidx

    def prefill_offset(self, t: int) -> int:
        if t > self.capacity:
            raise ValueError(
                f"prompt length {t} exceeds linear capacity {self.capacity}")
        return 0

    def copy_segments(self, t: int) -> list[tuple[int, int, int]]:
        self.prefill_offset(t)
        return [(0, t, 0)]


@dataclasses.dataclass(frozen=True)
class RingLayout:
    """Sliding-window layout: slot ``pos % capacity``; capacity == window."""

    capacity: int

    def token_slot(self, pos):
        return pos % self.capacity

    def group_slot(self, gidx, ngroups: int):
        return gidx % ngroups

    def prefill_offset(self, t: int) -> int:
        return max(0, t - self.capacity)

    def copy_segments(self, t: int) -> list[tuple[int, int, int]]:
        return ring_segments(t, self.capacity)


def ring_segments(t: int, cap: int) -> list[tuple[int, int, int]]:
    """Static (src_lo, src_hi, dst_lo) copy segments mapping positions
    [max(0, t-cap), t) onto slots pos % cap. At most two segments."""
    start = max(0, t - cap)
    if start == 0:
        return [(0, t, 0)]
    p0 = -(-start // cap) * cap  # first position mapping to slot 0
    segs = []
    if p0 > start:
        segs.append((start, min(p0, t), start % cap))
    if t > p0:
        segs.append((p0, t, 0))
    return segs


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Paged layout: a pool of ``num_pages`` fixed-size pages shared by up
    to ``slots`` concurrent sequences, each owning at most
    ``pages_per_slot`` pages via its page-table row.

    ``page_size`` must equal the quantization group size: page == group is
    what lets the paged cache reuse the grouped encode/decode machinery
    (and the fused LUT decode kernel) unchanged on gathered views.
    """

    page_size: int
    num_pages: int       # allocatable pages (scratch page excluded)
    slots: int
    pages_per_slot: int

    @property
    def scratch_page(self) -> int:
        """Write target for masked-out lanes; readers never see it because
        every read is masked by per-slot lengths."""
        return self.num_pages

    @property
    def pool_pages(self) -> int:
        """Physical pages to allocate: pool + one scratch page."""
        return self.num_pages + 1

    @property
    def tokens_per_slot(self) -> int:
        return self.pages_per_slot * self.page_size

    def pages_for(self, num_tokens: int) -> int:
        """Pages needed to hold ``num_tokens`` tokens of one sequence."""
        return -(-num_tokens // self.page_size)


class PageAllocator:
    """Host-side free-list allocator over a :class:`PagedLayout`.

    Not a pytree: lives in the serving scheduler, mutates numpy state
    between jitted steps, and exposes the device-ready ``table``.
    """

    def __init__(self, layout: PagedLayout):
        self.layout = layout
        self._free: deque[int] = deque(range(layout.num_pages))
        self._table = np.full((layout.slots, layout.pages_per_slot),
                              layout.scratch_page, np.int32)
        self._owned: list[list[int]] = [[] for _ in range(layout.slots)]

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.layout.num_pages - len(self._free)

    def utilization(self) -> float:
        return self.used_pages / max(self.layout.num_pages, 1)

    def slot_pages(self, slot: int) -> int:
        return len(self._owned[slot])

    def can_alloc(self, count: int) -> bool:
        return len(self._free) >= count

    def alloc(self, slot: int, count: int = 1) -> bool:
        """Append ``count`` pages to ``slot``'s table row. All-or-nothing:
        returns False (state unchanged) when the pool or the slot's row
        can't fit them."""
        owned = self._owned[slot]
        if count > len(self._free):
            return False
        if len(owned) + count > self.layout.pages_per_slot:
            return False
        for _ in range(count):
            page = self._free.popleft()
            self._table[slot, len(owned)] = page
            owned.append(page)
        return True

    def free_slot(self, slot: int) -> int:
        """Return all of ``slot``'s pages to the free list; returns the
        number reclaimed."""
        owned = self._owned[slot]
        n = len(owned)
        self._free.extend(owned)
        self._owned[slot] = []
        self._table[slot, :] = self.layout.scratch_page
        return n

    def table(self) -> jnp.ndarray:
        """Device-ready (slots, pages_per_slot) int32 page table."""
        return jnp.asarray(self._table)

    def table_np(self) -> np.ndarray:
        return self._table.copy()
