"""Paged quantized KV cache: a shared page pool + per-slot page tables.

One page holds exactly one quantization group (``page_size == group_size``):
its key codes/stats and its ``page_size`` token-major value rows. Requests
own pages through a host-managed page table (see
``cache_layout.PageAllocator``); admission and reclamation are free-list
bookkeeping — no buffer copies, no recompiles (all shapes static).

Key buffers come from the resolved :class:`~repro.core.codecs.KeyCodec`
(see ``core/codecs.py``). Buffer shapes (``PP = num_pages + 1``: last page
is the masked-write scratch page, ``S`` = slots, ``N`` = pages_per_slot,
``g`` = page size):

* grouped codecs (polar / kivi / zipcache / third-party):
    - ``key_codes``    (PP, H, g, ·) uint8 page pool
    - ``key_scales``   dict of (PP, H, 1|g, ·) stat pools
    - ``key_residual`` (S, H, g, d) per-slot fp not-yet-full group
* token-wise codecs (int / fp passthrough): ``key_codes`` (PP, H, g, ·)
  token-major page rows + per-token ``key_scales`` pools
* values (all codecs): token-major page rows, quantized or fp
* ``lengths`` (S,) int32 per-slot token counts

The invariant mirrors the dense cache: value rows for positions
``[0, len)`` live in pages (row ``pos % g`` of page ``table[pos // g]``),
key codes for ``[0, flushed)`` live in pages, and keys of the partial
group ``[flushed, len)`` live in the per-slot residual. Decode attention
(``paged_decode_attention``) dispatches per codec: codecs with a
page-native kernel (``supports_paged_decode``, e.g. polar) read their
pages *in place* through the page table (``kernels/paged_decode.py``);
the rest fall back to ``gather_view``, which materializes a per-slot
dense :class:`~repro.core.kv_cache.KVCache` view so the dense decode
machinery is reused unchanged — also the reference path the kernel is
parity-tested against.

Streaming parity: prefill rounds keys through ``cfg.residual_dtype``
exactly like the dense cache, so paged and dense caches produce
bit-identical codes for the same token stream.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.utils import pytree_dataclass, static_field
from repro.core import kv_cache as kvc
from repro.core import quantizers as qz
from repro.core.cache_layout import LinearLayout, PagedLayout
from repro.core.quantizers import QuantConfig

Array = jax.Array


@pytree_dataclass
class PagedKVCache:
    key_codes: Array        # codec page pool (fp rows for the passthrough)
    key_scales: Any         # dict of stat pools (codec-specific; may be {})
    key_residual: Any       # (S, H, g, d) or None (grouped codecs only)
    value_codes: Any
    value_scale: Any
    value_zero: Any
    value_fp: Any
    lengths: Array          # (S,) int32
    cfg: QuantConfig = static_field(default=QuantConfig())
    layout: PagedLayout = static_field(default=None)

    @property
    def num_kv_heads(self) -> int:
        return self.key_codes.shape[1]

    @property
    def head_dim(self) -> int:
        v = self.value_codes if self.value_codes is not None else self.value_fp
        return v.shape[-1]

    @property
    def codec(self):
        return self.cfg.codec

    @property
    def grouped(self) -> bool:
        return self.cfg.codec.grouped


def init_paged_cache(cfg: QuantConfig, layout: PagedLayout,
                     num_kv_heads: int, head_dim: int,
                     dtype=jnp.bfloat16) -> PagedKVCache:
    """Allocate empty page pools for ``layout`` under policy ``cfg``."""
    codec = cfg.codec
    if codec.grouped and layout.page_size != cfg.group_size:
        raise ValueError(
            f"page_size {layout.page_size} must equal group_size "
            f"{cfg.group_size} (one page == one quantization group)")
    pp, s = layout.pool_pages, layout.slots
    h, d, g = num_kv_heads, head_dim, layout.page_size
    key_codes, key_scales = codec.init_buffers(cfg, (pp, h), g, d, dtype)
    key_residual = None
    if codec.grouped:
        # one group per page: codec buffers are (PP, H, 1, g, ·) — drop the
        # G axis so the pool indexes pages directly
        key_codes = key_codes[:, :, 0]
        key_scales = {k: v[:, :, 0] for k, v in key_scales.items()}
        key_residual = jnp.zeros((s, h, g, d), jnp.dtype(cfg.residual_dtype))

    sdt = jnp.dtype(cfg.scale_dtype)
    value_codes = value_scale = value_zero = value_fp = None
    if cfg.value_bits > 0:
        value_codes = jnp.zeros((pp, h, g, d), jnp.uint8)
        value_scale = jnp.zeros((pp, h, g, 1), sdt)
        value_zero = jnp.zeros((pp, h, g, 1), sdt)
    else:
        value_fp = jnp.zeros((pp, h, g, d), dtype)

    return PagedKVCache(key_codes=key_codes, key_scales=key_scales,
                        key_residual=key_residual,
                        value_codes=value_codes, value_scale=value_scale,
                        value_zero=value_zero, value_fp=value_fp,
                        lengths=jnp.zeros((s,), jnp.int32), cfg=cfg,
                        layout=layout)


# ---------------------------------------------------------------------------
# Page pool scatter/gather helpers
# ---------------------------------------------------------------------------


def _scatter_pages(pool: Array, pages: Array, update: Array) -> Array:
    """pool (PP, H, a, b) <- update (G, H, a, b) at page ids ``pages`` (G,).

    Masked-out rows point at the scratch page; duplicate scratch writes race
    but the scratch page is never read.
    """
    return pool.at[pages].set(update.astype(pool.dtype))


def _gather_pages(pool: Array, table: Array) -> Array:
    """pool (PP, H, a, b), table (S, N) -> (S, H, N, a, b)."""
    return pool[table].transpose(0, 2, 1, 3, 4)


def _scatter_rows(pool: Array, pages: Array, rows: Array,
                  update: Array) -> Array:
    """pool (PP, H, g, b) <- update (S, H, b) at (page, row) per slot."""
    return pool.at[pages, :, rows].set(update.astype(pool.dtype))


# ---------------------------------------------------------------------------
# Prefill (one request, padded to a static bucket length)
# ---------------------------------------------------------------------------


def paged_prefill(cache: PagedKVCache, slot: Array, page_row: Array,
                  k: Array, v: Array, true_len: Array,
                  start: Array | int = 0) -> PagedKVCache:
    """Write one request's prompt (or one prefill *chunk* of it) into its
    assigned pages.

    k/v: (1, Hkv, Tp, d) post-RoPE, ``Tp`` a *static* bucket length
    (multiple of the page size; the real tokens occupy the first
    ``true_len`` of it, the tail is padding). ``slot``: () int32 slot id;
    ``page_row``: (N,) int32 page-table row for the slot (entries beyond
    the written pages may be scratch). Pages whose group index is not
    fully/partially covered by real tokens are redirected to the scratch
    page, so padding never pollutes the pool.

    ``start`` (page-aligned) writes the tokens at absolute positions
    ``[start, start + true_len)`` — the chunked-prefill path: pages come
    from ``page_row[start//g:]`` and the slot length lands at
    ``start + true_len``. Callers must RoPE ``k`` at the absolute
    positions. The classic whole-prompt call is ``start == 0``.
    """
    cfg = cache.cfg
    codec = cache.codec
    lay = cache.layout
    _, h, tp, d = k.shape
    g = lay.page_size
    if tp % g:
        raise ValueError(f"bucket length {tp} not a multiple of page {g}")
    npage = tp // g
    gi = jnp.arange(npage, dtype=jnp.int32)
    true_len = jnp.asarray(true_len, jnp.int32)
    start = jnp.asarray(start, jnp.int32)
    nfull = true_len // g                     # fully-real key groups
    ntouch = -(-true_len // g)                # pages holding any real value
    scratch = lay.scratch_page
    # pad with scratch before slicing: dynamic_slice CLAMPS an
    # out-of-range start, so without padding a final chunk whose static
    # window [start//g, start//g + npage) overruns the row would silently
    # shift onto (and overwrite) earlier context pages. Real tokens never
    # extend past the row — admission bounds the context — so the padded
    # entries are only ever scratch-redirect targets.
    padded_row = jnp.concatenate(
        [page_row, jnp.full((max(npage - 1, 0),), scratch, page_row.dtype)])
    row_pages = jax.lax.dynamic_slice_in_dim(padded_row, start // g, npage)
    updates: dict[str, Any] = {}

    # --- values: token-major rows of every touched page ---
    def vpages():
        return jnp.where(gi < ntouch, row_pages, scratch)

    def to_pages(x):  # (1, H, Tp, ·) -> (G, H, g, ·)
        return x[0].reshape(h, npage, g, x.shape[-1]).transpose(1, 0, 2, 3)

    if cfg.value_bits > 0:
        qv = qz.encode_values(v, cfg.value_bits, cfg.scale_dtype)
        updates["value_codes"] = _scatter_pages(
            cache.value_codes, vpages(), to_pages(qv.codes))
        updates["value_scale"] = _scatter_pages(
            cache.value_scale, vpages(), to_pages(qv.scale))
        updates["value_zero"] = _scatter_pages(
            cache.value_zero, vpages(), to_pages(qv.zero))
    else:
        updates["value_fp"] = _scatter_pages(
            cache.value_fp, vpages(), to_pages(v))

    # --- keys ---
    if not codec.grouped:
        codes, scales = codec.encode(cfg, k)
        updates["key_codes"] = _scatter_pages(
            cache.key_codes, vpages(), to_pages(codes))
        updates["key_scales"] = {
            key: _scatter_pages(cache.key_scales[key], vpages(),
                                to_pages(scales[key]))
            for key in cache.key_scales}
    else:
        kpages = jnp.where(gi < nfull, row_pages, scratch)
        # round through the residual dtype: streaming-parity invariant with
        # the dense cache and with later token-by-token appends
        k_rdt = k.astype(jnp.dtype(cfg.residual_dtype))
        codes, scales = codec.encode(cfg, k_rdt)    # (1,H,G,g,·)/(1,H,G,1|g,·)
        updates["key_codes"] = _scatter_pages(
            cache.key_codes, kpages, codes[0].transpose(1, 0, 2, 3))
        updates["key_scales"] = {
            key: _scatter_pages(cache.key_scales[key], kpages,
                                scales[key][0].transpose(1, 0, 2, 3))
            for key in cache.key_scales}
        # partial group -> per-slot residual. The clamp binds only when
        # nfull*g == Tp, i.e. rem == 0: the slice is then misaligned
        # garbage, but every residual read is masked by lengths and later
        # appends (or the next prefill chunk) overwrite row (pos % g)
        # before it can become visible.
        res_lo = jnp.minimum(nfull * g, tp - g)
        k_res = jax.lax.dynamic_slice_in_dim(k_rdt, res_lo, g, axis=2)[0]
        residual = cache.key_residual.at[slot].set(
            k_res.astype(cache.key_residual.dtype))
        updates["key_residual"] = residual

    lengths = cache.lengths.at[slot].set(start + true_len)
    return dataclasses.replace(cache, lengths=lengths, **updates)


# ---------------------------------------------------------------------------
# Chunked prefill: attention of one chunk over the cached prefix
# ---------------------------------------------------------------------------


def chunk_prefill_attention(cache: PagedKVCache, q: Array, k_chunk: Array,
                            v_chunk: Array, page_row: Array, start: Array,
                            chunk_len: Array,
                            scale: float | None = None) -> Array:
    """Attention of one prefill chunk over the slot's cached prefix.

    q: (1, Hq, Tc, d) post-RoPE queries at absolute positions
    ``start + [0, Tc)``; k_chunk/v_chunk: (1, Hkv, Tc, d) the chunk's own
    fp keys/values (real tokens = first ``chunk_len``). ``page_row``: the
    slot's (N,) table row; ``start`` must be page-aligned, so the cached
    prefix ``[0, start)`` is fully flushed into pages (no residual term).

    Scores over the prefix go through the codec score path (the polar
    angle LUT) against the *encoded* page bytes — the same numeric path
    decode uses — while within-chunk attention is fp causal. Both the
    shared-prefix and the from-scratch chunked prefill run this exact
    function, which is what makes prefix reuse bit-identical to the
    unshared chunked baseline (DESIGN.md §12).
    """
    cfg = cache.cfg
    codec = cache.codec
    lay = cache.layout
    _, hq, tc, d = q.shape
    hkv = cache.num_kv_heads
    qpk = hq // hkv
    n = page_row.shape[0]
    g = lay.page_size
    t_cap = n * g
    scale = scale if scale is not None else d ** -0.5
    start = jnp.asarray(start, jnp.int32)
    chunk_len = jnp.asarray(chunk_len, jnp.int32)
    pvalid = (page_row >= 0) & (page_row < lay.num_pages)

    def gat(pool):  # (PP, H, a, b) -> (1, H, N, a, b), invalid pages zeroed
        x = pool[page_row]
        x = jnp.where(pvalid[:, None, None, None], x, jnp.zeros((), x.dtype))
        return x.transpose(1, 0, 2, 3)[None]

    def flat(x):  # (1, H, N, g, ·) -> (1, H, N*g, ·)
        return x.reshape(1, hkv, t_cap, x.shape[-1])

    q4 = (q.astype(jnp.float32) * scale).reshape(1, hkv, qpk, tc, d)

    # --- prefix scores: codec score path over the gathered page bytes,
    # chunk queries folded onto the query-head axis ---
    key_codes = gat(cache.key_codes)
    key_scales = {kk: gat(vv) for kk, vv in cache.key_scales.items()}
    if not cache.grouped:
        key_codes = flat(key_codes)
        key_scales = {kk: flat(vv) for kk, vv in key_scales.items()}
    qf = q4.reshape(1, hkv, qpk * tc, d)
    s_prefix = codec.scores(cfg, qf, key_codes, key_scales)
    s_prefix = s_prefix.reshape(1, hkv, qpk, tc, t_cap)
    pos = jnp.arange(t_cap, dtype=jnp.int32)
    s_prefix = jnp.where((pos < start)[None, None, None, None, :],
                         s_prefix, kvc.NEG_INF)

    # --- within-chunk fp causal scores ---
    kf = k_chunk.astype(jnp.float32)                       # (1, Hkv, Tc, d)
    s_chunk = jnp.einsum("bhqtd,bhsd->bhqts", q4, kf)
    i = jnp.arange(tc, dtype=jnp.int32)
    cmask = (i[:, None] >= i[None, :]) & (i[None, :] < chunk_len)
    s_chunk = jnp.where(cmask[None, None, None], s_chunk, kvc.NEG_INF)

    probs = jax.nn.softmax(
        jnp.concatenate([s_prefix, s_chunk], axis=-1), axis=-1)

    # --- values: dequantized prefix rows + the chunk's own fp rows ---
    if cfg.value_bits > 0:
        v_tilde = qz.decode_values(qz.QuantizedValues(
            codes=flat(gat(cache.value_codes)),
            scale=flat(gat(cache.value_scale)),
            zero=flat(gat(cache.value_zero)), bits=cfg.value_bits))
    else:
        v_tilde = flat(gat(cache.value_fp)).astype(jnp.float32)
    v_all = jnp.concatenate([v_tilde, v_chunk.astype(jnp.float32)], axis=2)
    out = jnp.einsum("bhqts,bhsd->bhqtd", probs, v_all)
    return out.reshape(1, hq, tc, d).astype(q.dtype)


# Prefill backends over a paged cache. "jnp" is the reference formulation
# (full-pool gather + dense softmax above); the rest run page-native where
# the codec supports it ("paged_fused" picks the platform-resolved mode —
# the Pallas kernel on TPU, the jitted jnp oracle elsewhere; "ref"/
# "interpret"/"pallas" select the kernel execution mode explicitly).
PREFILL_BACKENDS = ("jnp", "paged_fused", "ref", "interpret", "pallas")


def paged_prefill_attention(cache: PagedKVCache, q: Array, k_chunk: Array,
                            v_chunk: Array, page_row: Array, start: Array,
                            chunk_len: Array, scale: float | None = None,
                            backend: str = "jnp") -> Array:
    """Backend-dispatched chunk-prefill attention (the prefill twin of
    :func:`paged_decode_attention`).

    ``backend`` (see :data:`PREFILL_BACKENDS`):

    * ``"jnp"`` — :func:`chunk_prefill_attention`: gather the page pool
      (O(capacity)) and run the codec score path densely (the reference).
    * ``"paged_fused"`` | ``"ref"`` | ``"interpret"`` | ``"pallas"`` —
      page-native: the codec's ``paged_prefill`` walks the table row and
      scores the quantized prefix pages in place with one fused online
      softmax over prefix + chunk (``paged_fused`` resolves to the Pallas
      kernel on TPU and the jitted jnp oracle elsewhere; the others pick
      the kernel execution mode). Codecs without the capability fall back
      to the jnp reference automatically, so mixed per-layer policies take
      the fast path segment by segment.

    ``page_row`` may be width-sliced to the pages covering
    ``start + chunk_len`` (the engines bucket it), shrinking the per-chunk
    read volume from O(capacity) to O(live prefix).
    """
    if backend not in PREFILL_BACKENDS:
        raise ValueError(f"unknown paged prefill backend {backend!r}; "
                         f"expected one of {PREFILL_BACKENDS}")
    if backend == "jnp" or not cache.codec.supports_paged_prefill:
        return chunk_prefill_attention(cache, q, k_chunk, v_chunk, page_row,
                                       start, chunk_len, scale=scale)
    if backend == "paged_fused":
        # platform-resolved execution mode, matching paged_decode_attention
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    return cache.codec.paged_prefill(cache, q, k_chunk, v_chunk, page_row,
                                     start, chunk_len, scale=scale,
                                     backend=backend)


# ---------------------------------------------------------------------------
# Copy-on-write page copy (device half of PageAllocator.cow)
# ---------------------------------------------------------------------------


def copy_pool_pages(cache: PagedKVCache, src: Array, dst: Array
                    ) -> PagedKVCache:
    """Copy whole pool pages ``src`` -> ``dst`` (scalar ids) in every
    page-indexed buffer — the device half of a COW split.

    Works on both a bare cache and a per-segment *stacked* cache (leading
    layer axis): pool buffers are ``(..., PP, H, a, b)`` so the page axis
    is located from the right. Slot-indexed state (``key_residual``,
    ``lengths``) is untouched — COW only duplicates pool bytes.
    """
    def cp(buf):
        if buf is None:
            return None
        b0 = jnp.moveaxis(buf, buf.ndim - 4, 0)
        b0 = b0.at[dst].set(b0[src])
        return jnp.moveaxis(b0, 0, buf.ndim - 4)

    return dataclasses.replace(
        cache,
        key_codes=cp(cache.key_codes),
        key_scales={kk: cp(vv) for kk, vv in cache.key_scales.items()},
        value_codes=cp(cache.value_codes),
        value_scale=cp(cache.value_scale),
        value_zero=cp(cache.value_zero),
        value_fp=cp(cache.value_fp),
    )


def pool_page_bytes(cache: PagedKVCache) -> int:
    """Physical bytes one pool page occupies across this (possibly
    stacked) cache's page-indexed buffers — the unit of the shared-prefix
    memory win (one adopted page saves this many bytes)."""
    total = 0
    for buf in (cache.key_codes, *cache.key_scales.values(),
                cache.value_codes, cache.value_scale, cache.value_zero,
                cache.value_fp):
        if buf is not None:
            pp = buf.shape[buf.ndim - 4]
            total += buf.size * buf.dtype.itemsize // pp
    return total


# ---------------------------------------------------------------------------
# Append (batched decode step over all slots)
# ---------------------------------------------------------------------------


def paged_append(cache: PagedKVCache, k_new: Array, v_new: Array,
                 page_table: Array, active: Array) -> PagedKVCache:
    """Append one token per *active* slot. k_new/v_new: (S, Hkv, 1, d)
    post-RoPE; page_table: (S, N) int32; active: (S,) bool.

    Inactive slots write to the scratch page / keep their state; lengths
    advance only where active. Unlike the dense cache's ``lax.cond`` flush
    (one shared position), every slot sits at its own position, so the
    group encode runs every step and the flush is realized as a masked
    scatter target.

    Scan-carry invariant (run-ahead decode, DESIGN.md §18): this
    function is pure in the ``cache`` carry — the residual fp buffer,
    the masked flush target, and ``lengths`` are ordinary arrays with no
    host-side state — so it may be iterated inside ``jax.lax.scan``
    (``models.transformer.decode_runahead_fn``) and quant-group
    boundary commits mid-scan behave exactly as they do across separate
    dispatches. Nothing here may grow host-side caches or data-dependent
    Python control flow without breaking that path.
    """
    cfg = cache.cfg
    codec = cache.codec
    lay = cache.layout
    s, h, _, d = k_new.shape
    g = lay.page_size
    scratch = lay.scratch_page
    pos = cache.lengths                       # (S,)
    # clamp to the table width: the engines may pass a width-sliced table
    # covering only the live pages; inactive slots whose stale position
    # exceeds it are redirected to scratch below anyway
    gidx = jnp.minimum(pos // g, page_table.shape[1] - 1)
    page = jnp.take_along_axis(page_table, gidx[:, None], axis=1)[:, 0]
    page = jnp.where(active, page, scratch)   # (S,)
    row = pos % g                             # (S,)
    sid = jnp.arange(s)
    updates: dict[str, Any] = {}

    # --- values (token-major page rows) ---
    if cfg.value_bits > 0:
        qv = qz.encode_values(v_new, cfg.value_bits, cfg.scale_dtype)
        updates["value_codes"] = _scatter_rows(
            cache.value_codes, page, row, qv.codes[:, :, 0])
        updates["value_scale"] = _scatter_rows(
            cache.value_scale, page, row, qv.scale[:, :, 0])
        updates["value_zero"] = _scatter_rows(
            cache.value_zero, page, row, qv.zero[:, :, 0])
    else:
        updates["value_fp"] = _scatter_rows(
            cache.value_fp, page, row, v_new[:, :, 0])

    # --- keys ---
    if not codec.grouped:
        codes, scales = codec.encode(cfg, k_new)
        updates["key_codes"] = _scatter_rows(
            cache.key_codes, page, row, codes[:, :, 0])
        updates["key_scales"] = {
            key: _scatter_rows(cache.key_scales[key], page, row,
                               scales[key][:, :, 0])
            for key in cache.key_scales}
    else:
        written = cache.key_residual.at[sid, :, row].set(
            k_new[:, :, 0].astype(cache.key_residual.dtype))
        residual = jnp.where(active[:, None, None, None], written,
                             cache.key_residual)
        flush = active & (row == g - 1)
        codes, scales = codec.encode(cfg, residual)   # (S,H,1,g,·)
        fpage = jnp.where(flush, page, scratch)
        updates["key_codes"] = _scatter_pages(
            cache.key_codes, fpage, codes[:, :, 0])
        updates["key_scales"] = {
            key: _scatter_pages(cache.key_scales[key], fpage,
                                scales[key][:, :, 0])
            for key in cache.key_scales}
        updates["key_residual"] = residual

    lengths = pos + active.astype(jnp.int32)
    return dataclasses.replace(cache, lengths=lengths, **updates)


def paged_append_span(cache: PagedKVCache, k_span: Array, v_span: Array,
                      page_table: Array, n_keep: Array) -> PagedKVCache:
    """Append the first ``n_keep[s]`` span tokens per slot in ONE shot.

    k_span/v_span: (S, Hkv, Q, d) post-RoPE (the speculative verifier's
    collected span kv); ``n_keep``: (S,) int32 tokens to commit (0 = slot
    untouched). Bit-identical — outside the never-read scratch page — to
    ``n_keep`` sequential masked :func:`paged_append` calls PROVIDED the
    kept rows stay inside the slot's current group
    (``n_keep <= g - lengths % g``; the engine's span clamp guarantees
    it): the multi-row residual/value writes leave exactly the bytes the
    sequential appends would, and the at-most-one group flush (kept row
    ``g-1``, necessarily the last) encodes exactly the residual state a
    sequential flush would see at that moment. One codec encode per layer
    instead of Q — the reason the spec step's commit is ~flat in Q.
    """
    cfg = cache.cfg
    codec = cache.codec
    lay = cache.layout
    s, h, qn, d = k_span.shape
    g = lay.page_size
    scratch = lay.scratch_page
    pos = cache.lengths                            # (S,)
    r0 = pos % g
    keep = jnp.arange(qn, dtype=jnp.int32)[None, :] < n_keep[:, None]
    gidx = jnp.minimum(pos // g, page_table.shape[1] - 1)
    page = jnp.take_along_axis(page_table, gidx[:, None], axis=1)[:, 0]
    page = jnp.where(n_keep > 0, page, scratch)    # (S,)
    sid = jnp.arange(s)
    updates: dict[str, Any] = {}

    # token-major page rows: kept rows land at (page, r0+j), rejected /
    # inactive rows are redirected to the scratch page
    rows = jnp.minimum(r0[:, None] + jnp.arange(qn)[None, :], g - 1)
    pages_j = jnp.where(keep, page[:, None], scratch)
    pf = pages_j.reshape(s * qn)
    rf = rows.reshape(s * qn)

    def sc_rows(pool, upd):  # upd (S, H, Q, b) -> scatter S*Q page rows
        u = upd.transpose(0, 2, 1, 3).reshape(s * qn, h, upd.shape[-1])
        return _scatter_rows(pool, pf, rf, u)

    # --- values ---
    if cfg.value_bits > 0:
        qv = qz.encode_values(v_span, cfg.value_bits, cfg.scale_dtype)
        updates["value_codes"] = sc_rows(cache.value_codes, qv.codes)
        updates["value_scale"] = sc_rows(cache.value_scale, qv.scale)
        updates["value_zero"] = sc_rows(cache.value_zero, qv.zero)
    else:
        updates["value_fp"] = sc_rows(cache.value_fp, v_span)

    # --- keys ---
    if not codec.grouped:
        codes, scales = codec.encode(cfg, k_span)
        updates["key_codes"] = sc_rows(cache.key_codes, codes)
        updates["key_scales"] = {
            key: sc_rows(cache.key_scales[key], scales[key])
            for key in cache.key_scales}
    else:
        # masked multi-row residual write: rejected rows go to a discard
        # zone past the real buffer (the double-width trick)
        res = cache.key_residual
        ext = jnp.concatenate([res, jnp.zeros_like(res)], axis=2)
        extT = ext.transpose(0, 2, 1, 3)           # (S, 2g, H, d)
        wrows = jnp.where(keep, rows, 2 * g - 1)
        extT = extT.at[sid[:, None], wrows].set(
            k_span.transpose(0, 2, 1, 3).astype(res.dtype))
        residual = extT[:, :g].transpose(0, 2, 1, 3)
        flush = (n_keep > 0) & (r0 + n_keep == g)

        # the group-boundary flush is rare (at most once per g committed
        # tokens per slot): gate the codec encode + page scatters behind
        # it so the steady-state commit is just the residual/value writes
        def _do_flush(pools):
            codes_p, scales_p = pools
            fcodes, fscales = codec.encode(cfg, residual)  # (S,H,1,g,·)
            fpage = jnp.where(flush, page, scratch)
            return (_scatter_pages(codes_p, fpage, fcodes[:, :, 0]),
                    {key: _scatter_pages(scales_p[key], fpage,
                                         fscales[key][:, :, 0])
                     for key in scales_p})

        updates["key_codes"], updates["key_scales"] = jax.lax.cond(
            jnp.any(flush), _do_flush, lambda pools: pools,
            (cache.key_codes, cache.key_scales))
        updates["key_residual"] = residual

    return dataclasses.replace(cache, lengths=pos + n_keep, **updates)


# ---------------------------------------------------------------------------
# Gathered dense view + decode attention
# ---------------------------------------------------------------------------


def gather_view(cache: PagedKVCache, page_table: Array) -> kvc.KVCache:
    """Materialize per-slot dense cache views from the page table.

    Returns a :class:`KVCache` with batch == slots, ``length`` (S,) —
    consumable by ``kv_cache.decode_attention`` (batched masks) and
    ``kv_cache.fused_decode_attention`` (per-slot kernel lengths).
    Unassigned table entries (pointing at the scratch page, or out of
    pool range) are masked at *page* granularity: their gathered pages
    are zeroed before any scoring, so stale masked-write garbage on the
    scratch page can never leak through a zero-probability lane
    (``0 * NaN``) — length masking downstream stays a correctness
    guarantee, not the only line of defense.
    """
    cfg = cache.cfg
    lay = cache.layout
    s, n = page_table.shape
    g = lay.page_size
    t_cap = n * g
    key_residual = None
    # (S, N) page-validity mask: real pool pages only
    pvalid = (page_table >= 0) & (page_table < lay.num_pages)

    def masked(x):  # zero gathered pages of unassigned table entries
        gathered = _gather_pages(x, page_table)        # (S, H, N, a, b)
        return jnp.where(pvalid[:, None, :, None, None], gathered,
                         jnp.zeros((), x.dtype))

    def flat_tokens(x):  # (S, H, N, g, ·) -> (S, H, N*g, ·)
        return x.reshape(x.shape[0], x.shape[1], t_cap, x.shape[-1])

    if cache.grouped:
        key_codes = masked(cache.key_codes)
        key_scales = {k: masked(v) for k, v in cache.key_scales.items()}
        key_residual = cache.key_residual
    else:
        key_codes = flat_tokens(masked(cache.key_codes))
        key_scales = {k: flat_tokens(masked(v))
                      for k, v in cache.key_scales.items()}

    value_codes = value_scale = value_zero = value_fp = None
    if cfg.value_bits > 0:
        value_codes = flat_tokens(masked(cache.value_codes))
        value_scale = flat_tokens(masked(cache.value_scale))
        value_zero = flat_tokens(masked(cache.value_zero))
    else:
        value_fp = flat_tokens(masked(cache.value_fp))

    return kvc.KVCache(key_codes=key_codes, key_scales=key_scales,
                       key_residual=key_residual,
                       value_codes=value_codes, value_scale=value_scale,
                       value_zero=value_zero, value_fp=value_fp,
                       length=cache.lengths, cfg=cfg, max_len=t_cap,
                       layout=LinearLayout(t_cap))


# Decode backends over a paged cache. "jnp" and "gathered" are the
# reference formulations (dense per-slot copy via gather_view); the rest
# run page-native where the codec supports it ("paged_fused" picks the
# pure-jnp page walk — the fast jitted path on CPU; "ref"/"interpret"/
# "pallas" select the kernel execution mode explicitly).
PAGED_BACKENDS = ("jnp", "gathered", "paged_fused", "ref", "interpret",
                  "pallas")


def gathered_decode_attention(cache: PagedKVCache, q: Array,
                              page_table: Array, *,
                              scale: float | None = None,
                              backend: str = "jnp") -> Array:
    """Reference/fallback decode path: materialize the dense per-slot view
    (O(capacity) HBM copy) and reuse the dense decode machinery.

    This is the pre-page-native formulation — kept as the parity oracle
    for the page-walking kernel and as the fallback for codecs without a
    page-native ``paged_decode``.
    """
    view = gather_view(cache, page_table)
    if backend == "jnp" or not cache.codec.supports_fused_decode:
        return kvc.decode_attention(view, q, scale=scale)
    return kvc.fused_decode_attention(view, q, scale=scale, backend=backend)


def paged_decode_attention(cache: PagedKVCache, q: Array, page_table: Array,
                           scale: float | None = None,
                           backend: str = "jnp") -> Array:
    """Single-step attention of q (S, Hq, d) over all slots' pages.

    ``backend`` (see :data:`PAGED_BACKENDS`):

    * ``"jnp"`` — gathered dense view + pure-jnp masked softmax (the
      reference path).
    * ``"gathered"`` — gathered dense view + the dense fused kernel
      (the PR-2 hot path, kept for A/B benchmarking).
    * ``"paged_fused"`` | ``"ref"`` | ``"interpret"`` | ``"pallas"`` —
      page-native: the codec's ``paged_decode`` walks the page table and
      reads quantized pages in place (``paged_fused`` resolves to the
      jitted pure-jnp page walk; the others pick the kernel execution
      mode). Codecs without the capability fall back to the gathered
      reference automatically, so mixed per-layer policies take the fast
      path segment by segment.

    ``page_table`` may be width-sliced to the live pages (the engines
    bucket it), shrinking the per-step read volume from O(capacity) to
    O(live tokens).
    """
    if backend not in PAGED_BACKENDS:
        raise ValueError(f"unknown paged decode backend {backend!r}; "
                         f"expected one of {PAGED_BACKENDS}")
    # platform-resolved execution mode for the dispatch names: the real
    # Pallas kernels on TPU, the jitted jnp oracles elsewhere (interpret
    # mode is far slower than the oracle on CPU and exists for kernel-body
    # CI coverage) — both arms resolve the same way so A/B stays fair
    resolved = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend in ("jnp", "gathered"):
        kb = "jnp" if backend == "jnp" else resolved
        return gathered_decode_attention(cache, q, page_table, scale=scale,
                                         backend=kb)
    if backend == "paged_fused":
        backend = resolved
    return cache.codec.paged_decode(cache, q, page_table, scale=scale,
                                    backend=backend)


def span_verify_attention(cache: PagedKVCache, q: Array, k_span: Array,
                          v_span: Array, page_table: Array,
                          scale: float | None = None) -> Array:
    """Speculative-span attention: Q draft positions per slot in ONE
    dispatch, reproducing the sequential decode view bit-for-bit.

    q: (S, Hq, Q, d) post-RoPE queries at absolute positions
    ``lengths + [0, Q)``; k_span/v_span: (S, Hkv, Q, d) the span's own
    post-RoPE fp keys/values. The cache is NOT mutated — the engine
    commits accepted positions afterwards (:func:`paged_append_span`).

    Sequential decode at span position j appends its own kv first, then
    attends over (grouped codecs):

    * groups ``[0, flushed)`` — codec scores over the page pool;
    * residual rows ``[flushed, L+j+1)`` — fp scores against the rolling
      residual, span keys ROUNDED to ``cfg.residual_dtype`` by the append;
    * if row ``(L+j) % g == g-1``, the append flushed the current group:
      position j scores it through the codec instead.

    All three are emulated against the *original* cache: span keys are
    written (rounded) into a copy of the residual, span values into the
    gathered value view, and the possible boundary flush is reproduced by
    encoding the final residual buffer once — the same bytes a sequential
    flush would encode, because callers guarantee the span never extends
    past the slot's current group (``span <= g - lengths % g``; the
    engine clamps drafts), so at most the LAST span position crosses.
    Token-wise codecs need no residual/flush emulation: span keys are
    encoded per row and scattered into the gathered code view. Positions
    past a slot's real span (the batch pads to a shared Q) produce
    don't-care outputs, finite by construction.
    """
    cfg = cache.cfg
    codec = cache.codec
    lay = cache.layout
    s, hq, qn, d = q.shape
    hkv = cache.num_kv_heads
    qpk = hq // hkv
    g = lay.page_size
    t_cap = page_table.shape[1] * g
    scale = scale if scale is not None else d ** -0.5
    lengths = cache.lengths                        # (S,)
    flushed0 = (lengths // g) * g
    sid = jnp.arange(s)
    pvalid = (page_table >= 0) & (page_table < lay.num_pages)

    def masked(x):  # (PP, H, a, b) -> (S, H, N, a, b), invalid pages zeroed
        gathered = _gather_pages(x, page_table)
        return jnp.where(pvalid[:, None, :, None, None], gathered,
                         jnp.zeros((), x.dtype))

    def flat(x):    # (S, H, N, g, ·) -> (S, H, N*g, ·)
        return x.reshape(s, hkv, t_cap, x.shape[-1])

    def sc_span(view, upd, tpos):  # scatter span rows into a (S,H,T,·) view
        vT = view.transpose(0, 2, 1, 3)
        vT = vT.at[sid[:, None], tpos].set(
            upd.transpose(0, 2, 1, 3).astype(view.dtype), mode="drop")
        return vT.transpose(0, 2, 1, 3)

    # fold span positions onto the query-head axis, exactly like the
    # decode path folds GQA heads: scores/probs rows stay (·, t_cap)
    q4 = (q.astype(jnp.float32) * scale).reshape(s, hkv, qpk, qn, d)
    qf = q4.reshape(s, hkv, qpk * qn, d)
    pos = jnp.arange(t_cap, dtype=jnp.int32)[None, None, :]
    vl = (lengths[:, None] + 1
          + jnp.arange(qn, dtype=jnp.int32)[None, :])[:, :, None]  # (S,Q,1)
    tpos = jnp.minimum(lengths[:, None] + jnp.arange(qn)[None, :],
                       t_cap)                       # (S, Q); == t_cap drops

    def bc(m):  # (S, Q, T) -> broadcast against (S, Hkv, qpk, Q, T)
        return m[:, None, None]

    if cache.grouped:
        # final residual: span keys rounded+written at rows r0..r0+Q-1
        # (overflow rows land in a discard zone — the double-width trick)
        res = cache.key_residual
        ext = jnp.concatenate([res, jnp.zeros_like(res)], axis=2)
        extT = ext.transpose(0, 2, 1, 3)            # (S, 2g, H, d)
        rows = jnp.minimum((lengths % g)[:, None] + jnp.arange(qn)[None, :],
                           2 * g - 1)
        extT = extT.at[sid[:, None], rows].set(
            k_span.transpose(0, 2, 1, 3).astype(res.dtype))
        res_new = extT[:, :g].transpose(0, 2, 1, 3)  # (S, Hkv, g, d)

        s_pages = codec.scores(cfg, qf, masked(cache.key_codes),
                               {kk: masked(vv)
                                for kk, vv in cache.key_scales.items()})
        # the flush emulation (encode + LUT scores over the completed
        # group) only matters when some span position can fill its slot's
        # current group (m_flush below is all-False otherwise, which the
        # engine's span clamp makes the common case) — skip the encode
        # entirely on the other steps instead of scoring dead work
        def _flush_scores(r):
            fc, fs = codec.encode(cfg, r)                # (S, H, 1, g, ·)
            return codec.scores(cfg, qf, fc, fs)          # (·, g)

        proto = jax.eval_shape(_flush_scores, res_new)
        s_flush = jax.lax.cond(
            jnp.any((lengths % g) + qn >= g), _flush_scores,
            lambda r: jnp.zeros(proto.shape, proto.dtype), res_new)
        s_res = jnp.einsum("bhqd,bhgd->bhqg", qf,
                           res_new.astype(jnp.float32))   # (·, g)

        reps = t_cap // g
        s5 = lambda x: x.reshape(s, hkv, qpk, qn, -1)  # noqa: E731
        s_pages = s5(s_pages)
        s_flush = s5(jnp.tile(s_flush, (1, 1, 1, reps)))
        s_res = s5(jnp.tile(s_res, (1, 1, 1, reps)))

        base = flushed0[:, None, None]                   # (S, 1, 1)
        m_pages = pos < base
        m_flush = (pos >= base) & (pos < base + g) & (vl >= base + g)
        m_res = (pos >= base) & (pos < vl) & (vl < base + g)
        scores = jnp.where(bc(m_res), s_res,
                           jnp.where(bc(m_flush), s_flush,
                                     jnp.where(bc(m_pages), s_pages,
                                               kvc.NEG_INF)))
    else:
        # token-wise: encode span keys per row into the gathered view
        kc = flat(masked(cache.key_codes))
        ks = {kk: flat(masked(vv)) for kk, vv in cache.key_scales.items()}
        codes, scales = codec.encode(cfg, k_span)
        kc = sc_span(kc, codes, tpos)
        ks = {kk: sc_span(ks[kk], scales[kk], tpos) for kk in ks}
        scores = codec.scores(cfg, qf, kc, ks).reshape(
            s, hkv, qpk, qn, t_cap)
        scores = jnp.where(bc(pos < vl), scores, kvc.NEG_INF)

    probs = jax.nn.softmax(scores, axis=-1)

    # --- values: dequantized page rows + the span's own rows, written
    # through the same encode/rounding the append would apply ---
    if cfg.value_bits > 0:
        qv = qz.encode_values(v_span, cfg.value_bits, cfg.scale_dtype)
        v_tilde = qz.decode_values(qz.QuantizedValues(
            codes=sc_span(flat(masked(cache.value_codes)), qv.codes, tpos),
            scale=sc_span(flat(masked(cache.value_scale)), qv.scale, tpos),
            zero=sc_span(flat(masked(cache.value_zero)), qv.zero, tpos),
            bits=cfg.value_bits))
    else:
        v_tilde = flat(masked(cache.value_fp))
        v_tilde = sc_span(v_tilde, v_span, tpos).astype(jnp.float32)

    out = jnp.einsum("bhqt,bhtd->bhqd",
                     probs.reshape(s, hkv, qpk * qn, t_cap), v_tilde)
    return out.reshape(s, hq, qn, d).astype(q.dtype)
