"""Chunked online-softmax (flash) attention with a custom VJP.

Pure-JAX flash attention used by every model for training and prefill:
O(T) memory (only ``(out, lse)`` saved for backward; scores recomputed per
chunk in the backward scan). Supports GQA natively, and the mask modes the
model zoo needs:

* ``causal``       — autoregressive LM
* ``full``         — encoder / cross-attention
* ``prefix``       — prefix-LM (PaliGemma): bidirectional over the first
                     ``prefix_len`` positions, causal after
* ``local``        — sliding-window causal (RecurrentGemma local attention)

`q`: (B, H, Tq, d); `k`, `v`: (B, Hkv, Tk, d); H % Hkv == 0.
Scores computed in fp32; output cast back to q.dtype.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import ctx

Array = jax.Array
NEG_INF = -1e30

# Sharding boundary discipline: the fwd output and the bwd cotangents are
# pinned to head-sharding so a sequence-sharded residual stream reshards
# ONCE per layer at the attention boundary — without this, the seq<->head
# conflict propagates INTO the k-chunk scan and XLA inserts a full
# rematerialization copy per chunk iteration (measured at 47% of dbrx
# train_4k collective bytes; see EXPERIMENTS.md §Perf).
_HEADS = ("batch", "heads", None, None)
_KV_HEADS = ("batch", "kv_heads", None, None)


def _mask_bias(mode: str, window: int, q_idx: Array, k_idx: Array,
               prefix_len: Optional[Array], kv_len: int) -> Array:
    """Boolean validity -> additive bias. q_idx: (Tq,), k_idx: (ck,).

    Returns (B?, Tq, ck) bias; prefix mode adds a batch dim via prefix_len.
    """
    qi = q_idx[:, None]
    ki = k_idx[None, :]
    valid = ki < kv_len  # padding chunks
    if mode == "causal":
        valid = valid & (qi >= ki)
    elif mode == "local":
        valid = valid & (qi >= ki) & (qi - ki < window)
    elif mode == "prefix":
        causal = qi >= ki
        if prefix_len is None:
            raise ValueError("prefix mask requires prefix_len")
        bidir = ki < prefix_len[:, None, None]  # (B,1,1)
        valid = valid & (causal | bidir)
    elif mode == "full":
        pass
    else:
        raise ValueError(f"unknown mask mode {mode!r}")
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


def _chunk_kv(x: Array, chunk: int) -> tuple[Array, int]:
    """(B,H,Tk,d) -> (nc, B, H, ck, d), padding Tk up to a chunk multiple."""
    b, h, t, d = x.shape
    nc = -(-t // chunk)
    pad = nc * chunk - t
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return x.reshape(b, h, nc, chunk, d).transpose(2, 0, 1, 3, 4), t


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _flash(mode: str, window: int, scale: float, chunk: int,
           q: Array, k: Array, v: Array, prefix_len: Optional[Array]) -> Array:
    out, _ = _flash_fwd_impl(mode, window, scale, chunk, q, k, v, prefix_len)
    return out


def _flash_fwd_impl(mode, window, scale, chunk, q, k, v, prefix_len):
    b, h, tq, d = q.shape
    hkv = k.shape[1]
    qpk = h // hkv
    q5 = (q.astype(jnp.float32) * scale).reshape(b, hkv, qpk, tq, d)
    kc, tk = _chunk_kv(k.astype(jnp.float32), chunk)
    vc, _ = _chunk_kv(v.astype(jnp.float32), chunk)
    q_idx = jnp.arange(tq, dtype=jnp.int32)

    def body(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp
        k_idx = j * chunk + jnp.arange(chunk, dtype=jnp.int32)
        bias = _mask_bias(mode, window, q_idx, k_idx, prefix_len, tk)
        if bias.ndim == 3:  # (B,Tq,ck) -> (B,1,1,Tq,ck)
            bias = bias[:, None, None]
        s = jnp.einsum("bhqtd,bhcd->bhqtc", q5, kj) + bias      # (B,Hkv,Qh,Tq,ck)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhqtc,bhcd->bhqtd", p, vj)
        return (m_new, l, acc), None

    nc = kc.shape[0]
    init = (jnp.full((b, hkv, qpk, tq), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, qpk, tq), jnp.float32),
            jnp.zeros((b, hkv, qpk, tq, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        body, init, (jnp.arange(nc, dtype=jnp.int32), kc, vc))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l_safe[..., None]).reshape(b, h, tq, d).astype(q.dtype)
    lse = m + jnp.log(l_safe)
    return out, lse


def _flash_fwd(mode, window, scale, chunk, q, k, v, prefix_len):
    out, lse = _flash_fwd_impl(mode, window, scale, chunk, q, k, v, prefix_len)
    return out, (q, k, v, prefix_len, out, lse)


def _flash_bwd(mode, window, scale, chunk, res, dout):
    q, k, v, prefix_len, out, lse = res
    dout = ctx.shard(dout, _HEADS)
    b, h, tq, d = q.shape
    hkv = k.shape[1]
    qpk = h // hkv
    q5 = q.astype(jnp.float32).reshape(b, hkv, qpk, tq, d)
    do5 = dout.astype(jnp.float32).reshape(b, hkv, qpk, tq, d)
    o5 = out.astype(jnp.float32).reshape(b, hkv, qpk, tq, d)
    delta = jnp.sum(do5 * o5, axis=-1)                           # (B,Hkv,Qh,Tq)
    kc, tk = _chunk_kv(k.astype(jnp.float32), chunk)
    vc, _ = _chunk_kv(v.astype(jnp.float32), chunk)
    q_idx = jnp.arange(tq, dtype=jnp.int32)

    def body(dq, inp):
        j, kj, vj = inp
        k_idx = j * chunk + jnp.arange(chunk, dtype=jnp.int32)
        bias = _mask_bias(mode, window, q_idx, k_idx, prefix_len, tk)
        if bias.ndim == 3:
            bias = bias[:, None, None]
        s = jnp.einsum("bhqtd,bhcd->bhqtc", q5 * scale, kj) + bias
        p = jnp.exp(s - lse[..., None])                          # (B,Hkv,Qh,Tq,ck)
        dv_j = jnp.einsum("bhqtc,bhqtd->bhcd", p, do5)
        dp = jnp.einsum("bhqtd,bhcd->bhqtc", do5, vj)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bhqtc,bhcd->bhqtd", ds, kj)
        dk_j = jnp.einsum("bhqtc,bhqtd->bhcd", ds, q5)
        return dq, (dk_j, dv_j)

    nc = kc.shape[0]
    dq5 = jnp.zeros((b, hkv, qpk, tq, d), jnp.float32)
    dq5, (dkc, dvc) = jax.lax.scan(
        body, dq5, (jnp.arange(nc, dtype=jnp.int32), kc, vc))
    dk = dkc.transpose(1, 2, 0, 3, 4).reshape(b, hkv, nc * chunk, d)[:, :, :tk]
    dv = dvc.transpose(1, 2, 0, 3, 4).reshape(b, hkv, nc * chunk, d)[:, :, :tk]
    dq = ctx.shard(dq5.reshape(b, h, tq, d).astype(q.dtype), _HEADS)
    dk = ctx.shard(dk.astype(k.dtype), _KV_HEADS)
    dv = ctx.shard(dv.astype(v.dtype), _KV_HEADS)
    return dq, dk, dv, None


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: Array, k: Array, v: Array, *, mode: str = "causal", window: int = 0,
    scale: float | None = None, chunk: int = 512,
    prefix_len: Optional[Array] = None,
) -> Array:
    """Memory-efficient attention. See module docstring for shapes/modes."""
    d = q.shape[-1]
    scale = d ** -0.5 if scale is None else scale
    chunk = min(chunk, k.shape[2]) if k.shape[2] > 0 else chunk
    out = _flash(mode, window, scale, chunk, q, k, v, prefix_len)
    return ctx.shard(out, _HEADS)


def reference_attention(
    q: Array, k: Array, v: Array, *, mode: str = "causal", window: int = 0,
    scale: float | None = None, prefix_len: Optional[Array] = None,
) -> Array:
    """O(T^2)-memory oracle used by tests."""
    b, h, tq, d = q.shape
    hkv = k.shape[1]
    qpk = h // hkv
    scale = d ** -0.5 if scale is None else scale
    q5 = (q.astype(jnp.float32) * scale).reshape(b, hkv, qpk, tq, d)
    s = jnp.einsum("bhqtd,bhcd->bhqtc", q5, k.astype(jnp.float32))
    bias = _mask_bias(mode, window, jnp.arange(tq), jnp.arange(k.shape[2]),
                      prefix_len, k.shape[2])
    if bias.ndim == 3:
        bias = bias[:, None, None]
    p = jax.nn.softmax(s + bias, axis=-1)
    out = jnp.einsum("bhqtc,bhcd->bhqtd", p, v.astype(jnp.float32))
    return out.reshape(b, h, tq, d).astype(q.dtype)
