"""Polar transformation of RoPE-paired key dimensions (PolarQuant §3.2).

A post-RoPE key vector ``K ∈ R^d`` is viewed as ``d/2`` two-dimensional
sub-vectors. Each sub-vector is the pair of dimensions rotated together by
one RoPE 2x2 rotary block. Two pairing conventions exist in the wild:

* ``"half"``  — dims ``(j, j + d/2)`` rotate together (llama ``rotate_half``).
* ``"adjacent"`` — dims ``(2j, 2j+1)`` rotate together (the matrix form, Eq. 1).

The paper's analysis (footnote 5) notes both are equivalent for the method;
the pairing here MUST match the RoPE implementation of the model so that
rotation is magnitude-preserving within a pair. Our models use ``"half"``.

The transform maps a pair ``(x, y)`` to polar coordinates:

    rho   = sqrt(x^2 + y^2)
    theta = atan2(y, x) + pi          in (0, 2*pi]

and back via ``x = rho*cos(theta - pi)``... — we keep the ``+pi`` shift
exactly as the paper does and invert it symmetrically, i.e. dequantization
uses ``cos(theta_tilde)`` / ``sin(theta_tilde)`` on the *shifted* angle with
the shift folded into the reconstruction (cos(t - pi) = -cos(t)). To stay
bit-faithful to the paper's appendix code (which uses cos/sin of the shifted
angle directly and absorbs the sign into the quantization grid), we follow
the appendix: theta in (0, 2pi] is quantized as-is and reconstruction uses
cos/sin of theta_tilde *minus pi* — equivalently we store theta' = theta - pi
= atan2(y, x) in (-pi, pi] internally. Both forms are affine-equivalent; the
quantization grid is identical because the zero-point absorbs the shift.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def split_pairs(k: Array, pairing: str = "half") -> tuple[Array, Array]:
    """Split the last dim of ``k`` into the (x, y) components of RoPE pairs.

    Returns two arrays of shape ``(..., d/2)``.
    """
    d = k.shape[-1]
    if d % 2:
        raise ValueError(f"head_dim must be even, got {d}")
    if pairing == "half":
        return k[..., : d // 2], k[..., d // 2 :]
    elif pairing == "adjacent":
        return k[..., 0::2], k[..., 1::2]
    raise ValueError(f"unknown pairing {pairing!r}")


def merge_pairs(x: Array, y: Array, pairing: str = "half") -> Array:
    """Inverse of :func:`split_pairs`."""
    if pairing == "half":
        return jnp.concatenate([x, y], axis=-1)
    elif pairing == "adjacent":
        stacked = jnp.stack([x, y], axis=-1)  # (..., d/2, 2)
        return stacked.reshape(*stacked.shape[:-2], -1)
    raise ValueError(f"unknown pairing {pairing!r}")


def to_polar(k: Array, pairing: str = "half") -> tuple[Array, Array]:
    """Cartesian -> polar. Returns (rho, theta) each of shape (..., d/2).

    theta follows the paper's convention: atan2(y, x) + pi, in (0, 2*pi].
    Computation in fp32 for numerical stability regardless of input dtype.
    """
    x, y = split_pairs(k, pairing)
    x32 = x.astype(jnp.float32)
    y32 = y.astype(jnp.float32)
    # XLA CPU's arctan2 returns NaN for denormal operands — flush them
    # (< smallest normal, i.e. numerically irrelevant for keys) to zero so
    # a stray denormal can't poison quantization stats / attention scores.
    tiny = jnp.float32(1.1754944e-38)
    x32 = jnp.where(jnp.abs(x32) < tiny, 0.0, x32)
    y32 = jnp.where(jnp.abs(y32) < tiny, 0.0, y32)
    rho = jnp.sqrt(x32 * x32 + y32 * y32)
    theta = jnp.arctan2(y32, x32) + jnp.pi
    # zero-radius pairs have an undefined angle — pin to the shifted zero
    theta = jnp.where(rho > 0, theta, jnp.pi)
    return rho, theta


def from_polar(rho: Array, theta: Array, pairing: str = "half",
               dtype: jnp.dtype | None = None) -> Array:
    """Polar -> Cartesian, inverting the ``+pi`` shift of :func:`to_polar`."""
    t = theta.astype(jnp.float32) - jnp.pi
    r = rho.astype(jnp.float32)
    x = r * jnp.cos(t)
    y = r * jnp.sin(t)
    out = merge_pairs(x, y, pairing)
    return out.astype(dtype) if dtype is not None else out


def pair_cos_sin(theta: Array) -> tuple[Array, Array]:
    """cos/sin of a paper-convention (shifted) angle: returns cos(theta - pi),
    sin(theta - pi) — i.e. the direction of the original vector."""
    t = theta.astype(jnp.float32) - jnp.pi
    return jnp.cos(t), jnp.sin(t)
