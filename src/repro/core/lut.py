"""LUT-based query-key scores for PolarQuant decode (paper §3.3 + Appendix A).

Core identity: for a quantized key group, the dequantized key sub-vector at
channel pair ``j`` is ``(rho~ * cos(th~ - pi), rho~ * sin(th~ - pi))`` where
``th~`` comes from a finite set of ``2^t`` per-(group, channel) states and
``rho~`` is *affine* in its code. Hence

    q . K~_n  =  sum_j  rho~_n[j] * A[j, theta_code_n[j]]
    A[j, a]   =  q_x[j] * cos(th~(a)[j] - pi) + q_y[j] * sin(th~(a)[j] - pi)

``A`` is a (d/2, 2^t) table built once per (query, group) — O(d * 2^t) work
amortized over the g tokens of the group. The radius never needs a table
(one fused multiply-add per element). This module is the pure-jnp reference;
``repro/kernels/polar_decode.py`` is the Pallas TPU kernel with the same
semantics (gather realized as a compare/select tree — see DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import polar
from repro.core.quantizers import PolarKeys, decode_polar_keys

Array = jax.Array


def build_angle_table(
    q: Array, theta_scale: Array, theta_zero: Array, theta_bits: int,
    pairing: str = "half",
) -> Array:
    """Per-(group, channel-pair, angle-state) partial dot products.

    Args:
      q: query ``(..., d)`` (post-RoPE), broadcastable against the group dims.
      theta_scale/theta_zero: ``(..., G, 1, P)`` group stats.
      theta_bits: t.

    Returns:
      ``A`` of shape ``(..., G, P, 2**t)`` in fp32.
    """
    qx, qy = polar.split_pairs(q.astype(jnp.float32), pairing)  # (..., P)
    states = jnp.arange(1 << theta_bits, dtype=jnp.float32)      # (S,)
    ts = theta_scale.astype(jnp.float32)[..., 0, :, None]        # (..., G, P, 1)
    tz = theta_zero.astype(jnp.float32)[..., 0, :, None]
    theta_tilde = (states + 0.5) * ts + tz                       # (..., G, P, S)
    cos_t = jnp.cos(theta_tilde - jnp.pi)
    sin_t = jnp.sin(theta_tilde - jnp.pi)
    return qx[..., None, :, None] * cos_t + qy[..., None, :, None] * sin_t


def lut_qk_scores(q: Array, pk: PolarKeys, impl: str = "select") -> Array:
    """q . K~ for every cached token via the angle LUT.

    Args:
      q: ``(..., d)`` single query vector per leading index.
      pk: PolarKeys with arrays ``(..., G, g, P)``.
      impl: ``"select"`` evaluates the LUT as a compare/select tree over the
        2^t angle states (mirrors the Pallas kernel; fuses without
        materializing a (..., g, P, 2^t) gather operand — ~2^t x less HBM
        traffic at the HLO level). ``"gather"`` is the naive
        take_along_axis formulation (kept for A/B, see EXPERIMENTS §Perf).

    Returns:
      scores ``(..., T)`` fp32, T = G*g.
    """
    a_table = build_angle_table(q, pk.theta_scale, pk.theta_zero,
                                pk.theta_bits, pk.pairing)        # (..., G, P, S)
    tcodes = pk.theta_codes().astype(jnp.int32)                   # (..., G, g, P)
    lead = jnp.broadcast_shapes(a_table.shape[:-3], tcodes.shape[:-3])
    gcount, g, p = tcodes.shape[-3:]
    s = a_table.shape[-1]
    if impl == "select":
        gathered = jnp.zeros((*lead, gcount, g, p), jnp.float32)
        for a in range(s):
            gathered = gathered + jnp.where(
                tcodes == a, a_table[..., :, None, :, a], 0.0)
    else:
        a_exp = jnp.broadcast_to(a_table[..., :, None, :, :],
                                 (*lead, gcount, g, p, s))
        tc = jnp.broadcast_to(tcodes[..., None], (*lead, gcount, g, p, 1))
        gathered = jnp.take_along_axis(a_exp, tc, axis=-1)[..., 0]
    rho = (pk.rho_codes().astype(jnp.float32) + 0.5) * \
        pk.rho_scale.astype(jnp.float32) + pk.rho_zero.astype(jnp.float32)
    scores = jnp.sum(rho * gathered, axis=-1)                     # (..., G, g)
    return scores.reshape(*lead, gcount * g)


def dequant_qk_scores(q: Array, pk: PolarKeys) -> Array:
    """Oracle: dequantize-then-matmul (paper's 'conventional approach')."""
    k_tilde = decode_polar_keys(pk)                               # (..., T, d)
    return jnp.einsum("...d,...td->...t", q.astype(jnp.float32), k_tilde)
