"""PolarQuant core: polar transform, quantizers, codec registry, quantized
KV cache, LUT decode."""
from repro.core.quantizers import (  # noqa: F401
    QuantConfig, PolarKeys, ChannelKeys, TokenKeys, ZipKeys, QuantizedValues,
    encode_keys, decode_keys, encode_polar_keys, decode_polar_keys,
    encode_values, decode_values,
)
from repro.core.codecs import (  # noqa: F401
    CachePolicy, CodecKeys, KeyCodec, get_codec, register_codec,
    registered_codecs,
)
from repro.core.kv_cache import (  # noqa: F401
    KVCache, init_cache, append, prefill, decode_attention,
    fused_decode_attention,
)
from repro.core.cache_layout import (  # noqa: F401
    LinearLayout, RingLayout, PagedLayout, PageAllocator, PrefixIndex,
    token_page_hashes,
)
from repro.core.paged_cache import (  # noqa: F401
    PAGED_BACKENDS, PagedKVCache, init_paged_cache, paged_prefill,
    paged_append, chunk_prefill_attention, copy_pool_pages, gather_view,
    gathered_decode_attention, paged_decode_attention, pool_page_bytes,
)
from repro.core.attention import flash_attention, reference_attention  # noqa: F401
from repro.core.lut import lut_qk_scores, dequant_qk_scores, build_angle_table  # noqa: F401
