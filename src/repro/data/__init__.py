"""Data pipeline."""
from repro.data.pipeline import SyntheticLMDataset, DataState  # noqa: F401
