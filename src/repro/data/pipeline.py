"""Deterministic synthetic LM data pipeline.

Produces *learnable* token streams (a mixture of k-order Markov "documents"
with per-document grammars), so loss curves are meaningful for the
end-to-end training example. Fully deterministic in (seed, step): resuming
after a crash replays the exact batch sequence — the trainer checkpoints
only (seed, step). Host-sharded: each process materializes only its slice
of the global batch (process_index-aware), and ``global_batch(step)``
assembles a jax.Array from addressable shards under a mesh.

Modality frontends are stubbed per the assignment: ``frames``/``patches``
are deterministic pseudo-embeddings derived from the same stream.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class DataState:
    seed: int
    step: int

    def to_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d: dict) -> "DataState":
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class SyntheticLMDataset:
    """Markov-mixture token stream. One instance per host process."""

    def __init__(self, cfg: ModelConfig, global_batch: int, seq_len: int,
                 seed: int = 0, num_grammars: int = 16, order: int = 2,
                 process_index: Optional[int] = None,
                 process_count: Optional[int] = None):
        self.cfg = cfg
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.state = DataState(seed=seed, step=0)
        self.process_index = (jax.process_index() if process_index is None
                              else process_index)
        self.process_count = (jax.process_count() if process_count is None
                              else process_count)
        assert global_batch % self.process_count == 0
        self.local_batch = global_batch // self.process_count
        self.vocab = cfg.vocab_size
        self.order = order
        rng = np.random.default_rng(seed)
        # per-grammar transition "logits" over a hashed context
        self._proj = rng.standard_normal((num_grammars, 64)).astype(np.float32)
        self.num_grammars = num_grammars

    def _tokens(self, step: int, rows: np.ndarray, length: int) -> np.ndarray:
        """Deterministic learnable tokens for given global row ids.

        Each document follows an order-1 chain over a small (64-symbol)
        per-grammar alphabet with 10% noise — contexts repeat densely, so a
        model can actually drive the loss down (tests/test_data.py asserts
        the predictability)."""
        alpha = min(64, self.vocab)
        out = np.empty((len(rows), length), np.int64)
        for i, row in enumerate(rows):
            rng = np.random.default_rng(
                (self.state.seed * 1_000_003 + step) * 65_537 + int(row))
            grammar = int(rng.integers(self.num_grammars))
            base = (grammar * 97) % max(self.vocab - alpha, 1)
            a, c = 5 + 2 * grammar, 17 + grammar
            idx = int(rng.integers(alpha))
            noise = rng.random(length) < 0.1
            rand_idx = rng.integers(alpha, size=length)
            seq = np.empty(length, np.int64)
            for j in range(length):
                seq[j] = base + idx
                idx = int(rand_idx[j]) if noise[j] else (a * idx + c) % alpha
            out[i] = seq
        return out

    def local_batch_np(self, step: Optional[int] = None) -> dict:
        step = self.state.step if step is None else step
        lo = self.process_index * self.local_batch
        rows = np.arange(lo, lo + self.local_batch)
        cfg = self.cfg
        text = self.seq_len - (cfg.frontend_tokens if cfg.family == "vlm" else 0)
        batch = {"tokens": self._tokens(step, rows, text + 1).astype(np.int32)}
        if cfg.family in ("encdec", "vlm"):
            key = jax.random.PRNGKey((self.state.seed << 20) ^ step)
            feats = jax.random.normal(
                key, (self.local_batch, cfg.frontend_tokens, cfg.frontend_dim),
                jnp.float32)
            batch["frames" if cfg.family == "encdec" else "patches"] = \
                np.asarray(feats, np.float32)
        return batch

    def next_batch(self) -> dict:
        b = self.local_batch_np()
        self.state.step += 1
        return b

    def global_batch_arrays(self, mesh, pspecs: dict) -> dict:
        """Assemble the next global batch as sharded jax.Arrays."""
        local = self.next_batch()
        out = {}
        for k, v in local.items():
            spec = pspecs[k]
            sharding = jax.NamedSharding(mesh, spec)
            global_shape = (self.global_batch,) + v.shape[1:]
            out[k] = jax.make_array_from_process_local_data(
                sharding, v, global_shape)
        return out
