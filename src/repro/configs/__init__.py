"""Architecture registry: one module per assigned architecture."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, ShapeConfig, SHAPES, get_config, list_configs,
    reduce_for_smoke,
)

# Importing each module registers its CONFIG.
from repro.configs import (  # noqa: F401
    seamless_m4t_medium, dbrx_132b, qwen2_moe_a2p7b, granite_8b,
    tinyllama_1p1b, qwen1p5_4b, yi_9b, paligemma_3b, mamba2_2p7b,
    recurrentgemma_9b, llama3_8b,
)

ARCH_IDS = [
    "seamless-m4t-medium", "dbrx-132b", "qwen2-moe-a2.7b", "granite-8b",
    "tinyllama-1.1b", "qwen1.5-4b", "yi-9b", "paligemma-3b", "mamba2-2.7b",
    "recurrentgemma-9b",
]
