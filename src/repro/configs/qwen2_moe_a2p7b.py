"""qwen2-moe-a2.7b [moe] — hf:Qwen/Qwen1.5-MoE-A2.7B.

24L d_model=2048 16H (GQA kv=16 = MHA) d_ff=1408/expert vocab=151936,
MoE: 60 routed experts top-4 + 4 shared expert units (always active).
QKV bias per the Qwen family.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=151936,
    num_experts=60,
    top_k=4,
    num_shared_experts=4,
    norm_topk=False,
    qkv_bias=True,
    rope_base=1000000.0,
    max_seq_len=32768,
))
