"""Model / shape / run configuration dataclasses and the shape suite.

Every assigned architecture gets a ``configs/<id>.py`` exporting
``CONFIG`` (exact published dims) and ``SMOKE_CONFIG`` (same family,
reduced) built with ``reduce_for_smoke``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional

from repro.core.codecs import CachePolicy
from repro.core.quantizers import QuantConfig


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|encdec|vlm|ssm|hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 => d_model // num_heads
    rope_base: float = 10000.0
    rope_ntk_scale: float = 1.0   # NTK-aware context extension (App. C)
    qkv_bias: bool = False
    tie_embeddings: bool = False
    act: str = "silu"                 # silu (SwiGLU) | gelu (GeGLU)
    norm_eps: float = 1e-6
    max_seq_len: int = 131072
    dtype: str = "bfloat16"
    scale_embedding: bool = False     # gemma-style sqrt(d_model) embed scale
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                 # per-expert hidden (0 => d_ff)
    capacity_factor: float = 1.25
    norm_topk: bool = True
    router_aux_loss: float = 0.01
    # --- enc-dec ---
    encoder_layers: int = 0
    frontend_dim: int = 0             # stubbed modality frontend feature dim
    frontend_tokens: int = 0          # tokens emitted by the frontend stub
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # --- hybrid (recurrentgemma) ---
    window: int = 0                   # local-attention window (0 = global)
    block_pattern: tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    conv1d_width: int = 4
    # --- cache policy ---
    # `quant` is the uniform default; `cache_policy` (optional) maps layer
    # index -> QuantConfig for KVTuner-style mixed precision. Read via the
    # `policy` property, which falls back to a uniform policy over `quant`.
    quant: QuantConfig = field(default_factory=QuantConfig)
    cache_policy: Optional[CachePolicy] = None
    # decode-attention backend: "jnp" = pure-jnp masked softmax over the
    # cache; "ref"|"interpret"|"pallas" route the polar policy through the
    # fused LUT flash-decode kernels at that execution mode. Paged decode
    # additionally accepts "paged_fused" (page-native: walk the page table
    # and read quantized pages in place — the serving hot path; resolved in
    # paged_cache.paged_decode_attention to the Pallas grid on TPU and the
    # jitted jnp page walk elsewhere) and "gathered" (dense gather_view +
    # fused kernel, the pre-page-native formulation kept for A/B). See
    # core.paged_cache.PAGED_BACKENDS.
    decode_backend: str = "jnp"
    # chunked-prefill attention backend (paged serving only): "jnp" = the
    # chunk_prefill_attention reference (full-pool gather + dense codec
    # scores); "paged_fused" = page-native fused chunk prefill — the codec
    # kernel walks the table row and LUT-scores the quantized prefix pages
    # in place (resolved in paged_cache.paged_prefill_attention to the
    # Pallas grid on TPU, the jitted jnp oracle elsewhere); "ref"|
    # "interpret"|"pallas" pick the kernel execution mode explicitly.
    # Codecs without a page-native prefill fall back to "jnp" per policy
    # segment. See core.paged_cache.PREFILL_BACKENDS.
    prefill_backend: str = "jnp"

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def policy(self) -> CachePolicy:
        """The resolved per-layer cache policy (uniform over ``quant``
        unless ``cache_policy`` is set)."""
        if self.cache_policy is not None:
            return self.cache_policy
        return CachePolicy(default=self.quant)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Supports long-context decode with bounded per-token state."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (matches init, used for roofline N)."""
        d, h = self.d_model, self.head_dim
        attn = d * h * self.num_heads + 2 * d * h * self.num_kv_heads + \
            self.num_heads * h * d
        if self.qkv_bias:
            attn += h * (self.num_heads + 2 * self.num_kv_heads)
        if self.family == "moe":
            eff = self.moe_d_ff or self.d_ff
            ffn = self.num_experts * 3 * d * eff + d * self.num_experts
            ffn += self.num_shared_experts * 3 * d * eff
        else:
            ffn = 3 * d * self.d_ff
        norms = 2 * d
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)

        if self.family == "ssm":
            din = self.ssm_expand * d
            nheads = din // self.ssm_headdim
            bc = 2 * self.ssm_ngroups * self.ssm_state
            in_proj = d * (2 * din + bc + nheads)
            conv = (din + bc) * self.ssm_conv
            per_layer = in_proj + conv + 2 * nheads + din + din * d + d
            return self.num_layers * per_layer + emb + d
        if self.family == "hybrid":
            w = self.lru_width or d
            # RG-LRU block: in-proj x2 (d->w), conv1d (w*width), gates
            # (block-diagonal: 2 * w * w / nheads), lambda + D, out proj w->d.
            nb = max(self.num_heads, 1)
            rec = 2 * d * w + self.conv1d_width * w + 2 * (w * w // nb) + \
                2 * w + w * d
            n_rec = sum(1 for i in range(self.num_layers)
                        if self.block_pattern[i % len(self.block_pattern)] == "rec")
            n_att = self.num_layers - n_rec
            return (n_att * (attn + ffn + norms) + n_rec * (rec + ffn + norms)
                    + emb + d)
        total_layers = self.num_layers + self.encoder_layers
        per_layer = attn + ffn + norms
        extra = 0
        if self.family == "encdec":
            extra = self.num_layers * (attn + d)   # decoder cross-attention
        if self.family == "vlm" and self.frontend_dim:
            extra = self.frontend_dim * d          # patch projector
        return total_layers * per_layer + extra + emb + d


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode
    generate_len: int = 1     # decode steps lowered (always 1 for dry-run)


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduce_for_smoke(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Shrink a config to CPU-smoke size while keeping the family topology."""
    small = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 4 * cfg.num_kv_heads // cfg.num_heads if cfg.num_heads else 1)),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        max_seq_len=512,
        dtype="float32",
    )
    if cfg.num_kv_heads == cfg.num_heads:
        small["num_kv_heads"] = 4
    elif cfg.num_kv_heads == 1:
        small["num_kv_heads"] = 1
    else:
        small["num_kv_heads"] = 2
    if cfg.family == "moe":
        small.update(num_experts=4, top_k=2, moe_d_ff=64,
                     num_shared_experts=min(cfg.num_shared_experts, 1))
    if cfg.family == "encdec":
        small.update(encoder_layers=2, frontend_dim=32, frontend_tokens=16)
    if cfg.family == "vlm":
        small.update(frontend_dim=32, frontend_tokens=16)
    if cfg.family == "ssm":
        small.update(ssm_state=16, ssm_headdim=16, ssm_chunk=32)
    if cfg.family == "hybrid":
        # one full block pattern + tail so attn AND rec layers are exercised
        small.update(window=64, lru_width=128,
                     num_layers=len(cfg.block_pattern) + 1)
    small["quant"] = replace(cfg.quant, group_size=32)
    if cfg.cache_policy is not None:
        small["cache_policy"] = cfg.cache_policy.map(
            lambda q: replace(q, group_size=32))
    small.update(overrides)
    return replace(cfg, name=cfg.name + "-smoke", **small)


_REGISTRY: dict[str, "ModelConfig"] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401 — populates the registry
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
