"""qwen1.5-4b [dense] — hf:Qwen/Qwen1.5-4B (QKV bias).

40L d_model=2560 20H (GQA kv=20 = MHA) d_ff=6912 vocab=151936.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    rope_base=1000000.0,
    max_seq_len=32768,
))
