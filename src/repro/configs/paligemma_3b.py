"""paligemma-3b [vlm] — arXiv:2407.07726 (SigLIP + gemma backbone).

18L d_model=2048 8H (GQA kv=1 = MQA) d_ff=16384 vocab=257216.
The SigLIP vision tower is STUBBED: ``input_specs()`` provides
precomputed patch embeddings (frontend_dim=1152, 256 patches) which the
model projects into d_model and prepends with a bidirectional
prefix-LM mask (PaliGemma attends fully over image + prefix text).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    act="gelu",                 # gemma GeGLU
    scale_embedding=True,
    frontend_dim=1152,          # SigLIP So400m width
    frontend_tokens=256,        # 224px / 14 patches -> 16x16
    rope_base=10000.0,
    max_seq_len=8192,
))
