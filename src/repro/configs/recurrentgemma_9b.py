"""recurrentgemma-9b [hybrid] — arXiv:2402.19427 (Griffin: RG-LRU + local attn).

38L d_model=4096 16H (GQA kv=1 = MQA) d_ff=12288 vocab=256000,
block pattern 1 attention : 2 recurrent -> (rec, rec, attn) repeating,
local attention window 2048, lru_width=4096, conv1d width 4.
PolarQuant applies to the (bounded) local-attention KV ring cache;
the RG-LRU recurrence state stays fp.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    act="gelu",
    scale_embedding=True,
    window=2048,
    block_pattern=("rec", "rec", "attn"),
    lru_width=4096,
    conv1d_width=4,
    rope_base=10000.0,
    max_seq_len=1 << 20,
))
