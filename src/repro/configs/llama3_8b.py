"""llama3.1-8b — the paper's own primary evaluation backbone (Table 1/4).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256, rope 500000.
Not part of the assigned pool; included because the paper's kernel
latency/throughput tables (Table 4, Figure 3) use this configuration.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama3.1-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_base=500000.0,
    max_seq_len=131072,
))
