"""seamless-m4t-medium [audio enc-dec] — arXiv:2308.11596.

12L d_model=1024 16H (GQA kv=16 = MHA) d_ff=4096 vocab=256206.
Enc-dec: 12 encoder + 12 decoder layers; the speech frontend
(conformer feature extractor) is STUBBED — ``input_specs()`` provides
precomputed frame embeddings (frontend_dim) per the assignment brief.
PolarQuant applies to decoder self-attention KV; cross-attention KV is
quantized with the same polar policy (transform is RoPE-independent).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,            # decoder layers
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    rope_base=10000.0,
    frontend_dim=160,         # stub: precomputed audio frame features
    frontend_tokens=1024,     # frames after the (stubbed) subsampler
    max_seq_len=4096,
))
