"""mamba2-2.7b [ssm] — arXiv:2405.21060 (SSD, state-space duality).

64L d_model=2560 (attention-free) vocab=50280, ssm_state=128.
d_inner = 2*d_model = 5120, headdim=64 -> 80 SSD heads, conv width 4.
No KV cache exists; PolarQuant is inapplicable (DESIGN.md
§Arch-applicability) — the architecture runs WITHOUT the technique.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
    max_seq_len=1 << 20,
))
