"""Explicit collectives: int8 error-feedback gradient compression.

Cross-boundary (e.g. cross-pod DCN) gradient reduction is the bandwidth
hot-spot at 1000+-node scale. ``ef_allreduce_mean`` is an error-feedback
int8 all-reduce: each participant quantizes (grad + carried error) to int8
with a per-participant fp32 scale, the int8 payload is what crosses the
axis (4x fewer DCN bytes than fp32, 2x fewer than bf16), and the
quantization error is carried into the next step (EF-SGD) so the bias
vanishes over time.

Interface: grads arrive stacked on a leading ``workers`` axis that is
sharded over the mesh axis being reduced — i.e. each participant holds its
own (1, ...) slice. This matches the cross-pod integration point (per-pod
partial gradients), and is exercised on a multi-device CPU mesh by
tests/examples. Convergence property (mean of EF-compressed reductions
tracks the true mean) is covered in tests/test_collectives.py.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array


def _ef_leaf(g: Array, err: Array, axis: str):
    """g, err: this participant's block (1, ...). Returns (mean, new_err)."""
    x = g[0].astype(jnp.float32) + err[0]
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_err = x - q.astype(jnp.float32) * scale
    # dequantize per participant, then psum — the wire payload in a real
    # DCN deployment is (q, scale); psum of the dequantized value keeps
    # the math identical while remaining one fused collective here.
    contrib = q.astype(jnp.float32) * scale
    total = jax.lax.psum(contrib, axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    return total / n, new_err[None]


def _shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions (check_vma / check_rep renames,
    pre-0.5 location under jax.experimental.shard_map)."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def ef_allreduce_mean(grads: Any, errors: Any, mesh: Mesh, axis: str = "dp"):
    """Error-feedback int8 mean-all-reduce over mesh axis ``axis``.

    grads/errors: pytrees whose leaves are stacked (W, ...) with W == the
    size of ``axis`` and that leading dim sharded over ``axis``.
    Returns (mean_grads (...), new_errors (W, ...)).
    """
    flat, treedef = jax.tree_util.tree_flatten(grads)
    flat_err = treedef.flatten_up_to(errors)

    outs, new_errs = [], []
    for g, e in zip(flat, flat_err):
        fn = _shard_map(
            functools.partial(_ef_leaf, axis=axis), mesh=mesh,
            in_specs=(P(axis), P(axis)), out_specs=(P(), P(axis)))
        o, ne = fn(g, e)
        outs.append(o)
        new_errs.append(ne)
    return treedef.unflatten(outs), treedef.unflatten(new_errs)
