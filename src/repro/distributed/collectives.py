"""Explicit collectives: softmax-stats merges + int8 error-feedback.

Two families live here:

* **Online-softmax stats merges** for sharded attention
  (:func:`softmax_stats`, :func:`combine_softmax_stats`,
  :func:`merge_softmax_stats`, :func:`allgather_concat`,
  :func:`finalize_softmax`). A shard that scored only part of a query's
  context holds partial ``(m, l, acc)`` carries (running max, normalizer,
  unnormalized value accumulator); merging rescales by
  ``exp(m_i - max_j m_j)`` and psums. The rescale is guarded against
  degenerate shards — a shard with zero live positions carries
  ``m = -inf`` (or the finite ``NEG_INF`` sentinel), and a naive
  ``exp(m - m_max)`` there is ``exp(-inf - -inf) = NaN``; the guard zeroes
  such contributions instead (the ``0 * NaN`` class of bug, same family
  the single-device gather path masks at page granularity).

* **int8 error-feedback gradient compression** (``ef_allreduce_mean``).
  Cross-boundary (e.g. cross-pod DCN) gradient reduction is the bandwidth
  hot-spot at 1000+-node scale: each participant quantizes (grad +
  carried error) to int8 with a per-participant fp32 scale, the int8
  payload is what crosses the axis, and the quantization error is carried
  into the next step (EF-SGD) so the bias vanishes over time. Grads
  arrive stacked on a leading ``workers`` axis sharded over the mesh axis
  being reduced.

Both families are exercised on a multi-device CPU mesh by
tests/test_collectives.py; the softmax merges additionally back the
context-parallel decode reference in distributed/serving.py.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array


def _ef_leaf(g: Array, err: Array, axis: str):
    """g, err: this participant's block (1, ...). Returns (mean, new_err)."""
    x = g[0].astype(jnp.float32) + err[0]
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_err = x - q.astype(jnp.float32) * scale
    # dequantize per participant, then psum — the wire payload in a real
    # DCN deployment is (q, scale); psum of the dequantized value keeps
    # the math identical while remaining one fused collective here.
    contrib = q.astype(jnp.float32) * scale
    total = jax.lax.psum(contrib, axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    return total / n, new_err[None]


def _shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions (check_vma / check_rep renames,
    pre-0.5 location under jax.experimental.shard_map)."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


# public name: serving/test code reaches shard_map through this compat
# wrapper rather than version-sniffing jax itself
shard_map_compat = _shard_map


# ---------------------------------------------------------------------------
# Online-softmax stats: per-shard partials + merge collectives
# ---------------------------------------------------------------------------


def softmax_stats(scores: Array, values: Array):
    """Partial online-softmax carries for a block of masked scores.

    scores: (..., T) with masked lanes at ``NEG_INF`` (or ``-inf``);
    values: (..., T, d) token-major value rows (masked lanes zeroed or
    finite — they are weighted by an exactly-underflowed 0). Returns
    ``(m, l, acc)``: running max (...,), normalizer (...,), and
    unnormalized accumulator (..., d). A fully-masked block yields
    ``l == 0`` / ``acc == 0`` (not NaN) so it merges away cleanly.
    """
    m = jnp.max(scores, axis=-1)
    safe_m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(jnp.isfinite(m)[..., None],
                  jnp.exp(scores - safe_m[..., None]), 0.0)
    # finite NEG_INF sentinel: when every lane is NEG_INF, m == NEG_INF and
    # p == 1 everywhere — poison the normalizer too so this block carries
    # zero weight into any merge (matching the -inf branch above)
    dead = m <= -1e29
    p = jnp.where(dead[..., None], 0.0, p)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("...t,...td->...d", p, values)
    return m, l, acc


def combine_softmax_stats(a, b):
    """Merge two partial ``(m, l, acc)`` carries over the same queries —
    the pure pairwise combiner (local, no collective). Degenerate operands
    (``m`` at -inf / NEG_INF, i.e. zero live positions) contribute exactly
    zero rather than NaN."""
    m1, l1, acc1 = a
    m2, l2, acc2 = b
    m = jnp.maximum(m1, m2)
    safe_m = jnp.where(jnp.isfinite(m), m, 0.0)

    def coeff(mi):
        return jnp.where(jnp.isfinite(mi) & (mi > -1e29),
                         jnp.exp(mi - safe_m), 0.0)

    c1, c2 = coeff(m1), coeff(m2)
    l = l1 * c1 + l2 * c2
    acc = acc1 * c1[..., None] + acc2 * c2[..., None]
    return m, l, acc


def merge_softmax_stats(m: Array, l: Array, acc: Array, axis: str):
    """Collective merge of per-shard ``(m, l, acc)`` partials over mesh
    axis ``axis`` (inside shard_map): ``m`` is pmax'd, ``l``/``acc`` are
    rescaled by ``exp(m - m_max)`` and psum'd. The rescale is guarded so a
    shard with zero live positions (``m`` at -inf / NEG_INF) contributes
    exactly zero — it must not poison the merged softmax."""
    m_max = jax.lax.pmax(m, axis)
    safe_max = jnp.where(jnp.isfinite(m_max), m_max, 0.0)
    c = jnp.where(jnp.isfinite(m) & (m > -1e29),
                  jnp.exp(m - safe_max), 0.0)
    l_tot = jax.lax.psum(l * c, axis)
    acc_tot = jax.lax.psum(acc * c[..., None], axis)
    return m_max, l_tot, acc_tot


def finalize_softmax(l: Array, acc: Array) -> Array:
    """``acc / l`` with the all-masked case (l == 0) mapped to 0, not NaN."""
    return jnp.where(l[..., None] > 0,
                     acc / jnp.maximum(l, 1e-38)[..., None], 0.0)


def allgather_concat(x: Array, axis_name: str, axis: int = -1) -> Array:
    """All-gather shard blocks of ``x`` concatenated along ``axis`` in mesh
    order (``tiled``) — the LUT-score all-gather: each context-parallel
    shard contributes its slice of the score row (or value rows), and every
    shard reconstructs the full row so the subsequent softmax is
    *bit-identical* to the single-device formulation (unlike the psum
    merge, whose reduction order differs in the last ulp)."""
    if axis < 0:
        axis += x.ndim
    return jax.lax.all_gather(x, axis_name, axis=axis, tiled=True)


def ef_allreduce_mean(grads: Any, errors: Any, mesh: Mesh, axis: str = "dp"):
    """Error-feedback int8 mean-all-reduce over mesh axis ``axis``.

    grads/errors: pytrees whose leaves are stacked (W, ...) with W == the
    size of ``axis`` and that leading dim sharded over ``axis``.
    Returns (mean_grads (...), new_errors (W, ...)).
    """
    flat, treedef = jax.tree_util.tree_flatten(grads)
    flat_err = treedef.flatten_up_to(errors)

    outs, new_errs = [], []
    for g, e in zip(flat, flat_err):
        fn = _shard_map(
            functools.partial(_ef_leaf, axis=axis), mesh=mesh,
            in_specs=(P(axis), P(axis)), out_specs=(P(), P(axis)))
        o, ne = fn(g, e)
        outs.append(o)
        new_errs.append(ne)
    return treedef.unflatten(outs), treedef.unflatten(new_errs)
