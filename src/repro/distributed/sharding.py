"""Parameter / activation / cache sharding rules (FSDP x TP x pod).

Strategy (DESIGN.md §4):

* **Params** — every weight gets ZeRO-3-style FSDP over the combined
  (``pod``, ``data``) axes on its largest eligible dim, plus tensor
  parallelism over ``model`` on the canonical matmul dim (name-pattern
  table, negative axis indices so scanned (L, ...) stacks match too).
  Divisibility is checked per-arch; ineligible dims gracefully fall back.
* **Activations** — logical-axis rules installed via ctx.use_sharding:
  batch over (pod, data); heads/ff/experts over model when divisible.
* **Decode caches** — batch over (pod, data); the *sequence/group* axis
  over model (context-parallel decode: softmax stats + output psum are the
  only collectives, each tiny compared to sharding channels, which would
  all-reduce full score tensors).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import data_axes

# name pattern -> axis (negative index) that carries tensor parallelism
_TP_RULES: list[tuple[str, int]] = [
    (r".*(wq|wg|wu|w_in|w_gate)$", -1),   # column parallel
    (r".*(wo|wd|w_out)$", -2),            # row parallel
    (r".*(wk|wv)$", -1),
    (r".*(bq)$", -1),
    (r".*lm_head$", -1),                  # vocab column parallel
    (r".*conv_w$", -1),                   # depthwise conv channels
    (r".*(rg_w|ig_w)$", -3),              # RG-LRU block-diagonal blocks
    (r".*(rg_b|ig_b|lam|conv_b|norm_w)$", -1),
]

_NO_FSDP = re.compile(r".*(ln1|ln2|ln_x|ln|final_norm|enc_norm|A_log|dt_bias|D)$")


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _divides(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def param_pspec(path: str, shape: tuple[int, ...], mesh: Mesh,
                cfg: Optional[ModelConfig] = None) -> P:
    """Resolve one parameter's PartitionSpec."""
    model_n = mesh.shape.get("model", 1)
    daxes = data_axes(mesh)
    fsdp_n = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    spec: list = [None] * len(shape)

    # --- tensor parallelism ---
    tp_axis = None
    for pat, ax in _TP_RULES:
        if re.match(pat, path):
            tp_axis = ax
            break
    # MoE expert tensors: prefer expert parallelism when E % model == 0
    if cfg is not None and cfg.family == "moe" and re.search(
            r"ffn/w[gud]$", path) and len(shape) >= 3:
        if _divides(shape[-3], model_n):
            tp_axis = -3
    if tp_axis is not None and len(shape) >= abs(tp_axis):
        if _divides(shape[tp_axis], model_n):
            spec[tp_axis] = "model"
        else:
            tp_axis = None

    # --- FSDP on the largest remaining eligible dim ---
    if not _NO_FSDP.match(path) and daxes:
        best, best_size = None, 0
        for i, dim in enumerate(shape):
            ni = i - len(shape)
            if spec[ni] is not None:
                continue
            if _divides(dim, fsdp_n) and dim > best_size:
                best, best_size = ni, dim
        if best is not None:
            spec[best] = daxes if len(daxes) > 1 else daxes[0]
    return P(*spec)


def param_pspecs(params_shapes: Any, mesh: Mesh,
                 cfg: Optional[ModelConfig] = None) -> Any:
    """Tree of PartitionSpecs matching a params (shape) tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(_path_str(path), leaf.shape, mesh, cfg),
        params_shapes)


# ---------------------------------------------------------------------------
# Activation logical rules
# ---------------------------------------------------------------------------


def logical_rules(cfg: ModelConfig, mesh: Mesh, global_batch: int) -> dict:
    model_n = mesh.shape.get("model", 1)
    daxes = data_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    batch_axes = (daxes if len(daxes) > 1 else daxes[0]) if daxes else None
    rules: dict[str, Any] = {
        "batch": batch_axes if _divides(global_batch, dp) else None,
        "heads": "model" if _divides(cfg.num_heads, model_n) else None,
        "kv_heads": "model" if _divides(cfg.num_kv_heads, model_n) else None,
        "ff": "model" if _divides(cfg.d_ff, model_n) else None,
        "vocab": "model" if _divides(cfg.vocab_size, model_n) else None,
        "experts": "model" if _divides(cfg.num_experts, model_n) else None,
        "expert_ff": None,
        "seq": "model",   # context-parallel decode / sequence-sharded saves
    }
    if rules["experts"] is None and cfg.family == "moe":
        eff = cfg.moe_d_ff or cfg.d_ff
        rules["expert_ff"] = "model" if _divides(eff, model_n) else None
    if cfg.family == "ssm":
        from repro.models.mamba2 import dims as ssm_dims
        dm = ssm_dims(cfg)
        rules["ssm_heads"] = ("model" if _divides(
            dm.nheads // dm.ngroups, model_n) else None)
        rules["ssm_conv"] = "model" if _divides(dm.conv_dim, model_n) else None
        rules["ssm_inner"] = "model" if _divides(dm.d_inner, model_n) else None
    if cfg.family == "hybrid":
        w = cfg.lru_width or cfg.d_model
        rules["rec_width"] = "model" if _divides(w, model_n) else None
    return rules


def serving_rules(cfg: ModelConfig, mesh: Mesh, global_batch: int) -> dict:
    """Logical rules for the *paged serving* stack (DESIGN.md §17):
    :func:`logical_rules` minus the context-parallel ``seq`` mapping.

    Serving shards heads only — page pools are partitioned over KV heads
    (distributed/serving.py) and decode/prefill kernels run per-shard with
    no collectives, so sequence/group axes must stay replicated; the
    ``"seq": "model"`` training rule would fight that placement on every
    activation annotation.
    """
    rules = logical_rules(cfg, mesh, global_batch)
    rules["seq"] = None
    return rules


def batch_pspecs(batch_specs: dict, mesh: Mesh, global_batch: int) -> dict:
    daxes = data_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    baxes = (daxes if len(daxes) > 1 else daxes[0]) if daxes else None
    if not _divides(global_batch, dp):
        baxes = None
    return {k: P(baxes, *([None] * (len(v.shape) - 1)))
            for k, v in batch_specs.items()}


# ---------------------------------------------------------------------------
# Decode-state sharding
# ---------------------------------------------------------------------------

def decode_state_pspec(path: str, shape: tuple[int, ...], mesh: Mesh,
                       global_batch: int) -> P:
    """Generic decode-state resolver: batch axis over (pod,data); the
    longest remaining axis over model if divisible (sequence for caches,
    heads/width for recurrent states)."""
    model_n = mesh.shape.get("model", 1)
    daxes = data_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    baxes = (daxes if len(daxes) > 1 else daxes[0]) if daxes else None
    spec: list = [None] * len(shape)
    # batch: find the axis whose size == global_batch (after the L axis)
    b_idx = None
    for i, dim in enumerate(shape):
        if i == 0:
            continue
        if dim == global_batch:
            b_idx = i
            break
    if b_idx is not None and _divides(global_batch, dp) and baxes is not None:
        spec[b_idx] = baxes
    # model axis: largest remaining divisible dim (prefer later axes on tie)
    best, best_size = None, 0
    for i, dim in enumerate(shape):
        if i == 0 or i == b_idx:
            continue
        if _divides(dim, model_n) and dim >= best_size and dim > 1:
            best, best_size = i, dim
    if best is not None:
        spec[best] = "model"
    return P(*spec)


def decode_state_pspecs(state_shapes: Any, mesh: Mesh,
                        global_batch: int) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: decode_state_pspec(
            _path_str(path), leaf.shape, mesh, global_batch),
        state_shapes)


def to_named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))
