"""Logical-axis sharding context.

Models annotate activations with *logical* axis names; the launcher installs
a rule set mapping logical names to mesh axes (see distributed/sharding.py).
Outside any context (unit tests, single-device runs) annotations are no-ops,
so model code stays mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: dict[str, object]):
    """rules: logical axis name -> mesh axis (str | tuple | None)."""
    prev = (current_mesh(), current_rules())
    _state.mesh, _state.rules = mesh, rules
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def resolve(logical: Sequence[Optional[str]]) -> Optional[P]:
    rules = current_rules()
    if rules is None:
        return None
    return P(*[rules.get(name) if name else None for name in logical])


def shard(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """Annotate ``x`` with the sharding implied by logical axis names.

    No-op when no sharding context is installed or ranks mismatch.
    """
    spec = resolve(logical)
    mesh = current_mesh()
    if spec is None or mesh is None or len(logical) != x.ndim:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
