"""Mesh-sharded paged serving: head-parallel page pools (DESIGN.md §17).

Strategy — head-sharded tensor parallelism first:

* **Pools are partitioned over KV heads.** Every page-pool buffer carries
  its head axis at ``ndim - 3`` — ``(PP, H, g, ·)`` per-layer,
  ``(L, PP, H, g, ·)`` stacked, ``(S, H, g, d)`` residual — mirroring the
  "page axis at ``ndim - 4``" convention in ``copy_pool_pages``. That axis
  is sharded over the mesh's ``model`` axis; ``lengths`` (and anything
  below rank 4) is replicated.
* **PageAllocator and the page table stay host-side and shard-agnostic.**
  Page ids are identical on every shard, so the allocator's refcount /
  COW / adopt lifecycles never see the mesh; only pool *payload* is
  partitioned (asserted in tests/test_prefix_cache.py).
* **Kernels run per-shard under shard_map.** Per-KV-head attention is
  embarrassingly parallel: each shard walks the same page table over its
  head slice of the pools and produces its head slice of the output —
  bit-identical per head, no collectives. The GQA query→KV head mapping
  survives sharding because both head counts divide the axis, so each
  shard's contiguous query-head block maps onto its contiguous KV block.
* **GQA fallback.** When ``num_kv_heads`` (or ``num_heads``) does not
  divide the model axis, dispatch falls back to the replicated
  single-device path — same math, no partitioning.
* **Context-parallel decode** (:func:`context_parallel_decode`) is the
  complementary strategy from distributed/sharding.py's decode-cache
  notes: shard the *page-table columns* instead, score each shard's slice
  of the context locally, and merge the online-softmax ``m/l/acc``
  carries with the psum collectives in distributed/collectives.py (or
  all-gather the LUT score rows for a bit-identical merge). It is the
  reference/oracle for the stats-merge collectives; the serving hot path
  is head-sharded.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import kv_cache as kvc
from repro.core import paged_cache as pgc
from repro.core import quantizers as qz
from repro.distributed import ctx
from repro.distributed.collectives import (allgather_concat, finalize_softmax,
                                           merge_softmax_stats, shard_map_compat,
                                           softmax_stats)

Array = jax.Array

MODEL_AXIS = "model"


# ---------------------------------------------------------------------------
# Head-axis partition specs
# ---------------------------------------------------------------------------


def _pool_heads(cache: pgc.PagedKVCache) -> int:
    """KV head count read at the canonical pool head axis (``ndim - 3``) —
    works on both per-layer (PP, H, g, ·) and stacked (L, PP, H, g, ·)
    caches, unlike the ``num_kv_heads`` property (shape[1])."""
    kc = cache.key_codes
    return kc.shape[kc.ndim - 3]


def leaf_pspec(x: Array, num_kv_heads: int, axis: str = MODEL_AXIS) -> P:
    """PartitionSpec for one pool leaf: the head axis (``ndim - 3``) over
    ``axis`` when it is actually the head axis; everything else (lengths,
    scalars) replicated."""
    nd = x.ndim
    if nd >= 4 and x.shape[nd - 3] == num_kv_heads:
        spec: list = [None] * nd
        spec[nd - 3] = axis
        return P(*spec)
    return P()


def cache_pspecs(cache: pgc.PagedKVCache, axis: str = MODEL_AXIS) -> Any:
    """Pytree of PartitionSpecs matching ``cache`` (per-layer or stacked):
    pool head axes over ``axis``, slot-indexed state replicated."""
    h = _pool_heads(cache)
    return jax.tree_util.tree_map(lambda x: leaf_pspec(x, h, axis), cache)


def paged_state_shardings(state: Any, mesh: Mesh,
                          axis: str = MODEL_AXIS) -> Any:
    """NamedSharding tree for a (tuple of per-segment) stacked
    PagedKVCache state: head-partitioned pools where the KV head count
    divides the mesh axis, fully replicated otherwise."""
    model_n = mesh.shape.get(axis, 1)
    segs = state if isinstance(state, tuple) else (state,)

    def seg_shardings(c):
        h = _pool_heads(c)
        if h % model_n:
            return jax.tree_util.tree_map(
                lambda x: NamedSharding(mesh, P()), c)
        return jax.tree_util.tree_map(
            lambda x: NamedSharding(mesh, leaf_pspec(x, h, axis)), c)

    out = tuple(seg_shardings(c) for c in segs)
    return out if isinstance(state, tuple) else out[0]


def shard_paged_state(state: Any, mesh: Mesh, axis: str = MODEL_AXIS) -> Any:
    """Place a paged decode state on ``mesh`` with head-partitioned pools."""
    return jax.device_put(state, paged_state_shardings(state, mesh, axis))


# ---------------------------------------------------------------------------
# Head-sharded kernels (the serving hot path)
# ---------------------------------------------------------------------------


def _head_divisible(cache: pgc.PagedKVCache, q_heads: int, mesh: Mesh,
                    axis: str) -> bool:
    n = mesh.shape.get(axis, 0)
    return n > 0 and _pool_heads(cache) % n == 0 and q_heads % n == 0


def sharded_paged_decode_attention(cache: pgc.PagedKVCache, q: Array,
                                   page_table: Array, *, mesh: Mesh,
                                   axis: str = MODEL_AXIS,
                                   scale: float | None = None,
                                   backend: str = "jnp") -> Array:
    """Head-sharded :func:`pgc.paged_decode_attention`: each shard runs the
    full decode dispatch over its KV-head slice of the pools and the
    matching query-head block — bit-identical per head to the
    single-device path (no cross-head math anywhere in the kernel).
    Falls back to the replicated path when heads don't divide the axis.
    """
    if not _head_divisible(cache, q.shape[1], mesh, axis):
        return pgc.paged_decode_attention(cache, q, page_table, scale=scale,
                                          backend=backend)

    def body(c, qq, pt):
        return pgc.paged_decode_attention(c, qq, pt, scale=scale,
                                          backend=backend)

    fn = shard_map_compat(
        body, mesh=mesh,
        in_specs=(cache_pspecs(cache, axis), P(None, axis, None),
                  P(None, None)),
        out_specs=P(None, axis, None))
    return fn(cache, q, page_table)


def sharded_paged_prefill_attention(cache: pgc.PagedKVCache, q: Array,
                                    k_chunk: Array, v_chunk: Array,
                                    page_row: Array, start: Array,
                                    chunk_len: Array, *, mesh: Mesh,
                                    axis: str = MODEL_AXIS,
                                    scale: float | None = None,
                                    backend: str = "jnp") -> Array:
    """Head-sharded :func:`pgc.paged_prefill_attention` (the chunk-prefill
    twin of :func:`sharded_paged_decode_attention`)."""
    if not _head_divisible(cache, q.shape[1], mesh, axis):
        return pgc.paged_prefill_attention(cache, q, k_chunk, v_chunk,
                                           page_row, start, chunk_len,
                                           scale=scale, backend=backend)

    def body(c, qq, kk, vv, row, st, cl):
        return pgc.paged_prefill_attention(c, qq, kk, vv, row, st, cl,
                                           scale=scale, backend=backend)

    h4 = P(None, axis, None, None)
    fn = shard_map_compat(
        body, mesh=mesh,
        in_specs=(cache_pspecs(cache, axis), h4, h4, h4, P(None), P(), P()),
        out_specs=h4)
    return fn(cache, q, k_chunk, v_chunk, page_row,
              jnp.asarray(start, jnp.int32), jnp.asarray(chunk_len, jnp.int32))


def _active_head_axis(cache: pgc.PagedKVCache, q_heads: int):
    """(mesh, axis) when the installed sharding context maps ``kv_heads``
    onto a mesh axis that divides both head counts; (None, None) otherwise
    (no context, GQA fallback, or a non-Mesh test double)."""
    mesh = ctx.current_mesh()
    rules = ctx.current_rules() or {}
    if not isinstance(mesh, Mesh):
        return None, None
    axis = rules.get("kv_heads")
    if not isinstance(axis, str) or axis not in mesh.shape:
        return None, None
    if not _head_divisible(cache, q_heads, mesh, axis):
        return None, None
    return mesh, axis


def dispatch_paged_decode_attention(cache: pgc.PagedKVCache, q: Array,
                                    page_table: Array, *,
                                    scale: float | None = None,
                                    backend: str = "jnp") -> Array:
    """Context-aware decode dispatch: head-sharded shard_map when the
    engine installed a mesh whose ``kv_heads`` rule divides the heads,
    the plain single-device path otherwise. Model code calls this so it
    stays mesh-agnostic (same contract as ctx.shard)."""
    mesh, axis = _active_head_axis(cache, q.shape[1])
    if mesh is None:
        return pgc.paged_decode_attention(cache, q, page_table, scale=scale,
                                          backend=backend)
    return sharded_paged_decode_attention(cache, q, page_table, mesh=mesh,
                                          axis=axis, scale=scale,
                                          backend=backend)


def dispatch_paged_prefill_attention(cache: pgc.PagedKVCache, q: Array,
                                     k_chunk: Array, v_chunk: Array,
                                     page_row: Array, start: Array,
                                     chunk_len: Array, *,
                                     scale: float | None = None,
                                     backend: str = "jnp") -> Array:
    """Context-aware chunk-prefill dispatch (see
    :func:`dispatch_paged_decode_attention`)."""
    mesh, axis = _active_head_axis(cache, q.shape[1])
    if mesh is None:
        return pgc.paged_prefill_attention(cache, q, k_chunk, v_chunk,
                                           page_row, start, chunk_len,
                                           scale=scale, backend=backend)
    return sharded_paged_prefill_attention(cache, q, k_chunk, v_chunk,
                                           page_row, start, chunk_len,
                                           mesh=mesh, axis=axis, scale=scale,
                                           backend=backend)


# ---------------------------------------------------------------------------
# Context-parallel decode (page-table columns sharded; stats-merge oracle)
# ---------------------------------------------------------------------------


def _block_scores_values(cache: pgc.PagedKVCache, q: Array, pt_block: Array,
                         pos0: Array, scale: float | None):
    """Masked scores + value rows for a contiguous block of page-table
    columns whose first token sits at global position ``pos0`` (a page
    multiple).

    Per slot, a token position is scored from exactly one source: codec
    codes when it is flushed into a page (``pos < flushed``), the fp
    residual row when it is in the open group (``flushed <= pos < len``;
    the residual is slot-indexed and replicated, but only the shard owning
    the open group's page *column* scores it — value rows are token-major
    in that page, so values and scores stay co-located on one shard).
    Everything else is ``NEG_INF`` / zeroed, so a block with no live
    positions yields a degenerate (zero-weight) stats carry.
    """
    cfg, codec, lay = cache.cfg, cache.codec, cache.layout
    s, n = pt_block.shape
    hkv = cache.num_kv_heads
    hq, d = q.shape[1], q.shape[-1]
    qpk = hq // hkv
    g = lay.page_size
    t_loc = n * g
    scale = scale if scale is not None else d ** -0.5
    pvalid = (pt_block >= 0) & (pt_block < lay.num_pages)

    def masked(x):  # (PP, H, a, b) -> (S, H, N, a, b), invalid pages zeroed
        gathered = pgc._gather_pages(x, pt_block)
        return jnp.where(pvalid[:, None, :, None, None], gathered,
                         jnp.zeros((), x.dtype))

    def flat(x):  # (S, H, N, g, ·) -> (S, H, N*g, ·)
        return x.reshape(s, hkv, t_loc, x.shape[-1])

    qf = (q.astype(jnp.float32) * scale).reshape(s, hkv, qpk, d)
    key_codes = masked(cache.key_codes)
    key_scales = {kk: masked(vv) for kk, vv in cache.key_scales.items()}
    if not cache.grouped:
        key_codes = flat(key_codes)
        key_scales = {kk: flat(vv) for kk, vv in key_scales.items()}
    s_pages = codec.scores(cfg, qf, key_codes, key_scales)  # (S,Hkv,qpk,Tl)

    pos = pos0 + jnp.arange(t_loc, dtype=jnp.int32)          # (T_loc,)
    length = cache.lengths[:, None]                          # (S, 1)
    if cache.grouped:
        flushed = (cache.lengths // g * g)[:, None]
        res = cache.key_residual.astype(jnp.float32)         # (S, H, g, d)
        s_res = jnp.einsum("shqd,shgd->shqg", qf, res)       # (S,Hkv,qpk,g)
        s_res = jnp.tile(s_res, (1, 1, 1, n))                # row == pos % g
        in_page = (pos[None, :] < flushed)                   # (S, T_loc)
        in_res = (pos[None, :] >= flushed) & (pos[None, :] < length)
        scores = jnp.where(in_page[:, None, None, :], s_pages,
                           jnp.where(in_res[:, None, None, :], s_res,
                                     kvc.NEG_INF))
    else:
        live = pos[None, :] < length
        scores = jnp.where(live[:, None, None, :], s_pages, kvc.NEG_INF)

    if cfg.value_bits > 0:
        v_tilde = qz.decode_values(qz.QuantizedValues(
            codes=flat(masked(cache.value_codes)),
            scale=flat(masked(cache.value_scale)),
            zero=flat(masked(cache.value_zero)), bits=cfg.value_bits))
    else:
        v_tilde = flat(masked(cache.value_fp)).astype(jnp.float32)
    v_tilde = v_tilde.reshape(s, hkv, 1, t_loc, -1)          # qpk broadcast
    return scores, v_tilde


def context_parallel_decode(cache: pgc.PagedKVCache, q: Array,
                            page_table: Array, *, mesh: Mesh,
                            axis: str = MODEL_AXIS, merge: str = "psum",
                            scale: float | None = None) -> Array:
    """Context-parallel (page-column-sharded) decode reference.

    Each shard scores its contiguous slice of every slot's page-table row
    — quantized pages through the codec score path, the open group through
    the fp residual — and the per-shard online-softmax ``(m, l, acc)``
    partials are merged across the mesh axis:

    * ``merge="psum"`` — pmax/psum of the rescaled carries
      (:func:`merge_softmax_stats`); fp-tolerance vs the single-device
      path (reduction order differs), degenerate shards guarded.
    * ``merge="allgather"`` — LUT score rows + value rows all-gathered in
      mesh order, softmax computed on the reconstructed full row
      (:func:`allgather_concat`); bit-identical merge.

    Returns (S, Hq, d). This is the oracle for the stats-merge
    collectives; the serving hot path shards heads instead.
    """
    if merge not in ("psum", "allgather"):
        raise ValueError(f"unknown merge {merge!r}")
    world = mesh.shape[axis]
    s, n = page_table.shape
    n_pad = -(-n // world) * world
    if n_pad != n:
        pad = jnp.full((s, n_pad - n), -1, page_table.dtype)
        page_table = jnp.concatenate([page_table, pad], axis=1)
    g = cache.layout.page_size
    hq = q.shape[1]

    def body(c, qq, pt):
        r = jax.lax.axis_index(axis)
        pos0 = r * pt.shape[1] * g
        scores, values = _block_scores_values(c, qq, pt, pos0, scale)
        if merge == "allgather":
            scores = allgather_concat(scores, axis, axis=-1)
            values = allgather_concat(values, axis, axis=-2)
            _, l, acc = softmax_stats(scores, values)
        else:
            m, l, acc = softmax_stats(scores, values)
            _, l, acc = merge_softmax_stats(m, l, acc, axis)
        out = finalize_softmax(l, acc)                  # (S, Hkv, qpk, d)
        return out.reshape(s, hq, -1).astype(qq.dtype)

    fn = shard_map_compat(
        body, mesh=mesh,
        in_specs=(jax.tree_util.tree_map(lambda x: P(), cache), P(),
                  P(None, axis)),
        out_specs=P())
    return fn(cache, q, page_table)
