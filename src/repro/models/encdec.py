"""Encoder-decoder transformer (seamless-m4t backbone, audio frontend stub).

Encoder: bidirectional self-attention over precomputed frame embeddings
(the conformer feature extractor is STUBBED per the assignment — inputs are
``frames (B, Tf, frontend_dim)``). Decoder: causal self-attention (quantized
KV cache) + cross-attention (static quantized cache built once from encoder
memory) + MLP.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import kv_cache as kvc
from repro.models import layers as L
from repro.models import attn_block as AB
from repro.models import transformer as TF

Array = jax.Array
Params = dict


def init_enc_layer(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": AB.init_attention(k1, cfg),
            "ffn": L.init_mlp(k2, cfg.d_model, cfg.d_ff)}


def init_dec_layer(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln_x": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": AB.init_attention(k1, cfg),
            "xattn": AB.init_attention(k3, cfg),
            "ffn": L.init_mlp(k2, cfg.d_model, cfg.d_ff)}


def init_params(key, cfg: ModelConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = TF.init_lm_common(k1, cfg)
    p["frontend_proj"] = L.dense_init(k4, cfg.frontend_dim, cfg.d_model)
    p["enc_layers"] = L.stack_layer_params(
        functools.partial(init_enc_layer, cfg=cfg), k2, cfg.encoder_layers)
    p["dec_layers"] = L.stack_layer_params(
        functools.partial(init_dec_layer, cfg=cfg), k3, cfg.num_layers)
    p["enc_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    return p


def encode(params: Params, frames: Array, cfg: ModelConfig,
           remat: str = "block") -> Array:
    x = L.linear(frames.astype(jnp.dtype(cfg.dtype)), params["frontend_proj"])

    def body(h, lp):
        a = AB.attention_train(lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                               cfg, mask_mode="full")
        h = h + a
        f = L.mlp(lp["ffn"], L.rms_norm(h, lp["ln2"], cfg.norm_eps), cfg.act)
        return h + f, None

    if remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_block_train(lp, h, memory, cfg):
    a = AB.attention_train(lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                           cfg, mask_mode="causal")
    h = h + a
    xa = AB.attention_train(lp["xattn"], L.rms_norm(h, lp["ln_x"], cfg.norm_eps),
                            cfg, memory=memory)
    h = h + xa
    f = L.mlp(lp["ffn"], L.rms_norm(h, lp["ln2"], cfg.norm_eps), cfg.act)
    return h + f


def lm_loss(params: Params, batch: dict, cfg: ModelConfig,
            remat: str = "block", ce_chunk: int = 512):
    """batch: frames (B, Tf, fd), tokens (B, T+1)."""
    memory = encode(params, batch["frames"], cfg, remat)
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    x = TF.embed_tokens(params, inputs, cfg)

    def body(h, lp):
        return _dec_block_train(lp, h, memory, cfg), None

    if remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    loss = TF.lm_head_loss(params, x, labels, cfg, ce_chunk)
    return loss, {"ce": loss}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      memory_len: int):
    self_cache = AB.make_cache(cfg, batch, max_len)
    cross_cache = AB.make_cache(cfg, batch, memory_len)
    stack = lambda c: jax.tree_util.tree_map(
        lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), c)
    return {"self": stack(self_cache), "cross": stack(cross_cache)}


def prefill_fn(params: Params, batch: dict, cfg: ModelConfig, state):
    """Encode frames, build cross caches, prefill decoder prompt tokens."""
    memory = encode(params, batch["frames"], cfg, remat="none")
    tokens = batch["tokens"]
    x = TF.embed_tokens(params, tokens, cfg)

    def body(h, xs):
        lp, self_c, cross_c = xs
        a, self_c = AB.attention_prefill(
            lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps), cfg, self_c,
            mask_mode="causal")
        h = h + a
        cross_c = AB.cross_attention_cache(lp["xattn"], memory, cfg, cross_c)
        xa = AB.attention_train(lp["xattn"],
                                L.rms_norm(h, lp["ln_x"], cfg.norm_eps),
                                cfg, memory=memory)
        h = h + xa
        f = L.mlp(lp["ffn"], L.rms_norm(h, lp["ln2"], cfg.norm_eps), cfg.act)
        return h + f, (self_c, cross_c)

    x, (self_cs, cross_cs) = jax.lax.scan(
        body, x, (params["dec_layers"], state["self"], state["cross"]))
    logits = TF.lm_logits(params, x[:, -1:], cfg)
    return logits[:, 0], {"self": self_cs, "cross": cross_cs}


def decode_fn(params: Params, state, token: Array, cfg: ModelConfig):
    x = TF.embed_tokens(params, token[:, None], cfg)

    def body(h, xs):
        lp, self_c, cross_c = xs
        a, self_c = AB.attention_decode(
            lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps), cfg, self_c)
        h = h + a
        xa, _ = AB.attention_decode(
            lp["xattn"], L.rms_norm(h, lp["ln_x"], cfg.norm_eps), cfg,
            cross_c, cross=True)
        h = h + xa
        f = L.mlp(lp["ffn"], L.rms_norm(h, lp["ln2"], cfg.norm_eps), cfg.act)
        return h + f, (self_c, cross_c)

    x, (self_cs, cross_cs) = jax.lax.scan(
        body, x, (params["dec_layers"], state["self"], state["cross"]))
    logits = TF.lm_logits(params, x, cfg)
    return logits[:, 0], {"self": self_cs, "cross": cross_cs}
