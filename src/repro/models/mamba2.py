"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Chunked SSD forward for training/prefill (O(T) memory, lax.scan over
chunks for the inter-chunk recurrence) and an O(1)-state recurrent step
for decode. Attention-free: there is NO KV cache, so PolarQuant is
inapplicable to this family (DESIGN.md §Arch-applicability) — decode
state is (conv window, SSD state).

Shapes: heads H = expand*d_model/headdim; B/C projections have G groups
(G=1 for the assigned config) with R = H/G heads per group.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import ctx
from repro.models import layers as L

Array = jax.Array
Params = dict


class SSMDims(NamedTuple):
    d_inner: int
    nheads: int
    headdim: int
    ngroups: int
    dstate: int
    conv_dim: int
    conv_w: int


def dims(cfg: ModelConfig) -> SSMDims:
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_headdim
    conv_dim = d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return SSMDims(d_inner, nheads, cfg.ssm_headdim, cfg.ssm_ngroups,
                   cfg.ssm_state, conv_dim, cfg.ssm_conv)


def init_mamba_layer(key, cfg: ModelConfig) -> Params:
    dm = dims(cfg)
    d = cfg.d_model
    k = jax.random.split(key, 4)
    in_dim = 2 * dm.d_inner + 2 * dm.ngroups * dm.dstate + dm.nheads
    return {
        "in_proj": L.dense_init(k[0], d, in_dim),
        "conv_w": jax.random.normal(k[1], (dm.conv_w, dm.conv_dim),
                                    jnp.float32) * 0.2,
        "conv_b": jnp.zeros((dm.conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, dm.nheads)),   # A = -exp()
        "dt_bias": jnp.full((dm.nheads,), -2.0, jnp.float32),
        "D": jnp.ones((dm.nheads,), jnp.float32),
        "norm_w": jnp.ones((dm.d_inner,), jnp.float32),
        "out_proj": L.dense_init(k[2], dm.d_inner, d),
        "ln": jnp.ones((d,), jnp.float32),
    }


def _causal_conv(u: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv. u: (B, T, C); w: (W, C).

    A single lax.conv_general_dilated — the earlier pad-per-tap shift
    formulation materialized 4 full padded copies per call (46% of this
    arch's train HBM traffic, see EXPERIMENTS.md §Perf mamba v4)."""
    wn, c = w.shape
    dn = jax.lax.conv_dimension_numbers(u.shape, (wn, 1, c),
                                        ("NWC", "WIO", "NWC"))
    out = jax.lax.conv_general_dilated(
        u, w[:, None, :].astype(u.dtype), window_strides=(1,),
        padding=[(wn - 1, 0)], dimension_numbers=dn, feature_group_count=c)
    return jax.nn.silu(out + b.astype(u.dtype))


def _conv_step(u_t: Array, conv_state: Array, w: Array, b: Array):
    """One-token conv. u_t: (B, C); conv_state: (B, W-1, C) past inputs."""
    window = jnp.concatenate([conv_state, u_t[:, None]], axis=1)  # (B, W, C)
    out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32)) + b
    new_state = window[:, 1:]
    return jax.nn.silu(out).astype(u_t.dtype), new_state


def ssd_chunked(xdt: Array, adt: Array, b_: Array, c_: Array, chunk: int,
                initial_state: Array | None = None):
    """Chunked SSD scan.

    xdt: (B, T, G, R, P) — inputs pre-multiplied by dt
    adt: (B, T, G, R)    — dt * A (negative)
    b_, c_: (B, T, G, N)
    Returns (y (B,T,G,R,P), final_state (B,G,R,P,N)).
    """
    bsz, t, g, r, p = xdt.shape
    n = b_.shape[-1]
    t_orig = t
    if t % chunk:
        # zero-pad: adt=0 (decay 1) and xdt=0 make padded steps identities
        pad = chunk - t % chunk
        pad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        xdt = jnp.pad(xdt, pad4 + ((0, 0),))
        adt = jnp.pad(adt, pad4[:4])
        b_ = jnp.pad(b_, pad4)
        c_ = jnp.pad(c_, pad4)
        t = t + pad
    nc = t // chunk
    f32 = jnp.float32

    xdt_c = xdt.reshape(bsz, nc, chunk, g, r, p).astype(f32)
    adt_c = adt.reshape(bsz, nc, chunk, g, r).transpose(0, 3, 4, 1, 2).astype(f32)
    b_c = b_.reshape(bsz, nc, chunk, g, n).astype(f32)
    c_c = c_.reshape(bsz, nc, chunk, g, n).astype(f32)

    a_cum = jnp.cumsum(adt_c, axis=-1)                       # (B,G,R,nc,L)

    # intra-chunk (the "attention-like" quadratic block, L = chunk).
    # Mask BEFORE exp: masked entries have seg > 0 and would overflow,
    # poisoning the VJP with 0 * inf = NaN.
    seg = a_cum[..., :, None] - a_cum[..., None, :]          # (B,G,R,nc,L,S)
    li = jnp.arange(chunk)
    tri = li[:, None] >= li[None, :]
    decay = jnp.exp(jnp.where(tri, seg, -1e30))
    y_diag = jnp.einsum("bclgn,bcsgn,bgrcls,bcsgrp->bclgrp",
                        c_c, b_c, decay, xdt_c)

    # per-chunk input states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)          # (B,G,R,nc,L)
    chunk_states = jnp.einsum("bcsgn,bgrcs,bcsgrp->bcgrpn", b_c, decay_states,
                              xdt_c)                          # (B,nc,G,R,P,N)

    # inter-chunk recurrence (sequential scan keeps HLO compact)
    chunk_decay = jnp.exp(a_cum[..., -1])                     # (B,G,R,nc)
    if initial_state is None:
        initial_state = jnp.zeros((bsz, g, r, p, n), f32)

    def body(state, inp):
        dec, new = inp                                        # (B,G,R), (B,G,R,P,N)
        prev = state
        state = state * dec[..., None, None] + new
        return state, prev

    final_state, prev_states = jax.lax.scan(
        body, initial_state.astype(f32),
        (chunk_decay.transpose(3, 0, 1, 2), chunk_states.transpose(1, 0, 2, 3, 4, 5)))

    # contribution of carried-in state to each chunk
    state_decay = jnp.exp(a_cum)                              # (B,G,R,nc,L)
    y_off = jnp.einsum("bclgn,cbgrpn,bgrcl->bclgrp", c_c, prev_states,
                       state_decay)

    y = (y_diag + y_off).reshape(bsz, t, g, r, p)[:, :t_orig]
    return y, final_state


def mamba_mix(params: Params, u: Array, cfg: ModelConfig,
              initial=None, want_state: bool = False):
    """The SSD mixer on (B, T, D) (post layer-norm input)."""
    dm = dims(cfg)
    bsz, t, _ = u.shape
    proj = L.linear(u, params["in_proj"])
    z, xin, bc, dt = jnp.split(
        proj, [dm.d_inner, 2 * dm.d_inner,
               2 * dm.d_inner + 2 * dm.ngroups * dm.dstate], axis=-1)
    conv_in = jnp.concatenate([xin, bc], axis=-1)
    # model-parallel SSD: conv channels + SSD heads shard over 'model'
    # (the conv is depthwise and the SSD einsums are head-parallel, so the
    # only collectives are at the in/out projections)
    conv_in = ctx.shard(conv_in, ("batch", None, "ssm_conv"))
    if initial is not None:
        conv_state0, ssd_state0 = initial
        padded = jnp.concatenate([conv_state0.astype(conv_in.dtype), conv_in], 1)
        conv_out = _causal_conv(padded, params["conv_w"], params["conv_b"])
        conv_out = conv_out[:, dm.conv_w - 1 :]
    else:
        conv_out = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
        ssd_state0 = None
    xin = conv_out[..., : dm.d_inner]
    b_ = conv_out[..., dm.d_inner : dm.d_inner + dm.ngroups * dm.dstate]
    c_ = conv_out[..., dm.d_inner + dm.ngroups * dm.dstate :]
    b_ = b_.reshape(bsz, t, dm.ngroups, dm.dstate)
    c_ = c_.reshape(bsz, t, dm.ngroups, dm.dstate)

    a = -jnp.exp(params["A_log"].astype(jnp.float32))           # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,T,H)
    xh = xin.reshape(bsz, t, dm.ngroups, dm.nheads // dm.ngroups, dm.headdim)
    dth = dt.reshape(bsz, t, dm.ngroups, dm.nheads // dm.ngroups)
    xh = ctx.shard(xh, ("batch", None, None, "ssm_heads", None))
    dth = ctx.shard(dth, ("batch", None, None, "ssm_heads"))
    chunk = min(cfg.ssm_chunk, t)
    y, state = ssd_chunked(xh.astype(jnp.float32) * dth[..., None],
                           dth * a.reshape(1, 1, dm.ngroups, -1),
                           b_, c_, chunk, ssd_state0)
    y = ctx.shard(y, ("batch", None, None, "ssm_heads", None))
    y = y.reshape(bsz, t, dm.d_inner)
    y = y + xin.astype(jnp.float32) * jnp.repeat(
        params["D"].astype(jnp.float32), dm.headdim)[None, None]
    y = L.rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype),
                   params["norm_w"], cfg.norm_eps)
    out = L.linear(y, params["out_proj"])
    if want_state:
        conv_tail = conv_in[:, t - (dm.conv_w - 1) :] if initial is None else \
            jnp.concatenate([conv_state0.astype(conv_in.dtype), conv_in],
                            1)[:, -(dm.conv_w - 1):]
        return out, (conv_tail, state)
    return out


def mamba_step(params: Params, u_t: Array, cfg: ModelConfig, state):
    """Single-token recurrent step. u_t: (B, D); state = (conv, ssd)."""
    dm = dims(cfg)
    conv_state, ssd_state = state
    bsz = u_t.shape[0]
    proj = L.linear(u_t, params["in_proj"])
    z, xin, bc, dt = jnp.split(
        proj, [dm.d_inner, 2 * dm.d_inner,
               2 * dm.d_inner + 2 * dm.ngroups * dm.dstate], axis=-1)
    conv_in = jnp.concatenate([xin, bc], axis=-1)
    conv_out, conv_state = _conv_step(conv_in, conv_state, params["conv_w"],
                                      params["conv_b"])
    xin = conv_out[..., : dm.d_inner]
    b_ = conv_out[..., dm.d_inner : dm.d_inner + dm.ngroups * dm.dstate]
    c_ = conv_out[..., dm.d_inner + dm.ngroups * dm.dstate :]
    b_ = b_.reshape(bsz, dm.ngroups, dm.dstate).astype(jnp.float32)
    c_ = c_.reshape(bsz, dm.ngroups, dm.dstate).astype(jnp.float32)

    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    r = dm.nheads // dm.ngroups
    xh = xin.reshape(bsz, dm.ngroups, r, dm.headdim).astype(jnp.float32)
    dth = dt.reshape(bsz, dm.ngroups, r)
    adt = dth * a.reshape(1, dm.ngroups, r)
    # state: (B, G, R, P, N)
    ssd_state = ssd_state * jnp.exp(adt)[..., None, None] + jnp.einsum(
        "bgrp,bgn->bgrpn", xh * dth[..., None], b_)
    y = jnp.einsum("bgrpn,bgn->bgrp", ssd_state, c_)
    y = y.reshape(bsz, dm.d_inner)
    y = y + xin.astype(jnp.float32) * jnp.repeat(params["D"], dm.headdim)[None]
    y = L.rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(u_t.dtype),
                   params["norm_w"], cfg.norm_eps)
    return L.linear(y, params["out_proj"]), (conv_state, ssd_state)


def init_state(cfg: ModelConfig, batch: int):
    dm = dims(cfg)
    return (jnp.zeros((batch, dm.conv_w - 1, dm.conv_dim), jnp.dtype(cfg.dtype)),
            jnp.zeros((batch, dm.ngroups, dm.nheads // dm.ngroups,
                       dm.headdim, dm.dstate), jnp.float32))
