"""Mixture-of-Experts FFN with sort-free ragged dispatch (top-k routing).

Dispatch strategy (TPU/SPMD-friendly, DESIGN.md §4):
  * per token-group (device shard), compute top-k expert assignments;
  * position-in-expert via slot-major cumsum (deterministic tie-break);
  * tokens scatter-add into a dense (E, C, D) expert buffer (row scatter,
    OOB-dropped when over capacity — NOT a (T, E, C) one-hot einsum, which
    costs O(T*E*C*D) MXU flops; the scatter is O(T*k*D));
  * per-expert FFN as a single (E, C, D) x (E, D, F) einsum (MXU bated);
  * gather rows back and combine with router gates.

Shared experts (qwen2-moe) run densely on every token.
Aux load-balancing loss follows Switch (mean_prob * mean_assignment * E).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import ctx
from repro.models import layers as L

Array = jax.Array
Params = dict


def init_moe(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    e = cfg.num_experts
    keys = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(keys[0], d, e, scale=0.02),
        "wg": jax.vmap(lambda k: L.dense_init(k, d, ff))(
            jax.random.split(keys[1], e)),
        "wu": jax.vmap(lambda k: L.dense_init(k, d, ff))(
            jax.random.split(keys[2], e)),
        "wd": jax.vmap(lambda k: L.dense_init(k, ff, d))(
            jax.random.split(keys[3], e)),
    }
    if cfg.num_shared_experts:
        p["shared"] = L.init_mlp(keys[4], d, ff * cfg.num_shared_experts)
    return p


def moe_ffn(params: Params, x: Array, cfg: ModelConfig):
    """x: (B, T, D) -> (y, aux_loss). Capacity C = T*k/E * capacity_factor
    per batch row (token group)."""
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = max(int(t * k / e * cfg.capacity_factor), 1)
    cap = -(-cap // 8) * 8  # sublane-align capacity

    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # (B, T, E)
    gates, eidx = jax.lax.top_k(probs, k)                     # (B, T, k)
    if cfg.norm_topk:
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # Switch-style load-balance aux loss.
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(eidx, e, dtype=jnp.float32), axis=2),
        axis=(0, 1))
    aux = e * jnp.sum(me * ce) * cfg.router_aux_loss

    # position-in-expert: slot-major cumsum (slot 0 of every token first).
    onehot = jax.nn.one_hot(eidx, e, dtype=jnp.int32)          # (B, T, k, E)
    flat = onehot.transpose(0, 2, 1, 3).reshape(b, k * t, e)   # slot-major
    pos = jnp.cumsum(flat, axis=1) - flat                      # (B, k*T, E)
    pos = jnp.sum(pos * flat, axis=-1)                         # (B, k*T)
    eflat = eidx.transpose(0, 2, 1).reshape(b, k * t)
    keep = pos < cap
    dst = jnp.where(keep, eflat * cap + pos, e * cap)          # OOB => drop

    xk = jnp.broadcast_to(x[:, None], (b, k, t, d)).reshape(b, k * t, d)
    buf = jnp.zeros((b, e * cap, d), x.dtype)
    buf = jax.vmap(lambda bf, ix, src: bf.at[ix].add(src, mode="drop"))(
        buf, dst, xk)
    buf = buf.reshape(b, e, cap, d)
    buf = ctx.shard(buf, ("batch", "experts", None, None))

    # Per-expert SwiGLU on the MXU: (B,E,C,D) x (E,D,F).
    dt = x.dtype
    h = jnp.einsum("becd,edf->becf", buf, params["wg"].astype(dt))
    u = jnp.einsum("becd,edf->becf", buf, params["wu"].astype(dt))
    h = jax.nn.silu(h) if cfg.act == "silu" else jax.nn.gelu(h)
    h = ctx.shard(h * u, ("batch", "experts", None, "expert_ff"))
    out_e = jnp.einsum("becf,efd->becd", h, params["wd"].astype(dt))
    out_e = ctx.shard(out_e, ("batch", "experts", None, None))

    rows = jax.vmap(
        lambda bf, ix: bf.at[ix].get(mode="fill", fill_value=0))(
        out_e.reshape(b, e * cap, d), dst)                     # (B, k*T, D)
    rows = rows.reshape(b, k, t, d)
    gk = (gates.transpose(0, 2, 1) * keep.reshape(b, k, t)).astype(dt)
    y = jnp.einsum("bktd,bkt->btd", rows, gk)

    if cfg.num_shared_experts:
        y = y + L.mlp(params["shared"], x, cfg.act)
    return y, aux
