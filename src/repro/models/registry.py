"""Uniform Model interface over every architecture family.

``get_model(cfg)`` returns a :class:`Model` bundle of pure functions:
  init(key) -> params
  loss(params, batch) -> (loss, metrics)                 [train step core]
  init_decode_state(batch, max_len) -> state             [concrete zeros]
  prefill(params, batch, state) -> (last_logits, state)
  decode(params, state, token) -> (logits, state)
  input_specs(shape) -> dict[str, ShapeDtypeStruct]      [dry-run stand-ins]

The decode path for attention families runs over the quantized KV cache
(cfg.quant policy — PolarQuant by default); ssm/hybrid use their O(1)
recurrent states (+ ring cache for hybrid local attention).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as TF
from repro.models import encdec as ED
from repro.models import ssm_lm as SSM
from repro.models import hybrid as HY

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[Array], dict]
    loss: Callable[[dict, dict], tuple[Array, dict]]
    init_decode_state: Callable[..., Any]
    prefill: Callable[[dict, dict, Any], tuple[Array, Any]]
    decode: Callable[[dict, Any, Array], tuple[Array, Any]]
    input_specs: Callable[[ShapeConfig], dict]
    # --- continuous batching over paged caches (None where unsupported) ---
    # init_paged_state(layout) -> per-segment stacked PagedKVCaches
    # prefill_paged(params, tokens (1,Tp), state, slot, page_row, true_len)
    # prefill_paged_chunk(params, tokens (1,Tc), state, slot, page_row,
    #                     start, chunk_len) — chunked prefill at an offset;
    #                     chunk attention dispatches per cfg.prefill_backend
    #                     (page-native fused kernel vs gathering jnp ref)
    # decode_paged(params, state, token (S,), page_table, active)
    # decode_runahead(params, state, token (S,), page_table, active, key,
    #                 remaining, done, *, horizon, temperature, top_k,
    #                 eos_id) — H fused decode micro-steps with on-device
    #                 sampling + EOS/budget masking in one lax.scan
    #                 dispatch (DESIGN.md §18); returns the (H, S) token
    #                 block plus the carries that seed the next horizon
    # copy_pages(state, src, dst) — COW page copy across segment pools
    # decode_paged_collect / commit_paged — the speculative verify split
    # (sequential reference): collect is decode_paged that also returns
    # per-layer post-RoPE kv; commit re-appends one span position's saved
    # kv. verify_span / commit_span are the batched production pair: all
    # Q span positions in one dispatch + one fused multi-row append
    # (spec/verify.py picks the pair per cfg.decode_backend)
    init_paged_state: Callable[..., Any] | None = None
    prefill_paged: Callable[..., Any] | None = None
    prefill_paged_chunk: Callable[..., Any] | None = None
    decode_paged: Callable[..., Any] | None = None
    decode_runahead: Callable[..., Any] | None = None
    copy_pages: Callable[..., Any] | None = None
    decode_paged_collect: Callable[..., Any] | None = None
    commit_paged: Callable[..., Any] | None = None
    verify_span: Callable[..., Any] | None = None
    commit_span: Callable[..., Any] | None = None
    # cache_layer_bytes(state) -> physical cache bytes per layer (None for
    # families without per-layer KV caches)
    cache_layer_bytes: Callable[[Any], list[int]] | None = None

    def decode_state_specs(self, shape: ShapeConfig):
        """ShapeDtypeStructs of the decode state (no allocation)."""
        return jax.eval_shape(
            lambda: self.init_decode_state(shape.global_batch, shape.seq_len))


def _token_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, t = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        text = t - (cfg.frontend_tokens if cfg.family == "vlm" else 0)
        specs = {"tokens": jax.ShapeDtypeStruct((b, text + 1), i32)}
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_tokens, cfg.frontend_dim), f)
        if cfg.family == "vlm":
            specs["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_tokens, cfg.frontend_dim), f)
        return specs
    if shape.kind == "prefill":
        text = t - (cfg.frontend_tokens if cfg.family == "vlm" else 0)
        specs = {"tokens": jax.ShapeDtypeStruct((b, text), i32)}
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_tokens, cfg.frontend_dim), f)
        if cfg.family == "vlm":
            specs["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_tokens, cfg.frontend_dim), f)
        return specs
    # decode: one new token against a state of size seq_len
    return {"token": jax.ShapeDtypeStruct((b,), i32)}


def get_model(cfg: ModelConfig) -> Model:
    specs = functools.partial(_token_specs, cfg)
    if cfg.family not in ("dense", "moe", "vlm") and not cfg.policy.is_uniform:
        raise ValueError(
            f"per-layer cache policies are not supported for the "
            f"{cfg.family!r} family (its decode state stacks one cache "
            "shape across layers)")
    if cfg.family in ("dense", "moe", "vlm"):
        paged = {}
        # vlm prefill needs the patch frontend; the paged attention path
        # has no sliding-window masking, so windowed configs are excluded
        if cfg.family != "vlm" and cfg.window == 0:
            paged = dict(
                init_paged_state=lambda layout: TF.init_paged_caches(
                    cfg, layout),
                prefill_paged=lambda p, toks, s, slot, row, tl:
                    TF.prefill_paged_fn(p, toks, cfg, s, slot, row, tl),
                prefill_paged_chunk=lambda p, toks, s, slot, row, start, cl:
                    TF.prefill_paged_chunk_fn(p, toks, cfg, s, slot, row,
                                              start, cl),
                decode_paged=lambda p, s, t, table, active:
                    TF.decode_paged_fn(p, s, t, table, active, cfg),
                decode_runahead=lambda p, s, t, table, active, key, rem,
                    done, horizon, temperature, top_k, eos_id:
                    TF.decode_runahead_fn(p, s, t, table, active, key,
                                          rem, done, cfg, horizon=horizon,
                                          temperature=temperature,
                                          top_k=top_k, eos_id=eos_id),
                copy_pages=TF.copy_state_pages,
                decode_paged_collect=lambda p, s, t, table, active:
                    TF.decode_paged_collect_fn(p, s, t, table, active, cfg),
                commit_paged=lambda s, kv, table, keep:
                    TF.commit_paged_fn(s, kv, table, keep, cfg),
                verify_span=lambda p, s, t, table, active:
                    TF.verify_span_fn(p, s, t, table, active, cfg),
                commit_span=lambda s, kv, table, n_keep:
                    TF.commit_span_paged_fn(s, kv, table, n_keep, cfg),
            )
        return Model(
            cfg=cfg,
            init=functools.partial(TF.init_params, cfg=cfg),
            loss=lambda p, b, **kw: TF.lm_loss(p, b, cfg, **kw),
            init_decode_state=lambda batch, max_len: TF.init_decode_caches(
                cfg, batch, max_len),
            prefill=lambda p, b, s: TF.prefill_fn(p, b, cfg, s),
            decode=lambda p, s, t: TF.decode_fn(p, s, t, cfg),
            input_specs=specs,
            cache_layer_bytes=lambda state: TF.per_layer_cache_bytes(
                cfg, state),
            **paged,
        )
    if cfg.family == "encdec":
        return Model(
            cfg=cfg,
            init=functools.partial(ED.init_params, cfg=cfg),
            loss=lambda p, b, **kw: ED.lm_loss(p, b, cfg, **kw),
            init_decode_state=lambda batch, max_len: ED.init_decode_state(
                cfg, batch, max_len, cfg.frontend_tokens),
            prefill=lambda p, b, s: ED.prefill_fn(p, b, cfg, s),
            decode=lambda p, s, t: ED.decode_fn(p, s, t, cfg),
            input_specs=specs,
        )
    if cfg.family == "ssm":
        return Model(
            cfg=cfg,
            init=functools.partial(SSM.init_params, cfg=cfg),
            loss=lambda p, b, **kw: SSM.lm_loss(p, b, cfg, **kw),
            init_decode_state=lambda batch, max_len: SSM.init_decode_state(
                cfg, batch),
            prefill=lambda p, b, s: SSM.prefill_fn(p, b, cfg, s),
            decode=lambda p, s, t: SSM.decode_fn(p, s, t, cfg),
            input_specs=specs,
        )
    if cfg.family == "hybrid":
        return Model(
            cfg=cfg,
            init=functools.partial(HY.init_params, cfg=cfg),
            loss=lambda p, b, **kw: HY.lm_loss(p, b, cfg, **kw),
            init_decode_state=lambda batch, max_len: HY.init_decode_state(
                cfg, batch, max_len),
            prefill=lambda p, b, s: HY.prefill_fn(p, b, cfg, s),
            decode=lambda p, s, t: HY.decode_fn(p, s, t, cfg),
            input_specs=specs,
        )
    raise ValueError(f"unknown family {cfg.family!r}")
