"""GQA attention block with pluggable quantized-KV-cache policy.

Three entry points share one QKV computation:
  * ``attention_train``   — full-sequence flash attention (no cache)
  * ``attention_prefill`` — flash attention + bulk cache fill
  * ``attention_decode``  — single-token append + quantized decode attention
    (LUT path for the polar policy)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import kv_cache as kvc
from repro.core import paged_cache as pgc
from repro.core.attention import flash_attention
from repro.distributed import ctx
from repro.distributed import serving as dsrv
from repro.models import layers as L

Array = jax.Array
Params = dict


def _dense_kernel_backend(backend: str) -> str:
    """Map cfg.decode_backend onto a dense-path kernel backend: the paged
    dispatch names ("paged_fused", "gathered") mean "the fast fused path"
    there, which for the dense cache is the ref (pure-jnp) kernel."""
    return "ref" if backend in ("paged_fused", "gathered") else backend


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(k1, d, cfg.num_heads * hd),
        "wk": L.dense_init(k2, d, cfg.num_kv_heads * hd),
        "wv": L.dense_init(k3, d, cfg.num_kv_heads * hd),
        "wo": L.dense_init(k4, cfg.num_heads * hd, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), jnp.float32)
    return p


def _qkv(params: Params, x: Array, cfg: ModelConfig, positions: Array,
         rope: bool = True):
    q = L.linear(x, params["wq"], params.get("bq"))
    k = L.linear(x, params["wk"], params.get("bk"))
    v = L.linear(x, params["wv"], params.get("bv"))
    q = L.split_heads(q, cfg.num_heads)
    k = L.split_heads(k, cfg.num_kv_heads)
    v = L.split_heads(v, cfg.num_kv_heads)
    q = ctx.shard(q, ("batch", "heads", None, None))
    k = ctx.shard(k, ("batch", "kv_heads", None, None))
    v = ctx.shard(v, ("batch", "kv_heads", None, None))
    if rope:
        q = L.apply_rope(q, positions, cfg.rope_base, cfg.rope_ntk_scale)
        k = L.apply_rope(k, positions, cfg.rope_base, cfg.rope_ntk_scale)
    return q, k, v


def attention_train(params: Params, x: Array, cfg: ModelConfig, *,
                    mask_mode: str = "causal",
                    prefix_len: Optional[Array] = None,
                    memory: Optional[Array] = None,
                    window: int = 0) -> Array:
    """x: (B, T, D). ``memory`` switches to cross-attention (K/V from
    memory, no RoPE on keys/queries)."""
    b, t, _ = x.shape
    if memory is None:
        positions = jnp.arange(t, dtype=jnp.int32)
        q, k, v = _qkv(params, x, cfg, positions, rope=True)
    else:
        q = L.split_heads(L.linear(x, params["wq"], params.get("bq")),
                          cfg.num_heads)
        k = L.split_heads(L.linear(memory, params["wk"], params.get("bk")),
                          cfg.num_kv_heads)
        v = L.split_heads(L.linear(memory, params["wv"], params.get("bv")),
                          cfg.num_kv_heads)
        mask_mode = "full"
    out = flash_attention(q, k, v, mode=mask_mode, window=window,
                          prefix_len=prefix_len)
    return L.linear(L.merge_heads(out), params["wo"])


def attention_prefill(params: Params, x: Array, cfg: ModelConfig,
                      cache: kvc.KVCache, *, mask_mode: str = "causal",
                      prefix_len: Optional[Array] = None,
                      window: int = 0):
    """Flash attention over the prompt + bulk cache fill. Returns (y, cache)."""
    b, t, _ = x.shape
    positions = jnp.arange(t, dtype=jnp.int32)
    q, k, v = _qkv(params, x, cfg, positions, rope=True)
    cache = kvc.prefill(cache, k, v)
    out = flash_attention(q, k, v, mode=mask_mode, window=window,
                          prefix_len=prefix_len)
    return L.linear(L.merge_heads(out), params["wo"]), cache


def cross_attention_cache(params: Params, memory: Array, cfg: ModelConfig,
                          cache: kvc.KVCache) -> kvc.KVCache:
    """Fill a cross-attention cache from encoder memory (no RoPE)."""
    k = L.split_heads(L.linear(memory, params["wk"], params.get("bk")),
                      cfg.num_kv_heads)
    v = L.split_heads(L.linear(memory, params["wv"], params.get("bv")),
                      cfg.num_kv_heads)
    return kvc.prefill(cache, k, v)


def attention_decode(params: Params, x: Array, cfg: ModelConfig,
                     cache: kvc.KVCache, *, window: int = 0,
                     cross: bool = False):
    """Single-token decode. x: (B, 1, D). Returns (y (B,1,D), cache)."""
    b = x.shape[0]
    q = L.split_heads(L.linear(x, params["wq"], params.get("bq")),
                      cfg.num_heads)                      # (B, H, 1, hd)
    if cross:
        # cross-attention: static cache, no RoPE, no append
        out = kvc.decode_attention(cache, q[:, :, 0], window=0)
        return L.linear(out.reshape(b, 1, -1), params["wo"]), cache
    pos = jnp.full((1,), cache.length, jnp.int32)
    k = L.split_heads(L.linear(x, params["wk"], params.get("bk")),
                      cfg.num_kv_heads)
    v = L.split_heads(L.linear(x, params["wv"], params.get("bv")),
                      cfg.num_kv_heads)
    q = L.apply_rope(q, pos, cfg.rope_base, cfg.rope_ntk_scale)
    k = L.apply_rope(k, pos, cfg.rope_base, cfg.rope_ntk_scale)
    cache = kvc.append(cache, k, v)
    if (cfg.decode_backend != "jnp" and cache.codec.supports_fused_decode
            and window == 0):
        # fused kernel assumes linear placement — ring windows stay on the
        # jnp path
        out = kvc.fused_decode_attention(
            cache, q[:, :, 0],
            backend=_dense_kernel_backend(cfg.decode_backend))
    else:
        out = kvc.decode_attention(cache, q[:, :, 0], window=window)
    return L.linear(out.reshape(b, 1, -1), params["wo"]), cache


def attention_prefill_paged(params: Params, x: Array, cfg: ModelConfig,
                            cache: pgc.PagedKVCache, *, slot: Array,
                            page_row: Array, true_len: Array):
    """One request's prompt attention + paged cache fill.

    x: (1, Tp, D) with Tp a static bucket length; real tokens occupy
    ``[0, true_len)``, the tail is padding. Causal masking means padding
    (at the end) never influences real positions, so the flash output for
    real tokens is exact. Returns (y (1, Tp, D), cache).
    """
    b, t, _ = x.shape
    positions = jnp.arange(t, dtype=jnp.int32)
    q, k, v = _qkv(params, x, cfg, positions, rope=True)
    cache = pgc.paged_prefill(cache, slot, page_row, k, v, true_len)
    out = flash_attention(q, k, v, mode="causal")
    return L.linear(L.merge_heads(out), params["wo"]), cache


def attention_prefill_chunk(params: Params, x: Array, cfg: ModelConfig,
                            cache: pgc.PagedKVCache, *, slot: Array,
                            page_row: Array, start: Array, chunk_len: Array):
    """One prefill *chunk*'s attention + paged cache fill at offset
    ``start`` (page-aligned).

    x: (1, Tc, D) with Tc the static chunk bucket; real tokens occupy
    ``[0, chunk_len)``, the tail is padding. RoPE runs at the absolute
    positions ``start + i``; queries attend to the slot's cached
    (quantized) prefix ``[0, start)`` through the codec score path plus fp
    causal attention within the chunk, dispatched per
    ``cfg.prefill_backend`` (``pgc.paged_prefill_attention``: page-native
    fused kernel where the codec supports it, the gathering jnp reference
    otherwise). Returns (y (1, Tc, D), cache).
    """
    b, t, _ = x.shape
    positions = start + jnp.arange(t, dtype=jnp.int32)
    q, k, v = _qkv(params, x, cfg, positions, rope=True)
    cache = pgc.paged_prefill(cache, slot, page_row, k, v, chunk_len,
                              start=start)
    # codec-capability fallback happens inside paged_prefill_attention,
    # mirroring the decode dispatch below; the dsrv dispatch additionally
    # runs the kernel per-KV-head-shard when the engine installed a mesh
    # whose "kv_heads" rule divides the heads (DESIGN.md §17)
    out = dsrv.dispatch_paged_prefill_attention(
        cache, q, k, v, page_row, start, chunk_len,
        backend=cfg.prefill_backend)
    return L.linear(L.merge_heads(out), params["wo"]), cache


def attention_decode_paged(params: Params, x: Array, cfg: ModelConfig,
                           cache: pgc.PagedKVCache, *, page_table: Array,
                           active: Array, return_kv: bool = False):
    """Batched single-token decode over continuous-batching slots.

    x: (S, 1, D); every slot sits at its own position (cache.lengths), so
    RoPE uses per-slot positions and attention masks per-slot lengths.
    Returns (y (S, 1, D), cache) — or (y, cache, (k, v)) with
    ``return_kv``, exposing the post-RoPE kv so the speculative verifier
    can re-commit accepted span tokens without a second forward.

    This block is the micro-step body of run-ahead decode
    (``transformer.decode_runahead_fn``, DESIGN.md §18): positions come
    from the cache carry and the append/attention pair is pure, so
    ``lax.scan`` iterating it H times is bit-identical to H separate
    dispatches — keep it free of host-side state.
    """
    s = x.shape[0]
    q = L.split_heads(L.linear(x, params["wq"], params.get("bq")),
                      cfg.num_heads)                      # (S, H, 1, hd)
    k = L.split_heads(L.linear(x, params["wk"], params.get("bk")),
                      cfg.num_kv_heads)
    v = L.split_heads(L.linear(x, params["wv"], params.get("bv")),
                      cfg.num_kv_heads)
    pos = cache.lengths[:, None]                          # (S, 1)
    q = L.apply_rope(q, pos, cfg.rope_base, cfg.rope_ntk_scale)
    k = L.apply_rope(k, pos, cfg.rope_base, cfg.rope_ntk_scale)
    cache = pgc.paged_append(cache, k, v, page_table, active)
    # codec-capability fallback happens inside paged_decode_attention:
    # page-native where the codec supports it, gathered reference otherwise
    # — so mixed per-layer policies pick the fast path per segment; the
    # dsrv dispatch additionally runs it per-KV-head-shard when the engine
    # installed a mesh whose "kv_heads" rule divides the heads
    out = dsrv.dispatch_paged_decode_attention(cache, q[:, :, 0], page_table,
                                               backend=cfg.decode_backend)
    y = L.linear(out.reshape(s, 1, -1), params["wo"])
    if return_kv:
        return y, cache, (k, v)
    return y, cache


def attention_verify_span(params: Params, x: Array, cfg: ModelConfig,
                          cache: pgc.PagedKVCache, *, page_table: Array):
    """Speculative-span attention block: Q positions per slot in one
    batched forward, cache untouched (verify-then-commit — accepted
    positions are appended later via ``paged_append_span``).

    x: (S, Q, D) at absolute positions ``cache.lengths + [0, Q)``.
    Returns (y (S, Q, D), (k, v)) with the span's post-RoPE kv
    (S, Hkv, Q, hd) for the commit.
    """
    qn = x.shape[1]
    q = L.split_heads(L.linear(x, params["wq"], params.get("bq")),
                      cfg.num_heads)                      # (S, H, Q, hd)
    k = L.split_heads(L.linear(x, params["wk"], params.get("bk")),
                      cfg.num_kv_heads)
    v = L.split_heads(L.linear(x, params["wv"], params.get("bv")),
                      cfg.num_kv_heads)
    pos = cache.lengths[:, None] + jnp.arange(qn, dtype=jnp.int32)[None, :]
    q = L.apply_rope(q, pos, cfg.rope_base, cfg.rope_ntk_scale)
    k = L.apply_rope(k, pos, cfg.rope_base, cfg.rope_ntk_scale)
    out = pgc.span_verify_attention(cache, q, k, v, page_table)
    y = L.linear(L.merge_heads(out), params["wo"])
    return y, (k, v)


def make_cache(cfg: ModelConfig, batch: int, max_len: int,
               layer: int = 0) -> kvc.KVCache:
    """Allocate one layer's cache under ``cfg.policy.layer_config(layer)``
    (layer 0 == the uniform default for models without per-layer mixing)."""
    from repro.core.cache_layout import LinearLayout, RingLayout
    quant = cfg.policy.layer_config(layer)
    cap = max_len
    if cfg.window:
        cap = min(cap, cfg.window)
    g = quant.group_size
    cap = -(-cap // g) * g  # round up to a group multiple
    layout = RingLayout(cap) if cfg.window else LinearLayout(cap)
    return kvc.init_cache(quant, batch, cfg.num_kv_heads, cfg.head_dim,
                          cap, dtype=jnp.dtype(cfg.dtype), layout=layout)
