"""Decoder-only transformer LM (dense / MoE / prefix-LM VLM families).

Layer stack is scanned (jax.lax.scan over stacked layer params) with an
optional remat policy — the HLO stays O(1) in depth, which keeps 512-device
SPMD compiles tractable and bounds saved activations to one layer input per
layer (sharded via ctx.shard logical rules).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import kv_cache as kvc
from repro.distributed import ctx
from repro.models import layers as L
from repro.models import attn_block as AB
from repro.models import moe as MOE

Array = jax.Array
Params = dict


# ---------------------------------------------------------------------------
# Common LM pieces
# ---------------------------------------------------------------------------


def init_lm_common(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"embed": L.embed_init(k1, cfg.vocab_size, cfg.d_model),
         "final_norm": jnp.ones((cfg.d_model,), jnp.float32)}
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(k2, cfg.d_model, cfg.vocab_size)
    return p


def embed_tokens(params: Params, tokens: Array, cfg: ModelConfig) -> Array:
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.scale_embedding:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return ctx.shard(x, ("batch", None, None))


def lm_logits(params: Params, x: Array, cfg: ModelConfig) -> Array:
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ w.astype(h.dtype)
    return ctx.shard(logits, ("batch", None, "vocab"))


def lm_head_loss(params: Params, h: Array, labels: Array, cfg: ModelConfig,
                 ce_chunk: int = 512) -> Array:
    """Cross entropy WITHOUT materializing (B, T, V) fp32 logits.

    Scans the lm head over token chunks (rematted so the backward
    recomputes each chunk's logits instead of saving them) — the dominant
    train-memory term for large-vocab archs. ``ce_chunk=0`` falls back to
    the single-shot path (kept for A/B in EXPERIMENTS.md §Perf)."""
    if ce_chunk <= 0 or h.shape[1] <= ce_chunk or h.shape[1] % ce_chunk:
        return L.cross_entropy_loss(lm_logits(params, h, cfg), labels)
    b, t, d = h.shape
    nc = t // ce_chunk
    hc = h.reshape(b, nc, ce_chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, ce_chunk).transpose(1, 0, 2)

    def body(acc, xs):
        hx, lx = xs
        logits = lm_logits(params, hx, cfg)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logits.astype(jnp.float32),
                                 lx[..., None].clip(0), axis=-1)[..., 0]
        mask = (lx != -1).astype(jnp.float32)
        return (acc[0] + jnp.sum((lse - ll) * mask), acc[1] + jnp.sum(mask)), None

    body = jax.checkpoint(body, prevent_cse=False)
    (nll, n), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hc, lc))
    return nll / jnp.maximum(n, 1.0)


# ---------------------------------------------------------------------------
# Decoder block
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
         "ln2": jnp.ones((cfg.d_model,), jnp.float32),
         "attn": AB.init_attention(k1, cfg)}
    if cfg.family == "moe":
        p["ffn"] = MOE.init_moe(k2, cfg)
    else:
        p["ffn"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff)
    return p


def _ffn_apply(bp: Params, x: Array, cfg: ModelConfig):
    if cfg.family == "moe":
        return MOE.moe_ffn(bp["ffn"], x, cfg)
    return L.mlp(bp["ffn"], x, cfg.act), jnp.zeros((), jnp.float32)


def block_train(bp: Params, x: Array, cfg: ModelConfig, *, mask_mode: str,
                prefix_len: Optional[Array], window: int = 0):
    h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
    x = x + AB.attention_train(bp["attn"], h, cfg, mask_mode=mask_mode,
                               prefix_len=prefix_len, window=window)
    h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
    f, aux = _ffn_apply(bp, h, cfg)
    # 'seq' -> model: the remat-saved per-layer carry is stored
    # sequence-sharded (Megatron-style sequence parallelism); attention
    # re-gathers K/V as needed. Rules may map 'seq' to None to disable.
    return ctx.shard(x + f, ("batch", "seq", None)), aux


def block_prefill(bp: Params, x: Array, cfg: ModelConfig, cache, *,
                  mask_mode: str, prefix_len: Optional[Array],
                  window: int = 0):
    h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
    y, cache = AB.attention_prefill(bp["attn"], h, cfg, cache,
                                    mask_mode=mask_mode,
                                    prefix_len=prefix_len, window=window)
    x = x + y
    h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
    f, _ = _ffn_apply(bp, h, cfg)
    return x + f, cache


def block_decode(bp: Params, x: Array, cfg: ModelConfig, cache, *,
                 window: int = 0):
    h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
    y, cache = AB.attention_decode(bp["attn"], h, cfg, cache, window=window)
    x = x + y
    h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
    f, _ = _ffn_apply(bp, h, cfg)
    return x + f, cache


# ---------------------------------------------------------------------------
# Stacked-layer forward passes
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = init_lm_common(k1, cfg)
    p["layers"] = L.stack_layer_params(
        functools.partial(init_block, cfg=cfg), k2, cfg.num_layers)
    if cfg.family == "vlm":
        p["projector"] = L.dense_init(k3, cfg.frontend_dim, cfg.d_model)
    return p


def forward_hidden(params: Params, x: Array, cfg: ModelConfig, *,
                   mask_mode: str = "causal",
                   prefix_len: Optional[Array] = None,
                   remat: str = "block") -> tuple[Array, Array]:
    """Run the scanned layer stack. Returns (hidden, aux_loss_sum)."""

    def body(carry, lp):
        h, aux = carry
        h, a = block_train(lp, h, cfg, mask_mode=mask_mode,
                           prefix_len=prefix_len, window=cfg.window)
        return (h, aux + a), None

    if remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    return x, aux


def lm_loss(params: Params, batch: dict, cfg: ModelConfig,
            remat: str = "block", ce_chunk: int = 512):
    """batch['tokens']: (B, T+1) int32. Returns (loss, metrics)."""
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    mask_mode = "causal" if cfg.window == 0 else "local"
    x = embed_tokens(params, inputs, cfg)
    prefix_len = None
    if cfg.family == "vlm":
        patches = batch["patches"].astype(x.dtype)  # (B, np, fd)
        px = L.linear(patches, params["projector"])
        x = jnp.concatenate([px, x], axis=1)
        prefix_len = jnp.full((x.shape[0],), cfg.frontend_tokens, jnp.int32)
        mask_mode = "prefix"
    h, aux = forward_hidden(params, x, cfg, mask_mode=mask_mode,
                            prefix_len=prefix_len, remat=remat)
    if cfg.family == "vlm":
        h = h[:, cfg.frontend_tokens :]
    loss = lm_head_loss(params, h, labels, cfg, ce_chunk)
    return loss + aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode over per-segment stacked caches
#
# cfg.policy partitions the layer stack into contiguous segments of equal
# QuantConfig; each segment gets one stacked cache and one lax.scan over its
# layers (a uniform policy => a single segment, i.e. the classic one-scan
# stack). Mixed policies pay one scan per segment — HLO stays O(#segments),
# not O(depth).
# ---------------------------------------------------------------------------


def _stack_layers(n: int, tree):
    return jax.tree_util.tree_map(
        lambda a: jnp.zeros((n,) + a.shape, a.dtype), tree)


def _segment_params(layers, lo: int, hi: int):
    return jax.tree_util.tree_map(lambda a: a[lo:hi], layers)


def init_decode_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Tuple of per-segment stacked caches (see segment note above)."""
    return tuple(
        _stack_layers(hi - lo, AB.make_cache(cfg, batch, max_len, layer=lo))
        for lo, hi, _ in cfg.policy.segments(cfg.num_layers))


def init_paged_caches(cfg: ModelConfig, layout):
    """Per-segment stacked paged caches sharing one page-table numbering."""
    from repro.core import paged_cache as pgc
    return tuple(
        _stack_layers(hi - lo, pgc.init_paged_cache(
            quant, layout, cfg.num_kv_heads, cfg.head_dim,
            dtype=jnp.dtype(cfg.dtype)))
        for lo, hi, quant in cfg.policy.segments(cfg.num_layers))


def _scan_segments(params: Params, x: Array, caches, cfg: ModelConfig, body):
    """Run ``body`` over every layer, one lax.scan per policy segment."""
    out = []
    for (lo, hi, _), cache in zip(cfg.policy.segments(cfg.num_layers),
                                  caches):
        lp = _segment_params(params["layers"], lo, hi)
        x, cache = jax.lax.scan(body, x, (lp, cache))
        out.append(cache)
    return x, tuple(out)


def per_layer_cache_bytes(cfg: ModelConfig, caches) -> list[int]:
    """Physical cache bytes per layer, reported segment-by-segment (paged
    segments report each layer's share of its page pool)."""
    from repro.utils import tree_bytes
    out: list[int] = []
    for (lo, hi, _), cache in zip(cfg.policy.segments(cfg.num_layers),
                                  caches):
        out.extend([tree_bytes(cache) // (hi - lo)] * (hi - lo))
    return out


def prefill_fn(params: Params, batch: dict, cfg: ModelConfig, caches):
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens, cfg)
    mask_mode = "causal" if cfg.window == 0 else "local"
    prefix_len = None
    if cfg.family == "vlm":
        px = L.linear(batch["patches"].astype(x.dtype), params["projector"])
        x = jnp.concatenate([px, x], axis=1)
        prefix_len = jnp.full((x.shape[0],), cfg.frontend_tokens, jnp.int32)
        mask_mode = "prefix"

    def body(h, xs):
        lp, cache = xs
        h, cache = block_prefill(lp, h, cfg, cache, mask_mode=mask_mode,
                                 prefix_len=prefix_len, window=cfg.window)
        return h, cache

    x, caches = _scan_segments(params, x, caches, cfg, body)
    logits = lm_logits(params, x[:, -1:], cfg)
    return logits[:, 0], caches


def decode_fn(params: Params, caches, token: Array, cfg: ModelConfig):
    """token: (B,) int32 -> (logits (B, V), caches)."""
    x = embed_tokens(params, token[:, None], cfg)

    def body(h, xs):
        lp, cache = xs
        h, cache = block_decode(lp, h, cfg, cache, window=cfg.window)
        return h, cache

    x, caches = _scan_segments(params, x, caches, cfg, body)
    logits = lm_logits(params, x, cfg)
    return logits[:, 0], caches


# ---------------------------------------------------------------------------
# Continuous batching: per-request prefill + batched decode over paged caches
# ---------------------------------------------------------------------------


def _block_prefill_paged(bp: Params, x: Array, cfg: ModelConfig, cache, *,
                         slot, page_row, true_len):
    h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
    y, cache = AB.attention_prefill_paged(bp["attn"], h, cfg, cache,
                                          slot=slot, page_row=page_row,
                                          true_len=true_len)
    x = x + y
    h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
    f, _ = _ffn_apply(bp, h, cfg)
    return x + f, cache


def _block_decode_paged(bp: Params, x: Array, cfg: ModelConfig, cache, *,
                        page_table, active):
    h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
    y, cache = AB.attention_decode_paged(bp["attn"], h, cfg, cache,
                                         page_table=page_table,
                                         active=active)
    x = x + y
    h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
    f, _ = _ffn_apply(bp, h, cfg)
    return x + f, cache


def prefill_paged_fn(params: Params, tokens: Array, cfg: ModelConfig,
                     caches, slot: Array, page_row: Array, true_len: Array):
    """Prefill ONE request into its slot's pages.

    tokens: (1, Tp) int32, Tp a static bucket length (real prompt =
    first ``true_len`` tokens). Returns (last-real-token logits (1, V),
    caches).
    """
    x = embed_tokens(params, tokens, cfg)

    def body(h, xs):
        lp, cache = xs
        h, cache = _block_prefill_paged(lp, h, cfg, cache, slot=slot,
                                        page_row=page_row, true_len=true_len)
        return h, cache

    x, caches = _scan_segments(params, x, caches, cfg, body)
    last = jax.lax.dynamic_slice_in_dim(x, true_len - 1, 1, axis=1)
    logits = lm_logits(params, last, cfg)
    return logits[:, 0], caches


def _block_prefill_chunk(bp: Params, x: Array, cfg: ModelConfig, cache, *,
                         slot, page_row, start, chunk_len):
    h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
    y, cache = AB.attention_prefill_chunk(bp["attn"], h, cfg, cache,
                                          slot=slot, page_row=page_row,
                                          start=start, chunk_len=chunk_len)
    x = x + y
    h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
    f, _ = _ffn_apply(bp, h, cfg)
    return x + f, cache


def prefill_paged_chunk_fn(params: Params, tokens: Array, cfg: ModelConfig,
                           caches, slot: Array, page_row: Array,
                           start: Array, chunk_len: Array):
    """Prefill ONE fixed-size chunk of one request at absolute offset
    ``start`` (page-aligned; the engine drives chunks front to back).

    tokens: (1, Tc) int32, Tc the static chunk bucket (real tokens = first
    ``chunk_len``). Compiles once for the whole workload — every chunk of
    every prompt reuses the same (1, Tc) shape, unlike the per-bucket
    one-shot prefill. Chunk attention over the cached prefix dispatches
    per ``cfg.prefill_backend`` segment by segment (page-native fused
    kernel for codecs that support it, the gathering jnp reference
    otherwise). Returns (last-real-token logits (1, V), caches); the
    logits are meaningful only on a request's final chunk.
    """
    x = embed_tokens(params, tokens, cfg)

    def body(h, xs):
        lp, cache = xs
        h, cache = _block_prefill_chunk(lp, h, cfg, cache, slot=slot,
                                        page_row=page_row, start=start,
                                        chunk_len=chunk_len)
        return h, cache

    x, caches = _scan_segments(params, x, caches, cfg, body)
    last = jax.lax.dynamic_slice_in_dim(x, chunk_len - 1, 1, axis=1)
    logits = lm_logits(params, last, cfg)
    return logits[:, 0], caches


def copy_state_pages(caches, src: Array, dst: Array):
    """Copy pool page ``src`` -> ``dst`` across every segment's stacked
    page pools — the device half of a COW split (DESIGN.md §12)."""
    from repro.core import paged_cache as pgc
    return tuple(pgc.copy_pool_pages(c, src, dst) for c in caches)


def decode_paged_fn(params: Params, caches, token: Array, page_table: Array,
                    active: Array, cfg: ModelConfig):
    """Batched decode step over all slots. token: (S,) int32 ->
    (logits (S, V), caches). Inactive slots produce don't-care logits and
    leave their cache state untouched (lengths included).

    ``page_table`` may be width-sliced to the live pages (the engine's
    pow2 buckets): every layer segment — whichever codec its policy
    assigns — addresses pages through the same sliced table, and each
    segment's codec picks its own decode path (page-native where
    supported, gathered fallback otherwise)."""
    x = embed_tokens(params, token[:, None], cfg)

    def body(h, xs):
        lp, cache = xs
        h, cache = _block_decode_paged(lp, h, cfg, cache,
                                       page_table=page_table, active=active)
        return h, cache

    x, caches = _scan_segments(params, x, caches, cfg, body)
    logits = lm_logits(params, x, cfg)
    return logits[:, 0], caches


def decode_runahead_fn(params: Params, caches, token: Array,
                       page_table: Array, active: Array, key: Array,
                       remaining: Array, done: Array, cfg: ModelConfig, *,
                       horizon: int, temperature: float, top_k: int,
                       eos_id: int):
    """Run-ahead decode: ``horizon`` fused micro-steps in one dispatch
    (DESIGN.md §18) — a ``lax.scan`` whose body is exactly one vanilla
    decode step: paged append + LUT decode attention via
    :func:`decode_paged_fn`, one PRNG split, on-device sampling (the
    same math as ``serve.core._sample``: argmax at temperature <= 0,
    else temperature scaling + top-k masking + categorical), then
    on-device EOS/budget masking. The engine fetches the whole
    ``(horizon, S)`` token block with a single host sync instead of one
    per token.

    Carries: ``token (S,)`` the last sampled token per slot (fed at
    micro-step 0), ``key`` the session PRNG key, ``remaining (S,)`` the
    per-slot token budget left (``eff_max - done_tokens``), and
    ``done (S,)`` slots frozen by an earlier horizon. A slot freezes
    when it samples EOS or exhausts ``remaining``: it leaves ``active``,
    so its cache stops advancing (``paged_append`` routes frozen lanes
    to the scratch page — the pure residual/flush carry is what makes
    quant-group boundary commits inside the scan safe) and its token
    lane goes don't-care; the engine truncates its emission when the
    block lands.

    Bit-identity with the H=1 host loop is by construction: the key is
    split once per micro-step in which *any* slot is live — the same
    split points the host loop takes (one split per decode dispatch,
    and no dispatch once every slot finished) — so greedy *and*
    temperature>0 sampling reproduce the sequential token stream
    exactly.

    Returns ``(tokens (horizon, S), caches, token, key, done,
    remaining)``; the trailing carries seed the next pipelined horizon
    with no host round trip.
    """

    def micro_step(carry, _):
        caches, tok, key, done, rem = carry
        act = active & ~done
        logits, caches = decode_paged_fn(params, caches, tok, page_table,
                                         act, cfg)
        nkey, sub = jax.random.split(key)
        key = jnp.where(jnp.any(act), nkey, key)
        if temperature <= 0.0:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            logits = logits / temperature
            if top_k > 0:
                vals, _ = jax.lax.top_k(logits, top_k)
                logits = jnp.where(logits < vals[..., -1:], -1e30, logits)
            nxt = jax.random.categorical(sub, logits).astype(jnp.int32)
        nxt = jnp.where(act, nxt, tok)   # frozen slots hold their token
        rem = rem - act.astype(jnp.int32)
        done = done | (act & (rem <= 0))
        if eos_id >= 0:
            done = done | (act & (nxt == eos_id))
        return (caches, nxt, key, done, rem), nxt

    carry = (caches, token, key, done, remaining)
    (caches, token, key, done, remaining), toks = jax.lax.scan(
        micro_step, carry, None, length=horizon)
    return toks, caches, token, key, done, remaining


def decode_paged_collect_fn(params: Params, caches, token: Array,
                            page_table: Array, active: Array,
                            cfg: ModelConfig):
    """``decode_paged_fn`` that additionally returns every layer's
    post-RoPE (k, v) — the speculative verify scan (spec/verify.py) runs
    this per span position on a throwaway cache copy and later re-commits
    accepted positions' kv via :func:`commit_paged_fn`, so verification
    and the vanilla decode step share one graph (bit-identical logits).

    Returns (logits (S, V), caches, kvs) with ``kvs`` a per-segment tuple
    of ((Lseg, S, Hkv, 1, hd), ...) key/value pairs.
    """
    x = embed_tokens(params, token[:, None], cfg)

    def body(h, xs):
        lp, cache = xs
        h1 = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        y, cache, kv = AB.attention_decode_paged(
            lp["attn"], h1, cfg, cache, page_table=page_table,
            active=active, return_kv=True)
        h = h + y
        h2 = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        f, _ = _ffn_apply(lp, h2, cfg)
        return h + f, (cache, kv)

    out, kvs = [], []
    for (lo, hi, _), cache in zip(cfg.policy.segments(cfg.num_layers),
                                  caches):
        lp = _segment_params(params["layers"], lo, hi)
        x, (cache, kv) = jax.lax.scan(body, x, (lp, cache))
        out.append(cache)
        kvs.append(kv)
    logits = lm_logits(params, x, cfg)
    return logits[:, 0], tuple(out), tuple(kvs)


def verify_span_fn(params: Params, caches, tokens: Array,
                   page_table: Array, active: Array, cfg: ModelConfig):
    """Speculative verify forward: all Q span positions of every slot in
    ONE batched dispatch (vs. the per-position scan of
    :func:`decode_paged_collect_fn` — same math, ~Q× fewer op
    executions, which is what makes the spec step cheaper than Q decode
    steps). tokens: (S, Q) int32, column 0 the real next token, columns
    1..Q-1 the zero-padded drafts.

    Returns (logits (S, Q, V), kvs); the caches are NOT mutated — the
    engine commits accepted positions via :func:`commit_span_paged_fn`.
    ``kvs`` is a per-segment tuple of ((Lseg, S, Hkv, Q, hd) k, same v).
    Bitwise equal per column to the sequential decode graph as long as
    the engine's span clamp holds (``span <= g - lengths % g`` per slot;
    see ``paged_cache.span_verify_attention``). ``active`` only gates the
    later commit; inactive slots produce don't-care logits here.
    """
    del active  # verification is read-only; the commit masks by activity
    x = embed_tokens(params, tokens, cfg)

    def body(h, xs):
        lp, cache = xs
        h1 = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        y, kv = AB.attention_verify_span(lp["attn"], h1, cfg, cache,
                                         page_table=page_table)
        h = h + y
        h2 = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        f, _ = _ffn_apply(lp, h2, cfg)
        return h + f, kv

    kvs = []
    for (lo, hi, _), cache in zip(cfg.policy.segments(cfg.num_layers),
                                  caches):
        lp = _segment_params(params["layers"], lo, hi)
        x, kv = jax.lax.scan(body, x, (lp, cache))
        kvs.append(kv)
    logits = lm_logits(params, x, cfg)
    return logits, tuple(kvs)


def commit_span_paged_fn(caches, kvs, page_table: Array, n_keep: Array,
                         cfg: ModelConfig):
    """Commit the first ``n_keep[s]`` span positions of every slot in one
    fused multi-row append per layer (vs. the per-position scan of
    :func:`commit_paged_fn`): masked residual/value row writes plus at
    most one group-boundary flush encode — see
    ``paged_cache.paged_append_span``. ``kvs`` is the per-segment
    ((Lseg, S, Hkv, Q, hd), ...) layout :func:`verify_span_fn` returns."""
    from repro.core import paged_cache as pgc

    def body(carry, xs):
        cache, k, v = xs
        return carry, pgc.paged_append_span(cache, k, v, page_table, n_keep)

    out = []
    for cache, (k, v) in zip(caches, kvs):
        _, cache = jax.lax.scan(body, 0, (cache, k, v))
        out.append(cache)
    return tuple(out)


def commit_paged_fn(caches, kvs, page_table: Array, active: Array,
                    cfg: ModelConfig):
    """Append one span position's saved per-layer (k, v) through the
    standard ``paged_append`` path (residual rounding, group flush,
    masked lengths). No model forward happens here — the kv were captured
    by :func:`decode_paged_collect_fn` during verification; only slots
    with ``active`` advance."""
    from repro.core import paged_cache as pgc

    def body(carry, xs):
        cache, k, v = xs
        return carry, pgc.paged_append(cache, k, v, page_table, active)

    out = []
    for cache, (k, v) in zip(caches, kvs):
        _, cache = jax.lax.scan(body, 0, (cache, k, v))
        out.append(cache)
    return tuple(out)
