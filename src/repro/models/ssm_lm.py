"""Mamba-2 language model: scanned stack of (RMSNorm -> SSD mixer) blocks.

Attention-free: decode state is (conv window, SSD state) per layer — O(1)
in sequence length, so decode_32k and long_500k lower with tiny state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import transformer as TF

Array = jax.Array
Params = dict


def init_params(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = TF.init_lm_common(k1, cfg)
    p["layers"] = L.stack_layer_params(
        functools.partial(M2.init_mamba_layer, cfg=cfg), k2, cfg.num_layers)
    return p


def lm_loss(params: Params, batch: dict, cfg: ModelConfig,
            remat: str = "block", ce_chunk: int = 512):
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    x = TF.embed_tokens(params, inputs, cfg)

    from repro.distributed import ctx

    def body(h, lp):
        y = M2.mamba_mix(lp, L.rms_norm(h, lp["ln"], cfg.norm_eps), cfg)
        # remat-saved carry stored sequence-sharded (layer re-gathers T;
        # compute stays head-sharded) — see EXPERIMENTS.md §Perf mamba v5
        return ctx.shard(h + y, ("batch", "seq", None)), None

    if remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    loss = TF.lm_head_loss(params, x, labels, cfg, ce_chunk)
    return loss, {"ce": loss}


def init_decode_state(cfg: ModelConfig, batch: int):
    single = M2.init_state(cfg, batch)
    return jax.tree_util.tree_map(
        lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), single)


def prefill_fn(params: Params, batch: dict, cfg: ModelConfig, state):
    x = TF.embed_tokens(params, batch["tokens"], cfg)

    def body(h, xs):
        lp, _unused = xs
        y, st = M2.mamba_mix(lp, L.rms_norm(h, lp["ln"], cfg.norm_eps), cfg,
                             want_state=True)
        return h + y, st

    x, state = jax.lax.scan(body, x, (params["layers"], state))
    logits = TF.lm_logits(params, x[:, -1:], cfg)
    return logits[:, 0], state


def decode_fn(params: Params, state, token: Array, cfg: ModelConfig):
    x = TF.embed_tokens(params, token[:, None], cfg)

    def body(h, xs):
        lp, st = xs
        y, st = M2.mamba_step(lp, L.rms_norm(h, lp["ln"], cfg.norm_eps)[:, 0],
                              cfg, st)
        return h + y[:, None], st

    x, state = jax.lax.scan(body, x, (params["layers"], state))
    logits = TF.lm_logits(params, x, cfg)
    return logits[:, 0], state
