"""Shared model building blocks (pure functions + dict params).

Parameters are plain nested dicts of fp32 arrays (master copies); compute
casts to the config dtype at use. Layer-stacked variants (for
scan-over-layers) are built with jax.vmap over init functions.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array
Params = dict


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, *, scale: float | None = None) -> Array:
    scale = (1.0 / math.sqrt(d_in)) if scale is None else scale
    return jax.random.normal(key, (d_in, d_out), jnp.float32) * scale


def embed_init(key, vocab: int, d: int) -> Array:
    return jax.random.normal(key, (vocab, d), jnp.float32) * 0.02


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------


def linear(x: Array, w: Array, b: Array | None = None) -> Array:
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def rms_norm(x: Array, w: Array, eps: float = 1e-6) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def swiglu(x: Array, wg: Array, wu: Array, wd: Array, act: str = "silu") -> Array:
    g = linear(x, wg)
    u = linear(x, wu)
    a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return linear(a * u, wd)


def init_mlp(key, d: int, d_ff: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wg": dense_init(k1, d, d_ff), "wu": dense_init(k2, d, d_ff),
            "wd": dense_init(k3, d_ff, d)}


def mlp(params: Params, x: Array, act: str = "silu") -> Array:
    return swiglu(x, params["wg"], params["wu"], params["wd"], act)


# ---------------------------------------------------------------------------
# RoPE ("half" pairing: dims (j, j+d/2) rotate together — matches the polar
# quantizer's pairing convention; see core/polar.py)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, base: float,
                     ntk_scale: float = 1.0) -> Array:
    """Inverse frequencies; ``ntk_scale > 1`` applies NTK-aware base
    scaling (paper Appendix C: PolarQuant under context extension) —
    base' = base * s^(d/(d-2))."""
    half = head_dim // 2
    if ntk_scale != 1.0:
        base = base * ntk_scale ** (head_dim / max(head_dim - 2, 1))
    return base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x: Array, positions: Array, base: float,
               ntk_scale: float = 1.0) -> Array:
    """x: (B, H, T, d); positions: (T,) or (B, T) int32."""
    d = x.shape[-1]
    inv = rope_frequencies(d, base, ntk_scale)           # (d/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., T, d/2)
    cos = jnp.cos(ang)
    sin = jnp.sin(ang)
    if positions.ndim == 1:
        cos, sin = cos[None, None], sin[None, None]       # (1,1,T,d/2)
    else:
        cos, sin = cos[:, None], sin[:, None]             # (B,1,T,d/2)
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : d // 2], x32[..., d // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------


def split_heads(x: Array, num_heads: int) -> Array:
    """(B, T, H*d) -> (B, H, T, d)."""
    b, t, hd = x.shape
    return x.reshape(b, t, num_heads, hd // num_heads).transpose(0, 2, 1, 3)


def merge_heads(x: Array) -> Array:
    """(B, H, T, d) -> (B, T, H*d)."""
    b, h, t, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * d)


def stack_layer_params(init_fn, key, num_layers: int) -> Params:
    """vmap an init over layer keys -> params with leading (L,) axis."""
    keys = jax.random.split(key, num_layers)
    return jax.vmap(init_fn)(keys)


def cross_entropy_loss(logits: Array, labels: Array,
                       ignore_id: int = -1) -> Array:
    """Mean token cross entropy in fp32. logits: (..., V), labels: (...)."""
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    ll = jnp.take_along_axis(logits32, labels[..., None].clip(0), axis=-1)[..., 0]
    nll = lse - ll
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
