"""Hybrid RG-LRU + local-attention LM (RecurrentGemma, arXiv:2402.19427).

Layer types follow ``cfg.block_pattern`` cyclically (("rec","rec","attn")
for the assigned config). The stack is scanned over *pattern periods*
(heterogeneous params per period stay homogeneous across periods), with a
trailing scan over leftover layers — HLO stays O(pattern) in depth.

Local attention uses the ring quantized KV cache (capacity == window) —
this is where PolarQuant applies in this family; the RG-LRU state is fp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import attn_block as AB
from repro.models import rglru as RG
from repro.models import transformer as TF

Array = jax.Array
Params = dict


def layer_types(cfg: ModelConfig) -> list[str]:
    pat = cfg.block_pattern or ("attn",)
    return [pat[i % len(pat)] for i in range(cfg.num_layers)]


def _period_split(cfg: ModelConfig) -> tuple[int, list[str], list[str]]:
    pat = list(cfg.block_pattern or ("attn",))
    n_periods = cfg.num_layers // len(pat)
    tail = layer_types(cfg)[n_periods * len(pat) :]
    if len(set(tail)) > 1:
        raise ValueError("tail layers must be homogeneous")
    return n_periods, pat, tail


def init_sub_layer(key, cfg: ModelConfig, kind: str) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
         "ln2": jnp.ones((cfg.d_model,), jnp.float32),
         "ffn": L.init_mlp(k2, cfg.d_model, cfg.d_ff)}
    if kind == "attn":
        p["mix"] = AB.init_attention(k1, cfg)
    else:
        p["mix"] = RG.init_rglru_layer(k1, cfg)
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    n_periods, pat, tail = _period_split(cfg)
    keys = jax.random.split(key, 2 + len(pat))
    p = TF.init_lm_common(keys[0], cfg)
    p["periods"] = {
        f"sub{i}_{kind}": L.stack_layer_params(
            functools.partial(init_sub_layer, cfg=cfg, kind=kind),
            keys[2 + i], n_periods)
        for i, kind in enumerate(pat)
    }
    if tail:
        p["tail"] = L.stack_layer_params(
            functools.partial(init_sub_layer, cfg=cfg, kind=tail[0]),
            keys[1], len(tail))
    return p


def _sub_train(lp: Params, x: Array, cfg: ModelConfig, kind: str) -> Array:
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    if kind == "attn":
        y = AB.attention_train(lp["mix"], h, cfg, mask_mode="local",
                               window=cfg.window)
    else:
        y = RG.rglru_mix(lp["mix"], h, cfg)
    x = x + y
    f = L.mlp(lp["ffn"], L.rms_norm(x, lp["ln2"], cfg.norm_eps), cfg.act)
    from repro.distributed import ctx
    return ctx.shard(x + f, ("batch", "seq", None))


def lm_loss(params: Params, batch: dict, cfg: ModelConfig,
            remat: str = "block", ce_chunk: int = 512):
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    x = TF.embed_tokens(params, inputs, cfg)
    n_periods, pat, tail = _period_split(cfg)

    def period_body(h, lps):
        for i, kind in enumerate(pat):
            h = _sub_train(lps[f"sub{i}_{kind}"], h, cfg, kind)
        return h, None

    body = period_body
    if remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["periods"])
    if tail:
        def tail_body(h, lp):
            return _sub_train(lp, h, cfg, tail[0]), None
        if remat != "none":
            tail_body = jax.checkpoint(tail_body, prevent_cse=False)
        x, _ = jax.lax.scan(tail_body, x, params["tail"])
    loss = TF.lm_head_loss(params, x, labels, cfg, ce_chunk)
    return loss, {"ce": loss}


# ---------------------------------------------------------------------------
# Serving (state = ring KV caches for attn subs + (conv, h) for rec subs)
# ---------------------------------------------------------------------------


def _stack(n: int, tree):
    return jax.tree_util.tree_map(
        lambda a: jnp.zeros((n,) + a.shape, a.dtype), tree)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    n_periods, pat, tail = _period_split(cfg)
    state = {"periods": {}}
    for i, kind in enumerate(pat):
        sub = (AB.make_cache(cfg, batch, max_len) if kind == "attn"
               else RG.init_state(cfg, batch))
        state["periods"][f"sub{i}_{kind}"] = _stack(n_periods, sub)
    if tail:
        sub = (AB.make_cache(cfg, batch, max_len) if tail[0] == "attn"
               else RG.init_state(cfg, batch))
        state["tail"] = _stack(len(tail), sub)
    return state


def _sub_prefill(lp, h, cfg, kind, sub_state):
    hn = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
    if kind == "attn":
        y, sub_state = AB.attention_prefill(lp["mix"], hn, cfg, sub_state,
                                            mask_mode="local",
                                            window=cfg.window)
    else:
        y, sub_state = RG.rglru_mix(lp["mix"], hn, cfg, want_state=True)
    h = h + y
    f = L.mlp(lp["ffn"], L.rms_norm(h, lp["ln2"], cfg.norm_eps), cfg.act)
    return h + f, sub_state


def _sub_decode(lp, h, cfg, kind, sub_state):
    hn = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
    if kind == "attn":
        y, sub_state = AB.attention_decode(lp["mix"], hn, cfg, sub_state,
                                           window=cfg.window)
    else:
        y, sub_state = RG.rglru_step(lp["mix"], hn[:, 0], cfg, sub_state)
        y = y[:, None]
    h = h + y
    f = L.mlp(lp["ffn"], L.rms_norm(h, lp["ln2"], cfg.norm_eps), cfg.act)
    return h + f, sub_state


def _run_stack(params, state, x, cfg, step_fn):
    n_periods, pat, tail = _period_split(cfg)

    def period_body(h, xs):
        lps, subs = xs
        new_subs = {}
        for i, kind in enumerate(pat):
            key = f"sub{i}_{kind}"
            h, new_subs[key] = step_fn(lps[key], h, cfg, kind, subs[key])
        return h, new_subs

    x, new_periods = jax.lax.scan(
        period_body, x, (params["periods"], state["periods"]))
    new_state = {"periods": new_periods}
    if tail:
        def tail_body(h, xs):
            lp, sub = xs
            h, sub = step_fn(lp, h, cfg, tail[0], sub)
            return h, sub
        x, new_tail = jax.lax.scan(tail_body, x, (params["tail"], state["tail"]))
        new_state["tail"] = new_tail
    return x, new_state


def prefill_fn(params: Params, batch: dict, cfg: ModelConfig, state):
    x = TF.embed_tokens(params, batch["tokens"], cfg)
    x, state = _run_stack(params, state, x, cfg, _sub_prefill)
    logits = TF.lm_logits(params, x[:, -1:], cfg)
    return logits[:, 0], state


def decode_fn(params: Params, state, token: Array, cfg: ModelConfig):
    x = TF.embed_tokens(params, token[:, None], cfg)
    x, state = _run_stack(params, state, x, cfg, _sub_decode)
    logits = TF.lm_logits(params, x, cfg)
    return logits[:, 0], state
