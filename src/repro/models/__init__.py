"""Model zoo: dense/MoE/enc-dec/VLM transformers, Mamba-2 SSD, RG-LRU hybrid."""
from repro.models.registry import Model, get_model  # noqa: F401
