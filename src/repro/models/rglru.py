"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrence: h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t), with
a_t = exp(-c * softplus(Lambda) * r_t), c = 8, and r/i gates computed by
block-diagonal linears from the (causally convolved) input branch. Training
uses jax.lax.associative_scan over time (log-depth); decode is an O(1)
state update. State = (conv window, h) — bounded, so the ``long_500k``
shape is well-defined for the hybrid family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import ctx
from repro.models import layers as L

Array = jax.Array
Params = dict
_C = 8.0


def init_rglru_layer(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    w = cfg.lru_width or d
    nb = max(cfg.num_heads, 1)
    bs = w // nb
    k = jax.random.split(key, 7)
    # Lambda init so that a^c is roughly uniform in [0.9, 0.999].
    u = jax.random.uniform(k[5], (w,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.exp(-jnp.log(u) / (2 * _C)) - 1.0)  # softplus^-1
    return {
        "w_gate": L.dense_init(k[0], d, w),
        "w_in": L.dense_init(k[1], d, w),
        "conv_w": jax.random.normal(k[2], (cfg.conv1d_width, w),
                                    jnp.float32) * 0.2,
        "conv_b": jnp.zeros((w,), jnp.float32),
        "rg_w": jax.random.normal(k[3], (nb, bs, bs), jnp.float32) / bs ** 0.5,
        "rg_b": jnp.zeros((w,), jnp.float32),
        "ig_w": jax.random.normal(k[4], (nb, bs, bs), jnp.float32) / bs ** 0.5,
        "ig_b": jnp.zeros((w,), jnp.float32),
        "lam": lam,
        "w_out": L.dense_init(k[6], w, d),
    }


def _block_diag(x: Array, w: Array, b: Array) -> Array:
    """x: (..., W) -> block-diagonal linear with w: (NB, bs, bs)."""
    nb, bs, _ = w.shape
    xb = x.reshape(*x.shape[:-1], nb, bs)
    y = jnp.einsum("...nb,nbc->...nc", xb.astype(jnp.float32),
                   w.astype(jnp.float32))
    return y.reshape(*x.shape) + b


def _causal_conv(u: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv via one lax.conv (see mamba2._causal_conv)."""
    wn, c = w.shape
    dn = jax.lax.conv_dimension_numbers(u.shape, (wn, 1, c),
                                        ("NWC", "WIO", "NWC"))
    out = jax.lax.conv_general_dilated(
        u, w[:, None, :].astype(u.dtype), window_strides=(1,),
        padding=[(wn - 1, 0)], dimension_numbers=dn, feature_group_count=c)
    return out + b.astype(u.dtype)


def _gates(params: Params, u: Array):
    r = jax.nn.sigmoid(_block_diag(u, params["rg_w"], params["rg_b"]))
    i = jax.nn.sigmoid(_block_diag(u, params["ig_w"], params["ig_b"]))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * u.astype(jnp.float32)
    return a, gated


def rglru_mix(params: Params, x: Array, cfg: ModelConfig, initial=None,
              want_state: bool = False):
    """The Griffin recurrent mixer. x: (B, T, D) (post layer-norm)."""
    y_gate = jax.nn.gelu(L.linear(x, params["w_gate"]))
    y_gate = ctx.shard(y_gate, ("batch", None, "rec_width"))
    u_raw = ctx.shard(L.linear(x, params["w_in"]),
                      ("batch", None, "rec_width"))
    cw = cfg.conv1d_width
    if initial is not None:
        conv_state0, h0 = initial
        padded = jnp.concatenate([conv_state0.astype(u_raw.dtype), u_raw], 1)
        u = _causal_conv(padded, params["conv_w"], params["conv_b"])[:, cw - 1 :]
    else:
        h0 = None
        u = _causal_conv(u_raw, params["conv_w"], params["conv_b"])
    a, gated = _gates(params, u)

    if h0 is not None:
        # fold the carried state in as a virtual step 0
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        gated = jnp.concatenate([h0[:, None].astype(jnp.float32), gated], axis=1)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    out = L.linear((y_gate.astype(jnp.float32) * h).astype(x.dtype),
                   params["w_out"])
    if want_state:
        # conv state holds raw (pre-conv) inputs
        if initial is not None:
            conv_tail = jnp.concatenate(
                [conv_state0.astype(u_raw.dtype), u_raw], axis=1)[:, -(cw - 1):]
        else:
            conv_tail = _tail_pad(u_raw, cw - 1)
        return out, (conv_tail, h[:, -1])
    return out


def _tail_pad(u: Array, n: int) -> Array:
    t = u.shape[1]
    if t >= n:
        return u[:, t - n :]
    return jnp.pad(u, ((0, 0), (n - t, 0), (0, 0)))


def rglru_step(params: Params, x_t: Array, cfg: ModelConfig, state):
    """Single-token step. x_t: (B, D); state = (conv (B, cw-1, W), h (B, W))."""
    conv_state, h = state
    y_gate = jax.nn.gelu(L.linear(x_t, params["w_gate"]))
    u_raw = L.linear(x_t, params["w_in"])
    window = jnp.concatenate([conv_state, u_raw[:, None]], axis=1)
    u = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                   params["conv_w"].astype(jnp.float32)) + params["conv_b"]
    u = u.astype(x_t.dtype)
    a, gated = _gates(params, u)
    h = a * h + gated
    out = L.linear((y_gate.astype(jnp.float32) * h).astype(x_t.dtype),
                   params["w_out"])
    return out, (window[:, 1:], h)


def init_state(cfg: ModelConfig, batch: int):
    w = cfg.lru_width or cfg.d_model
    return (jnp.zeros((batch, cfg.conv1d_width - 1, w), jnp.dtype(cfg.dtype)),
            jnp.zeros((batch, w), jnp.float32))
