"""Pallas TPU kernel: fused dequantization + query-key scores for PolarQuant.

Paper-faithful analogue of the Triton kernel in Appendix A, adapted to the
TPU memory/compute hierarchy (DESIGN.md §3):

* the per-(group, channel-pair) angle LUT ``A[j, a]`` is built in VMEM from
  the (gb, P) theta scale/zero tiles (one fused cos/sin pass per angle state);
* the "gather" ``A[j, theta_code]`` is a compare/select tree over the 2^t
  angle states — fully lane-parallel on the VPU, no per-element gather;
* the radius is reconstructed with a single FMA (affine in its code), never
  a table;
* codes arrive packed ((rho << t) | theta, one uint8 per channel pair =
  (r+t)/2 bits per key element) and are unpacked with shift/mask in-kernel,
  so HBM traffic is ~4x lower than bf16 keys — the roofline win for
  memory-bound decode.

Grid: (B, Hkv, G/gb). Each step processes ``gb`` quantization groups
(gb*g tokens) for all ``Qh`` query heads of one KV head.
VMEM per step ~= gb*g*P (codes) + 4*gb*P*4 (scales) + Qh*d*4 (q)
             + Qh*gb*g*4 (out tile): gb=4, g=128, P=64, Qh=8, d=128
             => 32KiB + 4KiB + 4KiB + 128KiB ~ 170KiB  << 16MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _qk_kernel(q_ref, codes_ref, rs_ref, rz_ref, ts_ref, tz_ref, out_ref, *,
               r_bits: int, t_bits: int):
    qh, d = q_ref.shape[2], q_ref.shape[3]
    p = d // 2
    q = q_ref[0, 0].astype(jnp.float32)             # (Qh, d)
    qx, qy = q[:, :p], q[:, p:]                     # "half" pairing
    codes = codes_ref[0, 0]                         # (gb, g, P) uint8
    gb, g, _ = codes.shape
    tc = (codes & ((1 << t_bits) - 1)).astype(jnp.int32)
    rc = (codes >> t_bits).astype(jnp.float32)
    rs = rs_ref[0, 0, :, 0].astype(jnp.float32)     # (gb, P)
    rz = rz_ref[0, 0, :, 0].astype(jnp.float32)
    ts = ts_ref[0, 0, :, 0].astype(jnp.float32)
    tz = tz_ref[0, 0, :, 0].astype(jnp.float32)

    rho = (rc + 0.5) * rs[:, None, :] + rz[:, None, :]          # (gb, g, P)

    # Angle LUT + select-tree over the 2^t states.
    gathered = jnp.zeros((qh, gb, g, p), jnp.float32)
    for a in range(1 << t_bits):
        theta = (a + 0.5) * ts + tz                              # (gb, P)
        cos_t = jnp.cos(theta - jnp.pi)
        sin_t = jnp.sin(theta - jnp.pi)
        a_tab = (qx[:, None, :] * cos_t[None] +
                 qy[:, None, :] * sin_t[None])                   # (Qh, gb, P)
        gathered = gathered + jnp.where(
            (tc == a)[None], a_tab[:, :, None, :], 0.0)

    scores = jnp.sum(rho[None] * gathered, axis=-1)              # (Qh, gb, g)
    out_ref[0, 0] = scores.reshape(qh, gb * g)


@functools.partial(jax.jit, static_argnames=("r_bits", "t_bits",
                                             "block_groups", "interpret"))
def polar_qk_scores(q: Array, codes: Array, rs: Array, rz: Array, ts: Array,
                    tz: Array, *, r_bits: int = 4, t_bits: int = 4,
                    block_groups: int = 4, interpret: bool = True) -> Array:
    """LUT q.K scores. Shapes as in ref.ref_polar_qk_scores.

    q: (B, Hkv, Qh, d); codes: (B, Hkv, G, g, P); stats: (B, Hkv, G, 1, P).
    Returns (B, Hkv, Qh, G*g) fp32.
    """
    b, hkv, qh, d = q.shape
    _, _, gcount, g, p = codes.shape
    assert p * 2 == d, (p, d)
    gb = min(block_groups, gcount)
    while gcount % gb:
        gb -= 1
    nb = gcount // gb

    kern = functools.partial(_qk_kernel, r_bits=r_bits, t_bits=t_bits)
    stat_spec = pl.BlockSpec((1, 1, gb, 1, p), lambda i, j, n: (i, j, n, 0, 0))
    return pl.pallas_call(
        kern,
        grid=(b, hkv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, qh, d), lambda i, j, n: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, gb, g, p), lambda i, j, n: (i, j, n, 0, 0)),
            stat_spec, stat_spec, stat_spec, stat_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, qh, gb * g), lambda i, j, n: (i, j, 0, n)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, qh, gcount * g), jnp.float32),
        interpret=interpret,
    )(q, codes, rs, rz, ts, tz)
