"""Public jit'd wrappers around the Pallas kernels with ref fallbacks.

``backend``:
  * ``"pallas"``    — pl.pallas_call targeting TPU (interpret=False)
  * ``"interpret"`` — same kernel body executed in Python on CPU (default
                       here: this container has no TPU)
  * ``"ref"``       — pure-jnp oracle (fastest on CPU, used inside jitted
                       serving steps and the dry-run)

`polar_decode_attention_full` is the end-to-end decode-attention entry:
kernel partials over the grouped cache segment merged exactly with the fp
residual segment (associative online-softmax merge).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_mod
from repro.kernels.polar_qk import polar_qk_scores as _qk_pallas
from repro.kernels.polar_encode import polar_encode as _encode_pallas
from repro.kernels.polar_attention import (
    polar_decode_attention_grouped as _attn_pallas,
)
from repro.kernels.paged_decode import (
    polar_paged_decode_grouped as _paged_attn_pallas,
)
from repro.kernels.paged_prefill import (
    polar_paged_prefill_grouped as _paged_prefill_pallas,
)

Array = jax.Array
NEG_INF = -1e30
DEFAULT_BACKEND = "ref"
BACKENDS = ("ref", "interpret", "pallas")


def _check_backend(backend: str) -> None:
    if backend not in BACKENDS:
        raise ValueError(f"unknown kernel backend {backend!r}; "
                         f"expected one of {BACKENDS}")


def polar_qk_scores(q, codes, rs, rz, ts, tz, *, r_bits=4, t_bits=4,
                    backend: str = DEFAULT_BACKEND, block_groups: int = 4):
    _check_backend(backend)
    if backend == "ref":
        return ref_mod.ref_polar_qk_scores(q, codes, rs, rz, ts, tz,
                                           r_bits=r_bits, t_bits=t_bits)
    return _qk_pallas(q, codes, rs, rz, ts, tz, r_bits=r_bits, t_bits=t_bits,
                      block_groups=block_groups,
                      interpret=(backend == "interpret"))


def polar_encode(k, *, r_bits=4, t_bits=4, group_size=128,
                 scale_dtype="float32", backend: str = DEFAULT_BACKEND):
    _check_backend(backend)
    if backend == "ref":
        return ref_mod.ref_polar_encode(k, r_bits=r_bits, t_bits=t_bits,
                                        group_size=group_size,
                                        scale_dtype=scale_dtype)
    return _encode_pallas(k, r_bits=r_bits, t_bits=t_bits,
                          group_size=group_size, scale_dtype=scale_dtype,
                          interpret=(backend == "interpret"))


def polar_decode_attention_grouped(q, codes, rs, rz, ts, tz, values, vscale,
                                   vzero, length, *, r_bits=4, t_bits=4,
                                   backend: str = DEFAULT_BACKEND,
                                   block_groups: int = 4):
    _check_backend(backend)
    if backend == "ref":
        if vscale is not None:
            values = (values.astype(jnp.float32) * vscale.astype(jnp.float32)
                      + vzero.astype(jnp.float32))
        return ref_mod.ref_polar_decode_attention(
            q, codes, rs, rz, ts, tz, values, length,
            r_bits=r_bits, t_bits=t_bits, softmax_scale=1.0)
    return _attn_pallas(q, codes, rs, rz, ts, tz, values, vscale, vzero,
                        length, r_bits=r_bits, t_bits=t_bits,
                        block_groups=block_groups,
                        interpret=(backend == "interpret"))


def polar_paged_decode_attention_grouped(q, codes, rs, rz, ts, tz, values,
                                         vscale, vzero, page_table, flushed,
                                         *, r_bits=4, t_bits=4,
                                         backend: str = DEFAULT_BACKEND):
    """Page-native fused flash-decode over the grouped segment: pool
    buffers + page table in, flash partials out (no dense gather copy)."""
    _check_backend(backend)
    if backend == "ref":
        return ref_mod.ref_polar_paged_decode_attention(
            q, codes, rs, rz, ts, tz, values, vscale, vzero, page_table,
            flushed, r_bits=r_bits, t_bits=t_bits)
    return _paged_attn_pallas(q, codes, rs, rz, ts, tz, values, vscale,
                              vzero, page_table, flushed, r_bits=r_bits,
                              t_bits=t_bits,
                              interpret=(backend == "interpret"))


def polar_paged_prefill_attention(q, k_chunk, v_chunk, codes, rs, rz, ts,
                                  tz, values, vscale, vzero, page_row,
                                  start, chunk_len, *, r_bits=4, t_bits=4,
                                  softmax_scale: float | None = None,
                                  backend: str = DEFAULT_BACKEND):
    """Page-native fused chunk-prefill attention: one chunk's queries
    against the slot's quantized prefix pages (LUT scores, in-place page
    walk) + the chunk's own fp causal tile, one online softmax.

    q: (1, Hq, Tc, d) UNscaled post-RoPE queries; k_chunk/v_chunk:
    (1, Hkv, Tc, d); pools as in :func:`polar_paged_decode_attention_grouped`;
    page_row: (N,) int32; start (page-aligned) / chunk_len: () int32.
    Returns (1, Hq, Tc, d) in q.dtype.
    """
    _check_backend(backend)
    if backend == "ref":
        return ref_mod.ref_polar_paged_prefill_attention(
            q, k_chunk, v_chunk, codes, rs, rz, ts, tz, values, vscale,
            vzero, page_row, start, chunk_len, r_bits=r_bits, t_bits=t_bits,
            softmax_scale=softmax_scale)
    _, hq, tc, d = q.shape
    hkv = codes.shape[1]
    qpk = hq // hkv
    scale = d ** -0.5 if softmax_scale is None else softmax_scale
    # fold chunk queries onto the head axis (row = qh * Tc + t) and
    # pre-scale — the kernel consumes one tall 2-D operand per kv head
    qf = (q.astype(jnp.float32) * scale).reshape(hkv, qpk * tc, d)
    out = _paged_prefill_pallas(
        qf, k_chunk[0], v_chunk[0], codes, rs, rz, ts, tz, values, vscale,
        vzero, page_row, start, chunk_len, r_bits=r_bits, t_bits=t_bits,
        interpret=(backend == "interpret"))
    return out.reshape(1, hq, tc, d).astype(q.dtype)


def merge_softmax_partials(parts: list[tuple[Array, Array, Array]]) -> Array:
    """Exactly merge flash partials [(acc, m, l), ...] -> normalized output.

    acc: (..., d) = sum exp(s - m) v;  m, l: (...,).
    """
    m_tot = functools.reduce(jnp.maximum, [m for _, m, _ in parts])
    l_tot = 0.0
    acc_tot = 0.0
    for acc, m, l in parts:
        corr = jnp.exp(m - m_tot)
        l_tot = l_tot + l * corr
        acc_tot = acc_tot + acc * corr[..., None]
    l_safe = jnp.where(l_tot == 0.0, 1.0, l_tot)
    return acc_tot / l_safe[..., None]


def _residual_flash_partials(q4: Array, key_residual: Array, n_res: Array,
                             v_res: Array):
    """Flash partials of the fp residual segment, shared by the dense and
    paged full-decode entries.

    q4: (B, Hkv, Qh, d) ALREADY scaled; key_residual: (B, Hkv, g, d);
    n_res: (B,) tokens in the residual window; v_res: (B, Hkv, g, d) fp32
    value rows for positions [flushed, flushed + g) — dead rows may hold
    garbage (clamped gathers, scratch pages) and are zeroed under the
    mask here, so ``p == 0`` lanes can never contribute ``0 * NaN``.
    Returns (acc_r, m_r, l_r).
    """
    res = key_residual.astype(jnp.float32)
    g = res.shape[2]
    s_res = jnp.einsum("bhqd,bhgd->bhqg", q4, res)
    slot = jnp.arange(g, dtype=jnp.int32)
    mask = slot[None, None, None, :] < n_res[:, None, None, None]
    s_res = jnp.where(mask, s_res, NEG_INF)
    m_r = jnp.max(s_res, axis=-1)
    p_r = jnp.where(mask, jnp.exp(s_res - m_r[..., None]), 0.0)
    l_r = jnp.sum(p_r, axis=-1)
    row_live = slot[None, :] < n_res[:, None]
    v_res = jnp.where(row_live[:, None, :, None], v_res, 0.0)
    acc_r = jnp.einsum("bhqg,bhgd->bhqd", p_r, v_res)
    return acc_r, m_r, l_r


def polar_decode_attention_full(
    q: Array, codes, rs, rz, ts, tz, key_residual, values, vscale, vzero,
    length: Array, *, r_bits=4, t_bits=4, softmax_scale: float | None = None,
    backend: str = DEFAULT_BACKEND, block_groups: int = 4,
) -> Array:
    """Full decode attention: grouped (quantized) segment via kernel +
    fp residual segment, merged exactly.

    q: (B, Hq, d); key_residual: (B, Hkv, g, d); values: (B, Hkv, T, d) or
    uint8 codes (+ vscale/vzero (B,Hkv,T,1)); length: () or (B,) total
    tokens — per-sequence lengths mask each continuous-batching slot at its
    own decode position. Returns (B, Hq, d) in q.dtype.
    """
    b, hq, d = q.shape
    hkv = codes.shape[1]
    g = codes.shape[3]
    qpk = hq // hkv
    scale = d ** -0.5 if softmax_scale is None else softmax_scale
    q4 = (q.astype(jnp.float32) * scale).reshape(b, hkv, qpk, d)
    len_b = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
    flushed = (len_b // g) * g                                   # (B,)

    acc_g, m_g, l_g = polar_decode_attention_grouped(
        q4, codes, rs, rz, ts, tz, values, vscale, vzero, flushed,
        r_bits=r_bits, t_bits=t_bits, backend=backend,
        block_groups=block_groups)

    # residual V rows live token-major at [flushed, flushed + g) — gathered
    # per sequence (flushed differs across slots; clamp keeps the gather in
    # bounds when a full cache leaves no residual rows to read)
    t_cap = values.shape[2]
    slot = jnp.arange(g, dtype=jnp.int32)
    rows = jnp.minimum(flushed[:, None] + slot[None, :], t_cap - 1)
    idx = rows[:, None, :, None]                                 # (B,1,g,1)
    v_res = jnp.take_along_axis(values, idx, axis=2).astype(jnp.float32)
    if vscale is not None:
        vs_res = jnp.take_along_axis(vscale, idx, axis=2)
        vz_res = jnp.take_along_axis(vzero, idx, axis=2)
        v_res = (v_res * vs_res.astype(jnp.float32)
                 + vz_res.astype(jnp.float32))
    acc_r, m_r, l_r = _residual_flash_partials(q4, key_residual,
                                               len_b - flushed, v_res)

    out = merge_softmax_partials([(acc_g, m_g, l_g), (acc_r, m_r, l_r)])
    return out.reshape(b, hq, d).astype(q.dtype)


def polar_paged_decode_attention_full(
    q: Array, codes, rs, rz, ts, tz, key_residual, values, vscale, vzero,
    page_table: Array, lengths: Array, *, r_bits=4, t_bits=4,
    softmax_scale: float | None = None, backend: str = DEFAULT_BACKEND,
) -> Array:
    """End-to-end page-native decode attention: grouped segment via the
    page-table-walking kernel + fp residual segment, merged exactly.

    q: (S, Hq, d); pools as in :func:`polar_paged_decode_attention_grouped`;
    key_residual: (S, Hkv, g, d) per-slot partial group; page_table: (S, N)
    int32 (N may be width-sliced to the live pages); lengths: (S,) int32
    total tokens per slot. The residual's value rows live in the one page
    currently being filled (``table[s, flushed // g]``, rows
    ``[0, lengths - flushed)``), so the merge reads a single page per slot
    instead of a dense token-major copy. Returns (S, Hq, d) in q.dtype.
    """
    s, hq, d = q.shape
    hkv = codes.shape[1]
    g = codes.shape[2]
    qpk = hq // hkv
    scale = d ** -0.5 if softmax_scale is None else softmax_scale
    q4 = (q.astype(jnp.float32) * scale).reshape(s, hkv, qpk, d)
    len_b = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (s,))
    flushed = (len_b // g) * g                                   # (S,)

    acc_g, m_g, l_g = polar_paged_decode_attention_grouped(
        q4, codes, rs, rz, ts, tz, values, vscale, vzero, page_table,
        flushed, r_bits=r_bits, t_bits=t_bits, backend=backend)

    # residual V rows sit in the page being filled; empty slots clamp to
    # table entry 0 (possibly scratch) and every row is masked below
    gidx = jnp.minimum(flushed // g, page_table.shape[1] - 1)
    pv = jnp.take_along_axis(page_table.astype(jnp.int32),
                             gidx[:, None], axis=1)[:, 0]        # (S,)
    v_res = values[pv].astype(jnp.float32)                       # (S,H,g,d)
    if vscale is not None:
        v_res = (v_res * vscale[pv].astype(jnp.float32)
                 + vzero[pv].astype(jnp.float32))
    acc_r, m_r, l_r = _residual_flash_partials(q4, key_residual,
                                               len_b - flushed, v_res)

    out = merge_softmax_partials([(acc_g, m_g, l_g), (acc_r, m_r, l_r)])
    return out.reshape(s, hq, d).astype(q.dtype)
