"""Pallas TPU kernels for PolarQuant hot spots + jnp oracles.

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU with interpret=True against ``ref.py``.
"""
from repro.kernels.ops import (  # noqa: F401
    polar_qk_scores, polar_encode, polar_decode_attention_grouped,
    polar_decode_attention_full, merge_softmax_partials,
)
from repro.kernels.flash_prefill import flash_prefill  # noqa: F401
