"""Pallas TPU kernel: page-native fused PolarQuant decode attention.

The gathered path (``paged_cache.gather_view`` + the dense fused kernel)
re-materializes a dense copy of every slot's *entire capacity* — codes,
stats and values — in HBM on every decode step: an O(S·N·g·(P+d)) round
trip that grows with the pool capacity and dwarfs the LUT win at long
context. This kernel removes the copy entirely: its grid iterates
``(slot, kv_head, page)`` and the BlockSpec index maps dereference the
scalar-prefetched ``(S, N)`` page table, so every block load reads the
quantized page pools *in place* (vLLM-style paged attention):

    per (s, h) slot/KV head, for each page n of the slot's table row:
        codes/stats/values  <- pool[table[s, n]]        (index-map walk)
        scores = LUT(q, codes_n)                        (VPU select-tree)
        m, l   = online-softmax update                  (VMEM scratch)
        acc   += exp(s - m) @ V_n                       (MXU)

Per-slot lengths mask dead pages: grid steps past ``flushed[s] // g``
contribute nothing, and their index maps *clamp to the slot's last live
page* — consecutive grid steps then map to the same block, which the
Pallas pipeline recognizes and skips the redundant DMA. Clamping also
means the scratch page (stale masked-write garbage) is never read when a
slot has any live page at all; value rows are additionally zeroed under
the token mask so even a poisoned pool page cannot leak NaNs through a
zero-probability lane (``0 * NaN``).

Outputs are the unnormalized flash partials ``(acc, m, l)`` over the
grouped (flushed) tokens; the wrapper in ``kernels/ops.py`` merges the fp
residual segment exactly, fetching the residual value rows from the one
page currently being filled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.polar_attention import _lut_scores_block

Array = jax.Array
NEG_INF = -1e30


def _paged_attn_kernel(table_ref, flushed_ref, q_ref, codes_ref, rs_ref,
                       rz_ref, ts_ref, tz_ref, v_ref, vs_ref, vz_ref,
                       out_ref, m_out_ref, l_out_ref, m_ref, l_ref, acc_ref,
                       *, r_bits: int, t_bits: int, quantized_values: bool,
                       page_size: int):
    s, n = pl.program_id(0), pl.program_id(2)
    g = page_size

    @pl.when(n == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # (Qh, d)
    codes = codes_ref[0, 0][None]                          # (1, g, P)
    scores = _lut_scores_block(
        q, codes,
        rs_ref[0, 0].astype(jnp.float32),
        rz_ref[0, 0].astype(jnp.float32),
        ts_ref[0, 0].astype(jnp.float32),
        tz_ref[0, 0].astype(jnp.float32),
        r_bits, t_bits)                                    # (Qh, g)

    flushed = flushed_ref[s]
    pos = n * g + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    mask = pos < flushed                                   # (Qh, g)
    scores = jnp.where(mask, scores, NEG_INF)

    m_prev = m_ref[...]                                    # (Qh, 1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(scores - m_new), 0.0)      # (Qh, g)
    corr = jnp.exp(m_prev - m_new)

    if quantized_values:
        v = (v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0].astype(jnp.float32)
             + vz_ref[0, 0].astype(jnp.float32))           # (g, d)
    else:
        v = v_ref[0, 0].astype(jnp.float32)
    # zero dead rows: a masked lane's p is exactly 0, but 0 * NaN (stale
    # scratch-page garbage) would still poison the MXU accumulation
    vpos = n * g + jax.lax.broadcasted_iota(jnp.int32, (g, 1), 0)
    v = jnp.where(vpos < flushed, v, 0.0)

    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    # Final carry lands in the (s, h)-indexed output tiles on the last page;
    # intermediate writes are overwritten (n is the innermost grid dim).
    out_ref[0, 0] = acc_ref[...]
    m_out_ref[0, 0] = m_ref[..., 0]
    l_out_ref[0, 0] = l_ref[..., 0]


@functools.partial(jax.jit, static_argnames=("r_bits", "t_bits", "interpret"))
def polar_paged_decode_grouped(
    q: Array, codes: Array, rs: Array, rz: Array, ts: Array, tz: Array,
    values, vscale, vzero, page_table: Array, flushed: Array, *,
    r_bits: int = 4, t_bits: int = 4, interpret: bool = True,
):
    """Fused flash-decode over the grouped segment, straight off the pools.

    q: (S, Hkv, Qh, d) — ALREADY scaled by the softmax scale.
    codes: (PP, Hkv, g, P) page pool; stats rs/rz/ts/tz: (PP, Hkv, 1, P).
    values: (PP, Hkv, g, d) fp rows, or uint8 codes with vscale/vzero
    (PP, Hkv, g, 1) (pass vscale=None for fp values).
    page_table: (S, N) int32 — N may be a *sliced* width covering the live
    pages only (the serve engines bucket it); flushed: (S,) int32 valid
    grouped tokens per slot (a multiple of the page size).

    Returns (out (S,Hkv,Qh,d), m (S,Hkv,Qh), l (S,Hkv,Qh)) — unnormalized
    flash partials (see module docstring).
    """
    s, hkv, qh, d = q.shape
    _, _, g, p = codes.shape
    n = page_table.shape[1]
    quantized_values = vscale is not None
    page_table = page_table.astype(jnp.int32)
    flushed = jnp.broadcast_to(
        jnp.asarray(flushed, jnp.int32).reshape(-1), (s,))

    def page_map(i, j, k, table_ref, flushed_ref):
        # clamp dead grid steps to the slot's last live page: repeated block
        # indices skip the DMA, and the scratch page is never dereferenced
        # while the slot has live pages at all
        live = jnp.maximum(flushed_ref[i] // g, 1)
        return (table_ref[i, jnp.minimum(k, live - 1)], j, 0, 0)

    kern = functools.partial(
        _paged_attn_kernel, r_bits=r_bits, t_bits=t_bits,
        quantized_values=quantized_values, page_size=g)

    codes_spec = pl.BlockSpec((1, 1, g, p), page_map)
    stat_spec = pl.BlockSpec((1, 1, 1, p), page_map)
    if quantized_values:
        v_in = (values, vscale, vzero)
        v_specs = [pl.BlockSpec((1, 1, g, d), page_map),
                   pl.BlockSpec((1, 1, g, 1), page_map),
                   pl.BlockSpec((1, 1, g, 1), page_map)]
    else:
        dummy = jnp.zeros((1, 1, 1, 1), jnp.float32)
        v_in = (values, dummy, dummy)
        zmap = lambda i, j, k, t, f: (0, 0, 0, 0)
        v_specs = [pl.BlockSpec((1, 1, g, d), page_map),
                   pl.BlockSpec((1, 1, 1, 1), zmap),
                   pl.BlockSpec((1, 1, 1, 1), zmap)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(s, hkv, n),
        in_specs=[
            pl.BlockSpec((1, 1, qh, d), lambda i, j, k, t, f: (i, j, 0, 0)),
            codes_spec,
            stat_spec, stat_spec, stat_spec, stat_spec,
            *v_specs,
        ],
        out_specs=[
            pl.BlockSpec((1, 1, qh, d), lambda i, j, k, t, f: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, qh), lambda i, j, k, t, f: (i, j, 0)),
            pl.BlockSpec((1, 1, qh), lambda i, j, k, t, f: (i, j, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((qh, 1), jnp.float32),
            pltpu.VMEM((qh, 1), jnp.float32),
            pltpu.VMEM((qh, d), jnp.float32),
        ],
    )
    out, m, l = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((s, hkv, qh, d), jnp.float32),
            jax.ShapeDtypeStruct((s, hkv, qh), jnp.float32),
            jax.ShapeDtypeStruct((s, hkv, qh), jnp.float32),
        ],
        interpret=interpret,
    )(page_table, flushed, q, codes, rs, rz, ts, tz, *v_in)
    return out, m, l
