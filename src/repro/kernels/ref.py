"""Pure-jnp oracles for every Pallas kernel (array-based signatures).

Each ``ref_*`` function is the semantic ground truth its kernel must match
(tests sweep shapes/dtypes and ``assert_allclose`` kernel vs oracle).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lut as lut_mod
from repro.core import quantizers as qz
from repro.core.quantizers import PolarKeys, QuantConfig

Array = jax.Array
NEG_INF = -1e30


def _mk_polar_keys(codes, rs, rz, ts, tz, r_bits, t_bits) -> PolarKeys:
    return PolarKeys(codes=codes, rho_scale=rs, rho_zero=rz, theta_scale=ts,
                     theta_zero=tz, rho_bits=r_bits, theta_bits=t_bits,
                     pairing="half")


def ref_polar_qk_scores(q, codes, rs, rz, ts, tz, *, r_bits: int,
                        t_bits: int) -> Array:
    """LUT q.K scores over quantized groups.

    q: (B, Hkv, Qh, d); codes: (B, Hkv, G, g, P); scales: (B, Hkv, G, 1, P).
    Returns (B, Hkv, Qh, G*g) fp32.
    """
    pk = _mk_polar_keys(codes, rs, rz, ts, tz, r_bits, t_bits)
    pk_exp = jax.tree_util.tree_map(lambda a: a[:, :, None], pk)
    return lut_mod.lut_qk_scores(q, pk_exp)


def ref_polar_encode(k, *, r_bits: int, t_bits: int, group_size: int,
                     scale_dtype: str = "float32"):
    """Group-quantize post-RoPE keys. k: (B, Hkv, T, d), T % g == 0.

    Returns (codes, rho_scale, rho_zero, theta_scale, theta_zero).
    """
    cfg = QuantConfig(method="polar", rho_bits=r_bits, theta_bits=t_bits,
                      group_size=group_size, scale_dtype=scale_dtype)
    pk = qz.encode_polar_keys(k, cfg)
    return pk.codes, pk.rho_scale, pk.rho_zero, pk.theta_scale, pk.theta_zero


def ref_polar_decode_attention(q, codes, rs, rz, ts, tz, values, length, *,
                               r_bits: int, t_bits: int,
                               softmax_scale: float | None = None):
    """Fused decode attention over the *grouped* part of the cache.

    q: (B, Hkv, Qh, d); values: (B, Hkv, T, d) fp; length: () or (B,) int32
    = number of valid grouped tokens per sequence (a multiple of g) — the
    batched form serves continuous batching, where every slot sits at its
    own decode position.
    Returns (out, m, l): un-normalized flash-style partial results so the
    caller can merge the fp residual segment —
        out: (B, Hkv, Qh, d) = sum_t exp(s_t - m) v_t
        m:   (B, Hkv, Qh)    = running max of masked scores
        l:   (B, Hkv, Qh)    = sum_t exp(s_t - m)
    """
    b, hkv, qh, d = q.shape
    scale = d ** -0.5 if softmax_scale is None else softmax_scale
    s = ref_polar_qk_scores(q * scale, codes, rs, rz, ts, tz,
                            r_bits=r_bits, t_bits=t_bits)
    t_cap = s.shape[-1]
    len_b = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
    pos = jnp.arange(t_cap, dtype=jnp.int32)
    valid = pos[None, None, None, :] < len_b[:, None, None, None]
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid, p, 0.0)  # kill exp(NEG_INF - NEG_INF) rows
    l = jnp.sum(p, axis=-1)
    # zero dead value rows too: p is exactly 0 there, but 0 * NaN (stale
    # scratch-page garbage in gathered paged views) would poison the matmul
    vmask = pos[None, :] < len_b[:, None]
    values = jnp.where(vmask[:, None, :, None], values.astype(jnp.float32),
                       0.0)
    out = jnp.einsum("bhqt,bhtd->bhqd", p, values)
    return out, m, l


def ref_polar_paged_prefill_attention(q, k_chunk, v_chunk, codes, rs, rz,
                                      ts, tz, values, vscale, vzero,
                                      page_row, start, chunk_len, *,
                                      r_bits: int, t_bits: int,
                                      softmax_scale: float | None = None):
    """Page-native chunk-prefill oracle: one prefill chunk's attention over
    the slot's quantized prefix pages + its own fp causal tile.

    q: (1, Hq, Tc, d) post-RoPE queries at absolute positions
    ``start + [0, Tc)`` (UNscaled); k_chunk/v_chunk: (1, Hkv, Tc, d);
    codes: (PP, Hkv, g, P) page pool with stats (PP, Hkv, 1, P); values:
    (PP, Hkv, g, d) fp rows or uint8 codes with vscale/vzero
    (PP, Hkv, g, 1); page_row: (N,) int32 table row; start: () int32
    page-aligned offset; chunk_len: () int32 real chunk tokens.

    This mirrors ``paged_cache.chunk_prefill_attention`` *op for op* — the
    same gather/zero/LUT/concat/softmax/einsum sequence in the same order
    (the LUT runs the default select-tree, matching ``cfg.lut_impl``'s
    default) — so at the polar defaults the page-native prefill backend
    produces bit-identical outputs to the jnp fallback. The kernel is
    parity-tested against this oracle, which carries the flash-rewrite
    tolerance instead.

    Returns (1, Hq, Tc, d) in q.dtype.
    """
    _, hq, tc, d = q.shape
    hkv = codes.shape[1]
    qpk = hq // hkv
    n = page_row.shape[0]
    g = codes.shape[2]
    t_cap = n * g
    num_pages = codes.shape[0] - 1          # last pool page is scratch
    scale = d ** -0.5 if softmax_scale is None else softmax_scale
    start = jnp.asarray(start, jnp.int32)
    chunk_len = jnp.asarray(chunk_len, jnp.int32)
    pvalid = (page_row >= 0) & (page_row < num_pages)

    def gat(pool):  # (PP, H, a, b) -> (1, H, N, a, b), invalid pages zeroed
        x = pool[page_row]
        x = jnp.where(pvalid[:, None, None, None], x, jnp.zeros((), x.dtype))
        return x.transpose(1, 0, 2, 3)[None]

    def flat(x):  # (1, H, N, g, ·) -> (1, H, N*g, ·)
        return x.reshape(1, hkv, t_cap, x.shape[-1])

    q4 = (q.astype(jnp.float32) * scale).reshape(1, hkv, qpk, tc, d)

    qf = q4.reshape(1, hkv, qpk * tc, d)
    s_prefix = ref_polar_qk_scores(qf, gat(codes), gat(rs), gat(rz),
                                   gat(ts), gat(tz), r_bits=r_bits,
                                   t_bits=t_bits)
    s_prefix = s_prefix.reshape(1, hkv, qpk, tc, t_cap)
    pos = jnp.arange(t_cap, dtype=jnp.int32)
    s_prefix = jnp.where((pos < start)[None, None, None, None, :],
                         s_prefix, NEG_INF)

    kf = k_chunk.astype(jnp.float32)
    s_chunk = jnp.einsum("bhqtd,bhsd->bhqts", q4, kf)
    i = jnp.arange(tc, dtype=jnp.int32)
    cmask = (i[:, None] >= i[None, :]) & (i[None, :] < chunk_len)
    s_chunk = jnp.where(cmask[None, None, None], s_chunk, NEG_INF)

    probs = jax.nn.softmax(
        jnp.concatenate([s_prefix, s_chunk], axis=-1), axis=-1)

    if vscale is not None:
        v_tilde = qz.decode_values(qz.QuantizedValues(
            codes=flat(gat(values)), scale=flat(gat(vscale)),
            zero=flat(gat(vzero)), bits=0))
    else:
        v_tilde = flat(gat(values)).astype(jnp.float32)
    v_all = jnp.concatenate([v_tilde, v_chunk.astype(jnp.float32)], axis=2)
    out = jnp.einsum("bhqts,bhsd->bhqtd", probs, v_all)
    return out.reshape(1, hq, tc, d).astype(q.dtype)


def ref_polar_paged_decode_attention(q, codes, rs, rz, ts, tz, values,
                                     vscale, vzero, page_table, flushed, *,
                                     r_bits: int, t_bits: int):
    """Page-native fused decode oracle: pool buffers + page table in,
    flash partials out — same semantics as the Pallas page-walking kernel.

    q: (S, Hkv, Qh, d) ALREADY scaled; codes: (PP, Hkv, g, P) page pool
    with stats (PP, Hkv, 1, P); values: (PP, Hkv, g, d) fp rows or uint8
    codes with vscale/vzero (PP, Hkv, g, 1); page_table: (S, N) int32
    (possibly width-sliced); flushed: (S,) int32 grouped tokens per slot.

    The oracle reads exactly the pages named by the table (a gather in
    jnp, in-place block loads in the kernel) — never a dense copy of the
    whole pool.
    """
    def pages(x):  # (PP, H, a, b) -> (S, H, N, a, b)
        return x[page_table].transpose(0, 2, 1, 3, 4)

    v = pages(values).astype(jnp.float32)
    if vscale is not None:
        v = v * pages(vscale).astype(jnp.float32) \
            + pages(vzero).astype(jnp.float32)
    s_, h = v.shape[:2]
    v = v.reshape(s_, h, -1, v.shape[-1])                  # (S, H, N*g, d)
    return ref_polar_decode_attention(
        q, pages(codes), pages(rs), pages(rz), pages(ts), pages(tz), v,
        flushed, r_bits=r_bits, t_bits=t_bits, softmax_scale=1.0)
