"""Pallas TPU kernel: forward-only flash attention for serving prefill.

The §Roofline table shows every prefill cell is memory-bound, dominated by
the jnp-level flash attention spilling per-chunk fp32 score tiles to HBM.
This kernel keeps the (q_blk, k_blk) score tile and the online-softmax
carry in VMEM — HBM traffic reduces to Q/K/V/O streaming (the roofline
floor). Forward-only: prefill has no backward pass.

Grid: (B, H, Tq/q_blk, Tk/k_blk), k innermost; scratch carries (m, l, acc)
per q block across k steps. GQA via the K/V index map (h -> h // q_per_kv).
Causal masking by absolute block offsets; fully-masked tiles short-circuit
via @pl.when (no MXU work issued for the upper triangle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, q_blk: int, k_blk: int, nk: int,
            kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * q_blk
    k_start = ki * k_blk

    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (q_blk, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (k_blk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        q_idx = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_idx = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_idx < kv_len
        if causal:
            mask = mask & (q_idx >= k_idx)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        v = v_ref[0, 0].astype(jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)

    if causal:
        # skip fully-masked tiles (first key index beyond last query index)
        pl.when(k_start <= q_start + q_blk - 1)(_body)
    else:
        _body()

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "q_blk",
                                             "k_blk", "interpret"))
def flash_prefill(q: Array, k: Array, v: Array, *, causal: bool = True,
                  scale: float | None = None, q_blk: int = 128,
                  k_blk: int = 128, interpret: bool = True) -> Array:
    """q: (B, H, Tq, d); k/v: (B, Hkv, Tk, d); returns (B, H, Tq, d).

    VMEM per step ~= (q_blk + 2*k_blk)*d*4 + q_blk*k_blk*4 + q_blk*d*4
    (~260 KiB at 128/128/d=128) << 16 MiB.
    """
    b, h, tq, d = q.shape
    hkv, tk = k.shape[1], k.shape[2]
    qpk = h // hkv
    scale = d ** -0.5 if scale is None else scale
    q_blk = min(q_blk, tq)
    k_blk = min(k_blk, tk)
    nq = -(-tq // q_blk)
    nk = -(-tk // k_blk)
    if tq % q_blk or tk % k_blk:
        # pad to block multiples; padded keys masked via kv_len
        qpad = nq * q_blk - tq
        kpad = nk * k_blk - tk
        q = jnp.pad(q, ((0, 0), (0, 0), (0, qpad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, kpad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, kpad), (0, 0)))

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             q_blk=q_blk, k_blk=k_blk, nk=nk, kv_len=tk)
    out = pl.pallas_call(
        kern,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, k_blk, d),
                         lambda b_, h_, i, j: (b_, h_ // qpk, j, 0)),
            pl.BlockSpec((1, 1, k_blk, d),
                         lambda b_, h_, i, j: (b_, h_ // qpk, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, d),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, nq * q_blk, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :tq]
