"""Pallas TPU kernel: fully-fused PolarQuant decode attention (beyond-paper).

The paper's Triton kernel fuses dequantization + QK only; scores round-trip
through HBM before softmax and the value matmul. On TPU the score spill is
the dominant extra traffic at 32K context, so this kernel carries the online
softmax across the group-block grid dimension in VMEM scratch and fuses the
value matmul (flash-decode structure):

    per (b, h) KV head, for each block n of gb groups:
        s     = LUT-scores(q, codes_n)            (VPU select-tree)
        m,l   = online-softmax update             (VMEM scratch carry)
        acc  += exp(s - m) @ V_n                  (MXU)

Outputs are the *unnormalized* flash partials (acc, m, l) over the grouped
tokens so the wrapper can merge the fp residual segment exactly (the merge
is associative). Values may be fp or token-wise uint8-quantized; dequant of
V happens in-register before the MXU matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -1e30


def _lut_scores_block(q, codes, rs, rz, ts, tz, r_bits, t_bits):
    """Shared LUT score tile: q (Qh,d) fp32; codes (gb,g,P) -> (Qh, gb*g)."""
    qh, d = q.shape
    p = d // 2
    qx, qy = q[:, :p], q[:, p:]
    gb, g, _ = codes.shape
    tc = (codes & ((1 << t_bits) - 1)).astype(jnp.int32)
    rc = (codes >> t_bits).astype(jnp.float32)
    rho = (rc + 0.5) * rs[:, None, :] + rz[:, None, :]
    gathered = jnp.zeros((qh, gb, g, p), jnp.float32)
    for a in range(1 << t_bits):
        theta = (a + 0.5) * ts + tz
        cos_t = jnp.cos(theta - jnp.pi)
        sin_t = jnp.sin(theta - jnp.pi)
        a_tab = qx[:, None, :] * cos_t[None] + qy[:, None, :] * sin_t[None]
        gathered = gathered + jnp.where((tc == a)[None], a_tab[:, :, None, :], 0.0)
    return jnp.sum(rho[None] * gathered, axis=-1).reshape(qh, gb * g)


def _attn_kernel(q_ref, codes_ref, rs_ref, rz_ref, ts_ref, tz_ref, v_ref,
                 vs_ref, vz_ref, len_ref, out_ref, m_out_ref, l_out_ref,
                 m_ref, l_ref, acc_ref, *, r_bits: int, t_bits: int,
                 quantized_values: bool, block_tokens: int):
    n = pl.program_id(2)
    qh, d = q_ref.shape[2], q_ref.shape[3]

    @pl.when(n == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)
    codes = codes_ref[0, 0]
    s = _lut_scores_block(
        q, codes,
        rs_ref[0, 0, :, 0].astype(jnp.float32),
        rz_ref[0, 0, :, 0].astype(jnp.float32),
        ts_ref[0, 0, :, 0].astype(jnp.float32),
        tz_ref[0, 0, :, 0].astype(jnp.float32),
        r_bits, t_bits)                                # (Qh, S)

    length = len_ref[0, 0]
    pos = n * block_tokens + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = pos < length
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                # (Qh, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)       # (Qh, S)
    corr = jnp.exp(m_prev - m_new)

    if quantized_values:
        v = (v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0].astype(jnp.float32)
             + vz_ref[0, 0].astype(jnp.float32))       # (S, d)
    else:
        v = v_ref[0, 0].astype(jnp.float32)

    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    # Final carry lands in the (b, h)-indexed output tiles on the last step;
    # intermediate writes are overwritten (n is the innermost grid dim).
    out_ref[0, 0] = acc_ref[...]
    m_out_ref[0, 0] = m_ref[..., 0]
    l_out_ref[0, 0] = l_ref[..., 0]


@functools.partial(jax.jit, static_argnames=(
    "r_bits", "t_bits", "block_groups", "interpret"))
def polar_decode_attention_grouped(
    q: Array, codes: Array, rs: Array, rz: Array, ts: Array, tz: Array,
    values, vscale, vzero, length: Array, *, r_bits: int = 4,
    t_bits: int = 4, block_groups: int = 4, interpret: bool = True,
):
    """Fused flash-decode over the grouped cache segment.

    q: (B,Hkv,Qh,d) — ALREADY scaled by softmax scale.
    codes: (B,Hkv,G,g,P); stats: (B,Hkv,G,1,P).
    values: (B,Hkv,T,d) fp, or uint8 codes with vscale/vzero (B,Hkv,T,1)
    (pass vscale=None for fp values). length: () or (B,) int32 valid
    grouped tokens — per-sequence when batched (continuous batching slots
    at heterogeneous positions); the kernel reads its own row via the
    length BlockSpec, so the body is unchanged.

    Returns (out (B,Hkv,Qh,d), m (B,Hkv,Qh), l (B,Hkv,Qh)) — unnormalized
    partials (see module docstring).
    """
    b, hkv, qh, d = q.shape
    _, _, gcount, g, p = codes.shape
    quantized_values = vscale is not None
    gb = min(block_groups, gcount)
    while gcount % gb:
        gb -= 1
    nb = gcount // gb
    s_blk = gb * g

    kern = functools.partial(
        _attn_kernel, r_bits=r_bits, t_bits=t_bits,
        quantized_values=quantized_values, block_tokens=s_blk)
    stat_spec = pl.BlockSpec((1, 1, gb, 1, p), lambda i, j, n: (i, j, n, 0, 0))
    v_spec = pl.BlockSpec((1, 1, s_blk, d), lambda i, j, n: (i, j, n, 0))
    vstat_spec = pl.BlockSpec((1, 1, s_blk, 1), lambda i, j, n: (i, j, n, 0))
    len2 = jnp.broadcast_to(
        jnp.asarray(length, jnp.int32).reshape(-1, 1), (b, 1))

    if quantized_values:
        v_in = (values, vscale, vzero)
        v_specs = [v_spec, vstat_spec, vstat_spec]
    else:
        # dummy (1,1,1,1) stat inputs keep the kernel signature uniform
        dummy = jnp.zeros((1, 1, 1, 1), jnp.float32)
        v_in = (values, dummy, dummy)
        v_specs = [v_spec,
                   pl.BlockSpec((1, 1, 1, 1), lambda i, j, n: (0, 0, 0, 0)),
                   pl.BlockSpec((1, 1, 1, 1), lambda i, j, n: (0, 0, 0, 0))]

    out, m, l = pl.pallas_call(
        kern,
        grid=(b, hkv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, qh, d), lambda i, j, n: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, gb, g, p), lambda i, j, n: (i, j, n, 0, 0)),
            stat_spec, stat_spec, stat_spec, stat_spec,
            *v_specs,
            pl.BlockSpec((1, 1), lambda i, j, n: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, qh, d), lambda i, j, n: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, qh), lambda i, j, n: (i, j, 0)),
            pl.BlockSpec((1, 1, qh), lambda i, j, n: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, qh, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, qh), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, qh), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((qh, 1), jnp.float32),
            pltpu.VMEM((qh, 1), jnp.float32),
            pltpu.VMEM((qh, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, codes, rs, rz, ts, tz, *v_in, len2)
    return out, m, l
