"""Pallas TPU kernel: group-wise polar quantization of post-RoPE keys.

One grid step quantizes one (batch, kv-head, group) tile: loads a (g, d)
key tile from HBM into VMEM, computes the polar transform, reduces per
channel-pair min/max over the g tokens, and emits packed uint8 codes plus
the four per-group stat rows. Token axis g is sublane-aligned (g % 8 == 0
for all supported group sizes); channel-pair axis P = d/2 sits in lanes.

Mirrors ``repro.core.quantizers.encode_polar_keys`` bit-for-bit (same
mid-rise grid, same eps guard) — tests assert exact code equality.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array
_EPS = 1e-8


def _encode_kernel(k_ref, codes_ref, rs_ref, rz_ref, ts_ref, tz_ref, *,
                   r_bits: int, t_bits: int):
    k = k_ref[0, 0].astype(jnp.float32)            # (g, d)
    g, d = k.shape
    p = d // 2
    x, y = k[:, :p], k[:, p:]                      # "half" pairing
    rho = jnp.sqrt(x * x + y * y)                  # (g, P)
    theta = jnp.arctan2(y, x) + jnp.pi

    def stats(v, bits):
        mn = jnp.min(v, axis=0, keepdims=True)     # (1, P)
        mx = jnp.max(v, axis=0, keepdims=True)
        s = jnp.maximum((mx - mn) / (1 << bits), _EPS)
        c = jnp.clip(jnp.floor((v - mn) / s), 0, (1 << bits) - 1)
        return c.astype(jnp.uint8), s, mn

    rc, rs, rz = stats(rho, r_bits)
    tc, ts, tz = stats(theta, t_bits)
    codes_ref[0, 0, 0] = (rc << t_bits) | tc
    rs_ref[0, 0, 0] = rs.astype(rs_ref.dtype)
    rz_ref[0, 0, 0] = rz.astype(rz_ref.dtype)
    ts_ref[0, 0, 0] = ts.astype(ts_ref.dtype)
    tz_ref[0, 0, 0] = tz.astype(tz_ref.dtype)


@functools.partial(jax.jit, static_argnames=("r_bits", "t_bits", "group_size",
                                             "scale_dtype", "interpret"))
def polar_encode(k: Array, *, r_bits: int = 4, t_bits: int = 4,
                 group_size: int = 128, scale_dtype: str = "float32",
                 interpret: bool = True):
    """Quantize keys (B, Hkv, T, d) with T % group_size == 0.

    Returns (codes (B,Hkv,G,g,P) uint8, rho_scale, rho_zero, theta_scale,
    theta_zero — each (B,Hkv,G,1,P))."""
    b, hkv, t, d = k.shape
    g = group_size
    assert t % g == 0, (t, g)
    gcount = t // g
    p = d // 2
    sdt = jnp.dtype(scale_dtype)

    kern = functools.partial(_encode_kernel, r_bits=r_bits, t_bits=t_bits)
    stat = jax.ShapeDtypeStruct((b, hkv, gcount, 1, p), sdt)
    stat_spec = pl.BlockSpec((1, 1, 1, 1, p), lambda i, j, n: (i, j, n, 0, 0))
    return pl.pallas_call(
        kern,
        grid=(b, hkv, gcount),
        in_specs=[pl.BlockSpec((1, 1, g, d), lambda i, j, n: (i, j, n, 0))],
        out_specs=[
            pl.BlockSpec((1, 1, 1, g, p), lambda i, j, n: (i, j, n, 0, 0)),
            stat_spec, stat_spec, stat_spec, stat_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, gcount, g, p), jnp.uint8),
            stat, stat, stat, stat,
        ],
        interpret=interpret,
    )(k)
