"""Pallas TPU kernel: page-native fused PolarQuant chunk-prefill attention.

The jnp chunked-prefill path (``paged_cache.chunk_prefill_attention``)
gathers the slot's *entire page pool* per chunk (``pool[page_row]``,
O(capacity) HBM traffic) and spills full ``(Hq, Tc, N*g)`` fp32 score
tensors before one dense softmax — so long-prompt TTFT degrades with pool
capacity exactly like decode did before the page-native decode kernel.
This kernel is the prefill twin of ``kernels/paged_decode.py``: one
``pallas_call`` computes a whole chunk's attention directly against the
quantized prefix pages plus the chunk's own fp keys, with nothing dense
ever materialized:

    per kv head j, for each prefix page k of the slot's table row:
        codes/stats/values <- pool[row[k]]            (index-map walk)
        scores = LUT(q_fold, codes_k)                 (VPU select-tree)
        m, l, acc online-softmax update               (VMEM carry)
    final grid step (k == N):
        s = q_fold @ k_chunk^T, causal-masked         (MXU, fp)
        same m/l/acc update, then out = acc / l       (flash finish)

The chunk's ``Tc`` query rows ride folded onto the query-head axis
(``QT = (Hq/Hkv) * Tc`` rows per kv head, row = qh * Tc + t), so the LUT
select-tree and the MXU matmuls see one tall 2-D operand — the same
folding ``chunk_prefill_attention`` uses. Causality needs no masking on
the prefix steps (every chunk token sits at position ``start + t`` ≥
``start`` > any prefix position); within the chunk the final step applies
the standard triangular mask by ``t = row % Tc``. Because the fp causal
tile shares the *same* online-softmax carry as the LUT prefix steps
(``flash_prefill.py``'s m/l/acc structure), the kernel emits the complete
normalized chunk output in one pass — no partial merge on the host.

Dead grid steps (pages at or past ``start // g``) clamp their index maps
to the last live prefix page, exactly as in ``paged_decode``: repeated
block indices skip the redundant DMA, and the scratch page is never
dereferenced while the slot has any live page. Masked lanes contribute
exact zeros (p == 0 and value rows zeroed under the mask), so stale pool
garbage cannot leak through ``0 * NaN``.

``start`` must be page-aligned (the chunked-prefill invariant): the
cached prefix ``[0, start)`` is fully flushed into pages, so there is no
fp-residual term.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.polar_attention import _lut_scores_block

Array = jax.Array
NEG_INF = -1e30


def _paged_prefill_kernel(row_ref, info_ref, q_ref, kc_ref, vc_ref,
                          codes_ref, rs_ref, rz_ref, ts_ref, tz_ref,
                          v_ref, vs_ref, vz_ref, out_ref,
                          m_ref, l_ref, acc_ref, *, r_bits: int, t_bits: int,
                          quantized_values: bool, page_size: int,
                          chunk_tokens: int, n_pages: int):
    k = pl.program_id(1)
    g = page_size
    start = info_ref[0]
    clen = info_ref[1]

    @pl.when(k == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                       # (QT, d), scaled

    def _online(scores, mask, v):
        # one flash update of the shared m/l/acc carry.
        # scores/mask: (QT, L); v: (L, d) with dead rows already zeroed.
        m_prev = m_ref[...]                                # (QT, 1)
        m_new = jnp.maximum(m_prev,
                            jnp.max(scores, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(scores - m_new), 0.0)  # (QT, L)
        corr = jnp.exp(m_prev - m_new)
        m_ref[...] = m_new
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(k < n_pages)
    def _prefix_page():
        codes = codes_ref[0, 0][None]                      # (1, g, P)
        scores = _lut_scores_block(
            q, codes,
            rs_ref[0, 0].astype(jnp.float32),
            rz_ref[0, 0].astype(jnp.float32),
            ts_ref[0, 0].astype(jnp.float32),
            tz_ref[0, 0].astype(jnp.float32),
            r_bits, t_bits)                                # (QT, g)
        pos = k * g + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        mask = pos < start                                 # (QT, g)
        scores = jnp.where(mask, scores, NEG_INF)
        if quantized_values:
            v = (v_ref[0, 0].astype(jnp.float32)
                 * vs_ref[0, 0].astype(jnp.float32)
                 + vz_ref[0, 0].astype(jnp.float32))       # (g, d)
        else:
            v = v_ref[0, 0].astype(jnp.float32)
        vpos = k * g + jax.lax.broadcasted_iota(jnp.int32, (g, 1), 0)
        v = jnp.where(vpos < start, v, 0.0)
        _online(scores, mask, v)

    @pl.when(k == n_pages)
    def _chunk_tile():
        kc = kc_ref[0].astype(jnp.float32)                 # (Tc, d)
        s = jnp.dot(q, kc.T, preferred_element_type=jnp.float32)  # (QT, Tc)
        t_q = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % chunk_tokens
        t_k = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (t_q >= t_k) & (t_k < clen)
        s = jnp.where(mask, s, NEG_INF)
        vc = vc_ref[0].astype(jnp.float32)                 # (Tc, d)
        vrow = jax.lax.broadcasted_iota(jnp.int32, (chunk_tokens, 1), 0)
        vc = jnp.where(vrow < clen, vc, 0.0)
        _online(s, mask, vc)

    # the flash finish: every query row has at least its own diagonal lane
    # unmasked once the chunk tile lands, so l > 0 for real rows; padded
    # rows (t >= clen) stay fully masked and the l == 0 guard keeps them
    # finite. Written every step, last (chunk) step wins.
    l = l_ref[...]
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out_ref[0] = acc_ref[...] / l_safe


@functools.partial(jax.jit, static_argnames=("r_bits", "t_bits", "interpret"))
def polar_paged_prefill_grouped(
    q: Array, k_chunk: Array, v_chunk: Array, codes: Array, rs: Array,
    rz: Array, ts: Array, tz: Array, values, vscale, vzero,
    page_row: Array, start: Array, chunk_len: Array, *,
    r_bits: int = 4, t_bits: int = 4, interpret: bool = True,
):
    """One prefill chunk's fused attention, straight off the pools.

    q: (Hkv, QT, d) — chunk queries folded onto the head axis
    (``QT = (Hq/Hkv) * Tc``, row = qh * Tc + t) and ALREADY scaled by the
    softmax scale. k_chunk/v_chunk: (Hkv, Tc, d) the chunk's own fp
    keys/values. codes: (PP, Hkv, g, P) page pool with stats
    (PP, Hkv, 1, P); values: (PP, Hkv, g, d) fp rows or uint8 codes with
    vscale/vzero (PP, Hkv, g, 1) (pass vscale=None for fp values).
    page_row: (N,) int32 — the slot's table row (may be width-sliced to
    the live pages); start: () int32 page-aligned chunk offset;
    chunk_len: () int32 real tokens in the chunk.

    Returns (Hkv, QT, d) fp32 — the complete normalized chunk output.
    """
    hkv, qt, d = q.shape
    _, _, g, p = codes.shape
    tc = k_chunk.shape[1]
    n = page_row.shape[0]
    quantized_values = vscale is not None
    page_row = page_row.astype(jnp.int32)
    info = jnp.stack([jnp.asarray(start, jnp.int32),
                      jnp.asarray(chunk_len, jnp.int32)])

    def page_map(j, k, row_ref, info_ref):
        # clamp dead grid steps (k >= start // g, incl. the chunk step) to
        # the last live prefix page: repeated block indices skip the DMA
        live = jnp.maximum(info_ref[0] // g, 1)
        return (row_ref[jnp.minimum(k, live - 1)], j, 0, 0)

    def head_map(j, k, row_ref, info_ref):
        return (j, 0, 0)

    kern = functools.partial(
        _paged_prefill_kernel, r_bits=r_bits, t_bits=t_bits,
        quantized_values=quantized_values, page_size=g, chunk_tokens=tc,
        n_pages=n)

    codes_spec = pl.BlockSpec((1, 1, g, p), page_map)
    stat_spec = pl.BlockSpec((1, 1, 1, p), page_map)
    if quantized_values:
        v_in = (values, vscale, vzero)
        v_specs = [pl.BlockSpec((1, 1, g, d), page_map),
                   pl.BlockSpec((1, 1, g, 1), page_map),
                   pl.BlockSpec((1, 1, g, 1), page_map)]
    else:
        dummy = jnp.zeros((1, 1, 1, 1), jnp.float32)
        v_in = (values, dummy, dummy)
        zmap = lambda j, k, r, i: (0, 0, 0, 0)
        v_specs = [pl.BlockSpec((1, 1, g, d), page_map),
                   pl.BlockSpec((1, 1, 1, 1), zmap),
                   pl.BlockSpec((1, 1, 1, 1), zmap)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(hkv, n + 1),
        in_specs=[
            pl.BlockSpec((1, qt, d), head_map),
            pl.BlockSpec((1, tc, d), head_map),
            pl.BlockSpec((1, tc, d), head_map),
            codes_spec,
            stat_spec, stat_spec, stat_spec, stat_spec,
            *v_specs,
        ],
        out_specs=pl.BlockSpec((1, qt, d), head_map),
        scratch_shapes=[
            pltpu.VMEM((qt, 1), jnp.float32),
            pltpu.VMEM((qt, 1), jnp.float32),
            pltpu.VMEM((qt, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((hkv, qt, d), jnp.float32),
        interpret=interpret,
    )(page_row, info, q, k_chunk, v_chunk, codes, rs, rz, ts, tz, *v_in)
