import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count at first init). Everything below may import jax.

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import dryrun_lib as lib  # noqa: E402
from repro.train.train_step import StepConfig  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Multi-pod dry-run: lower+compile every (arch x shape).")
    ap.add_argument("--arch", default="all",
                    help=f"one of {ARCH_IDS} or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {sorted(SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"],
                    help="16x16 single-pod or 2x16x16 multi-pod")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="block")
    args = ap.parse_args(argv)

    assert jax.device_count() == 512, (
        f"dry-run needs 512 placeholder devices, got {jax.device_count()} — "
        "run via `python -m repro.launch.dryrun`")
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    mesh_tag = "multi_2x16x16" if args.mesh == "multi" else "single_16x16"
    step_cfg = StepConfig(microbatches=args.microbatches, remat=args.remat)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = sorted(SHAPES) if args.shape == "all" else [args.shape]

    failures = []
    for arch in archs:
        for shape in shapes:
            t0 = time.monotonic()
            try:
                rec = lib.run_cell(arch, shape, mesh, args.out, mesh_tag,
                                   step_cfg)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((arch, shape, repr(e)[:300]))
                print(f"[dryrun] {arch} x {shape}: FAIL {repr(e)[:200]}")
                continue
            if rec["status"] == "skip":
                print(f"[dryrun] {arch} x {shape}: SKIP ({rec['reason'][:60]})")
                continue
            mem = rec["memory"]
            cost = rec["cost"]
            coll = rec["collectives"].get("total_bytes", 0)
            print(f"[dryrun] {arch} x {shape} [{mesh_tag}]: OK "
                  f"compile={rec['compile_s']:.1f}s "
                  f"peak/dev={mem['peak_per_device']/2**30:.2f}GiB "
                  f"fits16G={mem['fits_16g_hbm']} "
                  f"flops={cost.get('flops', 0):.3g} "
                  f"bytes={cost.get('bytes accessed', 0):.3g} "
                  f"coll={coll:.3g}B "
                  f"({time.monotonic()-t0:.0f}s)")

    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for a, s, e in failures:
            print(f"  {a} x {s}: {e}")
        return 1
    print("[dryrun] all requested cells passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
