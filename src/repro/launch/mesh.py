"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests must keep seeing 1 device.

Axes:
  * ``pod``   — inter-pod (DCN) axis; composes with ``data`` for
                data-parallel/FSDP work so exactly one fused gradient
                all-reduce crosses the pod boundary per step.
  * ``data``  — intra-pod data parallel / FSDP axis (ICI).
  * ``model`` — tensor/expert parallel axis (ICI).
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis_types where this jax supports them.

    Older jax (<0.5) has neither ``jax.sharding.AxisType`` nor the
    ``axis_types`` kwarg; Auto is its only behavior, so plain make_mesh is
    equivalent there.
    """
    try:
        auto = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=auto)
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """A small mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    model_axis = min(model_axis, n)
    return make_mesh((n // model_axis, model_axis), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """Mesh axes forming the data-parallel dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)
