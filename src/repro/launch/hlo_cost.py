"""Static cost analysis of partitioned HLO text with loop-aware counting.

``compiled.cost_analysis()`` visits while-loop bodies ONCE (verified on this
backend: a 10-step scanned matmul reports 1 matmul of flops), which makes
it useless for scan-over-layers programs. This module parses
``compiled.as_text()`` and computes, bottom-up over the call graph:

  * flops            — dot ops: 2 * |result| * |contracting dims|;
                       elementwise arithmetic: |result|; reduces: |input|
  * transcendentals  — exp/log/tanh/sin/cos/atan2/rsqrt/...
  * hbm_bytes        — per materializing op: result + operand buffer bytes
                       (fusion internals excluded — only fusion boundaries
                       move HBM data), a standard traffic proxy
  * collective_bytes — per-kind wire bytes (all-reduce counted 2x)

with while-loop bodies multiplied by trip counts parsed from the loop
condition (the scan bound constant). Shapes come from each computation's
SSA symbol table, so per-device (post-SPMD) sizes are used throughout.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "select", "compare", "and", "or", "xor", "not", "clamp",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "remainder", "power",
}
_TRANSCENDENTAL = {"exponential", "log", "log-plus-one", "exponential-minus-one",
                   "tanh", "sine", "cosine", "atan2", "rsqrt", "sqrt", "cbrt",
                   "logistic", "erf"}
_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id", "iota",
               "opt-barrier", "custom-call", "get-dimension-size"}
_COLLECTIVES = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^=]*?\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*?)\)(.*)$")
# computation headers start at column 0 (op lines are indented) and params
# may contain nested parens (tuple types), so match loosely up to `... {`
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")


def _type_elems_bytes(type_str: str) -> tuple[int, int]:
    elems = bytes_ = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


@dataclass
class Op:
    name: str
    type_str: str
    kind: str
    operands: list[str]
    attrs: str


@dataclass
class Cost:
    flops: float = 0.0
    transcendentals: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_detail: dict = field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.transcendentals += other.transcendentals
        self.hbm_bytes += other.hbm_bytes
        self.collective_bytes += other.collective_bytes
        for k, v in other.collective_detail.items():
            d = self.collective_detail.setdefault(k, {"count": 0, "bytes": 0.0})
            d["count"] += v["count"]
            d["bytes"] += v["bytes"]
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.transcendentals * f,
                    self.hbm_bytes * f, self.collective_bytes * f,
                    {k: {"count": v["count"] * f, "bytes": v["bytes"] * f}
                     for k, v in self.collective_detail.items()})


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_computations(text: str) -> dict[str, list[Op]]:
    comps: dict[str, list[Op]] = {}
    current: Optional[str] = None
    for line in text.splitlines():
        line = _COMMENT_RE.sub("", line)  # `/*index=5*/` inside tuple types
        if current is None:
            m = _COMP_RE.match(line)
            if m:
                current = m.group(1)
                comps[current] = []
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, type_str, kind, operands, attrs = m.groups()
            ops = [o.strip().lstrip("%") for o in _split_operands(operands)]
            comps[current].append(Op(name, type_str, kind, ops, attrs))
    return comps


def _split_operands(s: str) -> list[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            depth += ch in "([{"
            depth -= ch in ")]}"
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return [o for o in (x.strip() for x in out) if o]


def _operand_name(s: str) -> str:
    """SSA name of an operand reference.

    Newer HLO prints operands as ``%name``; older dumps prefix the type
    (``f32[64,64]{1,0} %name``) — take the last %-token, falling back to the
    first token (literal constant operands like ``7``).
    """
    for tok in reversed(s.split()):
        if tok.startswith("%"):
            return tok.lstrip("%")
    return s.split(" ")[0].lstrip("%")


def _attr(attrs: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w\.\-]+)", attrs)
    return m.group(1) if m else None


def _dims(attrs: str, key: str) -> list[int]:
    m = re.search(key + r"=\{([0-9,]*)\}", attrs)
    if not m or not m.group(1):
        return []
    return [int(x) for x in m.group(1).split(",")]


def _trip_count(cond_ops: list[Op]) -> int:
    """Scan-lowered loop conditions compare the induction var against a
    constant bound; take the max integer constant in the condition."""
    best = 1
    for op in cond_ops:
        if op.kind == "constant" and op.operands:
            try:
                best = max(best, int(op.operands[0]))
            except ValueError:
                pass
    return best


class HloCostModel:
    def __init__(self, text: str):
        self.comps = parse_computations(text)
        self.entry = self._find_entry(text)
        self._memo: dict[str, Cost] = {}

    def _find_entry(self, text: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
        return m.group(1) if m else next(iter(self.comps))

    def total(self) -> Cost:
        return self.comp_cost(self.entry)

    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # cycle guard
        ops = self.comps.get(name, [])
        shapes = {op.name: op.type_str for op in ops}
        total = Cost()
        for op in ops:
            total += self._op_cost(op, shapes)
        self._memo[name] = total
        return total

    def _op_cost(self, op: Op, shapes: dict[str, str]) -> Cost:
        c = Cost()
        kind = kind_base = op.kind
        if kind_base.endswith("-start"):
            kind_base = kind_base[: -len("-start")]
        elems, rbytes = _type_elems_bytes(op.type_str)

        if kind_base in _COLLECTIVES:
            wire = rbytes * _COLLECTIVES[kind_base]
            c.collective_bytes += wire
            c.collective_detail[kind_base] = {"count": 1, "bytes": wire}
            c.hbm_bytes += rbytes + self._operand_bytes(op, shapes)
            return c
        if kind == "while":
            body = _attr(op.attrs, "body")
            cond = _attr(op.attrs, "condition")
            trip = _trip_count(self.comps.get(cond, []))
            inner = Cost()
            inner += self.comp_cost(body)
            inner += self.comp_cost(cond)
            return inner.scaled(trip)
        if kind == "conditional":
            best = Cost()
            for m in re.finditer(r"branch_computations=\{([^}]*)\}", op.attrs):
                for branch in m.group(1).split(","):
                    bc = self.comp_cost(branch.strip().lstrip("%"))
                    if bc.flops + bc.hbm_bytes > best.flops + best.hbm_bytes:
                        best = bc
            tb = _attr(op.attrs, "true_computation")
            fb = _attr(op.attrs, "false_computation")
            for b in (tb, fb):
                if b:
                    bc = self.comp_cost(b)
                    if bc.flops + bc.hbm_bytes > best.flops + best.hbm_bytes:
                        best = bc
            best = best.scaled(1.0)
            best.hbm_bytes += rbytes
            return best
        if kind == "fusion":
            called = _attr(op.attrs, "calls")
            if called:
                inner = self.comp_cost(called)
                c.flops += inner.flops
                c.transcendentals += inner.transcendentals
                # HBM traffic only at the fusion boundary; operands consumed
                # solely by slicing ops inside count their SLICE bytes (scan
                # xs indexing must not count the whole stacked array/step)
                c.hbm_bytes += rbytes + self._fusion_operand_bytes(
                    op, shapes, called)
            else:
                c.hbm_bytes += rbytes + self._operand_bytes(op, shapes)
            return c
        if kind == "call":
            called = _attr(op.attrs, "to_apply") or _attr(op.attrs, "calls")
            if called:
                c += self.comp_cost(called)
            return c

        # slicing/updating ops touch only the sliced region, not the operand
        if kind in ("dynamic-slice", "slice", "gather"):
            c.hbm_bytes += 2 * rbytes
            return c
        if kind == "dynamic-update-slice" and len(op.operands) >= 2:
            upd = _operand_name(op.operands[1])
            ub = _type_elems_bytes(shapes.get(upd, ""))[1]
            c.hbm_bytes += 2 * ub
            return c
        if kind == "scatter" and len(op.operands) >= 3:
            upd = _operand_name(op.operands[2])
            ub = _type_elems_bytes(shapes.get(upd, ""))[1]
            c.hbm_bytes += 2 * ub
            return c

        # leaf ops
        if kind == "dot":
            lhs_shape = shapes.get(_operand_name(op.operands[0]), "")
            lelems, _ = _type_elems_bytes(lhs_shape)
            cdims = _dims(op.attrs, "lhs_contracting_dims")
            csize = 1
            mshape = _SHAPE_RE.search(lhs_shape)
            if mshape and cdims:
                dims = [int(x) for x in mshape.group(2).split(",") if x]
                for i in cdims:
                    if i < len(dims):
                        csize *= dims[i]
            c.flops += 2.0 * elems * csize
        elif kind == "convolution":
            c.flops += 2.0 * elems * 8  # rough; convs are rare here
        elif kind in _TRANSCENDENTAL:
            c.flops += elems
            c.transcendentals += elems
        elif kind in _ELEMENTWISE:
            c.flops += elems
        elif kind in ("reduce", "reduce-window"):
            c.flops += self._operand_elems(op, shapes)

        if kind not in _NO_TRAFFIC:
            c.hbm_bytes += rbytes + self._operand_bytes(op, shapes)
        return c

    def _fusion_operand_bytes(self, op: Op, shapes: dict[str, str],
                              called: str) -> float:
        """Boundary bytes with slicing-aware discounting per operand."""
        inner_ops = self.comps.get(called, [])
        inner_shapes = {o.name: o.type_str for o in inner_ops}
        # param index -> inner op name
        params: dict[int, str] = {}
        for o in inner_ops:
            if o.kind == "parameter" and o.operands:
                try:
                    params[int(o.operands[0])] = o.name
                except ValueError:
                    pass
        # usage map: inner op name -> consumer (kind, result bytes)
        total = 0.0
        for i, operand in enumerate(op.operands):
            nm = _operand_name(operand)
            full = _type_elems_bytes(shapes.get(nm, ""))[1]
            pname = params.get(i)
            if pname is None:
                total += full
                continue
            consumers = [o for o in inner_ops
                         if any(_operand_name(x) == pname
                                for x in o.operands)]
            if consumers and all(o.kind in ("dynamic-slice", "slice", "gather")
                                 for o in consumers):
                total += sum(_type_elems_bytes(o.type_str)[1]
                             for o in consumers)
            elif consumers and all(
                    o.kind == "dynamic-update-slice" and len(o.operands) >= 2
                    and _operand_name(o.operands[0]) == pname
                    for o in consumers):
                total += sum(
                    _type_elems_bytes(inner_shapes.get(
                        _operand_name(o.operands[1]), ""))[1]
                    for o in consumers)
            else:
                total += full
        return total

    def _operand_bytes(self, op: Op, shapes: dict[str, str]) -> int:
        total = 0
        for o in op.operands:
            nm = _operand_name(o)
            if nm in shapes:
                total += _type_elems_bytes(shapes[nm])[1]
        return total

    def _operand_elems(self, op: Op, shapes: dict[str, str]) -> int:
        total = 0
        for o in op.operands:
            nm = _operand_name(o)
            if nm in shapes:
                total += _type_elems_bytes(shapes[nm])[0]
        return total


def analyze_text(text: str) -> dict:
    cost = HloCostModel(text).total()
    return {
        "flops": cost.flops,
        "transcendentals": cost.transcendentals,
        "hbm_bytes": cost.hbm_bytes,
        "collective_bytes": cost.collective_bytes,
        "collective_detail": cost.collective_detail,
    }


_META_RE = re.compile(r'op_name="([^"]*)"')


def _op_label(op: Op, depth: int = 3,
              comps: Optional[dict] = None) -> str:
    m = _META_RE.search(op.attrs)
    if not m and op.kind == "fusion" and comps is not None:
        # fusion boundary carries no metadata; borrow the largest inner op's
        called = _attr(op.attrs, "calls")
        best, best_sz = None, -1
        for inner in comps.get(called, []):
            mi = _META_RE.search(inner.attrs)
            if mi:
                sz = _type_elems_bytes(inner.type_str)[1]
                if sz > best_sz:
                    best, best_sz = mi, sz
        m = best
    if not m:
        return f"<{op.kind}>"
    name = m.group(1)
    # strip jit wrapper and truncate to `depth` path segments
    parts = [p for p in name.split("/") if not p.startswith("jit(")]
    return "/".join(parts[:depth]) or name


def breakdown(text: str, key: str = "hbm_bytes", depth: int = 3,
              top: int = 20) -> list[tuple[str, float]]:
    """Attribute cost to jax-level op names (loop multipliers applied).

    key: hbm_bytes | flops | collective_bytes. The label is the op_name
    metadata truncated to `depth` path segments — enough to localize the
    model code responsible for each traffic hot-spot.
    """
    model = HloCostModel(text)
    acc: dict[str, float] = {}

    def walk(comp_name: str, mult: float):
        ops = model.comps.get(comp_name, [])
        shapes = {op.name: op.type_str for op in ops}
        for op in ops:
            if op.kind == "while":
                body = _attr(op.attrs, "body")
                cond = _attr(op.attrs, "condition")
                trip = _trip_count(model.comps.get(cond, []))
                walk(body, mult * trip)
                walk(cond, mult * trip)
                continue
            c = model._op_cost(op, shapes)
            val = getattr(c, key)
            if val:
                lbl = _op_label(op, depth, model.comps)
                acc[lbl] = acc.get(lbl, 0.0) + val * mult

    walk(model.entry, 1.0)
    return sorted(acc.items(), key=lambda kv: -kv[1])[:top]
