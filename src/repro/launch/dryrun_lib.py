"""Dry-run library: lower + compile every (arch x shape) on a given mesh.

Used by ``dryrun.py`` (which force-creates 512 host devices BEFORE any jax
import) and by tests (on small meshes). For each cell we:

  1. build ShapeDtypeStruct stand-ins for every step input (no allocation),
  2. jit with explicit in/out shardings and ``.lower().compile()``,
  3. record ``memory_analysis()`` (fits-in-HBM proof), ``cost_analysis()``
     (FLOPs/bytes for the roofline), and the per-collective byte census
     parsed from the partitioned HLO.

Skip table (DESIGN.md §5): ``long_500k`` needs sub-quadratic attention —
only ssm/hybrid run it; every other cell must compile or the cell FAILS.
"""
from __future__ import annotations

import json
import os
import re
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import ctx
from repro.distributed import sharding as shd
from repro.models.registry import Model, get_model
from repro.train.train_step import StepConfig, lower_train_step

# v5e hardware model (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 4.5e10 * 1.0        # ~50 GB/s per link (3D torus, per-direction)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]))\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

# bytes-on-wire multiplier per collective kind (ring algorithms ~ 1x the
# payload per chip; all-reduce = reduce-scatter + all-gather ~ 2x)
_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(type_str: str) -> int:
    """'bf16[2,512,128]' -> bytes; '(f32[..], f32[..])' -> sum."""
    total = 0
    for m in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_census(hlo_text: str) -> dict:
    """Per-kind op counts and wire bytes (per chip) from partitioned HLO."""
    out: dict[str, dict[str, float]] = {}
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(type_str) * _COLL_FACTOR[kind]
        rec = out.setdefault(kind, {"count": 0, "bytes": 0.0})
        rec["count"] += 1
        rec["bytes"] += b
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def should_skip(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention arch: long_500k requires sub-quadratic "
                "attention (skip noted in DESIGN.md §5)")
    return None


# ---------------------------------------------------------------------------
# Lowering per shape kind
# ---------------------------------------------------------------------------


def _decode_in_specs(model: Model, shape: ShapeConfig, mesh: Mesh):
    cfg = model.cfg
    state_shapes = model.decode_state_specs(shape)
    state_pspecs = shd.decode_state_pspecs(state_shapes, mesh,
                                           shape.global_batch)
    param_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    param_pspecs = shd.param_pspecs(param_shapes, mesh, cfg)
    mk = lambda sh, sp: jax.ShapeDtypeStruct(
        sh.shape, sh.dtype, sharding=NamedSharding(mesh, sp))
    params_in = jax.tree_util.tree_map(mk, param_shapes, param_pspecs,
                                       is_leaf=lambda x: isinstance(
                                           x, (jax.ShapeDtypeStruct, P)))
    state_in = jax.tree_util.tree_map(mk, state_shapes, state_pspecs,
                                      is_leaf=lambda x: isinstance(
                                          x, (jax.ShapeDtypeStruct, P)))
    daxes = [a for a in ("pod", "data") if a in mesh.shape]
    dp = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    tok_spec = P(tuple(daxes) if len(daxes) > 1 else (daxes[0] if daxes else None)) \
        if shape.global_batch % max(dp, 1) == 0 else P(None)
    token_in = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32,
                                    sharding=NamedSharding(mesh, tok_spec))
    return params_in, state_in, token_in, (param_pspecs, state_pspecs, tok_spec)


def lower_cell(arch: str, shape_name: str, mesh: Mesh,
               step_cfg: StepConfig = StepConfig(),
               shape_override: Optional[ShapeConfig] = None,
               quant_override: Optional[dict] = None,
               rules_override: Optional[dict] = None,
               cfg_override: Optional[dict] = None):
    """Lower one (arch x shape) on ``mesh``. Returns jax.stages.Lowered.

    ``quant_override``: dataclasses.replace kwargs applied to cfg.quant
    (e.g. {'lut_impl': 'gather', 'value_bits': 2}) — used by the §Perf A/Bs.
    ``rules_override``: logical-rule overrides (e.g. {'ssm_heads': None}).
    """
    import dataclasses
    cfg = get_config(arch)
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    if quant_override:
        cfg = dataclasses.replace(
            cfg, quant=dataclasses.replace(cfg.quant, **quant_override))
    shape = shape_override or SHAPES[shape_name]
    skip = should_skip(cfg, shape)
    if skip:
        raise SkipCell(skip)
    model = get_model(cfg)
    rules = shd.logical_rules(cfg, mesh, shape.global_batch)
    if rules_override:
        rules = dict(rules, **rules_override)

    with ctx.use_sharding(mesh, rules):
        if shape.kind == "train":
            return lower_train_step(model, mesh, step_cfg,
                                    shape.global_batch,
                                    model.input_specs(shape))
        if shape.kind == "prefill":
            params_in, state_in, _, (pp, sp, _) = _decode_in_specs(
                model, shape, mesh)
            batch_specs = model.input_specs(shape)
            bspecs = shd.batch_pspecs(batch_specs, mesh, shape.global_batch)
            batch_in = {k: jax.ShapeDtypeStruct(
                v.shape, v.dtype, sharding=NamedSharding(mesh, bspecs[k]))
                for k, v in batch_specs.items()}

            def prefill_step(params, batch, state):
                with ctx.use_sharding(mesh, rules):
                    return model.prefill(params, batch, state)

            fn = jax.jit(prefill_step, donate_argnums=(2,))
            return fn.lower(params_in, batch_in, state_in)

        # decode
        params_in, state_in, token_in, _ = _decode_in_specs(model, shape, mesh)

        def serve_step(params, state, token):
            with ctx.use_sharding(mesh, rules):
                return model.decode(params, state, token)

        fn = jax.jit(serve_step, donate_argnums=(1,))
        return fn.lower(params_in, state_in, token_in)


class SkipCell(Exception):
    pass


def run_cell(arch: str, shape_name: str, mesh: Mesh, out_dir: str,
             mesh_tag: str, step_cfg: StepConfig = StepConfig(),
             shape_override: Optional[ShapeConfig] = None,
             hbm_limit: float = 16e9, variant: str = "",
             **lower_kw) -> dict:
    """Lower + compile one cell; dump the JSON record. Raises on failure."""
    t0 = time.monotonic()
    rec: dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_tag, "variant": variant,
                           "devices": int(np.prod(list(mesh.shape.values())))}
    try:
        lowered = lower_cell(arch, shape_name, mesh, step_cfg, shape_override,
                             **lower_kw)
    except SkipCell as e:
        rec["status"] = "skip"
        rec["reason"] = str(e)
        _dump(rec, out_dir, mesh_tag, arch, shape_name)
        return rec
    rec["lower_s"] = time.monotonic() - t0

    t0 = time.monotonic()
    compiled = lowered.compile()
    rec["compile_s"] = time.monotonic() - t0

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                        None),
    }
    arg_b = rec["memory"]["argument_bytes"] or 0
    tmp_b = rec["memory"]["temp_bytes"] or 0
    out_b = rec["memory"]["output_bytes"] or 0
    alias_b = rec["memory"]["alias_bytes"] or 0
    rec["memory"]["peak_per_device"] = arg_b + tmp_b + out_b - alias_b
    rec["memory"]["fits_16g_hbm"] = bool(
        rec["memory"]["peak_per_device"] <= hbm_limit)

    # XLA's cost_analysis counts while (scan) bodies ONCE — kept only for
    # reference. The loop-aware static model (hlo_cost) is authoritative.
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # pre-0.5 jax returns [dict]
        cost = cost[0] if cost else {}
    rec["cost_xla_raw"] = {k: float(v) for k, v in cost.items()
                           if isinstance(v, (int, float)) and
                           k in ("flops", "bytes accessed", "transcendentals")}
    hlo = compiled.as_text()
    from repro.launch import hlo_cost
    static = hlo_cost.analyze_text(hlo)
    rec["cost"] = {"flops": static["flops"],
                   "bytes accessed": static["hbm_bytes"],
                   "transcendentals": static["transcendentals"]}
    rec["collectives"] = dict(static["collective_detail"],
                              total_bytes=static["collective_bytes"])
    rec["collectives_unrolled_once"] = collective_census(hlo)
    rec["hlo_bytes"] = len(hlo)
    rec["status"] = "ok"
    _dump(rec, out_dir, mesh_tag, arch,
          shape_name + (f"__{variant}" if variant else ""))
    return rec


def roofline_terms(rec: dict, n_devices: int) -> dict:
    """The three roofline terms (seconds) from a cell record.

    cost_analysis on the CPU backend reports whole-program (per-device)
    flops/bytes for the partitioned module."""
    flops = rec.get("cost", {}).get("flops", 0.0)
    bytes_acc = rec.get("cost", {}).get("bytes accessed", 0.0)
    coll = rec.get("collectives", {}).get("total_bytes", 0.0)
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": coll / ICI_BW,
    }


def _dump(rec: dict, out_dir: str, mesh_tag: str, arch: str, shape: str):
    d = os.path.join(out_dir, mesh_tag, arch)
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, f"{shape}.json"), "w") as f:
        json.dump(rec, f, indent=1)
