"""Serving launcher: batched generation over the PolarQuant cache.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --batch 4 --prompt-len 64 --gen 32 \
        --quant polar --rho-bits 4 --theta-bits 4 --value-bits 0

``--engine cb`` swaps in the continuous-batching engine over the paged
cache; ``--prefill-chunk`` enables interleaved chunked prefill and
``--prefix-cache`` shared-prefix page reuse (the launcher then gives every
request a common system-prompt prefix so the hit rate is visible).
``--stream`` drives the same workload open-loop through the streaming
front door (``repro.serve.api.StreamingEngine`` over ``EngineCore.step``):
tokens print the step they are sampled and the summary reports per-token
TTFT / inter-token-latency percentiles from the event stream.

QoS + chaos (DESIGN.md §16) quickstart::

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --engine cb --batch 4 --gen 16 \
        --tenant-budget 500 --ttft-slo 0.5 --max-pending 8 \
        --chaos "exhaust@8,cancel@12:0.5"

``--tenant-budget``/``--ttft-slo``/``--max-pending`` enable SLA-aware
admission (weighted-fair queueing, deadline shedding, bounded-queue
rejects); ``--chaos`` injects a deterministic fault schedule through the
production scheduler/allocator paths.

Run-ahead fused decode (DESIGN.md §18) quickstart::

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --engine cb --batch 2 --gen 64 --runahead 8

``--runahead H`` batches H decode micro-steps — paged append, LUT decode
attention, on-device sampling, EOS/budget masking — into one fused
device dispatch whenever the horizon planner sees a pure decode-bound
stretch, and pipelines the next horizon while a block is in flight.
Greedy outputs are bit-identical to ``--runahead 0``.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduce_for_smoke
from repro.models import get_model
from repro.serve import (
    ContinuousBatchingEngine, GenerationConfig, Request, ServeEngine,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--quant", default="polar",
                    choices=["polar", "kivi", "int", "zipcache", "none"])
    ap.add_argument("--rho-bits", type=int, default=4)
    ap.add_argument("--theta-bits", type=int, default=4)
    ap.add_argument("--value-bits", type=int, default=0)
    ap.add_argument("--group-size", type=int, default=0,
                    help="0 = keep config default")
    ap.add_argument("--int8-layers", type=int, default=0,
                    help="mixed policy: run the first N layers at int8 "
                         "(KVTuner-style) and the rest at --quant")
    ap.add_argument("--decode-backend", default="jnp",
                    choices=["jnp", "gathered", "paged_fused", "ref",
                             "interpret", "pallas"],
                    help="decode-attention backend (paged_fused = "
                         "page-native fused kernel on the paged path)")
    ap.add_argument("--prefill-backend", default="jnp",
                    choices=["jnp", "paged_fused", "ref", "interpret",
                             "pallas"],
                    help="chunked-prefill attention backend (cb engine "
                         "with --prefill-chunk; paged_fused = page-native "
                         "fused kernel over the quantized prefix pages)")
    ap.add_argument("--engine", default="static", choices=["static", "cb"],
                    help="static = one-shot batched ServeEngine; cb = "
                         "continuous batching over the paged cache")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="cb engine: chunked-prefill size in tokens "
                         "(0 = one-shot prefill; rounded up to the page "
                         "size)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="cb engine: shared-prefix page reuse (implies "
                         "chunked prefill)")
    ap.add_argument("--stream", action="store_true",
                    help="cb engine: serve through the streaming API, "
                         "printing tokens as they arrive and per-token "
                         "TTFT/ITL percentiles")
    ap.add_argument("--spec-mode", default="off",
                    choices=["off", "ngram", "draft"],
                    help="cb engine: speculative multi-token decode — "
                         "ngram self-speculation or a smaller draft model "
                         "(greedy outputs stay bit-identical to off)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens per speculative step")
    ap.add_argument("--shared-prefix-len", type=int, default=64,
                    help="cb engine: common system-prompt length prepended "
                         "to every request (demo workload for "
                         "--prefix-cache)")
    ap.add_argument("--tenant-budget", type=float, default=0.0,
                    help="cb engine: per-tenant token-bucket budget in "
                         "tokens/s of engine time (0 = unlimited); enables "
                         "QoS weighted-fair admission")
    ap.add_argument("--ttft-slo", type=float, default=0.0,
                    help="cb engine: session TTFT deadline in seconds — "
                         "requests whose deadline is blown or unmeetable "
                         "are shed with an explicit event (0 = off)")
    ap.add_argument("--max-pending", type=int, default=0,
                    help="cb engine: bounded admission queue — intake over "
                         "this depth rejects with an explicit event "
                         "(0 = unbounded)")
    ap.add_argument("--runahead", type=int, default=0,
                    help="cb engine: run-ahead fused decode horizon H — "
                         "batch H decode micro-steps with on-device "
                         "sampling into one dispatch in decode-bound "
                         "stretches (0/1 = off; greedy outputs stay "
                         "bit-identical)")
    ap.add_argument("--chaos", default="",
                    help="cb engine: deterministic fault injection spec, "
                         "e.g. 'exhaust@8,slow@5:0.05,cancel@12:0.5,"
                         "proposer@0.3' (see repro.serve.chaos)")
    ap.add_argument("--mesh-shape", default="",
                    help="thread a device mesh through the engine, e.g. "
                         "'1x2' = (data=1, model=2): KV page pools and "
                         "attention heads shard over the model axis where "
                         "divisible (DESIGN.md §17). Multi-device CPU runs "
                         "need XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N set before launch")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    qkw = dict(method=args.quant, rho_bits=args.rho_bits,
               theta_bits=args.theta_bits, value_bits=args.value_bits)
    if args.group_size:
        qkw["group_size"] = args.group_size
    quant = dataclasses.replace(cfg.quant, **qkw)
    policy = None
    if args.int8_layers > 0:
        from repro.core import CachePolicy
        policy = CachePolicy.first_k(
            args.int8_layers,
            dataclasses.replace(quant, method="int", key_bits=8),
            quant)
    cfg = dataclasses.replace(cfg, quant=quant, cache_policy=policy,
                              decode_backend=args.decode_backend,
                              prefill_backend=args.prefill_backend)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    mesh = None
    if args.mesh_shape:
        from repro.launch.mesh import make_mesh
        try:
            shape = tuple(int(x) for x in args.mesh_shape.split("x"))
        except ValueError:
            raise SystemExit(f"bad --mesh-shape {args.mesh_shape!r}; "
                             "expected e.g. '1x2' (data x model)")
        if len(shape) != 2:
            raise SystemExit("--mesh-shape takes two axes: data x model")
        mesh = make_mesh(shape, ("data", "model"))
        print(f"[serve] mesh data={shape[0]} model={shape[1]} over "
              f"{jax.device_count()} devices "
              f"(kv_heads={cfg.num_kv_heads}: "
              f"{'head-sharded' if cfg.num_kv_heads % shape[1] == 0 else 'replicated fallback'})")

    rng = np.random.default_rng(args.seed)
    batch = {"tokens": rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)}
    if cfg.family == "encdec":
        batch["frames"] = rng.standard_normal(
            (args.batch, cfg.frontend_tokens, cfg.frontend_dim)).astype(np.float32)
    if cfg.family == "vlm":
        batch["patches"] = rng.standard_normal(
            (args.batch, cfg.frontend_tokens, cfg.frontend_dim)).astype(np.float32)

    print(f"[serve] {cfg.name} quant={args.quant} bits/key-elem="
          f"{cfg.policy.avg_key_bits(cfg.num_layers, cfg.head_dim):.2f}")
    if args.engine == "cb":
        shared = rng.integers(0, cfg.vocab_size,
                              (args.shared_prefix_len,)).astype(np.int32)
        # the first request arrives alone so its prefill registers the
        # shared prefix's pages before the rest admit (simulated clock:
        # the idle gap is jumped, not slept)
        reqs = [Request(rid=i,
                        prompt=np.concatenate([shared, batch["tokens"][i]]),
                        max_new_tokens=args.gen,
                        arrival_time=0.0 if i == 0 else 100.0 + 0.01 * i)
                for i in range(args.batch)]
        spec = None
        if args.spec_mode != "off":
            from repro.spec import SpecConfig
            spec = SpecConfig(mode=args.spec_mode, k=args.spec_k)
        qos = None
        if args.tenant_budget > 0 or args.ttft_slo > 0 or \
                args.max_pending > 0:
            from repro.serve import QosConfig
            qos = QosConfig(tenant_budget=args.tenant_budget,
                            ttft_slo=args.ttft_slo,
                            max_pending=args.max_pending)
            print(f"[serve] qos: budget={args.tenant_budget} tok/s  "
                  f"ttft-slo={args.ttft_slo}s  "
                  f"max-pending={args.max_pending}")
        chaos = None
        if args.chaos:
            from repro.serve import ChaosConfig, ChaosInjector
            chaos = ChaosInjector(ChaosConfig.parse(args.chaos,
                                                    seed=args.seed))
            print(f"[serve] chaos: {chaos.cfg}")
        eng = ContinuousBatchingEngine(
            model, params, max_slots=args.batch, max_len=args.max_len,
            mesh=mesh, prefix_cache=args.prefix_cache,
            prefill_chunk=args.prefill_chunk, spec=spec,
            qos=qos, chaos=chaos, runahead=args.runahead)
        eng.warmup([r.prompt_len for r in reqs] + [args.max_len],
                   GenerationConfig(max_new_tokens=args.gen))
        gen = GenerationConfig(max_new_tokens=args.gen,
                               temperature=args.temperature, seed=args.seed)
        if args.stream:
            from repro.serve import StreamingEngine, stream_latency_stats
            stream = StreamingEngine(eng, gen)
            for r in reqs:
                stream.submit(r)
            texts: dict[int, list] = {r.rid: [] for r in reqs}
            events = []
            for ev in stream.events():
                events.append(ev)
                if ev.kind in ("first_token", "token"):
                    texts[ev.rid].append(ev.token)
                    print(f"[stream] t={ev.t * 1e3:8.1f}ms rid={ev.rid} "
                          f"slot={ev.slot} +{ev.token}")
                elif ev.kind == "preempt":
                    # the victim's last streamed token is retracted and
                    # re-sampled when it resumes
                    if texts[ev.rid]:
                        texts[ev.rid].pop()
                    print(f"[stream] t={ev.t * 1e3:8.1f}ms rid={ev.rid} "
                          f"preempt (-{ev.token})")
                else:
                    print(f"[stream] t={ev.t * 1e3:8.1f}ms rid={ev.rid} "
                          f"{ev.kind}")
            out = stream.result()
            lat = stream_latency_stats(events, reqs)
            print(f"[serve] streamed {out['total_tokens']} tokens  "
                  f"{out['tokens_per_s']:.1f} tok/s  "
                  f"ttft p50 {lat['ttft_s']['p50'] * 1e3:.1f}ms "
                  f"p99 {lat['ttft_s']['p99'] * 1e3:.1f}ms  "
                  f"itl p50 {lat['itl_s']['p50'] * 1e3:.1f}ms "
                  f"p99 {lat['itl_s']['p99'] * 1e3:.1f}ms")
            if "spec" in out:
                sp = out["spec"]
                print(f"[serve] spec mode={sp['mode']} k={sp['k']}  "
                      f"acceptance {sp['acceptance_rate'] * 100:.1f}%  "
                      f"({sp['accepted_tokens']}/{sp['drafted_tokens']} "
                      "drafts)")
                print(f"[serve] spec mean accepted/step "
                      f"{sp['mean_accepted_per_step']:.2f} over "
                      f"{sp['steps']} speculative steps")
            print(f"[serve] first sequence: {texts[reqs[0].rid]}")
            return 0
        out = eng.run(reqs, gen)
        print(f"[serve] cb decode {out['tokens_per_s']:.1f} tok/s  "
              f"p50 {out['p50_latency_s'] * 1e3:.1f}ms  "
              f"cache {out['cache_bytes'] / 2**20:.2f} MiB  "
              f"prefill-chunk {out['prefill_chunk']}")
        if "qos" in out:
            print(f"[serve] qos: {out['n_shed']} shed  "
                  f"{out['n_rejected']} rejected  "
                  f"prefill-rate-est {out['qos']['prefill_rate_est']}")
        if "chaos" in out:
            print(f"[serve] chaos: {out['chaos']}")
        if "spec" in out:
            sp = out["spec"]
            print(f"[serve] spec mode={sp['mode']} k={sp['k']}  "
                  f"acceptance {sp['acceptance_rate'] * 100:.1f}%  "
                  f"mean accepted/step {sp['mean_accepted_per_step']:.2f}")
        if "runahead" in out:
            ra = out["runahead"]
            print(f"[serve] runahead h={ra['h']}  "
                  f"{ra['horizons']} horizons  "
                  f"{ra['tokens']} horizon tokens  "
                  f"dispatch-gap ewma "
                  f"{ra['dispatch_gap_ewma_s'] * 1e3:.2f}ms")
        if args.prefix_cache:
            print(f"[serve] prefix hit rate "
                  f"{out['prefix_hit_rate'] * 100:.1f}%  "
                  f"({out['prefill_tokens_skipped']} prompt tokens "
                  f"skipped, {out['adopted_pages']} pages adopted, "
                  f"{out['prefix_pool_bytes_saved'] / 2**20:.2f} MiB "
                  "pool bytes shared)")
        first = out["requests"][0].out_tokens
        print(f"[serve] first sequence: {first}")
        return 0
    skw = {}
    if mesh is not None:
        from repro.distributed.sharding import serving_rules
        skw = dict(mesh=mesh, rules=serving_rules(cfg, mesh, args.batch))
    eng = ServeEngine(model, params, max_len=args.max_len, **skw)
    out = eng.generate(batch, GenerationConfig(
        max_new_tokens=args.gen, temperature=args.temperature, seed=args.seed))
    print(f"[serve] prefill {out['prefill_s'] * 1e3:.1f}ms  "
          f"decode {out['tokens_per_s']:.1f} tok/s  "
          f"cache {out['cache_bytes'] / 2**20:.2f} MiB")
    print(f"[serve] first sequence: {out['tokens'][0].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
