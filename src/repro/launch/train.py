"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 100 --batch 8 --seq 128 [--mesh host]

``--smoke`` trains the reduced config of the chosen architecture (CPU
friendly); without it the full published config is used (requires real
accelerators). ``--mesh host`` builds a mesh over the visible devices and
runs the fully-sharded (FSDP x TP) step.
"""
from __future__ import annotations

import argparse

from repro.configs import ARCH_IDS, get_config, reduce_for_smoke
from repro.data import SyntheticLMDataset
from repro.models import get_model
from repro.train import Trainer, TrainerConfig
from repro.train.train_step import StepConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_launch_train")
    ap.add_argument("--mesh", default="none", choices=["none", "host"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    model = get_model(cfg)
    print(f"[train] {cfg.name}: {cfg.param_count() / 1e6:.1f}M params")

    mesh = None
    if args.mesh == "host":
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(model_axis=1)

    ds = SyntheticLMDataset(cfg, global_batch=args.batch, seq_len=args.seq,
                            seed=args.seed)
    trainer = Trainer(
        model, ds,
        TrainerConfig(total_steps=args.steps, checkpoint_every=max(args.steps // 2, 1),
                      checkpoint_dir=args.ckpt, log_every=10, seed=args.seed),
        StepConfig(peak_lr=args.lr, warmup_steps=min(30, args.steps // 3),
                   total_steps=args.steps, microbatches=args.microbatches),
        mesh=mesh)
    res = trainer.run()
    print(f"[train] done: final loss {res['losses'][-1]:.4f} "
          f"(start {res['losses'][0]:.4f})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
