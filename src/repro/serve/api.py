"""Streaming request API: the open-loop front door over
:class:`~repro.serve.core.EngineCore` (DESIGN.md §13).

The batch adapter (``engine.ContinuousBatchingEngine``) takes every
request up front and returns tokens when the whole batch drains.
:class:`StreamingEngine` inverts that: requests are **added while the
loop runs**, tokens stream out as :class:`~repro.serve.core.TokenEvent`\\ s
the step they are sampled, and any request can be **cancelled**
mid-prefill or mid-decode — its pages are decref'd through the scheduler
(never freed under the prefix index's refcounts) and its slot is reusable
by the very next admission.

Host-side only: no ``jax`` anywhere in this module — every device
dispatch happens inside ``EngineCore.step()`` (enforced by
``scripts/check_engine_layering.sh``).

Typical interactive use::

    eng = StreamingEngine(EngineCore(model, params, max_slots=4))
    rid = eng.add_request(prompt, max_new_tokens=64)
    for ev in eng.events():
        if ev.kind in ("first_token", "token"):
            emit(ev.rid, ev.token)          # per-token streaming
        elif ev.kind == "preempt":
            retract_last(ev.rid)            # ev.token was withdrawn; it
                                            # is re-sampled on resume
        if bored_of(ev.rid):
            eng.cancel(ev.rid)              # frees pages + slot next step

``events()`` ends when the engine runs out of work; calling it again
after more ``add_request()`` calls resumes the same session (same cache,
same prefix index, same clock).

**Run-ahead stream semantics** (``EngineCore(runahead=H)``, DESIGN.md
§18): in decode-bound stretches the core batches H decode micro-steps
into one device dispatch and emits that horizon's tokens when the block
lands — typically on the *next* ``step()`` call, so a single step may
yield zero events (the dispatch step) and the following one a burst.
Horizon tokens reuse the speculative-span shape: per-token ordinals stay
dense, every token of a horizon shares one clock stamp with
``(span, span_ix)`` marking its position, and EOS/budget truncation
happens before emission — so ``check_event_stream`` and
``stream_latency_stats`` apply unchanged. A ``cancel()`` arriving while
a horizon is in flight lands the block first: its token events are
delivered ahead of the ``cancel`` event, never after it.
"""
from __future__ import annotations

from collections import deque
from typing import Iterable, Iterator, Optional

import numpy as np

from repro.serve.core import EngineCore, GenerationConfig, TokenEvent
from repro.serve.scheduler import Request
from repro.utils import nearest_rank_pct


class StreamingEngine:
    """Open-loop driver over an :class:`EngineCore`.

    ``core`` may be an ``EngineCore`` or anything exposing one as
    ``.core`` (e.g. a ``ContinuousBatchingEngine`` whose compiled
    functions you want to reuse). Construction starts a fresh session
    with ``gen`` as the sampling configuration.
    """

    def __init__(self, core, gen: Optional[GenerationConfig] = None):
        self.core: EngineCore = getattr(core, "core", core)
        self.core.reset(gen)
        self._next_rid = 0
        self._pending_events: deque[TokenEvent] = deque()

    # --- request intake ---------------------------------------------------

    def add_request(self, prompt, max_new_tokens: int = 32, *,
                    rid: Optional[int] = None,
                    arrival_time: Optional[float] = None,
                    tenant: str = "default",
                    ttft_deadline: float = 0.0) -> int:
        """Enqueue a prompt; returns its rid. ``arrival_time`` defaults
        to *now* on the engine clock (an open-loop caller never schedules
        the future; batch replays may). ``tenant``/``ttft_deadline``
        feed QoS accounting and deadline shedding when the core has a
        :class:`~repro.serve.qos.QosConfig` (ignored otherwise).

        With QoS bounded-queue backpressure, intake over a full queue
        never hangs silently: the request is marked rejected and an
        explicit ``reject`` event (``reason="queue_full"``) is queued
        for the next :meth:`step`/:meth:`events` pull."""
        if rid is None:
            rid = self._next_rid   # submit() advances the counter
        req = Request(
            rid=rid, prompt=np.asarray(prompt, np.int32),
            max_new_tokens=int(max_new_tokens),
            arrival_time=(self.core.clock if arrival_time is None
                          else float(arrival_time)),
            tenant=tenant, ttft_deadline=float(ttft_deadline))
        return self.submit(req)

    def submit(self, req: Request) -> int:
        """Enqueue a pre-built :class:`Request` (batch-replay path)."""
        self._next_rid = max(self._next_rid, req.rid) + 1
        rid = self.core.add_request(req)
        # surface intake-time QoS rejects immediately, ahead of step
        # events, so a caller that only polls events() sees the reject
        self._pending_events.extend(self.core.take_intake_events())
        return rid

    def cancel(self, rid: int) -> bool:
        """Cancel ``rid`` wherever it is — queued, mid-prefill, or
        mid-decode. Pages are decref'd and the slot freed immediately
        (host-side); the ``cancel`` event surfaces on the next
        :meth:`step` / :meth:`events` pull.

        Cancelling an unknown rid — including one that already finished,
        was already cancelled, or was shed/rejected by QoS — is a
        **documented no-op**: it returns False, emits nothing, and
        leaves the session untouched (racing a cancel against a
        completion must never error)."""
        events = self.core.cancel(rid)
        self._pending_events.extend(events)
        return bool(events)

    # --- the event stream -------------------------------------------------

    @property
    def has_work(self) -> bool:
        return bool(self._pending_events) or self.core.has_work

    def step(self) -> list[TokenEvent]:
        """One engine step's worth of events (cancel events emitted
        between steps are delivered first, in order)."""
        events = list(self._pending_events)
        self._pending_events.clear()
        if self.core.has_work:
            events.extend(self.core.step())
        return events

    def events(self) -> Iterator[TokenEvent]:
        """Yield events until the engine has no work. Safe to re-enter:
        add more requests and iterate again to continue the session."""
        while self.has_work:
            yield from self.step()

    def result(self) -> dict:
        """Aggregate session metrics so far (see
        :meth:`EngineCore.result`)."""
        return self.core.result()


# ---------------------------------------------------------------------------
# Event-stream latency accounting
# ---------------------------------------------------------------------------


def stream_latency_stats(events: Iterable[TokenEvent],
                         requests: Iterable[Request]) -> dict:
    """Per-request TTFT and inter-token latency percentiles from a
    :class:`TokenEvent` stream.

    * **TTFT** — first *kept* token minus the request's
      ``arrival_time``: queueing + admission + the whole prefill, the
      honest first-byte number a streaming client sees. A ``preempt``
      event retracts the rid's latest token; if that empties everything
      the client was shown, TTFT restarts at the post-resume token.
    * **ITL** — gaps between consecutive token-bearing events
      (``first_token``/``token``) of the same request. Preemption shows
      up as one long gap (the recompute), exactly as a client would
      experience it. A speculative step retires a whole span of tokens
      from ONE dispatch (``TokenEvent.span``/``span_ix``): every token
      of the span carries the same timestamp, so the intra-span gaps
      count as ~0 ITL — the client really does receive them together —
      and the gap to the *next* step carries the full step latency.
      Gaps are clamped at zero so replayed or merged event streams can
      never produce negative ITL entries.

    Returns ``{"ttft_s": {p50,p95,p99,mean,n}, "itl_s": {...}}`` (zeros
    when the stream is empty).
    """
    arrival = {r.rid: r.arrival_time for r in requests}
    first_t: dict[int, float] = {}
    last_t: dict[int, float] = {}
    ntoks: dict[int, int] = {}
    ttft_by: dict[int, float] = {}
    itls: list[float] = []
    for ev in events:
        if ev.kind == "preempt" and ntoks.get(ev.rid, 0) > 0:
            ntoks[ev.rid] -= 1
            if ntoks[ev.rid] == 0:
                # the whole visible stream was retracted: the next token
                # is the client's real first byte again
                first_t.pop(ev.rid, None)
                last_t.pop(ev.rid, None)
                ttft_by.pop(ev.rid, None)
            continue
        if ev.kind not in ("first_token", "token"):
            continue
        ntoks[ev.rid] = ntoks.get(ev.rid, 0) + 1
        if ev.rid not in first_t:
            first_t[ev.rid] = ev.t
            if ev.rid in arrival:
                ttft_by[ev.rid] = ev.t - arrival[ev.rid]
        else:
            itls.append(max(ev.t - last_t[ev.rid], 0.0))
        last_t[ev.rid] = ev.t
    ttfts = list(ttft_by.values())

    def stats(vals: list[float]) -> dict:
        vals = sorted(vals)
        return {
            "p50": nearest_rank_pct(vals, 50),
            "p95": nearest_rank_pct(vals, 95),
            "p99": nearest_rank_pct(vals, 99),
            "mean": float(np.mean(vals)) if vals else 0.0,
            "n": len(vals),
        }

    return {"ttft_s": stats(ttfts), "itl_s": stats(itls)}


def check_event_stream(events: Iterable[TokenEvent]) -> dict:
    """Assert the event-stream invariants every engine session must
    uphold, under any fault or overload (used by tests/test_chaos.py and
    the adversarial bench arms):

    * per-rid token ordinals are **dense** — each kept token's ordinal
      is exactly (tokens emitted so far − tokens retracted by preempts);
    * at most one **terminal** event per rid (``finish``, ``cancel``,
      ``shed``, or ``reject``), with no token/admit events after it;
    * ``first_token`` only ever happens once per rid;
    * timestamps are non-decreasing stream-wide.

    Returns per-rid terminal kinds (``{rid: kind}``) so callers can
    cross-check against request states; raises AssertionError on any
    violation."""
    ntoks: dict[int, int] = {}
    seen_first: set[int] = set()
    terminal: dict[int, str] = {}
    last_t = float("-inf")
    for ev in events:
        assert ev.t >= last_t, \
            f"timestamp regression at rid {ev.rid}: {ev.t} < {last_t}"
        last_t = ev.t
        if ev.rid in terminal:
            raise AssertionError(
                f"rid {ev.rid}: event {ev.kind!r} after terminal "
                f"{terminal[ev.rid]!r}")
        if ev.kind in ("first_token", "token"):
            if ev.kind == "first_token":
                assert ev.rid not in seen_first, \
                    f"rid {ev.rid}: duplicate first_token"
                seen_first.add(ev.rid)
            n = ntoks.get(ev.rid, 0)
            assert ev.ordinal == n, \
                f"rid {ev.rid}: ordinal {ev.ordinal} != dense {n}"
            ntoks[ev.rid] = n + 1
        elif ev.kind == "preempt":
            if ntoks.get(ev.rid, 0) > 0:
                ntoks[ev.rid] -= 1   # the retracted token re-samples
        elif ev.kind in ("finish", "cancel", "shed", "reject"):
            terminal[ev.rid] = ev.kind
    return terminal
