"""Continuous-batching scheduler state: requests, slots, page accounting.

Host-side only — everything here runs between jitted steps. The scheduler
owns the slot free-list and the :class:`~repro.core.cache_layout.PageAllocator`
and decides *which* requests run each step; the engine owns the jitted
model calls and the clock.

Policies (deliberately simple, vLLM-style FCFS):

* **Admission**: a pending request is admitted when a slot is free AND the
  pool can cover the pages for its context plus the first decoded token
  (so an admitted request can always produce at least one token without
  stalling). With a :class:`~repro.core.cache_layout.PrefixIndex` attached,
  the context is first matched against indexed prompt pages: hits are
  *adopted* into the slot's table row at refcount+1 (encoded bytes shared
  verbatim) and only the remainder needs fresh pages — under pool pressure
  index-only pages are evicted to make room (DESIGN.md §12).
* **Decode paging**: when a slot's next token starts a new group, one page
  is allocated on demand. If the pool is empty the slot *stalls* — it is
  simply excluded from the step's active mask and retried next step. If
  *every* active slot stalls, the engine recompute-preempts the most
  recently admitted request (free its pages, requeue, prefill the full
  context on re-admission) so the rest make progress.
* **Reclamation**: EOS / length-limit completion frees the slot and
  *decrefs* all of its pages — pages shared with other slots or pinned by
  the prefix index survive with their encoded bytes intact.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable, Optional

import numpy as np

from repro.core.cache_layout import (
    PageAllocator, PagedLayout, PrefixIndex, token_page_hashes,
)


@dataclasses.dataclass
class Request:
    """One generation request (host-side bookkeeping)."""

    rid: int
    prompt: np.ndarray                  # (Tp,) int32
    max_new_tokens: int = 32
    arrival_time: float = 0.0           # engine-clock seconds
    tenant: str = "default"             # QoS accounting bucket
    #: TTFT deadline in seconds from arrival (0 = fall back to the
    #: session's ``QosConfig.ttft_slo``; only enforced under QoS)
    ttft_deadline: float = 0.0

    # filled in by the engine
    out_tokens: list = dataclasses.field(default_factory=list)
    slot: int = -1
    state: str = "waiting"              # EngineCore lifecycle (core.py)
    preemptions: int = 0
    prefix_hit_tokens: int = 0          # tokens adopted at the last admission
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def context_len(self) -> int:
        """Tokens the cache must hold at (re-)admission: the prompt plus
        everything already generated (recompute-preemption resumes by
        prefilling the whole context)."""
        return self.prompt_len + len(self.out_tokens)

    def context_tokens(self) -> np.ndarray:
        return np.concatenate(
            [np.asarray(self.prompt, np.int32),
             np.asarray(self.out_tokens, np.int32)])

    @property
    def done_tokens(self) -> int:
        return len(self.out_tokens)

    def latency(self) -> float:
        return (self.t_done or 0.0) - self.arrival_time


@dataclasses.dataclass(frozen=True)
class CancelSummary:
    """Uniform shutdown report for a request leaving the scheduler early
    (cancel or QoS shed), identical in shape whether or not the request
    was ever admitted: ``slot`` is -1 and ``freed_pages`` 0 for a
    never-admitted (pending) request; an active request reports the slot
    it released and the pages that actually returned to the free list
    (shared / index-pinned pages survive and are not counted)."""

    req: Request
    slot: int = -1
    was_active: bool = False
    freed_pages: int = 0


class Scheduler:
    """Slot + page bookkeeping for one engine.

    ``prefix_index`` (optional) enables shared-prefix page reuse;
    ``chunk_tokens`` is the engine's prefill chunk size — adoption is
    rounded *down* to chunk boundaries and always leaves at least the
    final chunk to recompute, which is what keeps a shared-prefix prefill
    bit-identical to the unshared chunked baseline and guarantees the
    engine has live logits for the last prompt token (DESIGN.md §12).

    ``qos`` (optional, a :class:`~repro.serve.qos.QosState`) replaces the
    pure-FCFS head-of-queue admission poll with weighted fair queueing
    over the whole pending queue (budget-filtered, see DESIGN.md §16).
    With ``qos=None`` admission is bit-identical to the pre-QoS
    scheduler.
    """

    def __init__(self, layout: PagedLayout, *,
                 prefix_index: Optional[PrefixIndex] = None,
                 chunk_tokens: int = 0, qos=None):
        self.layout = layout
        self.alloc = PageAllocator(layout)
        self.prefix = prefix_index
        self.qos = qos
        self.chunk_tokens = int(chunk_tokens)
        self.free_slots: deque[int] = deque(range(layout.slots))
        self.active: dict[int, Request] = {}       # slot -> request
        self.pending: deque[Request] = deque()
        # prefix-reuse accounting (whole-run totals)
        self.adopted_pages = 0
        self.fresh_pages = 0
        self._last_query: tuple[int, int] = (-1, -1)
        self._hash_cache: tuple[int, int, list[bytes]] = (-1, -1, [])

    # --- admission -------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def _adoptable(self, req: Request) -> list[int]:
        """Pages of ``req``'s context the prefix index can serve, rounded
        down to a prefill-chunk boundary and capped so the chunk holding
        the last context token is always recomputed.

        Always matched fresh against the live index — never cached:
        eviction (e.g. from :meth:`ensure_pages` under decode pressure)
        may drop indexed pages between admission polls, and a stale page
        list would adopt a freed page. Index entries hold allocator refs,
        so pages returned by a fresh match are live by construction.
        Only the hit/query *stats* are deduplicated across repeated polls
        of the same queue head."""
        if self.prefix is None or self.chunk_tokens <= 0:
            return []
        ctx_len = req.context_len
        g = self.layout.page_size
        c = self.chunk_tokens
        count = self._last_query != (req.rid, ctx_len)
        self._last_query = (req.rid, ctx_len)
        # memoize the chain hashes (pure in the tokens — O(context) SHA1
        # work otherwise repeated on every admission poll of the same
        # queue head); the page walk itself always hits the live index
        rid, clen, hashes = self._hash_cache
        if (rid, clen) != (req.rid, ctx_len):
            hashes = token_page_hashes(req.context_tokens(), g)
            self._hash_cache = (req.rid, ctx_len, hashes)
        hit = self.prefix.match_hashes(hashes, count=count)
        n_chunks = min((len(hit) * g) // c, (ctx_len - 1) // c)
        return hit[: n_chunks * c // g]

    def reclaim(self, need: int, keep: Optional[set[int]] = None) -> int:
        """Evict index-only pages (LRU, leaf-first) until ``need`` pages
        are free; returns pages actually freed."""
        if self.prefix is None or need <= 0:
            return 0
        return self.prefix.evict(self.alloc, need, keep=keep)

    def _fits(self, req: Request) -> bool:
        """Can ``req`` be admitted right now (pages for its context plus
        the first decode append, after adopting prefix hits and evicting
        index-only pages if needed)?"""
        # pages for the context plus the first decode append: a new page is
        # only needed when the context ends exactly at a page boundary
        need = self.layout.pages_for(req.context_len + 1)
        if need > self.layout.pages_per_slot:
            raise ValueError(
                f"request {req.rid}: context {req.context_len} needs {need} "
                f"pages > pages_per_slot {self.layout.pages_per_slot}")
        hits = self._adoptable(req)
        need -= len(hits)
        if not self.alloc.can_alloc(need):
            self.reclaim(need - self.alloc.free_pages, keep=set(hits))
        return self.alloc.can_alloc(need)

    def admissible(self) -> Optional[Request]:
        """Next pending request that fits right now.

        Without QoS: FCFS, head only — a head that doesn't fit blocks the
        queue, preserving strict arrival-order fairness. With QoS: the
        pending queue is walked in weighted-fair order (over-budget
        tenants filtered) and the first request that fits is returned —
        a blocked head no longer starves everyone behind it."""
        if not self.pending or not self.free_slots:
            return None
        if self.qos is None:
            req = self.pending[0]
            return req if self._fits(req) else None
        for req in self.qos.admission_order(self.pending):
            if self._fits(req):
                return req
        return None

    def admit(self, req: Request) -> int:
        """Assign a slot; adopt prefix-hit pages (refcount+1, encoded bytes
        shared verbatim) and allocate fresh pages for the rest of the
        context plus the first decode token. Caller runs the prefill from
        ``req.prefix_hit_tokens`` onward."""
        self._remove_pending(req)
        slot = self.free_slots.popleft()
        hits = self._adoptable(req)
        need = self.layout.pages_for(req.context_len + 1) - len(hits)
        if hits:
            ok = self.alloc.adopt(slot, hits)
            assert ok, "admissible() checked row capacity"
        ok = self.alloc.alloc(slot, need)
        assert ok, "admissible() guaranteed capacity"
        self.adopted_pages += len(hits)
        self.fresh_pages += need
        req.prefix_hit_tokens = len(hits) * self.layout.page_size
        self._last_query = (-1, -1)
        req.slot = slot
        self.active[slot] = req
        if self.qos is not None:
            self.qos.on_admit(req)
        return slot

    def _remove_pending(self, req: Request) -> None:
        """Drop ``req`` from the pending queue by identity (QoS admission
        may pick a non-head request; Request.__eq__ is useless here — it
        compares prompt arrays)."""
        for i, r in enumerate(self.pending):
            if r is req:
                del self.pending[i]
                return
        raise AssertionError(f"request {req.rid} not pending")

    def register_prefix(self, slot: int) -> int:
        """Index the slot's *prompt* pages once its prefill completed (full
        prefill chunks only — trailing pages are never adopted, so indexing
        them would only pin pool space). The index increfs each newly
        registered page, keeping it alive past EOS reclamation."""
        if self.prefix is None or self.chunk_tokens <= 0:
            return 0
        req = self.active[slot]
        g = self.layout.page_size
        n_pages = (req.prompt_len // self.chunk_tokens) * \
            (self.chunk_tokens // g)
        if n_pages <= 0:
            return 0
        pages = self.alloc.slot_page_ids(slot)[:n_pages]
        return self.prefix.register(np.asarray(req.prompt, np.int32), pages,
                                    self.alloc)

    # --- decode-step paging ----------------------------------------------

    def ensure_pages(self, lengths: np.ndarray, skip: Iterable[int] = (),
                     spans: Optional[dict] = None) -> list[int]:
        """Allocate next-group pages for slots about to cross a page
        boundary; returns slots that must stall this step (pool empty even
        after evicting index-only pages).

        ``lengths``: (slots,) current per-slot token counts — the next
        append writes at ``lengths[slot]``. ``skip``: slots to leave alone
        (mid-prefill slots, whose pages were fully reserved at admission).
        ``spans``: optional slot -> tokens the next dispatch may append
        (>= 1, speculative decode). Without it — or for span 1 — behavior
        is the classic single-token rule. A wider span asks for every
        page covering ``[pos, pos + span)``; under a dry pool the
        trailing *draft* pages are shed one at a time (the engine then
        trims the drafts to the allocated capacity), and the slot only
        stalls when even its first append position has no page.
        """
        g = self.layout.page_size
        skip = set(skip)
        stalled = []
        for slot in self.active:
            if slot in skip:
                continue
            pos = int(lengths[slot])
            span = min(spans.get(slot, 1) if spans else 1,
                       self.layout.tokens_per_slot - pos)
            if span <= 1:
                need_page = pos // g
                if pos % g == 0 and self.alloc.slot_pages(slot) <= need_page:
                    if not self.alloc.can_alloc(1):
                        self.reclaim(1)
                    if not self.alloc.alloc(slot, 1):
                        stalled.append(slot)
                continue
            want = self.layout.pages_for(pos + span)
            need_min = self.layout.pages_for(pos + 1)
            while self.alloc.slot_pages(slot) < want:
                n = want - self.alloc.slot_pages(slot)
                if not self.alloc.can_alloc(n):
                    self.reclaim(n)
                if self.alloc.alloc(slot, n):
                    break
                if want <= need_min:
                    break
                want -= 1
            if self.alloc.slot_pages(slot) < need_min:
                stalled.append(slot)
        return stalled

    # --- cancellation ----------------------------------------------------

    def cancel(self, rid: int) -> Optional[CancelSummary]:
        """Cancel request ``rid`` wherever the scheduler holds it.

        Pending (never admitted): dequeued without ever touching the
        pool. Active (mid-prefill or mid-decode): released through
        :meth:`finish`, so every owned page is *decref'd* — pages shared
        with other slots or pinned by the prefix index survive with their
        encoded bytes intact, exclusive pages return to the free list —
        and the slot rejoins the free list for the next admission.

        Both paths return the same :class:`CancelSummary` shape (slot -1
        and zero freed pages for the pending case); ``None`` when ``rid``
        is unknown to the scheduler (already finished, already cancelled,
        or never submitted) — a documented no-op, not an error."""
        for i, req in enumerate(self.pending):
            if req.rid == rid:
                del self.pending[i]
                # both admission memos may describe the removed request;
                # a later request may legally reuse its rid (and even its
                # context length), so stale hashes would adopt the wrong
                # pages — force a fresh match for whoever is head next
                self._last_query = (-1, -1)
                self._hash_cache = (-1, -1, [])
                return CancelSummary(req)
        for slot, req in self.active.items():
            if req.rid == rid:
                self._last_query = (-1, -1)
                self._hash_cache = (-1, -1, [])
                free_before = self.alloc.free_pages
                self.finish(slot)
                return CancelSummary(
                    req, slot=slot, was_active=True,
                    freed_pages=self.alloc.free_pages - free_before)
        return None

    # --- completion ------------------------------------------------------

    def finish(self, slot: int) -> Request:
        req = self.active.pop(slot)
        self.alloc.free_slot(slot)
        self.free_slots.append(slot)
        req.slot = -1
        return req

    def preempt(self, slot: int) -> Request:
        """Recompute-preemption: free the slot and its pages, requeue the
        request at the head of the pending queue. The engine drops the
        latest un-appended token first, so resuming == prefilling
        ``prompt + out_tokens`` and re-sampling from there. The cache
        rebuilds bit-identically (streaming-parity invariant), but the
        resumed token is sampled from fp *prefill* logits rather than
        quantized-cache *decode* logits, so a resumed greedy sequence may
        diverge from an uninterrupted run at exactly the resume point —
        the same numeric boundary every request crosses after its initial
        prefill. With a prefix index attached, the victim's prompt pages
        usually survive preemption (index refs) and are re-adopted on
        resume, so the recompute cost shrinks to the unshared tail."""
        req = self.finish(slot)
        req.preemptions += 1
        self.pending.appendleft(req)
        return req

    # --- introspection ---------------------------------------------------

    @property
    def num_active(self) -> int:
        return len(self.active)

    @property
    def has_work(self) -> bool:
        return bool(self.active or self.pending)

    def utilization(self) -> float:
        return self.alloc.utilization()
