"""Continuous-batching scheduler state: requests, slots, page accounting.

Host-side only — everything here runs between jitted steps. The scheduler
owns the slot free-list and the :class:`~repro.core.cache_layout.PageAllocator`
and decides *which* requests run each step; the engine owns the jitted
model calls and the clock.

Policies (deliberately simple, vLLM-style FCFS):

* **Admission**: a pending request is admitted when a slot is free AND the
  pool can cover the pages for its context plus the first decoded token
  (so an admitted request can always produce at least one token without
  stalling).
* **Decode paging**: when a slot's next token starts a new group, one page
  is allocated on demand. If the pool is empty the slot *stalls* — it is
  simply excluded from the step's active mask and retried next step. If
  *every* active slot stalls, the engine recompute-preempts the most
  recently admitted request (free its pages, requeue, prefill the full
  context on re-admission) so the rest make progress.
* **Reclamation**: EOS / length-limit completion frees the slot and all of
  its pages immediately.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.core.cache_layout import PageAllocator, PagedLayout


@dataclasses.dataclass
class Request:
    """One generation request (host-side bookkeeping)."""

    rid: int
    prompt: np.ndarray                  # (Tp,) int32
    max_new_tokens: int = 32
    arrival_time: float = 0.0           # engine-clock seconds

    # filled in by the engine
    out_tokens: list = dataclasses.field(default_factory=list)
    slot: int = -1
    preemptions: int = 0
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def context_len(self) -> int:
        """Tokens the cache must hold at (re-)admission: the prompt plus
        everything already generated (recompute-preemption resumes by
        prefilling the whole context)."""
        return self.prompt_len + len(self.out_tokens)

    @property
    def done_tokens(self) -> int:
        return len(self.out_tokens)

    def latency(self) -> float:
        return (self.t_done or 0.0) - self.arrival_time


class Scheduler:
    """Slot + page bookkeeping for one engine."""

    def __init__(self, layout: PagedLayout):
        self.layout = layout
        self.alloc = PageAllocator(layout)
        self.free_slots: deque[int] = deque(range(layout.slots))
        self.active: dict[int, Request] = {}       # slot -> request
        self.pending: deque[Request] = deque()

    # --- admission -------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def admissible(self) -> Optional[Request]:
        """Next pending request that fits right now (FCFS — head only, to
        keep arrival-order fairness)."""
        if not self.pending or not self.free_slots:
            return None
        req = self.pending[0]
        # pages for the context plus the first decode append: a new page is
        # only needed when the context ends exactly at a page boundary
        need = self.layout.pages_for(req.context_len + 1)
        if need > self.layout.pages_per_slot:
            raise ValueError(
                f"request {req.rid}: context {req.context_len} needs {need} "
                f"pages > pages_per_slot {self.layout.pages_per_slot}")
        if not self.alloc.can_alloc(need):
            return None
        return req

    def admit(self, req: Request) -> int:
        """Assign a slot + pages for context and first decode token.
        Caller runs the prefill."""
        assert self.pending and self.pending[0] is req
        self.pending.popleft()
        slot = self.free_slots.popleft()
        ok = self.alloc.alloc(slot, self.layout.pages_for(req.context_len + 1))
        assert ok, "admissible() guaranteed capacity"
        req.slot = slot
        self.active[slot] = req
        return slot

    # --- decode-step paging ----------------------------------------------

    def ensure_pages(self, lengths: np.ndarray) -> list[int]:
        """Allocate next-group pages for slots about to cross a page
        boundary; returns slots that must stall this step (pool empty).

        ``lengths``: (slots,) current per-slot token counts — the next
        append writes at ``lengths[slot]``.
        """
        g = self.layout.page_size
        stalled = []
        for slot in self.active:
            pos = int(lengths[slot])
            need_page = pos // g
            if pos % g == 0 and self.alloc.slot_pages(slot) <= need_page:
                if not self.alloc.alloc(slot, 1):
                    stalled.append(slot)
        return stalled

    # --- completion ------------------------------------------------------

    def finish(self, slot: int) -> Request:
        req = self.active.pop(slot)
        self.alloc.free_slot(slot)
        self.free_slots.append(slot)
        req.slot = -1
        return req

    def preempt(self, slot: int) -> Request:
        """Recompute-preemption: free the slot and its pages, requeue the
        request at the head of the pending queue. The engine drops the
        latest un-appended token first, so resuming == prefilling
        ``prompt + out_tokens`` and re-sampling from there. The cache
        rebuilds bit-identically (streaming-parity invariant), but the
        resumed token is sampled from fp *prefill* logits rather than
        quantized-cache *decode* logits, so a resumed greedy sequence may
        diverge from an uninterrupted run at exactly the resume point —
        the same numeric boundary every request crosses after its initial
        prefill."""
        req = self.finish(slot)
        req.preemptions += 1
        self.pending.appendleft(req)
        return req

    # --- introspection ---------------------------------------------------

    @property
    def num_active(self) -> int:
        return len(self.active)

    @property
    def has_work(self) -> bool:
        return bool(self.active or self.pending)

    def utilization(self) -> float:
        return self.alloc.utilization()
