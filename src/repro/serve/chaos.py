"""Deterministic fault injection for the serving stack (DESIGN.md §16).

Host-side only — no jax in this module — and **inert by construction**
when disabled: ``EngineCore(chaos=None)`` takes zero chaos branches, and
an injector whose schedule is empty observes the engine without
perturbing it (both asserted bit-identical to a plain engine in
``tests/test_chaos.py``).

The engine exposes exactly one injection seam: at the start of every
scheduling *cycle* it asks :meth:`ChaosInjector.actions` what to do, and
inside :meth:`~repro.serve.core.EngineCore._propose_drafts` it calls
:meth:`ChaosInjector.maybe_fail_proposer` within the same try/except
that guards a *real* proposer bug. The injector never touches engine
state itself — it returns declarative actions (``("exhaust", n)``,
``("slow", s)``, ``("cancel_storm", frac)``) that the core applies
through the same scheduler/allocator paths normal operation uses, so
every fault exercises production code, not test shims. core.py does not
import this module (the seam is duck-typed) — enforced by
``scripts/check_engine_layering.sh``.

Faults are scheduled by **cycle number** (one engine scheduling cycle ==
one pass of admit/prefill/decode), which is deterministic for a fixed
workload; randomized choices (storm victims, proposer failures) come
from the injector's own seeded generator, never the engine RNG — so a
chaos run is exactly reproducible from ``(workload, ChaosConfig)``.

Spec-string form (the ``--chaos`` launcher flag)::

    exhaust@8          quarantine every free page at cycle 8 (held for
                       ``exhaust_steps`` cycles — decode stalls, the
                       preemption path fires)
    slow@5:0.05        inject a 50 ms slow step at cycle 5
    cancel@12:0.5      cancel a random half of live requests at cycle 12
    proposer@0.3       each proposer call fails with probability 0.3

joined with commas: ``--chaos "exhaust@8,cancel@12:0.5,proposer@0.1"``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


class ChaosError(RuntimeError):
    """The injected failure type (proposer faults raise it)."""


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Deterministic fault schedule. Empty tuples / zero rates = no
    faults (an injector over this config is provably inert)."""

    seed: int = 0
    #: cycles at which every currently-free page is quarantined
    exhaust_at: Tuple[int, ...] = ()
    #: cycles a quarantine is held before the pages return
    exhaust_steps: int = 4
    #: cycles at which a synthetic slow step is injected
    slow_at: Tuple[int, ...] = ()
    slow_s: float = 0.05
    #: cycles at which a cancel storm fires
    cancel_at: Tuple[int, ...] = ()
    #: fraction of live (pending + active) requests each storm cancels
    cancel_frac: float = 0.5
    #: per-call probability that the speculative proposer raises
    proposer_fail_rate: float = 0.0

    def __post_init__(self):
        if self.exhaust_steps < 1:
            raise ValueError("exhaust_steps must be >= 1")
        if not (0.0 <= self.cancel_frac <= 1.0):
            raise ValueError("cancel_frac must be in [0, 1]")
        if not (0.0 <= self.proposer_fail_rate <= 1.0):
            raise ValueError("proposer_fail_rate must be in [0, 1]")
        if self.slow_s < 0:
            raise ValueError("slow_s must be >= 0")

    @staticmethod
    def parse(spec: str, seed: int = 0) -> "ChaosConfig":
        """Parse the ``--chaos`` flag syntax (see module docstring)."""
        exhaust, slow, cancel = [], [], []
        kw: dict = {"seed": seed}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            name, _, arg = part.partition("@")
            if name == "exhaust":
                cyc, _, hold = arg.partition(":")
                exhaust.append(int(cyc))
                if hold:
                    kw["exhaust_steps"] = int(hold)
            elif name == "slow":
                cyc, _, secs = arg.partition(":")
                slow.append(int(cyc))
                if secs:
                    kw["slow_s"] = float(secs)
            elif name == "cancel":
                cyc, _, frac = arg.partition(":")
                cancel.append(int(cyc))
                if frac:
                    kw["cancel_frac"] = float(frac)
            elif name == "proposer":
                kw["proposer_fail_rate"] = float(arg)
            else:
                raise ValueError(f"unknown chaos fault {name!r} in {spec!r}")
        return ChaosConfig(exhaust_at=tuple(exhaust), slow_at=tuple(slow),
                           cancel_at=tuple(cancel), **kw)


class ChaosInjector:
    """Stateful driver over a :class:`ChaosConfig`. One injector serves
    one engine session; :meth:`reset` rewinds it for a fresh session so
    two sessions over the same workload inject identical faults."""

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        self.reset()

    def reset(self) -> None:
        self._rng = np.random.default_rng(self.cfg.seed)
        self.exhausts = 0
        self.slow_steps = 0
        self.cancel_storms = 0
        self.storm_cancels = 0
        self.proposer_faults = 0
        self.proposer_calls = 0

    # --- the cycle seam ----------------------------------------------------

    def actions(self, cycle: int) -> List[tuple]:
        """Declarative faults for this cycle, applied by the core:
        ``("exhaust", hold_cycles)`` — quarantine every free page;
        ``("slow", seconds)`` — advance the clock without work;
        ``("cancel_storm", fraction)`` — cancel that fraction of live
        requests (victims picked via :meth:`pick_victims`)."""
        acts: List[tuple] = []
        if cycle in self.cfg.exhaust_at:
            self.exhausts += 1
            acts.append(("exhaust", self.cfg.exhaust_steps))
        if cycle in self.cfg.slow_at:
            self.slow_steps += 1
            acts.append(("slow", self.cfg.slow_s))
        if cycle in self.cfg.cancel_at:
            self.cancel_storms += 1
            acts.append(("cancel_storm", self.cfg.cancel_frac))
        return acts

    def pick_victims(self, rids: List[int], frac: float) -> List[int]:
        """Deterministic storm victims: at least one, chosen from the
        sorted live rids by the injector's own generator."""
        if not rids:
            return []
        rids = sorted(rids)
        k = max(1, int(round(frac * len(rids))))
        picked = self._rng.choice(len(rids), size=min(k, len(rids)),
                                  replace=False)
        self.storm_cancels += len(picked)
        return [rids[i] for i in sorted(picked)]

    # --- the proposer seam -------------------------------------------------

    def maybe_fail_proposer(self) -> None:
        """Called inside the engine's proposer try/except; raises
        :class:`ChaosError` with the configured probability."""
        if self.cfg.proposer_fail_rate <= 0:
            return
        self.proposer_calls += 1
        if self._rng.random() < self.cfg.proposer_fail_rate:
            self.proposer_faults += 1
            raise ChaosError("injected proposer failure")

    # --- introspection -----------------------------------------------------

    def stats(self) -> dict:
        return {
            "exhausts": self.exhausts,
            "slow_steps": self.slow_steps,
            "cancel_storms": self.cancel_storms,
            "storm_cancels": self.storm_cancels,
            "proposer_faults": self.proposer_faults,
            "proposer_calls": self.proposer_calls,
        }
