"""EngineCore: the step-shaped device-dispatch layer of the serve stack
(DESIGN.md §13).

Everything in ``repro.serve`` that touches the device lives in this file:

* :class:`ServeEngine` — the static-batching dense-cache engine (one
  shared prefill, lock-step decode). Re-exported from
  ``repro.serve.engine`` for back-compat.
* :class:`EngineCore` — the continuous-batching core. One public
  :meth:`EngineCore.step` performs exactly **one** scheduling decision +
  at most one device dispatch (admit/adopt, one prefill chunk, or one
  batched decode step) and returns structured :class:`TokenEvent`\\ s.
  Requests move through an explicit state machine::

      WAITING -> PREFILLING -> DECODING -> FINISHED
                      \\_________/     \\-> PREEMPTED (-> WAITING)
                any live state -> CANCELLED

  The batch adapter (``engine.ContinuousBatchingEngine.run``) and the
  streaming front door (``api.StreamingEngine``) are both thin host-side
  drivers over this class — the layering lint
  (``scripts/check_engine_layering.sh``) keeps it that way.

Bit-identical replay invariant: driving :meth:`step` to quiescence over a
fixed request list reproduces the pre-refactor monolithic ``run()`` loop
exactly — same greedy tokens, same page-adoption decisions, same
scheduler metrics (asserted against the frozen oracle in
``tests/cb_reference.py``). The step machine therefore mirrors the
monolith's *cycle* structure: arrivals are pumped and the chunk-prefill
budget reset once per cycle (admit phase), not once per step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache_layout import PagedLayout, PrefixIndex
from repro.distributed import ctx
from repro.distributed import serving as dsrv
from repro.distributed.sharding import serving_rules
from repro.models.registry import Model
from repro.serve.qos import (
    DegradeController, QosConfig, QosState, RateEstimator,
)
from repro.serve.scheduler import Request, Scheduler
from repro.spec import SpecConfig, make_proposer, make_verifier
from repro.utils import (
    cdiv, nearest_rank_pct, pow2_bucket, tree_bytes as _tree_bytes,
)


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 => greedy
    top_k: int = 0
    eos_id: int = -1              # -1 => never stop early
    seed: int = 0


def _sample(logits, key, gen: GenerationConfig):
    if gen.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / gen.temperature
    if gen.top_k > 0:
        vals, _ = jax.lax.top_k(logits, gen.top_k)
        logits = jnp.where(logits < vals[..., -1:], -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Request lifecycle states and the event taxonomy
# ---------------------------------------------------------------------------

WAITING = "waiting"          # submitted, not yet holding a slot
PREFILLING = "prefilling"    # slot assigned, context not fully encoded
DECODING = "decoding"        # prefill done, producing tokens
FINISHED = "finished"        # EOS / length limit; slot + pages released
PREEMPTED = "preempted"      # pages reclaimed under pressure; requeued
CANCELLED = "cancelled"      # caller cancelled; slot + pages released
REJECTED = "rejected"        # QoS bounded-queue backpressure at intake
SHED = "shed"                # QoS deadline shed before admission

#: Every kind a :class:`TokenEvent` can carry, in lifecycle order.
#: ``reject`` and ``shed`` are terminal: a rejected/shed rid never emits
#: another event (the event-stream invariant bench arms assert).
EVENT_KINDS = ("admit", "first_token", "token", "finish", "preempt",
               "cancel", "reject", "shed")


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One observable engine transition, stamped with the device-time
    clock (queueing + measured compute seconds, same clock the latency
    percentiles are computed on).

    ``token`` is the sampled token id for ``first_token``/``token``
    events; for a ``preempt`` event it is the **retracted** token — the
    victim's most recent token is withdrawn (it was never fed back to
    the model) and re-sampled on resume, so a consumer accumulating
    streamed tokens must drop its last token for that rid when a
    ``preempt`` arrives. None otherwise. ``slot`` is the cache slot
    involved (-1 when the request never held one, e.g. a queued
    cancel).

    ``ordinal`` is the token's 0-based index in the request's output
    stream (monotone per rid; a preempt retraction rewinds it by one,
    matching the drop-last-token rule above). Speculative decode
    (DESIGN.md §15) can retire several tokens from one dispatch: each
    gets its own event sharing one clock stamp, with ``span`` the total
    tokens that dispatch retired for the rid and ``span_ix`` the event's
    position inside the span — plain decode is the degenerate
    ``span=1, span_ix=0``."""

    kind: str
    rid: int
    t: float
    token: Optional[int] = None
    slot: int = -1
    ordinal: int = -1
    span: int = 1
    span_ix: int = 0
    #: why a ``reject``/``shed`` event happened (``"queue_full"``,
    #: ``"deadline_blown"``, ``"deadline_unmeetable"``); None otherwise —
    #: clients see the reason instead of a silent hang.
    reason: Optional[str] = None


class ServeEngine:
    """Static batching: one shared prefill, lock-step decode, the whole
    batch stalls until its slowest request finishes. Kept as the baseline
    (and for single-batch offline use)."""

    def __init__(self, model: Model, params, max_len: int,
                 mesh=None, rules: Optional[dict] = None):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.mesh = mesh
        self.rules = rules
        self._prefill = jax.jit(model.prefill)
        # donate the decode state: cache buffers update in place instead of
        # being copied every step (the state is rebound to the result)
        self._decode = jax.jit(model.decode, donate_argnums=(1,))
        self._sample = jax.jit(_sample, static_argnames=("gen",))

    def _ctx(self):
        if self.mesh is not None and self.rules is not None:
            return ctx.use_sharding(self.mesh, self.rules)
        import contextlib
        return contextlib.nullcontext()

    def generate(self, batch: dict,
                 gen: Optional[GenerationConfig] = None):
        """batch: prompt inputs (tokens (B, Tp) [+ frames/patches]).

        Returns dict with generated tokens (B, max_new_tokens) and timings.
        """
        gen = gen if gen is not None else GenerationConfig()
        b = batch["tokens"].shape[0]
        cfg = self.model.cfg
        if cfg.family in ("dense", "moe", "vlm") and cfg.window == 0:
            # linear cache: prompt + appended tokens must fit (the last
            # sampled token is never appended, hence the -1)
            tp = batch["tokens"].shape[1] + (
                cfg.frontend_tokens if cfg.family == "vlm" else 0)
            if tp + gen.max_new_tokens - 1 > self.max_len:
                raise ValueError(
                    f"prompt {tp} + max_new_tokens {gen.max_new_tokens} "
                    f"exceeds cache capacity {self.max_len}")
        key = jax.random.PRNGKey(gen.seed)
        with self._ctx():
            state = self.model.init_decode_state(b, self.max_len)
            t0 = time.monotonic()
            logits, state = self._prefill(self.params, batch, state)
            logits.block_until_ready()  # sync: static-engine prefill timing
            t_prefill = time.monotonic() - t0

            toks = []
            tok = self._sample(logits, key, gen)
            toks.append(tok)
            t0 = time.monotonic()
            done = jnp.zeros((b,), bool)
            for i in range(gen.max_new_tokens - 1):
                logits, state = self._decode(self.params, state, tok)
                key, sub = jax.random.split(key)
                tok = self._sample(logits, sub, gen)
                if gen.eos_id >= 0:
                    done = done | (tok == gen.eos_id)
                    tok = jnp.where(done, gen.eos_id, tok)
                toks.append(tok)
            jax.block_until_ready(tok)  # sync: static-engine decode timing
            t_decode = time.monotonic() - t0
        out = jnp.stack(toks, axis=1)
        n_dec = max(gen.max_new_tokens - 1, 1)
        return {
            "tokens": np.asarray(out),
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "tokens_per_s": b * n_dec / max(t_decode, 1e-9),
            "cache_bytes": _tree_bytes(state),
            "cache_bytes_per_layer": (
                self.model.cache_layer_bytes(state)
                if self.model.cache_layer_bytes else None),
        }


# ---------------------------------------------------------------------------
# EngineCore: the continuous-batching step loop
# ---------------------------------------------------------------------------


class EngineCore:
    """Step-shaped continuous-batching core over per-layer paged KV caches.

    Construction compiles the device functions and fixes the pool layout
    (``max_slots`` concurrent requests over ``num_pages`` pages of
    ``group_size`` tokens; ``prefill_chunk``/``prefix_cache``/
    ``table_slicing`` as on the old monolithic engine — see
    ``engine.ContinuousBatchingEngine`` for the knob docs). Compiled
    functions persist across sessions; :meth:`reset` starts a fresh
    session (new device state, scheduler, prefix index, clock, RNG).

    Drive it with :meth:`add_request` / :meth:`cancel` / :meth:`step`:
    each ``step()`` makes one scheduling decision, performs at most one
    device dispatch, advances the device-time clock, and returns the
    :class:`TokenEvent`\\ s it caused. ``step()`` with no work is a no-op
    returning ``[]`` — an open-loop driver can keep calling it as
    requests arrive. The clock is *simulated*: while the engine is idle,
    it jumps to the next scheduled arrival instead of sleeping, so batch
    replays compose queueing + compute without wall-clock waits.
    """

    def __init__(self, model: Model, params, *, max_slots: int = 4,
                 max_len: int = 256, num_pages: Optional[int] = None,
                 mesh=None, rules: Optional[dict] = None,
                 table_slicing: bool = True, prefix_cache: bool = False,
                 prefill_chunk: int = 0, prefill_budget: int = 0,
                 spec: Optional[SpecConfig] = None,
                 qos: Optional[QosConfig] = None, chaos=None,
                 runahead: int = 0):
        if model.decode_paged is None:
            raise ValueError(
                f"family {model.cfg.family!r} has no paged decode path")
        self.model = model
        self.params = params
        self.mesh = mesh
        # a mesh without explicit rules gets the serving rule set: heads
        # (and the KV page pools, via distributed/serving.py) over the
        # "model" axis where divisible, batch over the data axes —
        # DESIGN.md §17. Rules without a mesh stay inert (matching _ctx).
        if mesh is not None and rules is None:
            rules = serving_rules(model.cfg, mesh, max_slots)
        self.rules = rules
        if mesh is not None:
            # params are replicated: head-sharded TP partitions cache
            # *pools*; weight TP is a separate (training-side) concern
            self.params = jax.device_put(
                params, jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()))
        # table_slicing=False ships the full (S, pages_per_slot) table every
        # step — the pre-width-bucketing behavior, kept as a benchmark
        # baseline (decode cost then scales with pool capacity)
        self.table_slicing = table_slicing
        # chunk prefill width-slices the slot's table row only on the
        # page-native backends: the kernel's read volume then tracks the
        # live prefix instead of the pool capacity. The jnp reference
        # keeps the full row (it gathers the whole pool regardless — the
        # benchmark contrast), and decode slicing stays independent, so
        # A/B arms share bit-identical decode steps.
        self._prefill_slicing = (table_slicing
                                 and model.cfg.prefill_backend != "jnp")
        # page == quantization group: every layer of the policy must agree
        # on the group size (bit-widths/methods may differ per layer)
        g = model.cfg.policy.page_group_size()
        pages_per_slot = cdiv(max_len, g)
        if num_pages is None:
            num_pages = max_slots * pages_per_slot
        self.layout = PagedLayout(page_size=g, num_pages=num_pages,
                                  slots=max_slots,
                                  pages_per_slot=pages_per_slot)
        self.prefix_cache = bool(prefix_cache)
        chunk = int(prefill_chunk)
        if chunk < 0:
            raise ValueError(f"prefill_chunk must be >= 0, got {chunk}")
        if self.prefix_cache and chunk == 0:
            chunk = 2 * g   # sharing requires the chunk-aligned path
        if chunk:
            chunk = cdiv(chunk, g) * g   # page-aligned chunks
            if model.prefill_paged_chunk is None:
                raise ValueError(
                    f"family {model.cfg.family!r} has no chunked prefill "
                    "path (prefill_paged_chunk)")
        self.prefill_chunk = chunk
        self.prefill_budget = int(prefill_budget) if prefill_budget else chunk
        self._prefill = jax.jit(model.prefill_paged)
        if chunk:
            self._prefill_chunk = jax.jit(model.prefill_paged_chunk,
                                          donate_argnums=(2,))
        if model.copy_pages is not None:
            self._copy_pages = jax.jit(model.copy_pages, donate_argnums=(0,))
        # donate the paged state: page pools update in place each step
        self._decode = jax.jit(model.decode_paged, donate_argnums=(1,))
        self._sample = jax.jit(_sample, static_argnames=("gen",))
        # run-ahead decode (DESIGN.md §18): when the horizon planner
        # predicts no scheduling event for the next `runahead` steps, one
        # lax.scan dispatch covers all of them — on-device sampling +
        # EOS/budget masking, a single host sync per (H, slots) token
        # block, and the next horizon chained off device-resident carries
        # while the previous block is still in flight. 0/1 disables it.
        self.runahead = int(runahead)
        if self.runahead < 0:
            raise ValueError(f"runahead must be >= 0, got {runahead}")
        if self.runahead > 1:
            if model.decode_runahead is None:
                raise ValueError(
                    f"family {model.cfg.family!r} has no run-ahead decode "
                    "path (decode_runahead)")
            self._runahead_fn = jax.jit(
                model.decode_runahead, donate_argnums=(1,),
                static_argnames=("horizon", "temperature", "top_k",
                                 "eos_id"))
        # speculative decode (DESIGN.md §15): a host-side proposer guesses
        # up to spec.k tokens per slot; one verify dispatch scores the
        # whole span and commits only accepted tokens through the vanilla
        # append path. Greedy-only — reset() enforces temperature 0.
        self.spec = spec if spec is not None and spec.mode != "off" else None
        if self.spec is not None:
            self._verify = jax.jit(make_verifier(model), donate_argnums=(1,))
            self._proposer = make_proposer(
                self.spec, target_cfg=model.cfg, target_model=model,
                target_params=params, max_len=self.layout.tokens_per_slot)
        # QoS (DESIGN.md §16): None means the engine is byte-for-byte the
        # pre-QoS FCFS engine — every QoS branch is gated on it.
        self.qos_cfg = qos
        # chaos is a pre-built injector (duck-typed: core never imports
        # serve.chaos — enforced by scripts/check_engine_layering.sh);
        # None takes zero chaos branches.
        self.chaos = chaos
        self.reset()

    # --- session lifecycle ------------------------------------------------

    def reset(self, gen: Optional[GenerationConfig] = None) -> None:
        """Start a fresh serving session: new device state, scheduler,
        prefix index, clock, RNG, and metrics. ``gen`` fixes the session's
        sampling configuration (per-request budgets still come from
        ``Request.max_new_tokens``)."""
        self.gen = gen if gen is not None else GenerationConfig()
        if self.spec is not None:
            if self.gen.temperature > 0.0:
                raise ValueError(
                    "speculative decoding requires greedy sampling "
                    "(temperature 0): acceptance compares the target "
                    "model's argmax per position")
            self._proposer.reset()
        self.prefix = (PrefixIndex(self.layout, self.prefill_chunk)
                       if self.prefix_cache else None)
        self.qos = QosState(self.qos_cfg) if self.qos_cfg is not None \
            else None
        self.degrade = (DegradeController(self.qos_cfg)
                        if self.qos_cfg is not None and self.qos_cfg.degrade
                        else None)
        self._prefill_rate = RateEstimator() if self.qos is not None \
            else None
        self.sched = Scheduler(self.layout, prefix_index=self.prefix,
                               chunk_tokens=self.prefill_chunk,
                               qos=self.qos)
        self.state = self._place_state(
            self.model.init_paged_state(self.layout))
        s = self.layout.slots
        self.clock = 0.0
        self._key = jax.random.PRNGKey(self.gen.seed)
        self._next_tok = np.zeros((s,), np.int32)
        self._lengths = np.zeros((s,), np.int64)
        self._eff_max: dict[int, int] = {}
        self._admit_seq: dict[int, int] = {}   # slot -> admission order
        self._prefilling: dict[int, dict] = {}  # slot -> {"ctx", "off"}
        self._n_admitted = 0
        self._arrivals: list[Request] = []     # sorted by arrival_time
        self.completed: list[Request] = []
        self.cancelled: list[Request] = []
        self.shed: list[Request] = []          # QoS deadline sheds
        self.rejected: list[Request] = []      # QoS queue-full rejects
        self._intake_events: list[TokenEvent] = []
        self._cycle = 0                        # chaos schedule domain
        self._quarantine_release = -1
        self._preempted_cycle = False          # degrade pressure signal
        self.proposer_faults = 0
        if self.chaos is not None:
            self.chaos.reset()
        # cycle state: the step machine mirrors one monolith loop
        # iteration as the phase sequence begin -> admit* -> prefill* ->
        # decode, pumping arrivals and resetting the chunk budget once
        # per cycle (bit-identical-replay invariant)
        self._phase = "begin"
        self._progressed = False
        self._budget_left = 0
        # metrics
        self._util: list[float] = []
        self._active_hist: list[int] = []
        self._step_times: list[float] = []
        self.decode_steps = 0
        self.prefill_computed = 0   # prefill tokens run through the model
        self.prefill_skipped = 0    # prefill tokens served from adoption
        self.cow_splits = 0
        self.spec_steps = 0         # decode steps that verified >=1 draft
        self.spec_drafted = 0       # draft tokens sent to verification
        self.spec_accepted = 0      # draft tokens accepted
        # run-ahead pipeline state (DESIGN.md §18): the in-flight horizon
        # record (which carries its own optimistic per-slot budgets) and
        # the host-vs-device attribution metrics
        self._inflight: Optional[dict] = None
        self._land_t = 0.0          # wall time the last horizon landed
        self.runahead_horizons = 0
        self.runahead_tokens = 0
        self._gap_ewma = None       # host overlap per horizon (EWMA, s)
        self._sync_wait_s = 0.0     # host time blocked on landing blocks
        self._overlap_s = 0.0       # host time overlapped w/ device work

    # --- request intake ---------------------------------------------------

    def add_request(self, req: Request) -> int:
        """Enqueue ``req`` for admission at ``req.arrival_time`` on the
        engine clock (insertion-ordered among equal times, so a
        pre-sorted batch replays FCFS exactly). Returns the rid.

        Rejects (ValueError) a context that can never fit one slot —
        at intake, so an open-loop session is never poisoned by an
        oversized request reaching the queue head mid-stream.

        With QoS bounded-queue backpressure (``QosConfig.max_pending``),
        intake over a full queue marks the request ``REJECTED`` and
        queues an explicit ``reject`` TokenEvent (``reason=
        "queue_full"``) — never a silent hang; the rid is still
        returned so the caller can match the event."""
        need = self.layout.pages_for(req.context_len + 1)
        if need > self.layout.pages_per_slot:
            raise ValueError(
                f"request {req.rid}: context {req.context_len} needs "
                f"{need} pages > pages_per_slot "
                f"{self.layout.pages_per_slot}")
        if self.qos is not None and self.qos_cfg.max_pending > 0 and \
                len(self._arrivals) + len(self.sched.pending) >= \
                self.qos_cfg.max_pending:
            req.state = REJECTED
            req.t_done = self.clock
            self.rejected.append(req)
            self.qos.on_reject(req)
            self._intake_events.append(TokenEvent(
                "reject", req.rid, self.clock, reason="queue_full"))
            return req.rid
        req.state = WAITING
        i = len(self._arrivals)
        while i > 0 and self._arrivals[i - 1].arrival_time > \
                req.arrival_time:
            i -= 1
        self._arrivals.insert(i, req)
        return req.rid

    def take_intake_events(self) -> list[TokenEvent]:
        """Drain events produced at intake (QoS rejects). :meth:`step`
        prepends these automatically; streaming drivers that want the
        reject surfaced before the next step may drain them directly."""
        evs, self._intake_events = self._intake_events, []
        return evs

    def cancel(self, rid: int) -> list[TokenEvent]:
        """Cancel a request wherever it is in the lifecycle.

        * scheduled / pending: dropped from the queue, no pages involved.
        * mid-prefill or mid-decode: the slot is released through the
          scheduler, which *decrefs* the slot's pages — pages shared with
          other slots or pinned by the prefix index survive with their
          encoded bytes intact; exclusive pages return to the free list.
          The slot is immediately reusable by the next admission.

        Returns the ``cancel`` event ([] when ``rid`` is unknown or
        already finished — a documented no-op, never an error).
        Host-side only — no new device dispatch; an in-flight run-ahead
        horizon is landed first (its token events precede the cancel in
        the returned list), so a cancelled rid never emits tokens after
        its terminal event and its pages are only released once the
        device is done writing them."""
        pre = self._reconcile_horizon() if self._inflight is not None \
            else []
        for i, r in enumerate(self._arrivals):
            if r.rid == rid:
                del self._arrivals[i]
                return pre + self._cancelled(r)
        summary = self.sched.cancel(rid)
        if summary is None:
            return pre
        req, slot = summary.req, summary.slot
        if slot >= 0:
            self._prefilling.pop(slot, None)
            self._eff_max.pop(rid, None)
        if self.spec is not None:
            self._proposer.release(rid)
        return pre + self._cancelled(req, slot)

    def _cancelled(self, req: Request, slot: int = -1) -> list[TokenEvent]:
        req.state = CANCELLED
        req.t_done = self.clock
        self.cancelled.append(req)
        return [TokenEvent("cancel", req.rid, self.clock, slot=slot)]

    @property
    def has_work(self) -> bool:
        return bool(self._arrivals) or self.sched.has_work or \
            self._inflight is not None

    # --- compile helpers --------------------------------------------------

    def _decode_widths(self) -> list[int]:
        """Page-table width buckets the decode step compiles against:
        powers of two capped at ``pages_per_slot``."""
        n = self.layout.pages_per_slot
        if not self.table_slicing:
            return [n]
        widths, w = [], 1
        while w < n:
            widths.append(w)
            w *= 2
        widths.append(n)
        return widths

    def _spec_q_buckets(self) -> list[int]:
        """Span-width buckets (Q = 1 bonus + drafts) the verify dispatch
        compiles against: 1 + pow2 draft counts, capped at ``spec.k + 1``
        — and at the group size, since the span clamp
        (:meth:`_propose_drafts`) keeps every span inside its slot's
        current quantization group."""
        cap = min(self.spec.k + 1, self.layout.page_size)
        out, q = [], 2
        while q < cap:
            out.append(q)
            q = 2 * (q - 1) + 1
        out.append(cap)
        return out

    def _spec_q(self, q_needed: int) -> int:
        """Smallest span-width bucket covering ``q_needed`` positions."""
        for q in self._spec_q_buckets():
            if q >= q_needed:
                return q
        return self.spec.k + 1

    def _step_width(self, pages_needed: int) -> int:
        """Smallest width bucket covering ``pages_needed`` live pages.

        The decode step reads the page table only up to this width, so its
        per-step cost scales with the *live* context of the current batch
        — O(max live tokens) — instead of the pool capacity."""
        if not self.table_slicing:
            return self.layout.pages_per_slot
        for w in self._decode_widths():
            if w >= pages_needed:
                return w
        return self.layout.pages_per_slot

    def _prefill_widths(self, prompt_lens: list[int]) -> list[int]:
        """Table-row width buckets the chunk prefill compiles against:
        the pow2 decode buckets up to the largest prompt's page count when
        the page-native backends slice the row, the full width otherwise."""
        if not self._prefill_slicing:
            return [self.layout.pages_per_slot]
        maxw = self._step_width(
            self.layout.pages_for(max(prompt_lens))
            if prompt_lens else self.layout.pages_per_slot)
        return [w for w in self._decode_widths() if w <= maxw]

    def _ctx(self):
        if self.mesh is not None and self.rules is not None:
            return ctx.use_sharding(self.mesh, self.rules)
        import contextlib
        return contextlib.nullcontext()

    def _place_state(self, state):
        """Place fresh paged state on the mesh: page pools partitioned
        over KV heads when the rule set maps ``kv_heads`` to a mesh axis,
        fully replicated otherwise (the GQA-indivisible fallback). Meshless
        engines pass through untouched. reset() and warmup() both route
        here so the donated decode signature sees one consistent
        placement."""
        if self.mesh is None:
            return state
        axis = (self.rules or {}).get("kv_heads")
        if isinstance(axis, str):
            return dsrv.shard_paged_state(state, self.mesh, axis)
        repl = jax.sharding.NamedSharding(self.mesh,
                                          jax.sharding.PartitionSpec())
        return jax.device_put(state, jax.tree_util.tree_map(
            lambda _: repl, state))

    def _bucket(self, prompt_len: int) -> int:
        return min(pow2_bucket(prompt_len, self.layout.page_size),
                   self.layout.tokens_per_slot)

    def warmup(self, prompt_lens: list[int],
               gen: Optional[GenerationConfig] = None) -> None:
        """Compile prefill buckets (or the single chunk shape) + the decode
        step against throwaway state."""
        gen = gen if gen is not None else GenerationConfig()
        state = self._place_state(self.model.init_paged_state(self.layout))
        sched = Scheduler(self.layout)
        key = jax.random.PRNGKey(0)
        s = self.layout.slots
        with self._ctx():
            if self.prefill_chunk:
                # one compile per table-row width covers every chunk of
                # every prompt (a single full-width compile unless the
                # page-native prefill backends slice the row)
                c = self.prefill_chunk
                for w in self._prefill_widths(prompt_lens):
                    logits, state = self._prefill_chunk(
                        self.params, jnp.zeros((1, c), jnp.int32), state,
                        jnp.zeros((), jnp.int32),
                        sched.alloc.table()[0][:w],
                        jnp.zeros((), jnp.int32), jnp.asarray(c, jnp.int32))
                    jax.block_until_ready(self._sample(logits, key, gen))  # sync: warmup compile barrier
            else:
                for tp in sorted({self._bucket(t) for t in prompt_lens}):
                    logits, state = self._prefill(
                        self.params, jnp.zeros((1, tp), jnp.int32), state,
                        jnp.zeros((), jnp.int32), sched.alloc.table()[0],
                        jnp.asarray(tp, jnp.int32))
                    jax.block_until_ready(self._sample(logits, key, gen))  # sync: warmup compile barrier
            for w in self._decode_widths():
                logits, state = self._decode(
                    self.params, state, jnp.zeros((s,), jnp.int32),
                    sched.alloc.table()[:, :w], jnp.zeros((s,), bool))
                jax.block_until_ready(self._sample(logits, key, gen))  # sync: warmup compile barrier
                if self.runahead > 1:
                    rtoks, state, _t, _k, _d, _r = self._runahead_fn(
                        self.params, state, jnp.zeros((s,), jnp.int32),
                        sched.alloc.table()[:, :w],
                        jnp.zeros((s,), bool), key,
                        jnp.zeros((s,), jnp.int32), jnp.zeros((s,), bool),
                        horizon=self.runahead,
                        temperature=gen.temperature, top_k=gen.top_k,
                        eos_id=gen.eos_id)
                    jax.block_until_ready(rtoks)  # sync: warmup compile barrier
                if self.spec is not None:
                    for q in self._spec_q_buckets():
                        preds, _, state = self._verify(
                            self.params, state,
                            jnp.zeros((s, q), jnp.int32),
                            jnp.zeros((s,), jnp.int32),
                            sched.alloc.table()[:, :w],
                            jnp.zeros((s,), bool))
                        jax.block_until_ready(preds)  # sync: warmup compile barrier

    # --- the step loop ----------------------------------------------------

    def step(self) -> list[TokenEvent]:
        """One scheduling decision + at most one device dispatch.

        Exactly one of, in cycle priority order:

        1. admit the next admissible request (adopting prefix pages;
           classic mode also runs its one-shot prefill here),
        2. run one prefill chunk (chunked mode, under the cycle budget),
        3. run one batched decode step over all decode-ready slots — or
           recompute-preempt the youngest admission when every slot
           stalls on a dry pool.

        Idle with scheduled arrivals jumps the clock; idle with no work
        at all returns ``[]`` immediately (streaming drivers poll)."""
        with self._ctx():
            intake = self.take_intake_events()
            return intake + self._step() if intake else self._step()

    def _step(self) -> list[TokenEvent]:
        if self._inflight is not None:
            # a run-ahead horizon is in flight: chain the next horizon
            # off its device-resident carries while it computes, then
            # land it and reconcile its events (DESIGN.md §18). The
            # phase machine only runs once the pipeline drains.
            return self._advance_runahead()
        if self._phase == "begin":
            self._pump_arrivals()
            if not self.sched.has_work:
                if not self._arrivals:
                    return []   # fully idle: wait for add_request()
                # idle engine: jump the clock to the next arrival
                self.clock = max(self.clock, self._arrivals[0].arrival_time)
                self._pump_arrivals()
            self._cycle += 1
            self._progressed = False
            self._budget_left = self.prefill_budget
            if self.qos is not None:
                self.qos.refill(self.clock)
            if self.degrade is not None:
                self.degrade.update(self.sched.utilization(),
                                    self._preempted_cycle)
                self._preempted_cycle = False
                self._budget_left = self.degrade.prefill_budget(
                    self.prefill_budget)
                if self.degrade.evict_ahead:
                    # proactively drop index-only prefix pages so live
                    # decode keeps ~1 page of headroom per active slot,
                    # ahead of the preemption path
                    want = (self.sched.num_active
                            - self.sched.alloc.free_pages)
                    if want > 0:
                        self.sched.reclaim(want)
            self._phase = "admit"
            if self.chaos is not None:
                events = self._apply_chaos()
                if events:
                    return events   # faults end the begin phase

        if self._phase == "admit":
            if self.qos is not None:
                shed_evs = self._shed_unmeetable()
                if shed_evs:
                    return shed_evs
            req = self.sched.admissible()
            if req is not None:
                return self._admit(req)
            if not self.sched.active:
                # nothing running and the queue head can't fit: future
                # arrivals can't free pages, so either wait them out
                # (clock jump) or fail loudly
                self._phase = "begin"
                if self.sched.pending and self._arrivals:
                    self.clock = max(self.clock,
                                     self._arrivals[0].arrival_time)
                    return []
                if self.sched.pending:
                    if self._quarantine_release >= 0:
                        # a chaos quarantine (not pool size) is holding
                        # the pages; it lifts at a known cycle — spin
                        return []
                    if self.qos is not None:
                        # an idle engine's clock freezes, so a tenant
                        # bucket blocking the whole queue would never
                        # refill — jump to the earliest affordable time
                        # (deadlines blown by the wait shed next cycle)
                        t = self.qos.next_affordable_time(
                            self.sched.pending, self.clock)
                        if t is not None:
                            self.clock = max(self.clock, t)
                            return []
                    raise RuntimeError(
                        "pool cannot fit a single pending request "
                        "(num_pages too small)")
                return []
            self._phase = "prefill"

        if self._phase == "prefill":
            if self._budget_left > 0 and self._prefilling:
                return self._prefill_one_chunk()
            self._phase = "decode"

        self._phase = "begin"   # decode (or preempt) ends the cycle
        return self._decode_step()

    def _pump_arrivals(self) -> None:
        while self._arrivals and \
                self._arrivals[0].arrival_time <= self.clock:
            self.sched.submit(self._arrivals.pop(0))

    # --- QoS + chaos seams (DESIGN.md §16) --------------------------------

    def _apply_chaos(self) -> list[TokenEvent]:
        """The single chaos seam: once per cycle, apply the injector's
        declarative faults through production paths (allocator
        quarantine, engine clock, the real cancel path). Inert when the
        injector's schedule yields nothing this cycle."""
        if 0 <= self._quarantine_release <= self._cycle:
            self.sched.alloc.release_quarantine()
            self._quarantine_release = -1
        events: list[TokenEvent] = []
        for act in self.chaos.actions(self._cycle):
            if act[0] == "exhaust":
                self.sched.alloc.quarantine(self.sched.alloc.free_pages)
                self._quarantine_release = self._cycle + int(act[1])
            elif act[0] == "slow":
                self.clock += float(act[1])
            elif act[0] == "cancel_storm":
                live = [r.rid for r in self.sched.pending] + \
                    [r.rid for r in self.sched.active.values()]
                for rid in self.chaos.pick_victims(live, float(act[1])):
                    events += self.cancel(rid)
        return events

    def _shed_unmeetable(self) -> list[TokenEvent]:
        """Deadline-aware admission control: drop pending requests whose
        TTFT deadline is already blown or provably unmeetable given the
        queue ahead of them and the measured prefill rate, emitting
        explicit ``shed`` events (QosState.unmeetable documents the
        projection)."""
        inflight = sum(len(cur["ctx"]) - cur["off"]
                       for cur in self._prefilling.values())
        rate = self._prefill_rate.rate if self._prefill_rate else None
        doomed = self.qos.unmeetable(self.sched.pending, self.clock,
                                     rate, inflight)
        events: list[TokenEvent] = []
        for req, reason in doomed:
            self.sched.cancel(req.rid)
            req.state = SHED
            req.t_done = self.clock
            self.shed.append(req)
            self.qos.on_shed(req)
            events.append(TokenEvent("shed", req.rid, self.clock,
                                     reason=reason))
        return events

    def _admit(self, req: Request) -> list[TokenEvent]:
        """Admission: assign a slot, adopt prefix hits, reserve pages.
        Chunked mode queues the prompt for interleaved chunk prefill;
        classic mode prefills the whole context in one shot (a preempted
        request resumes by prefilling its full context either way)."""
        slot = self.sched.admit(req)
        req.state = PREFILLING
        self._admit_seq[slot] = self._n_admitted
        self._n_admitted += 1
        ctx_toks = req.context_tokens()
        tl = len(ctx_toks)
        self._eff_max[req.rid] = req.done_tokens + min(
            req.max_new_tokens - req.done_tokens,
            self.layout.tokens_per_slot - tl + 1)
        events = [TokenEvent("admit", req.rid, self.clock, slot=slot)]
        if self.prefill_chunk:
            # adopted prefix pages skip their prefill compute; chunks
            # cover [prefix_hit_tokens, tl)
            self._prefilling[slot] = {"ctx": ctx_toks,
                                      "off": req.prefix_hit_tokens}
            self._lengths[slot] = req.prefix_hit_tokens
            self.prefill_skipped += req.prefix_hit_tokens
            return events
        toks = np.zeros((1, self._bucket(tl)), np.int32)
        toks[0, :tl] = ctx_toks
        t0 = time.monotonic()
        logits, self.state = self._prefill(
            self.params, jnp.asarray(toks), self.state,
            jnp.asarray(slot, jnp.int32),
            self.sched.alloc.table()[slot],
            jnp.asarray(tl, jnp.int32))
        self._key, sub = jax.random.split(self._key)
        tok = self._sample(logits, sub, self.gen)
        # one numpy fetch for the whole dispatch (np.asarray blocks until
        # the device is done), not a ready-barrier plus a scalar D2H
        tok0 = int(np.asarray(tok)[0])  # sync: classic prefill first token
        dt = time.monotonic() - t0
        self.clock += dt
        if self._prefill_rate is not None:
            self._prefill_rate.observe(tl, dt)
        self.prefill_computed += tl
        return events + self._take_first_token(slot, tok0, tl)

    def _prefill_one_chunk(self) -> list[TokenEvent]:
        """One prefill chunk for the oldest mid-prefill admission (FCFS);
        the slot joins the decode batch the step after its final chunk."""
        slot = min(self._prefilling, key=self._admit_seq.__getitem__)
        cur = self._prefilling[slot]
        ctx_toks, off = cur["ctx"], cur["off"]
        tl = len(ctx_toks)
        c = self.prefill_chunk
        clen = min(c, tl - off)
        toks = np.zeros((1, c), np.int32)
        toks[0, :clen] = ctx_toks[off:off + clen]
        row = self.sched.alloc.table()[slot]
        if self._prefill_slicing:
            # width-slice the row to the pages this chunk touches: the
            # page-native kernel then reads O(live prefix), not
            # O(capacity) (one compile per pow2 bucket, as in decode)
            row = row[:self._step_width(
                cdiv(off + clen, self.layout.page_size))]
        t0 = time.monotonic()
        logits, self.state = self._prefill_chunk(
            self.params, jnp.asarray(toks), self.state,
            jnp.asarray(slot, jnp.int32), row,
            jnp.asarray(off, jnp.int32),
            jnp.asarray(clen, jnp.int32))
        self._progressed = True
        self._budget_left -= clen
        self.prefill_computed += clen
        cur["off"] = off + clen
        self._lengths[slot] = off + clen
        if cur["off"] >= tl:
            # final chunk: its last-token logits seed decode
            self._key, sub = jax.random.split(self._key)
            tok = self._sample(logits, sub, self.gen)
            # single numpy fetch per dispatch (see _admit)
            tok0 = int(np.asarray(tok)[0])  # sync: final-chunk first token
            dt = time.monotonic() - t0
            self.clock += dt
            if self._prefill_rate is not None:
                self._prefill_rate.observe(clen, dt)
            del self._prefilling[slot]
            self.sched.register_prefix(slot)
            return self._take_first_token(slot, tok0, tl)
        jax.block_until_ready(logits)  # sync: chunk completion barrier (honest clock)
        dt = time.monotonic() - t0
        self.clock += dt
        if self._prefill_rate is not None:
            self._prefill_rate.observe(clen, dt)
        return []

    def _take_first_token(self, slot: int, tok0: int,
                          tl: int) -> list[TokenEvent]:
        """Record a request's first sampled token after its prefill."""
        req = self.sched.active[slot]
        req.state = DECODING
        first = req.t_first_token is None
        if req.t_admitted is None:
            req.t_admitted = req.t_first_token = self.clock
        req.out_tokens.append(tok0)
        self._next_tok[slot] = tok0
        self._lengths[slot] = tl
        if self.qos is not None:
            self.qos.on_tokens(req.tenant, 1)
        # a preemption-resume re-prefill is not the stream's first token
        events = [TokenEvent("first_token" if first else "token",
                             req.rid, self.clock, token=tok0, slot=slot,
                             ordinal=req.done_tokens - 1)]
        if (self.gen.eos_id >= 0 and tok0 == self.gen.eos_id) or \
                req.done_tokens >= self._eff_max[req.rid]:
            events += self._finish(slot)
        return events

    def _finish(self, slot: int) -> list[TokenEvent]:
        req = self.sched.active[slot]
        req.state = FINISHED
        req.t_done = self.clock
        self._eff_max.pop(req.rid, None)
        if self.spec is not None:
            self._proposer.release(req.rid)
        self.completed.append(self.sched.finish(slot))
        return [TokenEvent("finish", req.rid, self.clock, slot=slot)]

    def _decode_step(self) -> list[TokenEvent]:
        """Batched decode over non-stalled, fully-prefilled slots; falls
        back to recompute-preemption when nothing can run and no chunk
        progressed this cycle."""
        sched, g = self.sched, self.layout.page_size
        if not sched.active:
            return []   # cancellation emptied the cycle mid-flight
        drafts: dict[int, list[int]] = {}
        spans = None
        want_runahead = self._runahead_want()
        if want_runahead:
            # reserve the whole horizon's pages up front; a shortfall
            # (pool pressure) drops this step back to the H=1 dispatch,
            # which knows how to shed and preempt
            spans = {sl: self.runahead for sl in sched.active}
        if self.spec is not None:
            # proposer work bills to the session clock: for ngram it is
            # microseconds of suffix matching, but a draft-model proposer
            # runs real forwards and must not get them for free in tok/s
            t0 = time.monotonic()
            drafts = self._propose_drafts()
            self.clock += time.monotonic() - t0
            spans = {sl: 1 + len(d) for sl, d in drafts.items()}
        stalled = set(sched.ensure_pages(self._lengths,
                                         skip=self._prefilling.keys(),
                                         spans=spans))
        if self.spec is not None:
            # shed drafts the pool couldn't back: the accepted span must
            # stay inside the slot's allocated pages (only the *verify*
            # copy may spill to scratch, never the committed state)
            for sl, d in drafts.items():
                cap = (sched.alloc.slot_pages(sl) * g
                       - int(self._lengths[sl]) - 1)
                if len(d) > max(cap, 0):
                    del d[max(cap, 0):]
        step_slots = [sl for sl in sched.active
                      if sl not in stalled and sl not in self._prefilling]

        # copy-on-write guard: never append into a shared page.
        # Chunk-aligned adoption makes this a no-op in steady state
        # (adopted pages all precede the write frontier), but it is the
        # invariant that keeps sharing safe under any adoption policy
        # (DESIGN.md §12). A speculative span may cross into further
        # pages, so every page the commit could touch is checked.
        if step_slots and (self.prefix_cache or self.cow_splits):
            safe = []
            for sl in step_slots:
                lo = int(self._lengths[sl]) // g
                hi = (int(self._lengths[sl])
                      + len(drafts.get(sl, ()))) // g
                ok = True
                for pidx in range(lo, hi + 1):
                    if not (pidx < sched.alloc.slot_pages(sl) and
                            sched.alloc.refcount(
                                sched.alloc.page_at(sl, pidx)) > 1):
                        continue
                    if not sched.alloc.can_alloc(1):
                        sched.reclaim(1)
                    if not sched.alloc.can_alloc(1):
                        stalled.add(sl)
                        ok = False
                        break
                    src, dst = sched.alloc.cow(sl, pidx)
                    self.state = self._copy_pages(
                        self.state, jnp.asarray(src, jnp.int32),
                        jnp.asarray(dst, jnp.int32))
                    self.cow_splits += 1
                if ok:
                    safe.append(sl)
            step_slots = safe

        if not step_slots:
            if self._progressed:
                return []   # chunk prefill advanced; next cycle retries
            # every slot needs a page and the pool is dry: recompute-
            # preempt the most recent admission so the rest make progress
            victim = max(sched.active, key=self._admit_seq.__getitem__)
            vreq = sched.active[victim]
            if vreq.preemptions >= 64:
                raise RuntimeError(
                    "request thrashing on preemption — pool too small to "
                    "finish any request")
            # mid-prefill slots can't be victims: chunk work always
            # progresses when any exist, and progress skips this branch
            assert victim not in self._prefilling
            retracted = None
            if vreq.out_tokens:
                retracted = vreq.out_tokens.pop()   # un-fed; re-sampled
            self._eff_max.pop(vreq.rid, None)
            if self.spec is not None:
                self._proposer.release(vreq.rid)
            sched.preempt(victim)
            vreq.state = PREEMPTED
            self._preempted_cycle = True
            # the preempt event carries the retracted token: streaming
            # consumers must drop their last token for this rid
            return [TokenEvent("preempt", vreq.rid, self.clock,
                               token=retracted, slot=victim)]

        if self.spec is not None and any(drafts.get(sl) for sl in
                                         step_slots):
            return self._spec_dispatch(step_slots, drafts)
        if want_runahead and self._runahead_ready(step_slots):
            return self._runahead_dispatch(step_slots)
        return self._decode_dispatch(step_slots)

    def _decode_dispatch(self, step_slots: list[int]) -> list[TokenEvent]:
        """The vanilla one-token decode dispatch (also the fast path of a
        spec session when no slot has drafts this step)."""
        sched, g = self.sched, self.layout.page_size
        s = self.layout.slots
        mask = np.zeros((s,), bool)
        mask[step_slots] = True
        # width-slice the page table to the live pages of this step's
        # batch: the decode step then reads O(live tokens) instead of
        # O(pool capacity) (one compile per pow2 bucket)
        w = self._step_width(
            max(int(self._lengths[sl]) // g + 1 for sl in step_slots))
        t0 = time.monotonic()
        logits, self.state = self._decode(
            self.params, self.state, jnp.asarray(self._next_tok),
            sched.alloc.table()[:, :w], jnp.asarray(mask))
        self._key, sub = jax.random.split(self._key)
        toks = np.asarray(
            self._sample(logits, sub, self.gen))  # sync: H=1 decode token fetch
        step_s = time.monotonic() - t0
        self.clock += step_s
        self.decode_steps += 1
        self._step_times.append(step_s)
        self._util.append(sched.utilization())
        self._active_hist.append(len(step_slots))

        events = []
        for sl in step_slots:
            self._lengths[sl] += 1
            req = sched.active[sl]
            t = int(toks[sl])
            req.out_tokens.append(t)
            self._next_tok[sl] = t
            if self.qos is not None:
                self.qos.on_tokens(req.tenant, 1)
            events.append(TokenEvent("token", req.rid, self.clock,
                                     token=t, slot=sl,
                                     ordinal=req.done_tokens - 1))
            if (self.gen.eos_id >= 0 and t == self.gen.eos_id) or \
                    req.done_tokens >= self._eff_max[req.rid]:
                events += self._finish(sl)
        return events

    # --- run-ahead fused decode (DESIGN.md §18) ---------------------------

    def _runahead_want(self) -> bool:
        """Horizon-planner gate: run-ahead engages only when the next H
        step boundaries are provably event-free — nothing queued to
        admit, no prefill chunk due, and none of the subsystems that
        make per-step scheduling decisions (spec, QoS, chaos, prefix
        sharing, mesh placement) in play. Every other configuration
        takes the H=1 dispatch unchanged, which is why all prior
        bit-identity gates (spec, QoS, mesh, prefix A/Bs) are preserved
        by construction."""
        return (self.runahead > 1 and self.spec is None
                and self.qos is None and self.chaos is None
                and self.mesh is None and not self.prefix_cache
                and not self._prefilling and not self._arrivals
                and not self.sched.pending)

    def _runahead_ready(self, step_slots: list[int]) -> bool:
        """Per-slot hazard check after page reservation: every active
        slot decodes this step and holds pages covering the tokens its
        horizon can append (EOS and budget hazards are masked on
        device; a page shortfall means pool pressure, so fall back to
        the H=1 path, which can shed and preempt)."""
        sched, g = self.sched, self.layout.page_size
        if len(step_slots) != len(sched.active):
            return False
        for sl in step_slots:
            req = sched.active[sl]
            need = int(self._lengths[sl]) + min(
                self.runahead, self._eff_max[req.rid] - req.done_tokens)
            if sched.alloc.slot_pages(sl) * g < need:
                return False
        return True

    def _runahead_dispatch(self, step_slots: list[int]) -> list[TokenEvent]:
        """Dispatch one fresh run-ahead horizon from host-known carries
        (next tokens, session key, per-slot budgets). Returns no events:
        the host does not block on the block — tokens reconcile when it
        lands (the next step, or a cancel arriving mid-flight)."""
        s = self.layout.slots
        mask = np.zeros((s,), bool)
        mask[step_slots] = True
        rem = np.zeros((s,), np.int32)
        for sl in step_slots:
            req = self.sched.active[sl]
            rem[sl] = self._eff_max[req.rid] - req.done_tokens
        self._dispatch_scan(
            step_slots, jnp.asarray(self._next_tok), self._key,
            jnp.zeros((s,), bool), jnp.asarray(rem), jnp.asarray(mask),
            {sl: int(rem[sl]) for sl in step_slots},
            {sl: int(self._lengths[sl]) for sl in step_slots})
        return []

    def _chain_dispatch(self, live: list[int]) -> None:
        """Dispatch the next horizon directly off the in-flight one's
        device-resident carries (final token, key, done mask, budgets)
        — no host sync in between, so the device stays busy while the
        host reconciles the previous block's events."""
        blk = self._inflight
        tok, key, done, rem = blk["carry"]
        self._dispatch_scan(live, tok, key, done, rem, blk["mask"],
                            {sl: blk["ahead_rem"][sl] for sl in live},
                            {sl: blk["opt_len"][sl] for sl in live})

    def _dispatch_scan(self, slots, tok, key, done, rem, mask,
                       host_rem: dict, host_len: dict) -> None:
        g, h = self.layout.page_size, self.runahead
        cap = self.layout.tokens_per_slot
        # width covers every page the horizon can touch (appends and
        # attention reads up to len + H, clamped to slot capacity)
        w = self._step_width(max(
            (min(host_len[sl] + h, cap) - 1) // g + 1 for sl in slots))
        t0 = time.monotonic()
        toks, self.state, tok, key, done, rem = self._runahead_fn(
            self.params, self.state, tok,
            self.sched.alloc.table()[:, :w], mask, key, rem, done,
            horizon=h, temperature=self.gen.temperature,
            top_k=self.gen.top_k, eos_id=self.gen.eos_id)
        # adopt the post-scan key without a sync: the scan split it
        # exactly as H sequential host steps would have, so any later
        # H=1 dispatch continues the same key stream
        self._key = key
        self._inflight = {
            "toks": toks, "slots": list(slots),
            "rem": host_rem, "len": host_len,
            # optimistic carries for chaining: a slot that survives its
            # horizon advanced exactly min(h, rem) tokens; one that
            # finished is excluded from the next chain (budget) or
            # skipped at reconcile (EOS — its device lane froze)
            "ahead_rem": {sl: max(host_rem[sl] - h, 0) for sl in slots},
            "opt_len": {sl: host_len[sl] + min(h, host_rem[sl])
                        for sl in slots},
            "carry": (tok, key, done, rem), "mask": mask,
            "t0": t0, "t_disp": time.monotonic(),
        }

    def _advance_runahead(self) -> list[TokenEvent]:
        """The async pipeline's per-step beat: optionally chain the next
        horizon off the in-flight one's device carries, then land the
        in-flight block and reconcile its TokenEvents. Chaining happens
        *before* the landing sync, so host reconciliation overlaps the
        next horizon's device compute."""
        old = self._inflight
        chained = False
        if self._runahead_want():
            live = [sl for sl in old["slots"]
                    if old["ahead_rem"][sl] > 0
                    and sl in self.sched.active]
            if live:
                g = self.layout.page_size
                opt = self._lengths.copy()
                for sl in live:
                    opt[sl] = old["opt_len"][sl]
                dead = [sl for sl in self.sched.active if sl not in live]
                stalled = set(self.sched.ensure_pages(
                    opt, skip=dead,
                    spans={sl: self.runahead for sl in live}))
                ok = not stalled and all(
                    self.sched.alloc.slot_pages(sl) * g >=
                    old["opt_len"][sl] + min(self.runahead,
                                             old["ahead_rem"][sl])
                    for sl in live)
                if ok:
                    self._chain_dispatch(live)
                    chained = True
        if not chained:
            self._inflight = None
        return self._reconcile_block(old)

    def _reconcile_horizon(self) -> list[TokenEvent]:
        """Forcibly land the in-flight horizon (cancel or any other
        host-side mutation arriving mid-flight): sync, emit its events,
        drain the pipeline."""
        blk, self._inflight = self._inflight, None
        return self._reconcile_block(blk)

    def _reconcile_block(self, blk: dict) -> list[TokenEvent]:
        """Land one horizon's (H, S) token block — the single host sync
        for its H micro-steps — and replay the host-side bookkeeping the
        H=1 loop does per step: per-token ordinals, horizon-shared clock
        stamps with (span, span_ix) metadata, post-hoc truncation at EOS
        or budget, finish + page reclamation on the first host step
        after the block lands."""
        sched, h = self.sched, self.runahead
        t_sync = time.monotonic()
        gap = t_sync - blk["t_disp"]   # host work overlapped with device
        self._overlap_s += gap
        self._gap_ewma = gap if self._gap_ewma is None else \
            0.8 * self._gap_ewma + 0.2 * gap
        toks = np.asarray(blk["toks"])  # sync: horizon block lands — one fetch per H tokens
        now = time.monotonic()
        self._sync_wait_s += now - t_sync
        # wall time is partitioned across pipelined horizons: each bills
        # from the later of its dispatch and the previous landing
        step_s = now - max(blk["t0"], self._land_t)
        self._land_t = now
        self.clock += step_s
        events: list[TokenEvent] = []
        live = 0
        for sl in blk["slots"]:
            req = sched.active.get(sl)
            if req is None or req.state != DECODING:
                continue   # finished/cancelled before this block landed
            live += 1
            rem = blk["rem"][sl]
            emit: list[int] = []
            finished = False
            for j in range(min(h, rem)):
                t = int(toks[j, sl])
                emit.append(t)
                if self.gen.eos_id >= 0 and t == self.gen.eos_id:
                    finished = True
                    break
            if len(emit) >= rem:
                finished = True   # budget bound inside the horizon
            span = len(emit)
            # device lengths advanced once per live micro-step (the fed
            # token's append) — EOS froze the lane right after its
            # sample — so host and device lengths agree for every
            # surviving slot; a finishing slot's pages are reclaimed
            # here, the first host step after the block lands
            self._lengths[sl] += span
            for j, t in enumerate(emit):
                req.out_tokens.append(t)
                events.append(TokenEvent(
                    "token", req.rid, self.clock, token=t, slot=sl,
                    ordinal=req.done_tokens - 1, span=span, span_ix=j))
            self._next_tok[sl] = emit[-1]
            self.runahead_tokens += span
            if finished:
                events += self._finish(sl)
        self.runahead_horizons += 1
        self.decode_steps += h
        self._step_times.append(step_s / h)
        self._util.append(sched.utilization())
        self._active_hist.append(live)
        return events

    # --- speculative decode (DESIGN.md §15) -------------------------------

    def _propose_drafts(self) -> dict[int, list[int]]:
        """Up to ``spec.k`` draft tokens per decode-ready slot, clamped so
        (a) a fully-accepted span can never overshoot the request's
        effective budget (the bonus token is always emitted on top of the
        drafts), and (b) the span never extends past the slot's current
        quantization group (``span <= g - length % g``) — the invariant
        the batched span verifier and the fused span commit rely on: at
        most the LAST span position can trigger a group flush, so one
        residual buffer represents every per-position view bit-exactly
        (``paged_cache.span_verify_attention``). At worst — a slot one
        token shy of a boundary — the step degrades to plain decode."""
        g = self.layout.page_size
        # graceful degradation halves k per level (0 at level 3): under
        # sustained pool pressure speculative spans are the first cost
        # to drop before live requests get preempted
        k = (self.degrade.spec_k(self.spec.k) if self.degrade is not None
             else self.spec.k)
        drafts: dict[int, list[int]] = {}
        for sl, req in self.sched.active.items():
            if sl in self._prefilling:
                continue
            want = min(k,
                       self._eff_max[req.rid] - req.done_tokens - 1,
                       g - int(self._lengths[sl]) % g - 1)
            d: list = []
            if want > 0:
                # a proposer exception (real bug or injected fault) must
                # never take the engine down — the step degrades to plain
                # decode for this slot and the fault is counted
                try:
                    if self.chaos is not None:
                        self.chaos.maybe_fail_proposer()
                    d = self._proposer.propose(req, want)
                except Exception:
                    self.proposer_faults += 1
                    d = []
            drafts[sl] = [int(t) for t in d[:max(want, 0)]]
        return drafts

    def _spec_dispatch(self, step_slots: list[int],
                       drafts: dict[int, list[int]]) -> list[TokenEvent]:
        """One verify dispatch retiring 1..k+1 tokens per stepped slot.

        Column 0 of the span is the step's real next token (vanilla would
        have fed exactly it), columns 1..k the zero-padded drafts. The
        verifier returns the target argmax per position and the accepted
        count; emitted tokens are the argmaxes of column 0 plus the
        accepted drafts — precisely what vanilla greedy decode would have
        emitted over the next ``n_acc + 1`` steps — and the committed
        cache equals the vanilla one bitwise (spec/verify.py)."""
        sched, g = self.sched, self.layout.page_size
        s = self.layout.slots
        # bucket the span width to this step's longest draft (pow2-ish,
        # one compile per bucket): a step where every proposer came back
        # short doesn't pay for k+1 verify positions
        q = self._spec_q(1 + max(len(drafts.get(sl, ()))
                                 for sl in step_slots))
        mask = np.zeros((s,), bool)
        mask[step_slots] = True
        toks = np.zeros((s, q), np.int32)
        toks[:, 0] = self._next_tok
        dlen = np.zeros((s,), np.int32)
        for sl in step_slots:
            d = drafts.get(sl, [])
            toks[sl, 1:1 + len(d)] = d
            dlen[sl] = len(d)
        # width must cover every span position; span pages the scheduler
        # couldn't (or didn't need to) allocate resolve to the scratch
        # page, touched only by the discarded verify copy
        w = self._step_width(
            max((int(self._lengths[sl]) + q - 1) // g + 1
                for sl in step_slots))
        t0 = time.monotonic()
        preds, n_acc, self.state = self._verify(
            self.params, self.state, jnp.asarray(toks), jnp.asarray(dlen),
            sched.alloc.table()[:, :w], jnp.asarray(mask))
        preds = np.asarray(preds)   # sync: verify-span argmax block
        n_acc = np.asarray(n_acc)   # sync: verify-span accept counts
        step_s = time.monotonic() - t0
        self.clock += step_s
        self.decode_steps += 1
        self.spec_steps += 1
        self._step_times.append(step_s)
        self._util.append(sched.utilization())
        self._active_hist.append(len(step_slots))

        events = []
        for sl in step_slots:
            req = sched.active[sl]
            n = int(n_acc[sl])
            self.spec_drafted += int(dlen[sl])
            self.spec_accepted += n
            self._proposer.feedback(req.rid, int(dlen[sl]), n)
            # emit the argmax chain, truncating at EOS (the budget clamp
            # in _propose_drafts means eff_max can only bind at the last
            # span position, exactly like vanilla)
            emit: list[int] = []
            finished = False
            for j in range(n + 1):
                t = int(preds[sl, j])
                emit.append(t)
                if (self.gen.eos_id >= 0 and t == self.gen.eos_id) or \
                        req.done_tokens + len(emit) >= \
                        self._eff_max[req.rid]:
                    finished = True
                    break
            span = len(emit)
            if self.qos is not None:
                self.qos.on_tokens(req.tenant, span)
            for j, t in enumerate(emit):
                req.out_tokens.append(t)
                events.append(TokenEvent(
                    "token", req.rid, self.clock, token=t, slot=sl,
                    ordinal=req.done_tokens - 1, span=span, span_ix=j))
            # device lengths advanced by n+1 (the full accepted span);
            # when EOS truncates the emission the slot finishes and its
            # pages are reclaimed, so the host length is moot
            self._lengths[sl] += n + 1
            self._next_tok[sl] = emit[-1]
            if finished:
                events += self._finish(sl)
        return events

    # --- session results --------------------------------------------------

    def events(self) -> Iterator[TokenEvent]:
        """Drive :meth:`step` until the engine has no work, yielding each
        event as it happens (the batch-replay convenience; open-loop
        drivers call :meth:`step` themselves)."""
        while self.has_work:
            yield from self.step()

    def result(self) -> dict:
        """Aggregate session metrics plus the completed request objects
        (tokens + timestamps filled in) — the same dict the monolithic
        ``run()`` returned."""
        completed = self.completed
        total_tokens = sum(r.done_tokens for r in completed)
        lats = sorted(r.latency() for r in completed)

        def pct(p):
            return nearest_rank_pct(lats, p)

        step_times = self._step_times
        res = {
            "requests": completed,
            "total_tokens": total_tokens,
            "wall_s": self.clock,
            "tokens_per_s": total_tokens / max(self.clock, 1e-9),
            "p50_latency_s": pct(50),
            "p99_latency_s": pct(99),
            "decode_steps": self.decode_steps,
            "decode_step_s_mean": float(np.mean(step_times)) if step_times
            else 0.0,
            "decode_step_s_p50": float(np.median(step_times)) if step_times
            else 0.0,
            "decode_backend": self.model.cfg.decode_backend,
            "prefill_backend": self.model.cfg.prefill_backend,
            "mean_active_slots": float(np.mean(self._active_hist))
            if self._active_hist else 0.0,
            "mean_page_utilization": float(np.mean(self._util))
            if self._util else 0.0,
            "cache_bytes": _tree_bytes(self.state),
            "cache_bytes_per_layer": (
                self.model.cache_layer_bytes(self.state)
                if self.model.cache_layer_bytes else None),
            "prefill_chunk": self.prefill_chunk,
            "prefix_cache": self.prefix_cache,
            "prefill_tokens_computed": self.prefill_computed,
            "prefill_tokens_skipped": self.prefill_skipped,
            "prefix_hit_rate": self.prefill_skipped / max(
                self.prefill_skipped + self.prefill_computed, 1),
            "adopted_pages": self.sched.adopted_pages,
            "fresh_pages": self.sched.fresh_pages,
            "cow_splits": self.cow_splits,
            "cancelled_requests": self.cancelled,
            "n_cancelled": len(self.cancelled),
            "shed_requests": self.shed,
            "n_shed": len(self.shed),
            "rejected_requests": self.rejected,
            "n_rejected": len(self.rejected),
            "proposer_faults": self.proposer_faults,
        }
        if self.qos is not None:
            res["qos"] = {
                **self.qos.stats(),
                "prefill_rate_est": self._prefill_rate.rate,
                "degrade": (self.degrade.stats()
                            if self.degrade is not None else None),
            }
        if self.chaos is not None:
            res["chaos"] = self.chaos.stats()
        if self.runahead > 1:
            # host-vs-device attribution for the async pipeline: the
            # dispatch-gap EWMA is host time per horizon overlapped with
            # device compute; sync_wait is what the host still spends
            # blocked on landing blocks (the residual per-token sync
            # cost the run-ahead path exists to amortize)
            res["runahead"] = {
                "h": self.runahead,
                "horizons": self.runahead_horizons,
                "tokens": self.runahead_tokens,
                "dispatch_gap_ewma_s": self._gap_ewma or 0.0,
                "host_overlap_s": self._overlap_s,
                "sync_wait_s": self._sync_wait_s,
            }
        if self.spec is not None:
            res["spec"] = {
                "mode": self.spec.mode,
                "k": self.spec.k,
                "steps": self.spec_steps,
                "drafted_tokens": self.spec_drafted,
                "accepted_tokens": self.spec_accepted,
                "acceptance_rate": self.spec_accepted / max(
                    self.spec_drafted, 1),
                "mean_accepted_per_step": self.spec_accepted / max(
                    self.spec_steps, 1),
            }
        if self.prefix is not None:
            from repro.core import paged_cache as pgc
            page_bytes = sum(pgc.pool_page_bytes(c) for c in self.state)
            res["pool_page_bytes"] = page_bytes
            res["prefix_pool_bytes_saved"] = \
                self.sched.adopted_pages * page_bytes
            res["prefix_index"] = {
                "entries": len(self.prefix), "queries": self.prefix.queries,
                "evictions": self.prefix.evictions,
            }
        return res
