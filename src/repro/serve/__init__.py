"""Serving: batched decode engines over quantized KV caches.

Layering (DESIGN.md §13): ``core.py`` owns every device dispatch
(``EngineCore.step()`` + the static ``ServeEngine``); ``scheduler.py``
owns slots/pages host-side; ``engine.py`` (batch replay) and ``api.py``
(streaming) are thin host-side drivers over the core. ``qos.py``
(SLA-aware admission + graceful degradation) and ``chaos.py``
(deterministic fault injection) are host-side policy modules (§16) —
both optional, both provably inert when not configured.
"""
from repro.serve.api import (  # noqa: F401
    StreamingEngine, check_event_stream, stream_latency_stats,
)
from repro.serve.chaos import (  # noqa: F401
    ChaosConfig, ChaosError, ChaosInjector,
)
from repro.serve.core import (  # noqa: F401
    EngineCore, GenerationConfig, ServeEngine, TokenEvent,
)
from repro.serve.engine import ContinuousBatchingEngine  # noqa: F401
from repro.serve.qos import (  # noqa: F401
    DegradeController, QosConfig, QosState, goodput_under_sla,
)
from repro.serve.scheduler import (  # noqa: F401
    CancelSummary, Request, Scheduler,
)
