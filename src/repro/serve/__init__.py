"""Serving: batched decode engines over quantized KV caches."""
from repro.serve.engine import (  # noqa: F401
    ContinuousBatchingEngine, GenerationConfig, ServeEngine,
)
from repro.serve.scheduler import Request, Scheduler  # noqa: F401
