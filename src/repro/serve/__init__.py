"""Serving: batched decode engines over quantized KV caches.

Layering (DESIGN.md §13): ``core.py`` owns every device dispatch
(``EngineCore.step()`` + the static ``ServeEngine``); ``scheduler.py``
owns slots/pages host-side; ``engine.py`` (batch replay) and ``api.py``
(streaming) are thin host-side drivers over the core.
"""
from repro.serve.api import StreamingEngine, stream_latency_stats  # noqa: F401
from repro.serve.core import (  # noqa: F401
    EngineCore, GenerationConfig, ServeEngine, TokenEvent,
)
from repro.serve.engine import ContinuousBatchingEngine  # noqa: F401
from repro.serve.scheduler import Request, Scheduler  # noqa: F401
