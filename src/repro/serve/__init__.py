"""Serving: batched decode engine over quantized KV caches."""
from repro.serve.engine import ServeEngine, GenerationConfig  # noqa: F401
