"""QoS layer: SLA-aware admission, per-tenant fairness, and graceful
degradation for the serving stack (DESIGN.md §16).

Host-side only — **no jax anywhere in this module** (enforced by
``scripts/check_engine_layering.sh``). Everything here runs between
jitted steps and mutates nothing but its own counters; the scheduler and
:class:`~repro.serve.core.EngineCore` consult it at three seams:

* **Admission order** — :meth:`QosState.admission_order` replaces the
  scheduler's pure-FCFS head-of-queue poll with weighted fair queueing
  over pending requests: tenants are served in order of *attained
  weighted service* (committed tokens / weight), so a flooding tenant
  cannot starve a light one. Tenants over their token budget are skipped
  until their bucket refills.
* **Deadline shedding** — :meth:`QosState.unmeetable` flags pending
  requests whose TTFT deadline is already blown or unmeetable given the
  queue depth ahead of them and the measured prefill throughput
  (:class:`RateEstimator`). The engine sheds them with an explicit
  ``shed`` TokenEvent instead of wasting prefill on a request whose
  client has already timed out.
* **Degradation** — :class:`DegradeController` watches pool pressure
  (page utilization + preemption events) and downshifts through discrete
  levels with hysteresis: cap speculative draft length, shrink the
  per-cycle prefill budget, and (level 2+) proactively evict index-only
  prefix pages *before* any live request has to be recompute-preempted.
  Each transition is counted and surfaced in ``result()["qos"]``.

None of this module is imported when ``EngineCore(qos=None)`` — the
engine's QoS branches are all gated on the config, so a QoS-off session
is bit-identical to the pre-QoS engine (asserted by the golden-parity
tests).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class QosConfig:
    """Knobs for the QoS layer. The zero values disable each feature
    independently, so ``QosConfig()`` alone changes nothing but the
    admission *order* (and only when ``wfq`` is True and tenants
    differ)."""

    #: bound on queued requests (scheduled arrivals + pending); beyond it
    #: :meth:`EngineCore.add_request` rejects with a ``reject`` event
    #: instead of letting the queue grow without bound. 0 = unbounded.
    max_pending: int = 0
    #: session-default TTFT deadline (seconds from arrival) for requests
    #: that don't carry their own ``Request.ttft_deadline``. 0 = none.
    ttft_slo: float = 0.0
    #: per-tenant token-bucket refill rate (committed tokens / second of
    #: engine clock). 0 = budgets disabled.
    tenant_budget: float = 0.0
    #: bucket capacity; <= 0 defaults to two seconds of refill.
    tenant_burst: float = 0.0
    #: per-tenant WFQ weights (missing tenants weigh 1.0).
    weights: Mapping[str, float] = dataclasses.field(default_factory=dict)
    #: weighted-fair-queueing admission order (False = keep FCFS order,
    #: budgets/deadlines still apply).
    wfq: bool = True
    #: shed pending requests whose deadline is unmeetable.
    shed_late: bool = True
    #: enable the degradation controller.
    degrade: bool = True
    #: pool-pressure thresholds (page utilization) with hysteresis:
    #: ``hysteresis_up`` consecutive pressured cycles to downshift one
    #: level, ``hysteresis_down`` calm cycles to recover one level.
    pressure_hi: float = 0.92
    pressure_lo: float = 0.60
    hysteresis_up: int = 3
    hysteresis_down: int = 12
    max_level: int = 3

    def __post_init__(self):
        if self.max_pending < 0:
            raise ValueError("max_pending must be >= 0")
        if self.ttft_slo < 0 or self.tenant_budget < 0:
            raise ValueError("ttft_slo / tenant_budget must be >= 0")
        if not (0.0 <= self.pressure_lo <= self.pressure_hi <= 1.0):
            raise ValueError("need 0 <= pressure_lo <= pressure_hi <= 1")
        if self.hysteresis_up < 1 or self.hysteresis_down < 1:
            raise ValueError("hysteresis counts must be >= 1")
        if self.max_level < 1:
            raise ValueError("max_level must be >= 1")
        for t, w in self.weights.items():
            if w <= 0:
                raise ValueError(f"weight for tenant {t!r} must be > 0")

    @property
    def burst(self) -> float:
        return (self.tenant_burst if self.tenant_burst > 0
                else 2.0 * self.tenant_budget)


class RateEstimator:
    """EWMA tokens/second estimator for the prefill path. Returns None
    until the first observation — deadline *projection* is disabled until
    the engine has measured real throughput (already-blown deadlines are
    still shed)."""

    def __init__(self, alpha: float = 0.3):
        self.alpha = float(alpha)
        self._rate: Optional[float] = None

    def observe(self, tokens: int, seconds: float) -> None:
        if tokens <= 0 or seconds <= 0:
            return
        r = tokens / seconds
        self._rate = (r if self._rate is None
                      else self.alpha * r + (1 - self.alpha) * self._rate)

    @property
    def rate(self) -> Optional[float]:
        return self._rate


class TenantState:
    """Accounting for one tenant: attained weighted service (the WFQ
    key) and the token bucket."""

    def __init__(self, name: str, weight: float, cfg: QosConfig):
        self.name = name
        self.weight = max(float(weight), 1e-9)
        self.cfg = cfg
        self.committed_tokens = 0    # admission-time commitments (WFQ key)
        self.served_tokens = 0       # tokens actually produced (metrics)
        self.admitted = 0
        self.shed = 0
        self.rejected = 0
        self.bucket = cfg.burst      # starts full
        self._last_refill = 0.0

    def refill(self, clock: float) -> None:
        if self.cfg.tenant_budget <= 0:
            return
        dt = max(clock - self._last_refill, 0.0)
        self._last_refill = clock
        self.bucket = min(self.bucket + dt * self.cfg.tenant_budget,
                          self.cfg.burst)

    def can_afford(self, cost: int) -> bool:
        """Bucket check for an admission of ``cost`` committed tokens. A
        cost larger than the whole bucket capacity is charged at capacity
        so oversized requests can't starve forever."""
        if self.cfg.tenant_budget <= 0:
            return True
        return self.bucket >= min(float(cost), self.cfg.burst)

    def charge(self, cost: int) -> None:
        self.committed_tokens += int(cost)
        if self.cfg.tenant_budget > 0:
            self.bucket -= min(float(cost), self.cfg.burst)

    @property
    def attained(self) -> float:
        return self.committed_tokens / self.weight


def request_cost(req) -> int:
    """Committed tokens an admission signs up for: the context that must
    be prefilled plus the output budget."""
    return int(req.context_len + req.max_new_tokens)


def effective_deadline(req, cfg: QosConfig) -> float:
    """Per-request TTFT deadline in seconds from arrival (0 = none):
    the request's own ``ttft_deadline`` wins over the session SLO."""
    d = getattr(req, "ttft_deadline", 0.0)
    return float(d) if d > 0 else cfg.ttft_slo


class QosState:
    """Mutable per-session QoS state: tenant accounts + admission logic.
    Owned by :class:`~repro.serve.core.EngineCore`; the scheduler holds a
    reference and consults :meth:`admission_order`."""

    def __init__(self, cfg: QosConfig):
        self.cfg = cfg
        self.tenants: Dict[str, TenantState] = {}
        self.n_shed = 0
        self.n_rejected = 0

    def tenant(self, name: str) -> TenantState:
        ts = self.tenants.get(name)
        if ts is None:
            ts = TenantState(name, self.cfg.weights.get(name, 1.0),
                             self.cfg)
            self.tenants[name] = ts
        return ts

    def refill(self, clock: float) -> None:
        for ts in self.tenants.values():
            ts.refill(clock)

    # --- admission ---------------------------------------------------------

    def admission_order(self, pending: Sequence) -> List:
        """Pending requests in service order: weighted fair queueing by
        attained service (ties broken by queue position, i.e. arrival),
        with over-budget tenants filtered out until they refill. With
        ``wfq=False`` the FCFS order is kept and only the budget filter
        applies."""
        affordable = [r for r in pending
                      if self.tenant(r.tenant).can_afford(request_cost(r))]
        if not self.cfg.wfq:
            return affordable
        order = {id(r): i for i, r in enumerate(pending)}
        return sorted(affordable,
                      key=lambda r: (self.tenant(r.tenant).attained,
                                     order[id(r)]))

    def next_affordable_time(self, pending: Sequence,
                             clock: float) -> Optional[float]:
        """Earliest engine-clock time at which some pending request's
        tenant bucket will afford it, or None when there is nothing to
        wait for (budgets off, or a pending request is affordable right
        now — then the blocker is pages, not budget). The engine uses
        this to jump its simulated clock when the pool is otherwise
        idle: with no work running the clock — and therefore every
        bucket refill — would freeze, starving the queue forever."""
        if self.cfg.tenant_budget <= 0:
            return None
        best = None
        for r in pending:
            ts = self.tenant(r.tenant)
            need = min(float(request_cost(r)), self.cfg.burst)
            deficit = need - ts.bucket
            if deficit <= 0:
                return None
            t = clock + deficit / self.cfg.tenant_budget
            best = t if best is None else min(best, t)
        return best

    def on_admit(self, req) -> None:
        ts = self.tenant(req.tenant)
        ts.charge(request_cost(req))
        ts.admitted += 1

    def on_tokens(self, tenant: str, n: int) -> None:
        self.tenant(tenant).served_tokens += int(n)

    # --- deadline shedding -------------------------------------------------

    def unmeetable(self, pending: Sequence, clock: float,
                   prefill_rate: Optional[float],
                   inflight_tokens: int = 0) -> List[tuple]:
        """``(request, reason)`` pairs for pending requests whose TTFT
        deadline is already blown (``"deadline_blown"``), or provably
        unmeetable given the prefill work queued ahead of them at the
        measured prefill throughput (``"deadline_unmeetable"``). Walks
        the WFQ admission order, accumulating each survivor's context as
        backlog for the requests behind it; with no rate measurement yet
        the projection is disabled and only blown deadlines shed."""
        if not self.cfg.shed_late:
            return []
        doomed = []
        backlog = int(inflight_tokens)
        for req in self.admission_order(pending):
            deadline = effective_deadline(req, self.cfg)
            if deadline <= 0:
                backlog += req.context_len
                continue
            latest = req.arrival_time + deadline
            if clock >= latest:
                doomed.append((req, "deadline_blown"))
                continue
            if prefill_rate is not None and prefill_rate > 0:
                eta = clock + (backlog + req.context_len) / prefill_rate
                if eta > latest:
                    doomed.append((req, "deadline_unmeetable"))
                    continue
            backlog += req.context_len
        return doomed

    def on_shed(self, req) -> None:
        self.n_shed += 1
        self.tenant(req.tenant).shed += 1

    def on_reject(self, req) -> None:
        self.n_rejected += 1
        self.tenant(req.tenant).rejected += 1

    # --- introspection -----------------------------------------------------

    def stats(self) -> dict:
        return {
            "shed": self.n_shed,
            "rejected": self.n_rejected,
            "tenants": {
                name: {
                    "weight": ts.weight,
                    "admitted": ts.admitted,
                    "shed": ts.shed,
                    "rejected": ts.rejected,
                    "committed_tokens": ts.committed_tokens,
                    "served_tokens": ts.served_tokens,
                    "bucket": ts.bucket,
                } for name, ts in sorted(self.tenants.items())
            },
        }


class DegradeController:
    """Discrete downshift levels with hysteresis. ``update()`` once per
    engine cycle with the pool pressure signal; the engine then reads the
    level's effects:

    ========  =====================================================
    level     effect (cumulative)
    ========  =====================================================
    0         nothing — full service
    1         speculative draft cap halved; prefill budget halved
    2         + proactively evict index-only prefix pages so every
              active slot keeps one page of headroom (shed *cache*
              before shedding *live work*)
    3         + speculation off (``spec_k -> 0``), prefill budget
              floored at one chunk per cycle
    ========  =====================================================

    A downshift needs ``hysteresis_up`` consecutive pressured cycles
    (utilization >= pressure_hi, or a recompute-preemption happened); a
    recovery needs ``hysteresis_down`` consecutive calm cycles
    (utilization <= pressure_lo and no preemption). The dead zone in
    between resets the pressure streak but does not count as calm, so
    the controller never oscillates on a noisy boundary."""

    def __init__(self, cfg: QosConfig):
        self.cfg = cfg
        self.level = 0
        self.peak_level = 0
        self.downshifts = 0
        self.recoveries = 0
        self.cycles_degraded = 0
        self._hot = 0
        self._calm = 0

    def update(self, utilization: float, preempted: bool) -> int:
        """One cycle's pressure observation; returns the (possibly new)
        level."""
        cfg = self.cfg
        if preempted or utilization >= cfg.pressure_hi:
            self._hot += 1
            self._calm = 0
        elif utilization <= cfg.pressure_lo:
            self._calm += 1
            self._hot = 0
        else:
            self._hot = 0
        if self._hot >= cfg.hysteresis_up and self.level < cfg.max_level:
            self.level += 1
            self.peak_level = max(self.peak_level, self.level)
            self.downshifts += 1
            self._hot = 0
        if self._calm >= cfg.hysteresis_down and self.level > 0:
            self.level -= 1
            self.recoveries += 1
            self._calm = 0
        if self.level > 0:
            self.cycles_degraded += 1
        return self.level

    def spec_k(self, base: int) -> int:
        """Cap on speculative drafts at the current level: halved per
        level, fully off (0) at level 3 — under the worst pressure a
        verify span must never contend for pages with live decode."""
        if self.level == 0:
            return base
        if self.level >= 3:
            return 0
        return max(base >> self.level, 0)

    def prefill_budget(self, base: int) -> int:
        """Per-cycle prefill token budget at the current level. The
        engine always runs at least one chunk per cycle when any budget
        remains, so even a floor of 1 keeps prefill live — just maximally
        deprioritized against decode."""
        return base if self.level == 0 else max(base >> self.level, 1)

    @property
    def evict_ahead(self) -> bool:
        return self.level >= 2

    def stats(self) -> dict:
        return {
            "level": self.level,
            "peak_level": self.peak_level,
            "downshifts": self.downshifts,
            "recoveries": self.recoveries,
            "cycles_degraded": self.cycles_degraded,
        }


# ---------------------------------------------------------------------------
# Goodput under SLA — the headline adversarial-benchmark metric
# ---------------------------------------------------------------------------


def goodput_under_sla(requests: Iterable, wall_s: float,
                      slo: float = 0.0) -> dict:
    """Deadline-met goodput over completed requests: tokens/s counting
    only requests whose TTFT (first token minus arrival) met their
    deadline (``Request.ttft_deadline``, falling back to ``slo``;
    requests with neither always count). Shed / rejected / unfinished
    requests contribute nothing — that is the point of the metric: work
    the client had already given up on is not goodput."""
    met = missed = 0
    good_tokens = 0
    for r in requests:
        deadline = getattr(r, "ttft_deadline", 0.0) or slo
        if r.t_first_token is None:
            missed += 1
            continue
        ttft = r.t_first_token - r.arrival_time
        if deadline > 0 and ttft > deadline:
            missed += 1
            continue
        met += 1
        good_tokens += r.done_tokens
    return {
        "goodput_tokens_per_s": good_tokens / max(wall_s, 1e-9),
        "good_tokens": good_tokens,
        "deadline_met_requests": met,
        "deadline_missed_requests": missed,
        "deadline_met_rate": met / max(met + missed, 1),
    }
