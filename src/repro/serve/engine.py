"""Batched serving engine: prefill -> decode over the quantized KV cache.

The engine jit-compiles one prefill step per prompt length bucket and one
decode step; the decode step is the PolarQuant fast path (grouped LUT
scores + fp residual). Under a mesh, caches shard batch over (pod, data)
and the sequence/group axis over model (context-parallel decode).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import ctx
from repro.distributed import sharding as shd
from repro.models.registry import Model


@dataclasses.dataclass
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 => greedy
    top_k: int = 0
    eos_id: int = -1              # -1 => never stop early
    seed: int = 0


def _sample(logits, key, gen: GenerationConfig):
    if gen.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / gen.temperature
    if gen.top_k > 0:
        vals, _ = jax.lax.top_k(logits, gen.top_k)
        logits = jnp.where(logits < vals[..., -1:], -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


class ServeEngine:
    def __init__(self, model: Model, params, max_len: int,
                 mesh=None, rules: Optional[dict] = None):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.mesh = mesh
        self.rules = rules
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode)
        self._sample = jax.jit(_sample, static_argnames=("gen",))

    def _ctx(self):
        if self.mesh is not None and self.rules is not None:
            return ctx.use_sharding(self.mesh, self.rules)
        import contextlib
        return contextlib.nullcontext()

    def generate(self, batch: dict, gen: GenerationConfig = GenerationConfig()):
        """batch: prompt inputs (tokens (B, Tp) [+ frames/patches]).

        Returns dict with generated tokens (B, max_new_tokens) and timings.
        """
        b = batch["tokens"].shape[0]
        key = jax.random.PRNGKey(gen.seed)
        with self._ctx():
            state = self.model.init_decode_state(b, self.max_len)
            t0 = time.monotonic()
            logits, state = self._prefill(self.params, batch, state)
            logits.block_until_ready()
            t_prefill = time.monotonic() - t0

            toks = []
            tok = _sample(logits, key, gen)
            toks.append(tok)
            t0 = time.monotonic()
            done = jnp.zeros((b,), bool)
            for i in range(gen.max_new_tokens - 1):
                logits, state = self._decode(self.params, state, tok)
                key, sub = jax.random.split(key)
                tok = _sample(logits, sub, gen)
                if gen.eos_id >= 0:
                    done = done | (tok == gen.eos_id)
                    tok = jnp.where(done, gen.eos_id, tok)
                toks.append(tok)
            jax.block_until_ready(tok)
            t_decode = time.monotonic() - t0
        out = jnp.stack(toks, axis=1)
        n_dec = max(gen.max_new_tokens - 1, 1)
        return {
            "tokens": np.asarray(out),
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "tokens_per_s": b * n_dec / max(t_decode, 1e-9),
            "cache_bytes": _tree_bytes(state),
        }


def _tree_bytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "dtype"))
