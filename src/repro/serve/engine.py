"""Serving engines over the quantized KV cache.

* :class:`ServeEngine` — static batching: one shared prefill, lock-step
  decode, the whole batch stalls until its slowest request finishes.
  Defined in :mod:`repro.serve.core` (the device-dispatch layer),
  re-exported here for back-compat.
* :class:`ContinuousBatchingEngine` — the closed-batch adapter over
  :class:`~repro.serve.core.EngineCore`: submit a whole request list,
  drain the step loop to quiescence, return aggregate metrics. The step
  machine replays the pre-refactor monolithic loop bit-identically
  (same greedy tokens, same page-adoption decisions, same metrics —
  asserted against the frozen oracle in ``tests/cb_reference.py``), so
  ``run()`` is now ~20 lines of host-side driving with zero device
  dispatch of its own. For open-loop serving (requests arriving and
  cancelling while the loop runs, tokens streamed as they are sampled)
  use :class:`repro.serve.api.StreamingEngine` over the same core.

This module is deliberately host-side-only — no ``jax`` imports; the
layering lint (``scripts/check_engine_layering.sh``) enforces it.
"""
from __future__ import annotations

from typing import Optional

from repro.serve.core import (  # noqa: F401  (re-exports: back-compat)
    EngineCore, GenerationConfig, ServeEngine, TokenEvent,
)
from repro.serve.scheduler import Request


class ContinuousBatchingEngine:
    """Continuous-batching serve engine over per-layer paged KV caches.

    ``max_slots`` concurrent requests share ``num_pages`` cache pages of
    ``group_size`` tokens each (default: fully provisioned,
    ``max_slots * ceil(max_len/g)``; pass fewer to oversubscribe — slots
    then stall when the pool runs dry and resume as pages free up).

    ``run()`` drives a whole workload: arrivals (per-request
    ``arrival_time`` on an engine-relative clock), FCFS admission with
    per-request prefill into assigned pages, batched decode steps over all
    active slots, EOS/length completion with immediate page reclamation.
    The clock advances by measured device time, so reported latencies
    compose queueing + compute. Call :meth:`warmup` first to take jit
    compilation out of the measurements.

    **Chunked prefill** (``prefill_chunk > 0``): prompts are prefilled in
    fixed-size page-aligned chunks *interleaved* with decode steps under a
    per-cycle token budget (``prefill_budget``, default one chunk) — long
    prompts no longer stall decode latency for everyone else.

    **Shared-prefix page reuse** (``prefix_cache=True``, implies chunked
    prefill): completed prompt prefills register their full-chunk pages in
    a content-hash :class:`~repro.core.cache_layout.PrefixIndex`;
    admissions matching an indexed prefix adopt those pages at refcount+1
    and only prefill the tail — bit-identical to the unshared chunked
    baseline under greedy sampling (DESIGN.md §12).

    **QoS / chaos** (DESIGN.md §16): pass ``qos=QosConfig(...)`` for
    weighted-fair admission, tenant token budgets, TTFT-deadline
    shedding, bounded-queue rejects, and graceful degradation; pass
    ``chaos=ChaosInjector(ChaosConfig(...))`` for deterministic fault
    injection. Both default to ``None`` — the engine is then
    bit-identical to the pre-QoS FCFS engine.

    **Run-ahead fused decode** (``runahead=H > 1``, DESIGN.md §18): in
    decode-bound stretches where the horizon planner predicts no
    scheduling event, the core dispatches H fused micro-steps —
    on-device sampling and EOS/budget masking — per device call,
    pipelines the next horizon while a block is in flight, and
    reconciles TokenEvents when each (H, slots) block lands. Greedy
    outputs stay bit-identical to ``runahead=0`` by construction; spec,
    QoS, chaos, mesh, and prefix-cache configurations fall back to the
    H=1 dispatch untouched.

    Scheduling, paging, preemption, and the decode-step mechanics
    (width-sliced page tables, donated state, COW guard) all live in
    :class:`~repro.serve.core.EngineCore`; this class only adapts the
    batch-replay calling convention onto the step loop.
    """

    def __init__(self, model, params, *, max_slots: int = 4,
                 max_len: int = 256, num_pages: Optional[int] = None,
                 mesh=None, rules: Optional[dict] = None,
                 table_slicing: bool = True, prefix_cache: bool = False,
                 prefill_chunk: int = 0, prefill_budget: int = 0,
                 spec=None, qos=None, chaos=None, runahead: int = 0):
        self.core = EngineCore(
            model, params, max_slots=max_slots, max_len=max_len,
            num_pages=num_pages, mesh=mesh, rules=rules,
            table_slicing=table_slicing, prefix_cache=prefix_cache,
            prefill_chunk=prefill_chunk, prefill_budget=prefill_budget,
            spec=spec, qos=qos, chaos=chaos, runahead=runahead)

    # the knobs tests/benchmarks introspect, forwarded from the core
    @property
    def model(self):
        return self.core.model

    @property
    def params(self):
        return self.core.params

    @property
    def layout(self):
        return self.core.layout

    @property
    def prefill_chunk(self) -> int:
        return self.core.prefill_chunk

    @property
    def prefill_budget(self) -> int:
        return self.core.prefill_budget

    @property
    def prefix_cache(self) -> bool:
        return self.core.prefix_cache

    @property
    def table_slicing(self) -> bool:
        return self.core.table_slicing

    @property
    def runahead(self) -> int:
        return self.core.runahead

    def warmup(self, prompt_lens: list[int],
               gen: Optional[GenerationConfig] = None) -> None:
        """Compile prefill buckets (or the single chunk shape) + the
        decode step against throwaway state."""
        self.core.warmup(prompt_lens, gen)

    def run(self, requests: list[Request],
            gen: Optional[GenerationConfig] = None) -> dict:
        """Serve ``requests`` to completion. Returns aggregate metrics
        plus the completed request objects (tokens + timestamps filled
        in) and, new with the step-loop core, the full ``TokenEvent``
        stream under ``"events"`` (per-token timestamps for TTFT/ITL
        percentiles — see ``benchmarks/bench_serving.py``)."""
        core = self.core
        core.reset(gen)
        for req in sorted(requests, key=lambda r: r.arrival_time):
            core.add_request(req)
        events = list(core.events())
        res = core.result()
        res["events"] = events
        return res
