"""Serving engines over the quantized KV cache.

* :class:`ServeEngine` — static batching: one shared prefill, lock-step
  decode, the whole batch stalls until its slowest request finishes. Kept
  as the baseline (and for single-batch offline use).
* :class:`ContinuousBatchingEngine` — per-request admission into a paged
  cache (`core.paged_cache`): requests join mid-flight as slots/pages free
  up, decode steps batch all active slots at heterogeneous positions, and
  EOS immediately reclaims pages. All device shapes are static (slots,
  pages, prompt buckets), so the decode step jits exactly once and prefill
  jits once per bucket.

Under a mesh, caches shard batch over (pod, data) and the sequence/group
axis over model (context-parallel decode).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache_layout import PagedLayout, PrefixIndex
from repro.distributed import ctx
from repro.distributed import sharding as shd
from repro.models.registry import Model
from repro.serve.scheduler import Request, Scheduler
from repro.utils import cdiv, pow2_bucket, tree_bytes as _tree_bytes


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 => greedy
    top_k: int = 0
    eos_id: int = -1              # -1 => never stop early
    seed: int = 0


def _sample(logits, key, gen: GenerationConfig):
    if gen.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / gen.temperature
    if gen.top_k > 0:
        vals, _ = jax.lax.top_k(logits, gen.top_k)
        logits = jnp.where(logits < vals[..., -1:], -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


class ServeEngine:
    def __init__(self, model: Model, params, max_len: int,
                 mesh=None, rules: Optional[dict] = None):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.mesh = mesh
        self.rules = rules
        self._prefill = jax.jit(model.prefill)
        # donate the decode state: cache buffers update in place instead of
        # being copied every step (the state is rebound to the result)
        self._decode = jax.jit(model.decode, donate_argnums=(1,))
        self._sample = jax.jit(_sample, static_argnames=("gen",))

    def _ctx(self):
        if self.mesh is not None and self.rules is not None:
            return ctx.use_sharding(self.mesh, self.rules)
        import contextlib
        return contextlib.nullcontext()

    def generate(self, batch: dict, gen: GenerationConfig = GenerationConfig()):
        """batch: prompt inputs (tokens (B, Tp) [+ frames/patches]).

        Returns dict with generated tokens (B, max_new_tokens) and timings.
        """
        b = batch["tokens"].shape[0]
        cfg = self.model.cfg
        if cfg.family in ("dense", "moe", "vlm") and cfg.window == 0:
            # linear cache: prompt + appended tokens must fit (the last
            # sampled token is never appended, hence the -1)
            tp = batch["tokens"].shape[1] + (
                cfg.frontend_tokens if cfg.family == "vlm" else 0)
            if tp + gen.max_new_tokens - 1 > self.max_len:
                raise ValueError(
                    f"prompt {tp} + max_new_tokens {gen.max_new_tokens} "
                    f"exceeds cache capacity {self.max_len}")
        key = jax.random.PRNGKey(gen.seed)
        with self._ctx():
            state = self.model.init_decode_state(b, self.max_len)
            t0 = time.monotonic()
            logits, state = self._prefill(self.params, batch, state)
            logits.block_until_ready()
            t_prefill = time.monotonic() - t0

            toks = []
            tok = self._sample(logits, key, gen)
            toks.append(tok)
            t0 = time.monotonic()
            done = jnp.zeros((b,), bool)
            for i in range(gen.max_new_tokens - 1):
                logits, state = self._decode(self.params, state, tok)
                key, sub = jax.random.split(key)
                tok = self._sample(logits, sub, gen)
                if gen.eos_id >= 0:
                    done = done | (tok == gen.eos_id)
                    tok = jnp.where(done, gen.eos_id, tok)
                toks.append(tok)
            jax.block_until_ready(tok)
            t_decode = time.monotonic() - t0
        out = jnp.stack(toks, axis=1)
        n_dec = max(gen.max_new_tokens - 1, 1)
        return {
            "tokens": np.asarray(out),
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "tokens_per_s": b * n_dec / max(t_decode, 1e-9),
            "cache_bytes": _tree_bytes(state),
            "cache_bytes_per_layer": (
                self.model.cache_layer_bytes(state)
                if self.model.cache_layer_bytes else None),
        }



# ---------------------------------------------------------------------------
# Continuous batching over the paged cache
# ---------------------------------------------------------------------------


class ContinuousBatchingEngine:
    """Continuous-batching serve engine over per-layer paged KV caches.

    ``max_slots`` concurrent requests share ``num_pages`` cache pages of
    ``group_size`` tokens each (default: fully provisioned,
    ``max_slots * ceil(max_len/g)``; pass fewer to oversubscribe — slots
    then stall when the pool runs dry and resume as pages free up).

    ``run()`` drives a whole workload: arrivals (per-request
    ``arrival_time`` on an engine-relative clock), FCFS admission with
    per-request prefill into assigned pages, batched decode steps over all
    active slots, EOS/length completion with immediate page reclamation.
    The clock advances by measured device time, so reported latencies
    compose queueing + compute. Call :meth:`warmup` first to take jit
    compilation out of the measurements.

    Decode-step cost scales with the *live* context, not the pool: the
    page table ships width-sliced to the smallest pow2 bucket covering the
    step's live pages (one compile per bucket, see :meth:`_step_width`),
    and the decode state is donated so page pools update in place instead
    of being copied every step.

    **Chunked prefill** (``prefill_chunk > 0``): prompts are prefilled in
    fixed-size page-aligned chunks through the model's
    ``prefill_paged_chunk`` path (each chunk attends to the slot's cached
    quantized prefix plus fp causal within the chunk), *interleaved* with
    decode steps under a per-engine-step token budget
    (``prefill_budget``, default one chunk) — long prompts no longer
    stall decode latency for everyone else. One compile covers every
    chunk of every prompt. ``prefill_chunk=0`` keeps the classic one-shot
    prefill (per-bucket compiles, whole prompt before the next step).

    **Shared-prefix page reuse** (``prefix_cache=True``, implies chunked
    prefill): completed prompt prefills register their full-chunk pages
    in a content-hash :class:`~repro.core.cache_layout.PrefixIndex`;
    admissions matching an indexed prefix adopt those pages at
    refcount+1 — the encoded bytes are shared verbatim, no re-encode —
    and only prefill the tail. Adoption is chunk-aligned and the final
    chunk is always recomputed, which makes a shared-prefix run
    bit-identical to the unshared chunked baseline (greedy sampling).
    A copy-on-write guard checks every decode append target and splits
    shared pages before writing (a no-op under chunk-aligned adoption,
    but load-bearing for any future partial-page sharing — DESIGN.md §12).
    """

    def __init__(self, model: Model, params, *, max_slots: int = 4,
                 max_len: int = 256, num_pages: Optional[int] = None,
                 mesh=None, rules: Optional[dict] = None,
                 table_slicing: bool = True, prefix_cache: bool = False,
                 prefill_chunk: int = 0, prefill_budget: int = 0):
        if model.decode_paged is None:
            raise ValueError(
                f"family {model.cfg.family!r} has no paged decode path")
        self.model = model
        self.params = params
        self.mesh = mesh
        self.rules = rules
        # table_slicing=False ships the full (S, pages_per_slot) table every
        # step — the pre-width-bucketing behavior, kept as a benchmark
        # baseline (decode cost then scales with pool capacity)
        self.table_slicing = table_slicing
        # page == quantization group: every layer of the policy must agree
        # on the group size (bit-widths/methods may differ per layer)
        g = model.cfg.policy.page_group_size()
        pages_per_slot = cdiv(max_len, g)
        if num_pages is None:
            num_pages = max_slots * pages_per_slot
        self.layout = PagedLayout(page_size=g, num_pages=num_pages,
                                  slots=max_slots,
                                  pages_per_slot=pages_per_slot)
        self.prefix_cache = bool(prefix_cache)
        chunk = int(prefill_chunk)
        if chunk < 0:
            raise ValueError(f"prefill_chunk must be >= 0, got {chunk}")
        if self.prefix_cache and chunk == 0:
            chunk = 2 * g   # sharing requires the chunk-aligned path
        if chunk:
            chunk = cdiv(chunk, g) * g   # page-aligned chunks
            if model.prefill_paged_chunk is None:
                raise ValueError(
                    f"family {model.cfg.family!r} has no chunked prefill "
                    "path (prefill_paged_chunk)")
        self.prefill_chunk = chunk
        self.prefill_budget = int(prefill_budget) if prefill_budget else chunk
        self._prefill = jax.jit(model.prefill_paged)
        if chunk:
            self._prefill_chunk = jax.jit(model.prefill_paged_chunk,
                                          donate_argnums=(2,))
        if model.copy_pages is not None:
            self._copy_pages = jax.jit(model.copy_pages, donate_argnums=(0,))
        # donate the paged state: page pools update in place each step
        self._decode = jax.jit(model.decode_paged, donate_argnums=(1,))
        self._sample = jax.jit(_sample, static_argnames=("gen",))

    def _decode_widths(self) -> list[int]:
        """Page-table width buckets the decode step compiles against:
        powers of two capped at ``pages_per_slot``."""
        n = self.layout.pages_per_slot
        if not self.table_slicing:
            return [n]
        widths, w = [], 1
        while w < n:
            widths.append(w)
            w *= 2
        widths.append(n)
        return widths

    def _step_width(self, pages_needed: int) -> int:
        """Smallest width bucket covering ``pages_needed`` live pages.

        The decode step reads the page table only up to this width, so its
        per-step cost scales with the *live* context of the current batch
        — O(max live tokens) — instead of the pool capacity."""
        if not self.table_slicing:
            return self.layout.pages_per_slot
        for w in self._decode_widths():
            if w >= pages_needed:
                return w
        return self.layout.pages_per_slot

    def _ctx(self):
        if self.mesh is not None and self.rules is not None:
            return ctx.use_sharding(self.mesh, self.rules)
        import contextlib
        return contextlib.nullcontext()

    def _bucket(self, prompt_len: int) -> int:
        return min(pow2_bucket(prompt_len, self.layout.page_size),
                   self.layout.tokens_per_slot)

    def warmup(self, prompt_lens: list[int],
               gen: GenerationConfig = GenerationConfig()) -> None:
        """Compile prefill buckets (or the single chunk shape) + the decode
        step against throwaway state."""
        state = self.model.init_paged_state(self.layout)
        sched = Scheduler(self.layout)
        key = jax.random.PRNGKey(0)
        s = self.layout.slots
        with self._ctx():
            if self.prefill_chunk:
                # one compile covers every chunk of every prompt
                c = self.prefill_chunk
                logits, state = self._prefill_chunk(
                    self.params, jnp.zeros((1, c), jnp.int32), state,
                    jnp.zeros((), jnp.int32), sched.alloc.table()[0],
                    jnp.zeros((), jnp.int32), jnp.asarray(c, jnp.int32))
                jax.block_until_ready(self._sample(logits, key, gen))
            else:
                for tp in sorted({self._bucket(t) for t in prompt_lens}):
                    logits, state = self._prefill(
                        self.params, jnp.zeros((1, tp), jnp.int32), state,
                        jnp.zeros((), jnp.int32), sched.alloc.table()[0],
                        jnp.asarray(tp, jnp.int32))
                    jax.block_until_ready(self._sample(logits, key, gen))
            for w in self._decode_widths():
                logits, state = self._decode(
                    self.params, state, jnp.zeros((s,), jnp.int32),
                    sched.alloc.table()[:, :w], jnp.zeros((s,), bool))
                jax.block_until_ready(self._sample(logits, key, gen))

    def run(self, requests: list[Request],
            gen: GenerationConfig = GenerationConfig()) -> dict:
        """Serve ``requests`` to completion. Returns aggregate metrics plus
        the completed request objects (tokens + timestamps filled in)."""
        prefix = (PrefixIndex(self.layout, self.prefill_chunk)
                  if self.prefix_cache else None)
        sched = Scheduler(self.layout, prefix_index=prefix,
                          chunk_tokens=self.prefill_chunk)
        state = self.model.init_paged_state(self.layout)
        s = self.layout.slots
        g = self.layout.page_size
        next_tok = np.zeros((s,), np.int32)
        lengths = np.zeros((s,), np.int64)
        eff_max: dict[int, int] = {}
        admit_seq: dict[int, int] = {}   # slot -> admission order (victim pick)
        prefilling: dict[int, dict] = {}  # slot -> {"ctx": (T,) np, "off": int}
        n_admitted = 0
        clock = 0.0
        key = jax.random.PRNGKey(gen.seed)
        arrivals = deque(sorted(requests, key=lambda r: r.arrival_time))
        completed: list[Request] = []
        util, active_hist, step_times = [], [], []
        steps = 0
        prefill_computed = 0    # prefill tokens actually run through the model
        prefill_skipped = 0     # prefill tokens served from adopted pages
        cow_splits = 0

        def finish(slot: int):
            req = sched.active[slot]
            req.t_done = clock
            eff_max.pop(req.rid, None)
            completed.append(sched.finish(slot))

        def take_first_token(slot: int, tok0: int, tl: int):
            """Record a request's first sampled token after its prefill."""
            req = sched.active[slot]
            if req.t_admitted is None:
                req.t_admitted = req.t_first_token = clock
            req.out_tokens.append(tok0)
            next_tok[slot] = tok0
            lengths[slot] = tl
            if (gen.eos_id >= 0 and tok0 == gen.eos_id) or \
                    req.done_tokens >= eff_max[req.rid]:
                finish(slot)

        with self._ctx():
            while arrivals or sched.has_work:
                while arrivals and arrivals[0].arrival_time <= clock:
                    sched.submit(arrivals.popleft())

                # idle engine: jump the clock to the next arrival
                if not sched.has_work:
                    clock = max(clock, arrivals[0].arrival_time)
                    continue

                # FCFS admission: chunked mode queues the prompt for
                # interleaved chunk prefill; classic mode prefills the whole
                # context in one shot (a preempted request resumes by
                # prefilling its full context either way)
                while (req := sched.admissible()) is not None:
                    slot = sched.admit(req)
                    admit_seq[slot] = n_admitted
                    n_admitted += 1
                    ctx_toks = req.context_tokens()
                    tl = len(ctx_toks)
                    eff_max[req.rid] = req.done_tokens + min(
                        req.max_new_tokens - req.done_tokens,
                        self.layout.tokens_per_slot - tl + 1)
                    if self.prefill_chunk:
                        # adopted prefix pages skip their prefill compute;
                        # chunks cover [prefix_hit_tokens, tl)
                        prefilling[slot] = {"ctx": ctx_toks,
                                            "off": req.prefix_hit_tokens}
                        lengths[slot] = req.prefix_hit_tokens
                        prefill_skipped += req.prefix_hit_tokens
                        continue
                    toks = np.zeros((1, self._bucket(tl)), np.int32)
                    toks[0, :tl] = ctx_toks
                    t0 = time.monotonic()
                    logits, state = self._prefill(
                        self.params, jnp.asarray(toks), state,
                        jnp.asarray(slot, jnp.int32),
                        sched.alloc.table()[slot],
                        jnp.asarray(tl, jnp.int32))
                    key, sub = jax.random.split(key)
                    tok = self._sample(logits, sub, gen)
                    tok0 = int(jax.block_until_ready(tok)[0])
                    clock += time.monotonic() - t0
                    prefill_computed += tl
                    take_first_token(slot, tok0, tl)

                # interleaved chunk prefill: up to prefill_budget tokens per
                # engine step, FCFS over mid-prefill slots; a slot joins the
                # decode batch the step after its final chunk
                progressed = False
                budget = self.prefill_budget
                while budget > 0 and prefilling:
                    slot = min(prefilling, key=admit_seq.__getitem__)
                    cur = prefilling[slot]
                    ctx_toks, off = cur["ctx"], cur["off"]
                    tl = len(ctx_toks)
                    c = self.prefill_chunk
                    clen = min(c, tl - off)
                    toks = np.zeros((1, c), np.int32)
                    toks[0, :clen] = ctx_toks[off:off + clen]
                    t0 = time.monotonic()
                    logits, state = self._prefill_chunk(
                        self.params, jnp.asarray(toks), state,
                        jnp.asarray(slot, jnp.int32),
                        sched.alloc.table()[slot],
                        jnp.asarray(off, jnp.int32),
                        jnp.asarray(clen, jnp.int32))
                    progressed = True
                    budget -= clen
                    prefill_computed += clen
                    cur["off"] = off + clen
                    lengths[slot] = off + clen
                    if cur["off"] >= tl:
                        # final chunk: its last-token logits seed decode
                        key, sub = jax.random.split(key)
                        tok = self._sample(logits, sub, gen)
                        tok0 = int(jax.block_until_ready(tok)[0])
                        clock += time.monotonic() - t0
                        del prefilling[slot]
                        sched.register_prefix(slot)
                        take_first_token(slot, tok0, tl)
                    else:
                        jax.block_until_ready(logits)
                        clock += time.monotonic() - t0

                if not sched.active:
                    if sched.pending and sched.admissible() is None:
                        # nothing running and the queue head can't fit:
                        # future arrivals can't free pages, so either wait
                        # them out (clock jump) or fail loudly
                        if arrivals:
                            clock = max(clock, arrivals[0].arrival_time)
                            continue
                        raise RuntimeError(
                            "pool cannot fit a single pending request "
                            "(num_pages too small)")
                    continue

                # batched decode step over non-stalled, fully-prefilled slots
                stalled = set(sched.ensure_pages(lengths,
                                                 skip=prefilling.keys()))
                step_slots = [sl for sl in sched.active
                              if sl not in stalled and sl not in prefilling]

                # copy-on-write guard: never append into a shared page.
                # Chunk-aligned adoption makes this a no-op in steady state
                # (adopted pages all precede the write frontier), but it is
                # the invariant that keeps sharing safe under any adoption
                # policy (DESIGN.md §12).
                if step_slots and (self.prefix_cache or cow_splits):
                    safe = []
                    for sl in step_slots:
                        pidx = int(lengths[sl]) // g
                        if (pidx < sched.alloc.slot_pages(sl) and
                                sched.alloc.refcount(
                                    sched.alloc.page_at(sl, pidx)) > 1):
                            if not sched.alloc.can_alloc(1):
                                sched.reclaim(1)
                            if not sched.alloc.can_alloc(1):
                                stalled.add(sl)
                                continue
                            src, dst = sched.alloc.cow(sl, pidx)
                            state = self._copy_pages(
                                state, jnp.asarray(src, jnp.int32),
                                jnp.asarray(dst, jnp.int32))
                            cow_splits += 1
                        safe.append(sl)
                    step_slots = safe

                if not step_slots:
                    if progressed:
                        continue   # chunk prefill advanced; decode retries
                    # every slot needs a page and the pool is dry:
                    # recompute-preempt the most recent admission so the
                    # rest make progress
                    victim = max(sched.active, key=admit_seq.__getitem__)
                    vreq = sched.active[victim]
                    if vreq.preemptions >= 64:
                        raise RuntimeError(
                            "request thrashing on preemption — pool too "
                            "small to finish any request")
                    # mid-prefill slots can't be victims: chunk work always
                    # progresses when any exist, and progress skips this
                    # branch entirely
                    assert victim not in prefilling
                    if vreq.out_tokens:
                        vreq.out_tokens.pop()   # un-fed; re-sampled on resume
                    eff_max.pop(vreq.rid, None)
                    sched.preempt(victim)
                    continue
                mask = np.zeros((s,), bool)
                mask[step_slots] = True
                # width-slice the page table to the live pages of this
                # step's batch: the decode step then reads O(live tokens)
                # instead of O(pool capacity) (one compile per pow2 bucket)
                w = self._step_width(
                    max(int(lengths[sl]) // self.layout.page_size + 1
                        for sl in step_slots))
                t0 = time.monotonic()
                logits, state = self._decode(
                    self.params, state, jnp.asarray(next_tok),
                    sched.alloc.table()[:, :w], jnp.asarray(mask))
                key, sub = jax.random.split(key)
                toks = np.asarray(
                    jax.block_until_ready(self._sample(logits, sub, gen)))
                step_s = time.monotonic() - t0
                clock += step_s
                steps += 1
                step_times.append(step_s)
                util.append(sched.utilization())
                active_hist.append(len(step_slots))

                for sl in step_slots:
                    lengths[sl] += 1
                    req = sched.active[sl]
                    t = int(toks[sl])
                    req.out_tokens.append(t)
                    next_tok[sl] = t
                    if (gen.eos_id >= 0 and t == gen.eos_id) or \
                            req.done_tokens >= eff_max[req.rid]:
                        finish(sl)

        total_tokens = sum(r.done_tokens for r in completed)
        lats = sorted(r.latency() for r in completed)

        def pct(p):
            if not lats:
                return 0.0
            return lats[min(int(p / 100 * len(lats)), len(lats) - 1)]

        res = {
            "requests": completed,
            "total_tokens": total_tokens,
            "wall_s": clock,
            "tokens_per_s": total_tokens / max(clock, 1e-9),
            "p50_latency_s": pct(50),
            "p99_latency_s": pct(99),
            "decode_steps": steps,
            "decode_step_s_mean": float(np.mean(step_times)) if step_times
            else 0.0,
            "decode_step_s_p50": float(np.median(step_times)) if step_times
            else 0.0,
            "decode_backend": self.model.cfg.decode_backend,
            "mean_active_slots": float(np.mean(active_hist)) if active_hist
            else 0.0,
            "mean_page_utilization": float(np.mean(util)) if util else 0.0,
            "cache_bytes": _tree_bytes(state),
            "cache_bytes_per_layer": (
                self.model.cache_layer_bytes(state)
                if self.model.cache_layer_bytes else None),
            "prefill_chunk": self.prefill_chunk,
            "prefix_cache": self.prefix_cache,
            "prefill_tokens_computed": prefill_computed,
            "prefill_tokens_skipped": prefill_skipped,
            "prefix_hit_rate": prefill_skipped / max(
                prefill_skipped + prefill_computed, 1),
            "adopted_pages": sched.adopted_pages,
            "fresh_pages": sched.fresh_pages,
            "cow_splits": cow_splits,
        }
        if prefix is not None:
            from repro.core import paged_cache as pgc
            page_bytes = sum(pgc.pool_page_bytes(c) for c in state)
            res["pool_page_bytes"] = page_bytes
            res["prefix_pool_bytes_saved"] = sched.adopted_pages * page_bytes
            res["prefix_index"] = {
                "entries": len(prefix), "queries": prefix.queries,
                "evictions": prefix.evictions,
            }
        return res
