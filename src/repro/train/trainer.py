"""Trainer: loop + fault tolerance (checkpoint/restart, preemption,
straggler watchdog) around make_train_step.

Fault-tolerance contract:
  * checkpoints save (params, optimizer, data state) with an atomic
    manifest; restart resumes at the exact step with the exact next batch
    (the data pipeline is deterministic in (seed, step));
  * SIGTERM triggers an emergency checkpoint at the next step boundary
    (preemption tolerance);
  * a wall-clock watchdog flags straggling steps (> ``straggler_factor`` x
    the trailing median) — at scale this is the hook for re-sharding or
    hot-spare swap; here it logs and records the event;
  * checkpoints are mesh-agnostic: restarting on a different device count
    re-shards on restore (elastic scaling).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint
from repro.data.pipeline import SyntheticLMDataset
from repro.models.registry import Model
from repro.train.train_step import (StepConfig, TrainState, init_train_state,
                                    make_train_step)


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    seed: int = 0


class Trainer:
    def __init__(self, model: Model, dataset: SyntheticLMDataset,
                 cfg: TrainerConfig, step_cfg: StepConfig = StepConfig(),
                 mesh=None, log_fn: Callable[[str], None] = print):
        self.model = model
        self.dataset = dataset
        self.cfg = cfg
        self.step_cfg = step_cfg
        self.mesh = mesh
        self.log = log_fn
        self.step_fn = make_train_step(
            model, mesh, step_cfg, global_batch=dataset.global_batch)
        self.ckpt = CheckpointManager(cfg.checkpoint_dir,
                                      keep=cfg.keep_checkpoints)
        self.straggler_events: list[int] = []
        self._durations: list[float] = []
        self.step = 0
        self.state: Optional[TrainState] = None

    # -- state ----------------------------------------------------------
    def init_or_restore(self) -> TrainState:
        last = latest_step(self.cfg.checkpoint_dir)
        if last is not None:
            shapes = jax.eval_shape(
                lambda k: init_train_state(self.model, k),
                jax.random.PRNGKey(self.cfg.seed))
            shardings = getattr(self.step_fn, "state_shardings", None)
            self.state, extra = restore_checkpoint(
                self.cfg.checkpoint_dir, last, shapes, shardings)
            self.step = int(extra["step"])
            self.dataset.state.step = int(extra["data_step"])
            self.log(f"[trainer] restored step={self.step} "
                     f"(elastic: {jax.device_count()} devices)")
        else:
            self.state = init_train_state(self.model,
                                          jax.random.PRNGKey(self.cfg.seed))
            if self.mesh is not None and hasattr(self.step_fn, "state_shardings"):
                self.state = jax.device_put(self.state,
                                            self.step_fn.state_shardings)
        return self.state

    def _save(self):
        self.ckpt.save(self.step, self.state,
                       extra={"step": self.step,
                              "data_step": self.dataset.state.step})

    # -- loop ------------------------------------------------------------
    def run(self) -> dict:
        if self.state is None:
            self.init_or_restore()
        history = []
        while self.step < self.cfg.total_steps:
            batch = self.dataset.next_batch()
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            t0 = time.monotonic()
            self.state, metrics = self.step_fn(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            dur = time.monotonic() - t0
            self.step += 1
            history.append(float(metrics["loss"]))

            # straggler watchdog
            if len(self._durations) >= 5:
                med = statistics.median(self._durations[-20:])
                if dur > self.cfg.straggler_factor * med:
                    self.straggler_events.append(self.step)
                    self.log(f"[trainer] straggler at step {self.step}: "
                             f"{dur:.3f}s vs median {med:.3f}s")
            self._durations.append(dur)

            if self.step % self.cfg.log_every == 0:
                self.log(f"[trainer] step={self.step} "
                         f"loss={float(metrics['loss']):.4f} "
                         f"gnorm={float(metrics['grad_norm']):.3f} "
                         f"lr={float(metrics['lr']):.2e} {dur*1e3:.0f}ms")
            if self.step % self.cfg.checkpoint_every == 0:
                self._save()
            if self.ckpt.maybe_emergency_save(
                    self.step, self.state,
                    extra={"step": self.step,
                           "data_step": self.dataset.state.step}):
                self.log("[trainer] preemption checkpoint written; exiting")
                break
        if self.step % self.cfg.checkpoint_every:
            self._save()
        return {"losses": history, "stragglers": self.straggler_events,
                "final_step": self.step}
