"""Train-step construction: grad accumulation, remat, AdamW, sharding.

``make_train_step`` returns a jit'd (state, batch) -> (state, metrics) with
donated state, parameter/optimizer shardings resolved from
distributed/sharding.py, and activations constrained via the ctx logical
rules. Gradient accumulation scans over microbatches (bounds activation
memory; grads accumulate in param-sharded fp32 buffers).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import ctx
from repro.distributed import sharding as shd
from repro.models.registry import Model
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule

Array = jax.Array


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


class StepConfig(NamedTuple):
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    microbatches: int = 1
    remat: str = "block"
    ce_chunk: int = 512        # 0 => unchunked lm-head loss (A/B baseline)
    seq_shard: bool = True     # sequence-shard remat-saved activations
    param_dtype: str = "float32"  # bfloat16 halves grad-reduce wire bytes
                                  # (fp32 master lives in the Adam update)


def init_train_state(model: Model, key, param_dtype: str = "float32") -> TrainState:
    params = model.init(key)
    if param_dtype != "float32":
        dt = jnp.dtype(param_dtype)
        params = jax.tree_util.tree_map(lambda p: p.astype(dt), params)
    return TrainState(params=params, opt=adamw_init(params))


def train_state_pspecs(state_shapes: TrainState, mesh: Mesh,
                       cfg: ModelConfig) -> TrainState:
    pspecs = shd.param_pspecs(state_shapes.params, mesh, cfg)
    return TrainState(
        params=pspecs,
        opt=AdamWState(step=P(),
                       m=shd.param_pspecs(state_shapes.opt.m, mesh, cfg),
                       v=shd.param_pspecs(state_shapes.opt.v, mesh, cfg)))


def _loss_and_grad(model: Model, params, batch, remat: str, ce_chunk: int):
    def lf(p):
        loss, metrics = model.loss(p, batch, remat=remat, ce_chunk=ce_chunk)
        return loss, metrics
    (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
    return loss, metrics, grads


def make_train_step(model: Model, mesh: Optional[Mesh],
                    step_cfg: StepConfig = StepConfig(), *,
                    global_batch: int = 8, jit: bool = True):
    """Build the train step. With ``mesh``: fully sharded (FSDP x TP)."""
    cfg = model.cfg
    rules = (shd.logical_rules(cfg, mesh, global_batch)
             if mesh is not None else None)
    if rules is not None and not step_cfg.seq_shard:
        rules = dict(rules, seq=None)

    def step(state: TrainState, batch: dict):
        def run():
            if step_cfg.microbatches <= 1:
                loss, metrics, grads = _loss_and_grad(
                    model, state.params, batch, step_cfg.remat,
                    step_cfg.ce_chunk)
            else:
                n = step_cfg.microbatches
                micro = jax.tree_util.tree_map(
                    lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]),
                    batch)

                def body(acc, mb):
                    loss_a, metrics_a, g_a = acc
                    loss, metrics, grads = _loss_and_grad(
                        model, state.params, mb, step_cfg.remat,
                        step_cfg.ce_chunk)
                    g_a = jax.tree_util.tree_map(jnp.add, g_a, grads)
                    return (loss_a + loss,
                            jax.tree_util.tree_map(jnp.add, metrics_a, metrics),
                            g_a), None

                # microbatch 0 outside the scan fixes the metric/grad trees
                loss0, metrics0, g0 = _loss_and_grad(
                    model, state.params,
                    jax.tree_util.tree_map(lambda x: x[0], micro),
                    step_cfg.remat, step_cfg.ce_chunk)
                rest = jax.tree_util.tree_map(lambda x: x[1:], micro)
                (loss, metrics, grads), _ = jax.lax.scan(
                    body, (loss0, metrics0, g0), rest)
                inv = 1.0 / n
                loss = loss * inv
                metrics = jax.tree_util.tree_map(lambda x: x * inv, metrics)
                grads = jax.tree_util.tree_map(lambda g: g * inv, grads)

            lr = cosine_schedule(state.opt.step, step_cfg.peak_lr,
                                 step_cfg.warmup_steps, step_cfg.total_steps)
            params, opt, om = adamw_update(
                state.params, grads, state.opt, lr,
                weight_decay=step_cfg.weight_decay,
                clip_norm=step_cfg.clip_norm)
            metrics = dict(metrics, loss=loss, lr=lr, **om)
            return TrainState(params, opt), metrics

        if rules is not None:
            with ctx.use_sharding(mesh, rules):
                return run()
        return run()

    if not jit:
        return step

    if mesh is None:
        return jax.jit(step, donate_argnums=(0,))

    state_shapes = jax.eval_shape(
        lambda k: init_train_state(model, k, step_cfg.param_dtype),
        jax.random.PRNGKey(0))
    state_specs = train_state_pspecs(state_shapes, mesh, cfg)
    state_shd = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), state_specs,
        is_leaf=lambda x: isinstance(x, P))
    _cache: dict = {}

    def jitted(state, batch):
        key = tuple(sorted((k, v.shape, str(v.dtype)) for k, v in batch.items()))
        if key not in _cache:
            bspecs = shd.batch_pspecs(
                {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                 for k, v in batch.items()}, mesh, global_batch)
            _cache[key] = jax.jit(
                step,
                in_shardings=(state_shd, {k: NamedSharding(mesh, s)
                                          for k, s in bspecs.items()}),
                out_shardings=(state_shd, None), donate_argnums=(0,))
        return _cache[key](state, batch)

    jitted.state_specs = state_specs      # for checkpoint/dry-run use
    jitted.state_shardings = state_shd
    return jitted


def lower_train_step(model: Model, mesh: Mesh, step_cfg: StepConfig,
                     global_batch: int, batch_specs: dict):
    """Lower (no execution) for the dry-run: returns jax.stages.Lowered."""
    cfg = model.cfg
    state_shapes = jax.eval_shape(
        lambda k: init_train_state(model, k, step_cfg.param_dtype),
        jax.random.PRNGKey(0))
    state_specs = train_state_pspecs(state_shapes, mesh, cfg)
    state_in = jax.tree_util.tree_map(
        lambda sh, sp: jax.ShapeDtypeStruct(
            sh.shape, sh.dtype, sharding=NamedSharding(mesh, sp)),
        state_shapes, state_specs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))
    bspecs = shd.batch_pspecs(batch_specs, mesh, global_batch)
    batch_in = {k: jax.ShapeDtypeStruct(
        v.shape, v.dtype, sharding=NamedSharding(mesh, bspecs[k]))
        for k, v in batch_specs.items()}

    step = make_train_step(model, mesh, step_cfg,
                           global_batch=global_batch, jit=False)
    state_shd = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), state_specs,
        is_leaf=lambda x: isinstance(x, P))
    fn = jax.jit(step, in_shardings=(state_shd, {k: NamedSharding(mesh, s)
                                                 for k, s in bspecs.items()}),
                 out_shardings=(state_shd, None), donate_argnums=(0,))
    return fn.lower(state_in, batch_in)
