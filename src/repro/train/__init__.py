"""Training: step construction, trainer loop, fault tolerance."""
from repro.train.train_step import TrainState, make_train_step, init_train_state  # noqa: F401
from repro.train.trainer import Trainer, TrainerConfig  # noqa: F401
