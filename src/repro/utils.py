"""Shared utilities: pytree dataclasses, dtype helpers, shape math."""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, TypeVar

import jax
import jax.numpy as jnp
import numpy as np

T = TypeVar("T")


def pytree_dataclass(cls: type[T]) -> type[T]:
    """A frozen dataclass registered as a JAX pytree.

    Fields annotated with ``static=True`` metadata become aux (hashable) data.
    """
    cls = dataclasses.dataclass(frozen=True)(cls)
    data_fields = [f.name for f in dataclasses.fields(cls) if not f.metadata.get("static")]
    meta_fields = [f.name for f in dataclasses.fields(cls) if f.metadata.get("static")]
    jax.tree_util.register_dataclass(cls, data_fields=data_fields, meta_fields=meta_fields)
    return cls


def static_field(**kwargs):
    return dataclasses.field(metadata={"static": True}, **kwargs)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def pow2_bucket(n: int, multiple: int) -> int:
    """Smallest power-of-two multiple of ``multiple`` that is >= n.
    Static-shape buckets: one jit per bucket instead of one per length."""
    b = multiple
    while b < n:
        b *= 2
    return b


def nearest_rank_pct(sorted_vals, p: float) -> float:
    """Nearest-rank percentile of pre-*sorted* values: the value at
    1-based rank ``ceil(p/100 * n)`` (clamped to [1, n]); 0.0 on empty.
    The single definition behind every serving latency percentile
    (request latency, TTFT, ITL) so reported numbers stay comparable."""
    n = len(sorted_vals)
    if n == 0:
        return 0.0
    rank = min(max(math.ceil(p / 100 * n), 1), n)
    return sorted_vals[rank - 1]


def tree_bytes(tree: Any) -> int:
    """Total bytes of all arrays (or ShapeDtypeStructs) in a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
    return total


def tree_params_count(tree: Any) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree)
               if hasattr(l, "shape"))


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}Q"


def split_key(key: jax.Array, n: int) -> list[jax.Array]:
    return list(jax.random.split(key, n))


@functools.lru_cache(maxsize=None)
def pow2(bits: int) -> int:
    return 1 << bits


def assert_divisible(a: int, b: int, what: str = "") -> None:
    if a % b != 0:
        raise ValueError(f"{what}: {a} is not divisible by {b}")
