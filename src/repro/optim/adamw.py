"""AdamW with decoupled weight decay, fp32 moments, global-norm clipping.

State shards exactly like the parameters (ZeRO: m/v inherit param specs).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    step: Array        # () int32
    m: Any             # pytree like params (fp32)
    v: Any             # pytree like params (fp32)


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(zeros, params),
                      v=jax.tree_util.tree_map(zeros, params))


def global_norm(tree: Any) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(params: Any, grads: Any, state: AdamWState, lr: Array, *,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m = b1 * m + (1.0 - b1) * g32
        v = b2 * v + (1.0 - b2) * g32 * g32
        mh = m / bc1
        vh = v / bc2
        p32 = p.astype(jnp.float32)
        # decoupled weight decay (skip obvious gains/biases: ndim < 2)
        wd = weight_decay if p.ndim >= 2 else 0.0
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p32)
        return p32.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}
