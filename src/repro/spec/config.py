"""Speculative-decode configuration (host-side only; no jax here)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Knobs for ``EngineCore(spec=...)`` multi-token decode.

    ``mode`` names a registered proposer ("ngram", "draft") or "off";
    ``k`` caps the draft tokens verified per dispatch, so one step
    retires 1..k+1 tokens. Speculation requires greedy sampling — the
    verifier's acceptance rule compares the target model's argmax per
    position (DESIGN.md §15).
    """

    mode: str = "off"
    k: int = 4

    # ngram proposer: match the last n in [min_ngram, max_ngram] context
    # tokens against earlier context, longest n first
    max_ngram: int = 3
    min_ngram: int = 1

    # draft-model proposer: "" derives a shrunk copy of the target config
    # (draft_layers layers, no quantization); "self" reuses the target
    # model+params (an oracle up to dense-vs-paged parity); any other
    # string is a configs registry name
    draft_arch: str = ""
    draft_layers: int = 2
    draft_seed: int = 0

    def __post_init__(self) -> None:
        if self.k < 1:
            raise ValueError(f"spec k must be >= 1, got {self.k}")
        if self.min_ngram < 1 or self.max_ngram < self.min_ngram:
            raise ValueError(
                f"need 1 <= min_ngram <= max_ngram, got "
                f"[{self.min_ngram}, {self.max_ngram}]")
