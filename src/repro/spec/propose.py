"""Draft proposers: guess the next k tokens for a decoding request.

Mirrors ``core/codecs.py``'s registry pattern: proposer *classes*
register under a name (they are stateful per engine, unlike codec
instances), ``EngineCore`` instantiates one via ``make_proposer`` and
drives it host-side — ``propose()`` runs between scheduling and the
device dispatch, so proposers must be cheap. Wrong guesses cost only
wasted verify FLOPs, never correctness: the verifier accepts exactly the
tokens the target model would have produced (spec/verify.py).

This module is host-side only. jax is allowed in spec/verify.py and
spec/draft.py (enforced by scripts/check_engine_layering.sh).
"""
from __future__ import annotations

from typing import Dict, List, Type

from repro.spec.config import SpecConfig


class DraftProposer:
    """Base/protocol for draft proposers.

    Lifecycle: constructed once per engine, ``reset()`` at each serving
    session, ``propose(req, k)`` per decode-ready request per step,
    ``release(rid)`` when a request leaves its slot (finish, cancel, or
    preempt — after a preempt the context may *shrink*, so per-request
    state must not assume monotone growth).
    """

    name: str = ""

    def __init__(self, spec: SpecConfig, *, target_cfg=None,
                 target_model=None, target_params=None,
                 max_len: int = 0) -> None:
        self.spec = spec
        self.target_cfg = target_cfg

    def reset(self) -> None:
        """Drop all per-request state (new serving session)."""

    def release(self, rid: str) -> None:
        """A request left the engine; forget its state."""

    def propose(self, req, k: int) -> List[int]:
        """Up to ``k`` draft tokens continuing ``req``'s context
        (``req.prompt`` + ``req.out_tokens``). Fewer — or none — is
        always legal."""
        raise NotImplementedError

    def feedback(self, rid, drafted: int, accepted: int) -> None:
        """Verification outcome for the last proposal (optional hook):
        ``accepted`` of ``drafted`` tokens survived. Proposers may adapt
        — draft quality only, never correctness."""


_PROPOSERS: Dict[str, Type[DraftProposer]] = {}


def register_proposer(cls: Type[DraftProposer], *,
                      overwrite: bool = False) -> Type[DraftProposer]:
    """Register a proposer class under ``cls.name`` (usable as a
    decorator, like ``register_codec``)."""
    if not cls.name:
        raise ValueError(f"{cls.__name__} needs a non-empty .name")
    if cls.name in _PROPOSERS and not overwrite:
        raise ValueError(f"proposer {cls.name!r} already registered "
                         "(pass overwrite=True to replace)")
    _PROPOSERS[cls.name] = cls
    return cls


def get_proposer(name: str) -> Type[DraftProposer]:
    try:
        return _PROPOSERS[name]
    except KeyError:
        raise KeyError(f"unknown proposer {name!r}; registered: "
                       f"{sorted(_PROPOSERS)}") from None


def list_proposers() -> List[str]:
    return sorted(_PROPOSERS)


def make_proposer(spec: SpecConfig, **kwargs) -> DraftProposer:
    """Instantiate the proposer named by ``spec.mode``."""
    return get_proposer(spec.mode)(spec, **kwargs)


@register_proposer
class NgramProposer(DraftProposer):
    """Self-speculative prompt lookup: no extra model, no device work.

    Match the last n context tokens (n = max_ngram down to min_ngram)
    against earlier positions in the request's own prompt + output; on a
    hit, propose the k tokens that followed the *most recent* earlier
    occurrence (repetition is local — code, quoting, chat boilerplate).
    Misses cost nothing: an empty proposal makes the step plain decode.

    Verification feedback drives an exponential backoff: a streak of
    fully-rejected proposals (the context repeats but the model isn't
    following the repetition) pauses drafting for ``2^streak`` steps, so
    a non-cooperating request quickly degrades to ~vanilla step cost
    instead of paying the verify premium every step. Any accepted draft
    resets the streak.

    Feedback also ramps the draft *length*: verify cost grows ~linearly
    with span width, so wide spans only pay off when acceptance is high.
    Each request starts at 2 drafts; a fully-accepted proposal doubles
    its cap (up to ``spec.k``), a partial acceptance holds it near what
    was accepted, and a full rejection resets it — a request locked into
    repetition quickly earns full-width spans while a chaotic one never
    pays for more than narrow probes.
    """

    name = "ngram"
    _max_backoff = 32
    _start_cap = 2

    def __init__(self, spec: SpecConfig, **kwargs) -> None:
        super().__init__(spec, **kwargs)
        self._cooldown: Dict[int, List[int]] = {}  # rid -> [skip, streak]
        # rid -> incremental match state: the context as a plain int list
        # plus, per ngram size n, a dict mapping the n-gram tuple to its
        # two most recent start positions (latest, previous). propose()
        # is then O(max_ngram) dict lookups instead of an O(n * len)
        # rescan of the whole context every step — the proposer bills to
        # the session clock, so it must stay microseconds-cheap.
        self._state: Dict[int, dict] = {}
        self._cap: Dict[int, int] = {}   # rid -> current draft-length cap

    def reset(self) -> None:
        self._cooldown.clear()
        self._state.clear()
        self._cap.clear()

    def release(self, rid) -> None:
        # after preempt the context shrinks; drop and lazily rebuild
        self._cooldown.pop(rid, None)
        self._state.pop(rid, None)
        self._cap.pop(rid, None)

    def feedback(self, rid, drafted: int, accepted: int) -> None:
        if drafted <= 0:
            return
        cap = self._cap.get(rid, self._start_cap)
        if accepted >= drafted:
            cap = min(cap * 2, self.spec.k)
        elif accepted > 0:
            cap = max(self._start_cap, accepted + 1)
        else:
            cap = self._start_cap
        self._cap[rid] = cap
        cd = self._cooldown.setdefault(rid, [0, 0])
        if accepted > 0:
            cd[0] = cd[1] = 0
        else:
            cd[1] += 1
            cd[0] = min(2 ** cd[1], self._max_backoff)

    def _sync(self, req) -> dict:
        """Fold tokens appended since the last call into the index."""
        st = self._state.get(req.rid)
        if st is None:
            st = self._state[req.rid] = {
                "ctx": [int(t) for t in req.prompt],
                "idx": {n: {} for n in range(self.spec.min_ngram,
                                             self.spec.max_ngram + 1)},
                "done": 0,   # indexed prefix length
            }
        ctx = st["ctx"]
        ctx.extend(int(t) for t in req.out_tokens[st.pop("_out", 0):])
        st["_out"] = len(req.out_tokens)
        idx, done = st["idx"], st["done"]
        for p in range(done, len(ctx)):
            for n, table in idx.items():
                if p + 1 >= n:
                    key = tuple(ctx[p + 1 - n:p + 1])
                    prev = table.get(key)
                    table[key] = (p + 1 - n,
                                  prev[0] if prev is not None else None)
        st["done"] = len(ctx)
        return st

    def propose(self, req, k: int) -> List[int]:
        k = min(k, self._cap.get(req.rid, self._start_cap))
        if k <= 0:
            return []
        cd = self._cooldown.get(req.rid)
        if cd is not None and cd[0] > 0:
            cd[0] -= 1
            return []
        st = self._sync(req)
        ctx = st["ctx"]
        for n in range(self.spec.max_ngram, self.spec.min_ngram - 1, -1):
            if len(ctx) <= n:
                continue
            hit = st["idx"][n].get(tuple(ctx[-n:]))
            if hit is None:
                continue
            # the latest occurrence is the suffix itself (indexed when
            # its final token arrived); the previous one is the most
            # recent *earlier* match the old linear scan would find
            i = hit[1] if hit[0] == len(ctx) - n else hit[0]
            if i is not None:
                return ctx[i + n:i + n + k]
        return []
