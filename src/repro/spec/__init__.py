"""Speculative multi-token decode (DESIGN.md §15).

Three layers: *proposers* guess the next k tokens host-side
(spec/propose.py — protocol + registry, built-in ``ngram`` and
``draft``), the *verifier* scores a whole span against the paged
quantized cache in one dispatch and commits only accepted tokens
through the vanilla append path (spec/verify.py), and ``EngineCore``
(serve/core.py) wires both into its step loop behind
``spec=SpecConfig(...)`` — greedy outputs stay bit-identical to plain
decode by construction.
"""
from repro.spec.config import SpecConfig
from repro.spec.draft import DraftModelProposer
from repro.spec.propose import (DraftProposer, NgramProposer, get_proposer,
                                list_proposers, make_proposer,
                                register_proposer)
from repro.spec.verify import (make_scan_verifier, make_span_verifier,
                               make_verifier)

__all__ = [
    "SpecConfig",
    "DraftProposer",
    "DraftModelProposer",
    "NgramProposer",
    "register_proposer",
    "get_proposer",
    "list_proposers",
    "make_proposer",
    "make_verifier",
    "make_scan_verifier",
    "make_span_verifier",
]
