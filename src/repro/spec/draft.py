"""Draft-model proposer: a small dense-cache model guesses the span.

jax is allowed here (the draft runs real forwards); everything stays on
the *draft* model's own linear cache — the target's paged state is never
touched by proposal, only by verification (spec/verify.py).

Per request the proposer keeps a batch-1 dense decode cache plus the
count of context tokens it has absorbed. Each ``propose()`` feeds the
context delta token-by-token (cheap: the delta is the last accepted
span), then greedily rolls out k guesses. The speculative guesses are
appended into the draft cache too, so before returning we rewind by
resetting the cache ``length`` back to the real context size. For
grouped codecs that rewind is lossy at group boundaries (flushed rows
aren't un-flushed) — harmless, it can only degrade future draft quality,
and the default derived draft config quantizes nothing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.spec.config import SpecConfig
from repro.spec.propose import DraftProposer, register_proposer


def _derive_draft_cfg(target_cfg, spec: SpecConfig):
    """A shrunk, unquantized copy of the target config (first
    ``draft_layers`` layers' worth of depth, same vocab so proposed ids
    are meaningful)."""
    from repro.core.quantizers import QuantConfig
    return dataclasses.replace(
        target_cfg,
        name=f"{target_cfg.name}-draft{spec.draft_layers}",
        num_layers=max(1, min(spec.draft_layers, target_cfg.num_layers)),
        cache_policy=None,
        quant=QuantConfig(method="none", value_bits=0,
                          group_size=target_cfg.quant.group_size),
        decode_backend="jnp",
        prefill_backend="jnp",
    )


@register_proposer
class DraftModelProposer(DraftProposer):
    """Classic speculative sampling's proposer half, greedy flavor."""

    name = "draft"

    def __init__(self, spec: SpecConfig, *, target_cfg=None,
                 target_model=None, target_params=None,
                 max_len: int = 0) -> None:
        super().__init__(spec, target_cfg=target_cfg)
        from repro.models.registry import get_model
        if spec.draft_arch == "self":
            if target_model is None or target_params is None:
                raise ValueError(
                    "draft_arch='self' needs the target model and params")
            self.model, self.params = target_model, target_params
        else:
            if spec.draft_arch:
                from repro.configs import get_config
                base = get_config(spec.draft_arch)
                cfg = dataclasses.replace(
                    _derive_draft_cfg(base, spec),
                    vocab_size=target_cfg.vocab_size)
            else:
                cfg = _derive_draft_cfg(target_cfg, spec)
            self.model = get_model(cfg)
            self.params = self.model.init(jax.random.PRNGKey(spec.draft_seed))
        self.max_len = int(max_len) or 4096
        self._decode = jax.jit(self.model.decode, donate_argnums=(1,))
        self._by_rid: Dict[str, Dict[str, Any]] = {}

    def reset(self) -> None:
        self._by_rid.clear()

    def release(self, rid: str) -> None:
        self._by_rid.pop(rid, None)

    @staticmethod
    def _rewind(caches, n: int):
        """Forget everything past the first ``n`` tokens by resetting the
        per-segment cache lengths (positions >= length are masked out of
        attention, so stale rows are unreachable)."""
        return tuple(
            dataclasses.replace(c, length=jnp.full_like(c.length, n))
            for c in caches)

    def propose(self, req, k: int) -> List[int]:
        ctx = [int(t) for t in req.prompt] + [int(t) for t in req.out_tokens]
        n = len(ctx)
        k = min(k, self.max_len - n)
        if k <= 0 or n == 0:
            return []
        st = self._by_rid.get(req.rid)
        if st is None or st["n"] > n:
            # fresh request, or the context shrank under us (preemption
            # retracted a token) — start over
            st = {"caches": self.model.init_decode_state(1, self.max_len),
                  "n": 0}
            self._by_rid[req.rid] = st
        caches = st["caches"]
        # absorb the context delta; the loop always runs at least once
        # (the engine emits >= 1 token between proposals), leaving
        # `logits` = the draft's prediction for the next position
        logits = None
        for t in ctx[st["n"]:]:
            logits, caches = self._decode(
                self.params, caches, jnp.full((1,), t, jnp.int32))
        st["n"] = n
        if logits is None:  # context unchanged — nothing new to say
            st["caches"] = caches
            return []
        out: List[int] = []
        while True:
            out.append(int(np.asarray(jnp.argmax(logits[0]))))
            if len(out) >= k:
                break
            logits, caches = self._decode(
                self.params, caches, jnp.full((1,), out[-1], jnp.int32))
        st["caches"] = self._rewind(caches, n)
        return out
