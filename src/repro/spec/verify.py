"""Score + accept + commit a draft span in one device dispatch.

The verifier is the correctness heart of speculative decode (DESIGN.md
§15). Whatever the implementation, the contract is fixed: score the Q =
k+1 span token columns (column 0 the step's real next token, columns
1..k the zero-padded drafts) against the slot's paged quantized context
*without mutating it*, compute the greedy longest-matching-prefix
acceptance on device, and commit exactly the accepted positions through
the standard append path — rejected positions never touch the committed
state, so the group-residual/flush invariants hold by construction and
no rollback machinery exists anywhere.

Two interchangeable implementations, both bitwise faithful to vanilla
greedy decode:

* **Span verifier** (:func:`make_span_verifier`, the production path for
  the ``"jnp"`` decode backend) — ONE batched forward over all Q span
  positions (``model.verify_span``): projections/FFN/logits run with the
  span folded into the row axis, attention reproduces the sequential
  per-position decode view exactly (residual-dtype rounding of span
  keys, per-position masks, the at-most-one group-boundary flush — see
  ``paged_cache.span_verify_attention``), and the commit is one fused
  multi-row append (``model.commit_span``). Cost is ~flat in Q — the
  reason a spec step can beat Q vanilla steps.
* **Scan verifier** (:func:`make_scan_verifier`, the reference oracle
  and the fallback for non-``"jnp"`` decode backends) — ``lax.scan`` of
  the *exact* vanilla decode-step graph (``model.decode_paged_collect``)
  over the token columns on a throwaway cache copy, then a masked
  per-position commit scan (``model.commit_paged``). Trivially bitwise —
  it IS the vanilla graph — but does Q sequential forwards, so it never
  beats plain decode; it exists to prove the span verifier right
  (tests/test_spec_decode.py asserts span == scan bit-for-bit).

Acceptance (shared): ``n_acc = Σ cumprod(draft_j == argmax_j)`` over the
real draft columns. The accepted span is column 0's token plus the first
``n_acc`` drafts; their argmaxes (``n_acc + 1`` of them) are the emitted
tokens, exactly the tokens vanilla greedy decode would emit.

Out-of-range span positions (drafts beyond the slot's allocated pages)
are safe: unassigned page-table entries point at the pool's scratch
page, verification is read-only (span) or writes only a discarded copy
(scan), and the commit masks to ``active & (position <= n_acc)`` — the
committed state only ever receives positions the scheduler allocated
pages for.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _accept(tokens, preds, draft_len):
    """Greedy longest-matching-prefix: n_acc (S,) accepted drafts."""
    s, q = tokens.shape
    if q <= 1:
        return jnp.zeros((s,), jnp.int32)
    match = (tokens[:, 1:] == preds[:, :q - 1]) & (
        jnp.arange(q - 1)[None, :] < draft_len[:, None])
    return jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1),
                   axis=1).astype(jnp.int32)


def make_verifier(model, *, force_scan: bool = False):
    """Build ``verify(params, caches, tokens, draft_len, page_table,
    active) -> (preds, n_acc, caches)`` for a registry model.

    tokens: (S, Q) int32 — column 0 the real next token, 1..k drafts
    (zero-padded); draft_len: (S,) int32 valid-draft counts; page_table:
    (S, W) int32; active: (S,) bool. Returns preds (S, Q) target argmaxes
    per span position, n_acc (S,) accepted-draft counts, and the
    committed caches. Jit with ``donate_argnums=(1,)``.

    Picks the batched span verifier when the model has one and decodes
    through the ``"jnp"`` reference backend (whose gathered formulation
    the span attention reproduces bit-for-bit); any other backend — or
    ``force_scan`` — gets the sequential scan verifier, which shares the
    vanilla decode graph whatever the backend.
    """
    if (not force_scan and model.verify_span is not None
            and model.commit_span is not None
            and model.cfg.decode_backend == "jnp"):
        return make_span_verifier(model)
    return make_scan_verifier(model)


def make_span_verifier(model):
    """Batched verifier: one span forward + one fused span commit."""
    if model.verify_span is None or model.commit_span is None:
        raise ValueError(
            f"model {model.cfg.name!r} has no batched speculative verify "
            "path (verify_span/commit_span are unset)")

    def verify(params, caches, tokens, draft_len, page_table, active):
        logits, kvs = model.verify_span(params, caches, tokens,
                                        page_table, active)
        preds = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (S, Q)
        n_acc = _accept(tokens, preds, draft_len)
        n_keep = jnp.where(active, n_acc + 1, 0)
        caches = model.commit_span(caches, kvs, page_table, n_keep)
        return preds, n_acc, caches

    return verify


def make_scan_verifier(model):
    """Sequential reference verifier: scan the vanilla decode graph."""
    if model.decode_paged_collect is None or model.commit_paged is None:
        raise ValueError(
            f"model {model.cfg.name!r} has no speculative verify path "
            "(decode_paged_collect/commit_paged are unset)")

    def verify(params, caches, tokens, draft_len, page_table, active):
        def vstep(carry, tok):
            logits, carry, kvs = model.decode_paged_collect(
                params, carry, tok, page_table, active)
            return carry, (jnp.argmax(logits, axis=-1).astype(jnp.int32),
                           kvs)

        _, (preds, kvs) = jax.lax.scan(vstep, caches, tokens.T)
        preds = preds.T  # (S, Q)
        n_acc = _accept(tokens, preds, draft_len)

        def cstep(carry, xs):
            kv_j, j = xs
            keep = active & (j <= n_acc)
            return model.commit_paged(carry, kv_j, page_table, keep), None

        q = tokens.shape[1]
        caches, _ = jax.lax.scan(cstep, caches, (kvs, jnp.arange(q)))
        return preds, n_acc, caches

    return verify
