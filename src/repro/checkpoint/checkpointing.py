"""Sharded, mesh-agnostic checkpointing (no orbax dependency).

Design for fault tolerance at scale (DESIGN.md §4):

* Each host writes the *addressable* shards of every array into its own
  ``shards-<process>.npz``, keyed by ``<leaf-path>@<offset-tuple>`` — a host
  never touches another host's data (no cross-host traffic at save).
* A ``manifest.json`` (written last, atomically via rename, by process 0)
  records the tree structure, global shapes/dtypes and the step. A
  checkpoint without a manifest is invisible to ``latest_step`` — torn
  writes from preemption are never restored.
* Restore is **mesh-agnostic / elastic**: global arrays are reassembled
  from shard offsets and re-sharded onto whatever mesh/sharding the new job
  requests (device count may differ from the saving job).
* ``CheckpointManager`` adds retention, preemption (SIGTERM) emergency
  saves, and best-effort fsync.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import signal
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np

SEP = "::"


def _flatten_with_paths(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Save ``tree`` (pytree of jax.Array/np.ndarray) for ``step``."""
    ckpt_dir = os.path.join(directory, f"step_{step:010d}")
    os.makedirs(ckpt_dir, exist_ok=True)
    pidx = jax.process_index()

    shards: dict[str, np.ndarray] = {}
    meta: dict[str, dict] = {}
    for key, leaf in _flatten_with_paths(tree):
        if leaf is None:
            continue
        arr = leaf
        meta[key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        if isinstance(arr, jax.Array) and hasattr(arr, "addressable_shards"):
            seen = set()
            for sh in arr.addressable_shards:
                if sh.replica_id != 0:
                    continue
                offs = tuple(s.start or 0 for s in sh.index)
                if offs in seen:
                    continue
                seen.add(offs)
                shards[f"{key}@{','.join(map(str, offs))}"] = np.asarray(sh.data)
        else:
            if pidx == 0:
                shards[f"{key}@{','.join(['0'] * max(arr.ndim, 0))}"] = \
                    np.asarray(arr)

    shard_path = os.path.join(ckpt_dir, f"shards-{pidx:05d}.npz")
    with tempfile.NamedTemporaryFile(dir=ckpt_dir, delete=False) as tmp:
        np.savez(tmp, **shards)
        tmp.flush()
        os.fsync(tmp.fileno())
        tmp_name = tmp.name
    os.replace(tmp_name, shard_path)

    if pidx == 0:
        manifest = {"step": step, "leaves": meta, "extra": extra or {},
                    "process_count": jax.process_count()}
        mpath = os.path.join(ckpt_dir, "manifest.json")
        with tempfile.NamedTemporaryFile("w", dir=ckpt_dir, delete=False) as tmp:
            json.dump(manifest, tmp)
            tmp.flush()
            os.fsync(tmp.fileno())
            tmp_name = tmp.name
        os.replace(tmp_name, mpath)   # manifest lands last => atomic commit
    return ckpt_dir


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.match(r"step_(\d+)$", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target: Any,
                       shardings: Any = None) -> tuple[Any, dict]:
    """Restore onto ``target``'s tree structure (elastic: any mesh size).

    ``shardings``: optional matching tree of NamedSharding to place leaves;
    None leaves them as host numpy committed to default device placement.
    Returns (tree, extra).
    """
    ckpt_dir = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        manifest = json.load(f)

    # load every shard file (restore may run on fewer/more hosts than save)
    blobs: dict[str, np.ndarray] = {}
    for name in sorted(os.listdir(ckpt_dir)):
        if name.startswith("shards-") and name.endswith(".npz"):
            with np.load(os.path.join(ckpt_dir, name)) as z:
                for k in z.files:
                    blobs[k] = z[k]

    assembled: dict[str, np.ndarray] = {}
    for key, info in manifest["leaves"].items():
        full = np.zeros(info["shape"], dtype=np.dtype(info["dtype"]))
        for bk, arr in blobs.items():
            base, offs = bk.rsplit("@", 1)
            if base != key:
                continue
            off = tuple(int(o) for o in offs.split(",")) if offs else ()
            idx = tuple(slice(o, o + s) for o, s in zip(off, arr.shape))
            full[idx] = arr
        assembled[key] = full

    flat_target = _flatten_with_paths(target)
    treedef = jax.tree_util.tree_structure(target)
    leaves = []
    flat_shardings = (jax.tree_util.tree_leaves(shardings)
                      if shardings is not None else [None] * len(flat_target))
    for (key, tgt), shd in zip(flat_target, flat_shardings):
        if key not in assembled:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = assembled[key]
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]


class CheckpointManager:
    """Retention + SIGTERM emergency save (preemption tolerance)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._preempted = threading.Event()
        self._last: Optional[tuple[int, Any, dict]] = None
        try:
            signal.signal(signal.SIGTERM, self._on_sigterm)
        except ValueError:
            pass  # not in main thread (tests)

    def _on_sigterm(self, *_):
        self._preempted.set()

    @property
    def preempted(self) -> bool:
        return self._preempted.is_set()

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        path = save_checkpoint(self.directory, step, tree, extra)
        self._gc()
        return path

    def maybe_emergency_save(self, step: int, tree: Any,
                             extra: Optional[dict] = None) -> bool:
        if self._preempted.is_set():
            save_checkpoint(self.directory, step, tree, extra)
            return True
        return False

    def _gc(self):
        if jax.process_index() != 0:
            return
        steps = sorted(
            int(m.group(1)) for name in os.listdir(self.directory)
            if (m := re.match(r"step_(\d+)$", name)))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)
