"""Table 8 analog: PolarQuant composed with SnapKV-style eviction."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, rope_structured_keys
from repro.core.eviction import snapkv_select
from repro.core.quantizers import QuantConfig, decode_keys, encode_keys


def _attn(q, k, v, mask=None):
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhtd->bhqt", q * d ** -0.5, k)
    if mask is not None:
        s = jnp.where(mask[:, :, None, :], s, -1e30)
    return jnp.einsum("bhqt,bhtd->bhqd", jax.nn.softmax(s, -1), v)


def run() -> None:
    key = jax.random.PRNGKey(0)
    b, h, t, d = 2, 4, 4096, 128
    k = rope_structured_keys(key, b, h, t, d)
    v = jax.random.normal(jax.random.PRNGKey(1), (b, h, t, d))
    q = jax.random.normal(jax.random.PRNGKey(2), (b, h, 8, d))
    obs = 32
    q_obs = jax.random.normal(jax.random.PRNGKey(3), (b, h, obs, d))
    o_full = _attn(q, k, v)

    cfg = QuantConfig(method="polar", rho_bits=4, theta_bits=4, group_size=128)
    kq = decode_keys(encode_keys(k, cfg))
    for budget in (1024, 2048):
        mask = snapkv_select(q_obs, k, budget, obs)
        for name, keys in [("snapkv", k), ("snapkv_polar", kq)]:
            o = _attn(q, keys, v, mask)
            err = float(jnp.linalg.norm(o - o_full) / jnp.linalg.norm(o_full))
            emit(f"eviction/{name}/budget{budget}", 0.0, f"attn_rel={err:.4f}")
    # quantization-only reference row
    err_q = float(jnp.linalg.norm(_attn(q, kq, v) - o_full)
                  / jnp.linalg.norm(o_full))
    emit("eviction/polar_only/full", 0.0, f"attn_rel={err_q:.4f}")


if __name__ == "__main__":
    run()
