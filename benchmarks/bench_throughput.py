"""Table 4 (bottom) analog: end-to-end decode throughput + cache memory.

Runs the serving engine on a tiny model (CPU) across cache policies; the
tokens/s column is CPU-relative, the cache-bytes column is absolute and
matches the paper's Mem. column mechanism (the KV cache is what bounds the
max batch at 32K context).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config, reduce_for_smoke
from repro.models import get_model
from repro.serve import GenerationConfig, ServeEngine


def run() -> None:
    base = reduce_for_smoke(get_config("tinyllama-1.1b"))
    prompts = {"tokens": np.random.default_rng(0).integers(
        0, base.vocab_size, (8, 128)).astype(np.int32)}
    params = None
    for name, method, vbits in [("fp16", "none", 0),
                                ("kivi4", "kivi", 0),
                                ("polar44", "polar", 0),
                                ("polar44_v2", "polar", 2),
                                ("polar33", "polar", 0)]:
        qc = dataclasses.replace(base.quant, method=method, value_bits=vbits)
        if name == "polar33":
            qc = dataclasses.replace(qc, rho_bits=3, theta_bits=3)
        cfg = dataclasses.replace(base, quant=qc)
        m = get_model(cfg)
        if params is None:
            params = m.init(jax.random.PRNGKey(0))
        eng = ServeEngine(m, params, max_len=512)
        out = eng.generate(prompts, GenerationConfig(max_new_tokens=16))
        out = eng.generate(prompts, GenerationConfig(max_new_tokens=16))
        emit(f"throughput/{name}",
             out["decode_s"] / 15 * 1e6,
             f"tok_per_s={out['tokens_per_s']:.1f};"
             f"cache_bytes={out['cache_bytes']}")


if __name__ == "__main__":
    run()
