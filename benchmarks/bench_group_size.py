"""Table 5 analog: group size g ablation (error + effective bits)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import attention_output_error, emit, rope_structured_keys
from repro.core.quantizers import (QuantConfig, decode_keys, encode_keys)


def run() -> None:
    key = jax.random.PRNGKey(0)
    b, h, t, d = 2, 4, 2048, 128
    k = rope_structured_keys(key, b, h, t, d)
    q = jax.random.normal(jax.random.PRNGKey(1), (b, h, 8, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, h, t, d))
    for g in (32, 64, 128, 256):
        for method in ("polar", "kivi"):
            cfg = QuantConfig(method=method, rho_bits=4, theta_bits=4,
                              key_bits=4, group_size=g)
            kt = decode_keys(encode_keys(k, cfg))
            rec = float(jnp.linalg.norm(k - kt) / jnp.linalg.norm(k))
            att = attention_output_error(q, k, kt, v)
            emit(f"group_size/{method}/g{g}", 0.0,
                 f"bits={cfg.key_bits_per_element(d):.2f};rec_rel={rec:.4f};"
                 f"attn_rel={att:.4f}")


if __name__ == "__main__":
    run()
