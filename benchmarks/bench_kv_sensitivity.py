"""Table 7/9 analog: key-vs-value quantization sensitivity."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, rope_structured_keys
from repro.core.quantizers import (QuantConfig, decode_keys, decode_values,
                                   encode_keys, encode_values)


def _attn(q, k, v, scale):
    s = jnp.einsum("bhqd,bhtd->bhqt", q * scale, k)
    return jnp.einsum("bhqt,bhtd->bhqd", jax.nn.softmax(s, -1), v)


def run() -> None:
    key = jax.random.PRNGKey(0)
    b, h, t, d = 2, 4, 2048, 128
    k = rope_structured_keys(key, b, h, t, d)
    q = jax.random.normal(jax.random.PRNGKey(1), (b, h, 8, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, h, t, d))
    scale = d ** -0.5
    o_ref = _attn(q, k, v, scale)

    kt4 = decode_keys(encode_keys(k, QuantConfig(method="polar", rho_bits=4,
                                                 theta_bits=4, group_size=128)))
    kt2 = decode_keys(encode_keys(k, QuantConfig(method="polar", rho_bits=2,
                                                 theta_bits=2, group_size=128)))
    vt4 = decode_values(encode_values(v, 4))
    vt2 = decode_values(encode_values(v, 2))

    cases = {
        "K16_V16": (k, v), "K16_V4": (k, vt4), "K16_V2": (k, vt2),
        "K4_V16": (kt4, v), "K4_V4": (kt4, vt4), "K4_V2": (kt4, vt2),
        "K2_V16": (kt2, v),
    }
    for name, (kk, vv) in cases.items():
        err = float(jnp.linalg.norm(_attn(q, kk, vv, scale) - o_ref)
                    / jnp.linalg.norm(o_ref))
        emit(f"kv_sensitivity/{name}", 0.0, f"attn_rel={err:.4f}")


if __name__ == "__main__":
    run()
