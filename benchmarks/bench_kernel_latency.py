"""Table 4 / Figure 3 analog: query-key decode kernel latency.

The paper times Triton kernels on GPU across (batch, context). On this
CPU container we time the *jit-compiled jnp paths* (fp16-style dense QK,
dequant-then-matmul, and the LUT path) as a relative-structure check, and
report the analytic TPU bytes-moved model that the real kernel's roofline
win comes from (memory-bound decode: bytes ~ latency).

Columns: wall-clock us/call (CPU, relative only) + derived per-token HBM
bytes for a v5e (absolute, the quantity that sets TPU decode latency).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, rope_structured_keys, time_fn
from repro.core.quantizers import QuantConfig, encode_polar_keys
from repro.core import lut as lut_mod
from repro.core.quantizers import decode_polar_keys

# Llama-3.1-8B attention geometry (paper §4.2): 32 q heads, 8 kv heads, d=128
QH, HKV, D = 32, 8, 128


def hbm_bytes_per_layer(t: int, b: int, method: str, g: int = 128) -> int:
    """Bytes read from HBM per decode step for the K-score pass (per layer)."""
    pairs = D // 2
    if method == "fp16":
        per_tok = D * 2
    elif method == "kivi4":
        per_tok = D // 2 + 4 * D * 2 // g      # 4-bit codes + fp16 z/s per group
    elif method == "polar44":
        per_tok = pairs + 4 * pairs * 2 * 2 // g  # packed u8/pair + 4 fp16 stats
    elif method == "polar33":
        per_tok = (pairs * 6 + 7) // 8 + 4 * pairs * 2 * 2 // g
    else:
        raise ValueError(method)
    return b * HKV * t * per_tok


def run_paged() -> None:
    """Decode-step latency split on the paged cache: the gather_view copy
    vs the attention math, gathered vs page-native.

    Live context is held fixed while the pool *capacity* sweeps: the
    gathered path re-materializes every slot's full capacity each step
    (cost grows with the sweep), the page-native path walks only the live
    pages through a width-sliced table (cost flat). This is the structural
    O(capacity) -> O(live tokens) claim, measured.
    """
    import functools as ft

    import numpy as np

    from repro.core import paged_cache as pgc
    from repro.core.cache_layout import PageAllocator, PagedLayout
    from repro.core.quantizers import QuantConfig
    from repro.utils import pow2_bucket

    g = 64
    slots, live = 4, 256
    cfg = QuantConfig(method="polar", group_size=g, value_bits=4)
    for cap_tokens in (1024, 4096, 8192):
        lay = PagedLayout(page_size=g, num_pages=slots * cap_tokens // g,
                          slots=slots, pages_per_slot=cap_tokens // g)
        alloc = PageAllocator(lay)
        cache = pgc.init_paged_cache(cfg, lay, HKV, D)
        for s in range(slots):
            tl = live - 7 * s          # heterogeneous live lengths
            if not alloc.alloc(s, lay.pages_for(tl)):
                raise RuntimeError("page pool sized to fit every slot")
            bucket = -(-tl // g) * g
            k = rope_structured_keys(jax.random.PRNGKey(s), 1, HKV, bucket, D)
            v = jax.random.normal(jax.random.PRNGKey(100 + s),
                                  (1, HKV, bucket, D))
            cache = pgc.paged_prefill(cache, jnp.asarray(s),
                                      alloc.table()[s], k, v,
                                      jnp.asarray(tl))
        q = jax.random.normal(jax.random.PRNGKey(1), (slots, QH, D))
        table = alloc.table()
        wp = min(pow2_bucket(lay.pages_for(live), 1), lay.pages_per_slot)
        sliced = table[:, :wp]

        gather = jax.jit(pgc.gather_view)
        gathered = jax.jit(ft.partial(pgc.paged_decode_attention,
                                      backend="gathered"))
        paged = jax.jit(ft.partial(pgc.paged_decode_attention,
                                   backend="paged_fused"))
        us_gather = time_fn(gather, cache, table, iters=10)
        us_gathered = time_fn(gathered, cache, q, table, iters=10)
        us_paged = time_fn(paged, cache, q, sliced, iters=10)
        tag = f"paged_decode/cap{cap_tokens}_live{live}"
        emit(f"{tag}/gather_view_copy", us_gather,
             f"pool_bytes={sum(int(np.prod(a.shape)) * a.dtype.itemsize for a in jax.tree_util.tree_leaves(cache))}")
        emit(f"{tag}/gathered_total", us_gathered,
             "gather+dense fused (O(capacity))")
        emit(f"{tag}/paged_fused", us_paged,
             f"page-native, table width {wp} pages (O(live))")
        emit(f"{tag}/speedup_gathered_over_paged", 0.0,
             f"ratio={us_gathered / max(us_paged, 1e-9):.2f}x")


def run_prefill() -> None:
    """Chunked-prefill attention latency on the paged cache: jnp gather
    (O(capacity)) vs page-native fused (O(live prefix)).

    One slot holds a fixed live prefix while the pool capacity sweeps.
    The jnp reference gathers ``pool[page_row]`` over the *full* table row
    each chunk — cost grows with capacity even though the live prefix
    never changes — while the page-native path walks only the live pages
    through a width-sliced row and stays flat.
    """
    import functools as ft

    from repro.core import paged_cache as pgc
    from repro.core.cache_layout import PageAllocator, PagedLayout
    from repro.core.quantizers import QuantConfig
    from repro.utils import pow2_bucket

    g = 64
    live, tc = 512, 128                    # fixed prefix + one chunk
    cfg = QuantConfig(method="polar", group_size=g, value_bits=4)
    for cap_tokens in (1024, 4096, 8192):
        lay = PagedLayout(page_size=g, num_pages=cap_tokens // g + 1,
                          slots=1, pages_per_slot=cap_tokens // g)
        alloc = PageAllocator(lay)
        cache = pgc.init_paged_cache(cfg, lay, HKV, D)
        if not alloc.alloc(0, lay.pages_for(live)):
            raise RuntimeError("page pool sized to fit the prefix")
        k = rope_structured_keys(jax.random.PRNGKey(0), 1, HKV, live, D)
        v = jax.random.normal(jax.random.PRNGKey(100), (1, HKV, live, D))
        cache = pgc.paged_prefill(cache, jnp.asarray(0), alloc.table()[0],
                                  k, v, jnp.asarray(live))
        q = jax.random.normal(jax.random.PRNGKey(1), (1, QH, tc, D))
        kc = rope_structured_keys(jax.random.PRNGKey(2), 1, HKV, tc, D)
        vc = jax.random.normal(jax.random.PRNGKey(3), (1, HKV, tc, D))
        row = alloc.table()[0]
        start = jnp.asarray(live, jnp.int32)
        clen = jnp.asarray(tc, jnp.int32)
        wp = min(pow2_bucket(lay.pages_for(live + tc), 1),
                 lay.pages_per_slot)

        jnp_ref = jax.jit(ft.partial(pgc.paged_prefill_attention,
                                     backend="jnp"))
        fused = jax.jit(ft.partial(pgc.paged_prefill_attention,
                                   backend="paged_fused"))
        # few iters: the jnp arm's dense softmax over the full capacity is
        # seconds per call on CPU at 8k (which is the point being measured)
        us_jnp = time_fn(jnp_ref, cache, q, kc, vc, row, start, clen,
                         iters=3, warmup=1)
        us_fused = time_fn(fused, cache, q, kc, vc, row[:wp], start, clen,
                           iters=3, warmup=1)
        tag = f"paged_prefill/cap{cap_tokens}_live{live}_chunk{tc}"
        emit(f"{tag}/jnp_gather", us_jnp,
             "full-pool gather + dense softmax (O(capacity))")
        emit(f"{tag}/page_native", us_fused,
             f"fused over live pages, table width {wp} (O(live))")
        emit(f"{tag}/speedup_jnp_over_page_native", 0.0,
             f"ratio={us_jnp / max(us_fused, 1e-9):.2f}x")


def run() -> None:
    g = 128
    for b, t in [(1, 4096), (8, 4096), (8, 8192), (1, 32768)]:
        key = jax.random.PRNGKey(0)
        k = rope_structured_keys(key, b, HKV, t, D)
        q = jax.random.normal(jax.random.PRNGKey(1), (b, HKV, QH // HKV, D))
        cfg = QuantConfig(method="polar", group_size=g)
        pk = encode_polar_keys(k, cfg)
        pk_exp = jax.tree_util.tree_map(lambda a: a[:, :, None], pk)

        fp_qk = jax.jit(lambda q, k: jnp.einsum("bhqd,bhtd->bhqt", q, k))
        lut_qk = jax.jit(functools.partial(lut_mod.lut_qk_scores))
        deq_qk = jax.jit(lambda q, pk: jnp.einsum(
            "bhqd,bhtd->bhqt", q, decode_polar_keys(pk)))

        us_fp = time_fn(fp_qk, q, k, iters=10)
        us_lut = time_fn(lut_qk, q, pk_exp, iters=10)
        us_deq = time_fn(deq_qk, q, pk, iters=10)

        for name, us in [("fp16", us_fp), ("polar44_lut", us_lut),
                         ("polar44_dequant", us_deq)]:
            mth = {"fp16": "fp16"}.get(name, "polar44")
            hbm = hbm_bytes_per_layer(t, b, mth, g)
            emit(f"qk_latency/b{b}_t{t}/{name}", us,
                 f"tpu_hbm_bytes={hbm};v5e_mem_us={hbm / 819e9 * 1e6:.2f}")
        # paper Table 4 headline: byte ratio fp16 / polar
        ratio = hbm_bytes_per_layer(t, b, "fp16") / hbm_bytes_per_layer(
            t, b, "polar44", g)
        emit(f"qk_latency/b{b}_t{t}/bytes_ratio_fp16_over_polar44", 0.0,
             f"ratio={ratio:.2f}x")
    run_paged()
    run_prefill()


if __name__ == "__main__":
    run()
