"""Serving benchmark: continuous batching (paged cache) vs static batching.

Drives a Poisson-arrival workload with mixed prompt/output lengths through
both engines and reports aggregate *useful* tokens/s (padding and
over-generation excluded), p50/p99 per-request latency, and cache-page
utilization.

The continuous-batching arms run through the **streaming front door**
(`repro.serve.api.StreamingEngine` over `EngineCore.step()`): requests are
submitted to the open loop and tokens consumed as `TokenEvent`s, which is
what unlocks the honest per-token numbers — **TTFT** (arrival -> first
token, queueing + admission + the whole prefill) and **inter-token
latency** p50/p95/p99 — instead of end-of-run aggregates.

Both engines run against a simulated arrival clock: device time is
measured (block_until_ready) and added to the clock, while idle gaps jump
to the next arrival — so latencies compose queueing + compute without
having to sleep through the gaps.

The static baseline is the pre-refactor serving model: FCFS batches of up
to ``--slots`` requests, prompts right-padded to a shared bucket, one
shared prefill, and lock-step decode for the *batch max* output length —
every request holds its slot until the slowest one finishes.

Run (CPU):  PYTHONPATH=src python benchmarks/bench_serving.py
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.models import get_model
from repro.serve import (
    ChaosConfig, ChaosInjector, ContinuousBatchingEngine, GenerationConfig,
    QosConfig, Request, ServeEngine, StreamingEngine, check_event_stream,
    goodput_under_sla, stream_latency_stats,
)
from repro.utils import nearest_rank_pct as _pct, pow2_bucket as _bucket


def make_workload(n: int, rate: float, seed: int, prompt_lo: int,
                  prompt_hi: int, out_lo: int, out_hi: int) -> list[Request]:
    """Poisson arrivals (exponential gaps at ``rate`` req/s), uniform
    prompt and output lengths — output lengths deliberately heterogeneous:
    the static baseline pays for the batch max, continuous batching
    doesn't."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for i in range(n):
        t += rng.exponential(1.0 / rate)
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, 512, (int(rng.integers(
                prompt_lo, prompt_hi + 1)),)).astype(np.int32),
            max_new_tokens=int(rng.integers(out_lo, out_hi + 1)),
            arrival_time=t))
    return reqs


def make_shared_prefix_workload(n: int, rate: float, seed: int,
                                prefix_len: int, suffix_lo: int,
                                suffix_hi: int, out_lo: int,
                                out_hi: int) -> list[Request]:
    """The system-prompt workload: every request shares a ``prefix_len``
    token prefix (one system prompt for the whole fleet) followed by a
    short random user suffix — the regime where shared-prefix page reuse
    converts the prompt-heavy part of prefill into free page adoption."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, 512, (prefix_len,)).astype(np.int32)
    t = 0.0
    reqs = []
    for i in range(n):
        t += rng.exponential(1.0 / rate)
        suffix = rng.integers(0, 512, (int(rng.integers(
            suffix_lo, suffix_hi + 1)),)).astype(np.int32)
        reqs.append(Request(
            rid=i, prompt=np.concatenate([shared, suffix]),
            max_new_tokens=int(rng.integers(out_lo, out_hi + 1)),
            arrival_time=t))
    return reqs


def run_static(model, params, requests: list[Request], slots: int,
               max_len: int) -> dict:
    """FCFS static batching on the dense-cache ServeEngine under the same
    simulated clock. Batches are padded to (slots, bucket) so the engine
    compiles once per prompt bucket."""
    eng = ServeEngine(model, params, max_len=max_len)
    # dense caches allow mixed per-layer group sizes; bucket to the largest
    g = model.cfg.policy.max_group_size()
    queue = sorted(requests, key=lambda r: r.arrival_time)
    buckets = sorted({_bucket(r.prompt_len, g) for r in queue})

    for b in buckets:  # warmup: compile prefill per bucket + decode
        eng.generate({"tokens": np.zeros((slots, b), np.int32)},
                     GenerationConfig(max_new_tokens=2))

    clock, i, useful = 0.0, 0, 0
    done: list[Request] = []
    while i < len(queue):
        if queue[i].arrival_time > clock:
            clock = queue[i].arrival_time
        batch = []
        while (i < len(queue) and len(batch) < slots
               and queue[i].arrival_time <= clock):
            batch.append(queue[i])
            i += 1
        b = _bucket(max(r.prompt_len for r in batch), g)
        toks = np.zeros((slots, b), np.int32)
        for j, r in enumerate(batch):
            toks[j, : r.prompt_len] = r.prompt
        horizon = max(r.max_new_tokens for r in batch)
        t0 = time.monotonic()
        out = eng.generate({"tokens": toks},
                           GenerationConfig(max_new_tokens=horizon))
        clock += time.monotonic() - t0
        for j, r in enumerate(batch):
            r.t_done = clock
            r.out_tokens = out["tokens"][j, : r.max_new_tokens].tolist()
            useful += r.max_new_tokens
        done.extend(batch)

    lats = sorted(r.latency() for r in done)
    pct = lambda p: _pct(lats, p)
    return {"requests": done, "total_tokens": useful, "wall_s": clock,
            "tokens_per_s": useful / max(clock, 1e-9),
            "p50_latency_s": pct(50), "p99_latency_s": pct(99)}


def _strip_requests(r: dict) -> dict:
    """JSON-serializable copy of an engine result dict (drops the Request
    and TokenEvent objects; everything else is plain numbers/lists)."""
    return {k: v for k, v in r.items()
            if k not in ("requests", "events", "cancelled_requests",
                         "shed_requests", "rejected_requests")}


def run_cb(cfg, params, args, *, backend: str, max_len: int,
           table_slicing: bool = True, mesh=None) -> dict:
    """One continuous-batching arm at a decode backend + pool capacity,
    driven open-loop through the streaming API: the Poisson workload is
    submitted to ``StreamingEngine`` and consumed as TokenEvents, from
    which per-request TTFT and inter-token-latency percentiles are
    computed. ``mesh`` threads a device mesh through the engine
    (head-sharded KV page pools, DESIGN.md §17)."""
    model = get_model(dataclasses.replace(cfg, decode_backend=backend))
    eng = ContinuousBatchingEngine(
        model, params, max_slots=args.slots, max_len=max_len,
        num_pages=args.num_pages or None, table_slicing=table_slicing,
        mesh=mesh)
    wl = make_workload(args.requests, args.rate, args.seed,
                       args.prompt_lo, args.prompt_hi,
                       args.out_lo, args.out_hi)
    # include the capacity bucket: preemption-resume prefills the full
    # context, which can land above any prompt bucket
    eng.warmup([r.prompt_len for r in wl] + [max_len])
    stream = StreamingEngine(eng)
    for r in sorted(wl, key=lambda q: q.arrival_time):
        stream.submit(r)
    events = list(stream.events())
    res = stream.result()
    res.update(stream_latency_stats(events, wl))
    res["max_len"] = max_len
    res["table_slicing"] = table_slicing
    return res


def run_shared_prefix(cfg, params, args) -> dict:
    """Shared-system-prompt A/B: prefix-cache reuse vs the no-reuse chunked
    baseline on the same workload.

    Both arms run identical chunked prefill (same chunk size), so reuse
    must produce *bit-identical* greedy outputs — adopted pages hold the
    same encoded bytes the baseline recomputes — while skipping the shared
    prompt's prefill work and pool pages (the acceptance check for
    DESIGN.md §12)."""
    model = get_model(dataclasses.replace(cfg, decode_backend=args.backend))
    wl = lambda: make_shared_prefix_workload(
        args.requests, args.rate, args.seed, args.shared_prefix,
        args.suffix_lo, args.suffix_hi, args.out_lo, args.out_hi)
    arms = {}
    for name, reuse in (("baseline", False), ("reuse", True)):
        eng = ContinuousBatchingEngine(
            model, params, max_slots=args.slots, max_len=args.max_len,
            num_pages=args.num_pages or None, prefix_cache=reuse,
            prefill_chunk=args.prefill_chunk)
        eng.warmup([args.max_len])
        workload = wl()
        arms[name] = eng.run(workload, GenerationConfig())
        arms[name].update(
            stream_latency_stats(arms[name]["events"], workload))
    base, reuse = arms["baseline"], arms["reuse"]
    out_of = lambda r: {q.rid: list(q.out_tokens) for q in r["requests"]}
    identical = out_of(base) == out_of(reuse)
    saved_pages = reuse["adopted_pages"]
    per_req_base = base["fresh_pages"] / max(args.requests, 1)
    per_req_reuse = reuse["fresh_pages"] / max(args.requests, 1)
    print(f"shared-prefix({args.shared_prefix} tok) "
          f"hit={reuse['prefix_hit_rate'] * 100:5.1f}% "
          f"skipped={reuse['prefill_tokens_skipped']:5d} tok "
          f"pages/req {per_req_base:.1f}->{per_req_reuse:.1f} "
          f"bytes-shared={reuse['prefix_pool_bytes_saved'] / 2**20:.2f}MiB "
          f"bit-identical={identical}")
    return {
        "prefix_len": args.shared_prefix,
        "prefill_chunk": reuse["prefill_chunk"],
        "baseline": _strip_requests(base),
        "reuse": _strip_requests(reuse),
        "outputs_bit_identical": identical,
        "prefill_tokens_skipped": reuse["prefill_tokens_skipped"],
        "adopted_pages": saved_pages,
        "prefix_pool_bytes_saved": reuse["prefix_pool_bytes_saved"],
        "fresh_pages_per_request_baseline": per_req_base,
        "fresh_pages_per_request_reuse": per_req_reuse,
    }


def run_prefill_sweep(cfg, params, args) -> list[dict]:
    """Long-prompt TTFT A/B: chunked prefill through the gathering jnp
    reference vs the page-native fused path (``prefill_backend``), same
    decode settings on both arms.

    TTFT on a long prompt is dominated by the per-chunk attention over the
    already-cached prefix: the jnp arm gathers the slot's *full-capacity*
    table row every chunk, the page-native arm walks only the pages the
    prefix actually occupies (width-sliced row). Greedy outputs must stay
    bit-identical — the kernel reorders no float ops relative to the
    reference."""
    arms = []
    g = cfg.quant.group_size
    for plen in args.prefill_sweep:
        max_len = -(-(plen + args.out_hi) // g) * g
        per_pb = {}
        for pb in ("jnp", "paged_fused"):
            model = get_model(dataclasses.replace(
                cfg, decode_backend=args.backend, prefill_backend=pb))
            eng = ContinuousBatchingEngine(
                model, params, max_slots=2, max_len=max_len,
                prefill_chunk=args.prefill_sweep_chunk)
            eng.warmup([plen], GenerationConfig(max_new_tokens=4))
            # one request: TTFT here is pure chunked-prefill latency, and
            # the jnp arm is O(prompt * capacity) on CPU — keep it lean
            rng = np.random.default_rng(args.seed)
            wl = [Request(rid=0,
                          prompt=rng.integers(0, 512, (plen,))
                          .astype(np.int32),
                          max_new_tokens=4, arrival_time=0.0)]
            r = eng.run(wl, GenerationConfig(max_new_tokens=4))
            r.update(stream_latency_stats(r["events"], wl))
            r["outputs"] = {q.rid: list(q.out_tokens)
                            for q in r["requests"]}
            per_pb[pb] = r
        identical = (per_pb["jnp"]["outputs"]
                     == per_pb["paged_fused"]["outputs"])
        ttft_jnp = per_pb["jnp"]["ttft_s"]["p50"]
        ttft_fused = per_pb["paged_fused"]["ttft_s"]["p50"]
        print(f"  prefill sweep plen={plen:5d} "
              f"ttft jnp={ttft_jnp * 1e3:8.1f}ms "
              f"paged_fused={ttft_fused * 1e3:8.1f}ms "
              f"speedup={ttft_jnp / max(ttft_fused, 1e-9):.2f}x "
              f"bit-identical={identical}")
        arms.append({
            "prompt_len": plen,
            "prefill_chunk": args.prefill_sweep_chunk,
            "max_len": max_len,
            "jnp": _strip_requests(per_pb["jnp"]),
            "paged_fused": _strip_requests(per_pb["paged_fused"]),
            "ttft_speedup_fused_over_jnp":
                ttft_jnp / max(ttft_fused, 1e-9),
            "outputs_bit_identical": identical,
        })
    return arms


def run_context_sweep(cfg, params, args) -> list[dict]:
    """Decode-step latency vs pool capacity: the gathered baseline
    (PR-2 formulation: full-width table + gather_view copy) against the
    page-native path. The workload's live context is fixed, so a flat
    paged-fused line across the sweep is the "no full-cache gather"
    signature; the gathered baseline grows with capacity."""
    arms = []
    for max_len in args.sweep:
        for backend, slicing in (("gathered", False), ("paged_fused", True)):
            r = run_cb(cfg, params, args, backend=backend, max_len=max_len,
                       table_slicing=slicing)
            arm = _strip_requests(r)
            arm["arm"] = ("gathered_baseline" if backend == "gathered"
                          else "paged_fused")
            arms.append(arm)
            print(f"  sweep max_len={max_len:5d} {arm['arm']:17s} "
                  f"decode_step={r['decode_step_s_mean'] * 1e3:8.2f}ms "
                  f"tok/s={r['tokens_per_s']:8.1f}")
    return arms


def _spec_workload(cfg, kind: str, n: int, seed: int,
                   max_new: int) -> list[Request]:
    """Spec-decode workloads. ``repetitive``: every prompt is one token
    repeated — greedy continuations tend to fall into short cycles that
    ngram self-speculation rides (the favorable regime). ``random``:
    i.i.d. prompts whose continuations rarely repeat — the adversarial
    regime where acceptance, and any speedup, should collapse."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        if kind == "repetitive":
            prompt = np.full((36,), rng.randint(0, cfg.vocab_size),
                             np.int32)
        else:
            prompt = rng.randint(0, cfg.vocab_size, (36,)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=max_new,
                            arrival_time=i * 0.002))
    return reqs


def run_spec_sweep(cfg, params, args) -> dict:
    """Speculative-decode A/B: ngram self-speculation vs plain decode.

    Low-batch serving (2 slots) is the regime speculation targets: the
    per-dispatch overhead dominates per-token cost, so retiring several
    tokens per dispatch is a real win *when drafts get accepted*. Each
    arm must stay bit-identical to the baseline — a mismatch fails the
    whole benchmark (nonzero rc), speedups are only reported for correct
    runs. The sweep runs the span verifier's reference formulation
    (decode_backend="jnp", the backend the batched verifier reproduces
    bit-for-bit)."""
    from repro.spec import SpecConfig
    model = get_model(dataclasses.replace(cfg, decode_backend="jnp"))
    slots, max_len, max_new = 2, 512, args.spec_gen
    out: dict = {"slots": slots, "max_new": max_new,
                 "k_sweep": args.spec_sweep, "workloads": {}}
    rc_ok = True
    for kind in ("repetitive", "random"):
        wl = lambda: _spec_workload(cfg, kind, 6, args.seed + 3, max_new)
        arms = []
        base = None
        base_toks = None
        for k in [0] + args.spec_sweep:
            spec = SpecConfig(mode="ngram", k=k) if k else None
            eng = ContinuousBatchingEngine(
                model, params, max_slots=slots, max_len=max_len, spec=spec)
            reqs = wl()
            eng.warmup([r.prompt_len for r in reqs])
            r = eng.run(reqs, GenerationConfig())
            toks = {q.rid: list(q.out_tokens) for q in r["requests"]}
            if k == 0:
                base, base_toks = r, toks
                continue
            same = toks == base_toks
            rc_ok &= same
            sp = r["spec"]
            arms.append({
                "k": k,
                "tokens_per_s": r["tokens_per_s"],
                "speedup_vs_baseline": r["tokens_per_s"]
                / max(base["tokens_per_s"], 1e-9),
                "acceptance_rate": sp["acceptance_rate"],
                "mean_accepted_per_step": sp["mean_accepted_per_step"],
                "spec_steps": sp["steps"],
                "decode_steps": r["decode_steps"],
                "outputs_bit_identical": same,
            })
            print(f"spec/{kind:10s} k={k}: "
                  f"tok/s={r['tokens_per_s']:8.1f} "
                  f"({arms[-1]['speedup_vs_baseline']:.2f}x) "
                  f"acc={sp['acceptance_rate'] * 100:5.1f}% "
                  f"acc/step={sp['mean_accepted_per_step']:.2f} "
                  f"bit-identical={same}")
        out["workloads"][kind] = {
            "baseline_tokens_per_s": base["tokens_per_s"],
            "baseline_decode_steps": base["decode_steps"],
            "arms": arms,
        }
        print(f"spec/{kind:10s} base: tok/s={base['tokens_per_s']:8.1f}")
    rep = out["workloads"]["repetitive"]["arms"]
    out["best_speedup_repetitive"] = max(
        (a["speedup_vs_baseline"] for a in rep), default=0.0)
    out["outputs_bit_identical"] = rc_ok
    return out


def _runahead_workload(n: int, seed: int, prompt_len: int,
                       max_new: int) -> list[Request]:
    """Decode-bound workload for the run-ahead sweep: ``n`` equal-length
    short prompts all arriving ~t=0 (one per millisecond), so the queue
    drains immediately and the horizon planner sees the pure decode-bound
    stretch run-ahead targets."""
    rng = np.random.default_rng(seed)
    return [Request(
        rid=i,
        prompt=rng.integers(0, 512, (prompt_len,)).astype(np.int32),
        max_new_tokens=max_new, arrival_time=i * 1e-3)
        for i in range(n)]


def run_runahead_sweep(cfg, params, args) -> dict:
    """Run-ahead fused decode A/B (DESIGN.md §18): horizon H x slot-count
    grid against the H=1 per-token dispatch baseline, same decode-bound
    workload per slot arm.

    Low-slot decode is the regime the per-token host sync dominates: every
    step pays scheduling + event emission + a device round-trip for a
    handful of tokens. Run-ahead amortizes that host work over H fused
    micro-steps and overlaps it with device compute (async dispatch
    pipeline), so the win should be largest at 1-4 slots and taper as
    device compute grows with the batch. Greedy outputs must stay
    **bit-identical** to H=1 at every grid point — a digest mismatch fails
    the whole benchmark (nonzero rc); speedups are only reported for
    correct runs. The recorded dispatch-gap EWMA (host time between a
    block landing and the next horizon's dispatch) and sync-wait time are
    the per-step host-vs-device breakdown."""
    import hashlib
    import json

    model = get_model(dataclasses.replace(cfg, decode_backend=args.backend))
    g = cfg.quant.group_size
    max_new = args.runahead_gen
    plen = 2 * g
    max_len = -(-(plen + max_new) // g) * g + g
    out: dict = {"h_sweep": args.runahead_sweep,
                 "slots_sweep": args.runahead_slots,
                 "prompt_len": plen, "max_new": max_new,
                 "max_len": max_len, "arms": []}
    rc_ok = True
    for slots in args.runahead_slots:
        wl = lambda: _runahead_workload(slots, args.seed + 29, plen,
                                        max_new)
        base_tps, base_digest = None, None
        for h in args.runahead_sweep:
            eng = ContinuousBatchingEngine(
                model, params, max_slots=slots, max_len=max_len,
                runahead=0 if h <= 1 else h)
            reqs = wl()
            eng.warmup([plen])
            r = eng.run(reqs, GenerationConfig())
            outs = sorted((q.rid, list(q.out_tokens))
                          for q in r["requests"])
            digest = hashlib.sha256(
                json.dumps(outs).encode()).hexdigest()[:16]
            if base_tps is None:   # the first grid point is the baseline
                base_tps, base_digest = r["tokens_per_s"], digest
            same = digest == base_digest
            rc_ok &= same
            arm = {
                "h": h, "slots": slots,
                "tokens_per_s": r["tokens_per_s"],
                "total_tokens": r["total_tokens"],
                "decode_steps": r["decode_steps"],
                "speedup_vs_h1": r["tokens_per_s"] / max(base_tps, 1e-9),
                "outputs_digest": digest,
                "outputs_bit_identical": same,
            }
            if "runahead" in r:
                arm["horizons"] = r["runahead"]["horizons"]
                arm["horizon_tokens"] = r["runahead"]["tokens"]
                arm["dispatch_gap_ewma_s"] = \
                    r["runahead"]["dispatch_gap_ewma_s"]
                arm["sync_wait_s"] = r["runahead"]["sync_wait_s"]
            out["arms"].append(arm)
            extra = ""
            if "runahead" in r:
                extra = (f" horizons={arm['horizons']:4d} "
                         f"gap-ewma={arm['dispatch_gap_ewma_s'] * 1e3:6.2f}ms"
                         f" sync-wait={arm['sync_wait_s'] * 1e3:7.1f}ms")
            print(f"runahead slots={slots:2d} h={h}: "
                  f"tok/s={r['tokens_per_s']:8.1f} "
                  f"({arm['speedup_vs_h1']:.2f}x) "
                  f"bit-identical={same}{extra}")
    out["best_speedup_low_slots"] = max(
        (a["speedup_vs_h1"] for a in out["arms"] if a["slots"] <= 4),
        default=0.0)
    out["outputs_bit_identical"] = rc_ok
    return out


# ---------------------------------------------------------------------------
# Adversarial arms (DESIGN.md §16): hostile workloads, goodput-under-SLA
# ---------------------------------------------------------------------------


def make_bursty_workload(n: int, bursts: int, gap: float, seed: int,
                         prompt_lo: int, prompt_hi: int, out_lo: int,
                         out_hi: int, deadline: float = 0.0,
                         tenant: str = "default") -> list[Request]:
    """Synchronized arrival storms: ``bursts`` groups of ~n/bursts
    requests landing within a millisecond of each other, ``gap`` seconds
    apart — the anti-Poisson workload where FCFS queueing delay spikes
    and deadline-aware shedding has to triage."""
    rng = np.random.default_rng(seed)
    reqs, rid = [], 0
    per = max(n // bursts, 1)
    for b in range(bursts):
        for _ in range(per):
            reqs.append(Request(
                rid=rid,
                prompt=rng.integers(0, 512, (int(rng.integers(
                    prompt_lo, prompt_hi + 1)),)).astype(np.int32),
                max_new_tokens=int(rng.integers(out_lo, out_hi + 1)),
                arrival_time=b * gap + rng.uniform(0, 1e-3),
                ttft_deadline=deadline, tenant=tenant))
            rid += 1
    return reqs


def make_tenant_workload(n: int, rate: float, seed: int, prefix_len: int,
                         suffix_lo: int, suffix_hi: int, out_lo: int,
                         out_hi: int, heavy_frac: float = 0.9,
                         deadline: float = 0.0) -> list[Request]:
    """90/10 multi-tenant mix: a ``heavy`` tenant floods ~90% of the
    arrivals, a ``light`` tenant trickles the rest; each tenant has its
    own shared system prompt (prefix-cache-friendly within a tenant,
    cross-tenant pollution between them). Under FCFS the light tenant
    queues behind the flood; WFQ's attained-service ordering is what
    should keep its latency flat."""
    rng = np.random.default_rng(seed)
    prefixes = {t: rng.integers(0, 512, (prefix_len,)).astype(np.int32)
                for t in ("heavy", "light")}
    t, reqs = 0.0, []
    for i in range(n):
        t += rng.exponential(1.0 / rate)
        tenant = "heavy" if rng.random() < heavy_frac else "light"
        suffix = rng.integers(0, 512, (int(rng.integers(
            suffix_lo, suffix_hi + 1)),)).astype(np.int32)
        reqs.append(Request(
            rid=i, prompt=np.concatenate([prefixes[tenant], suffix]),
            max_new_tokens=int(rng.integers(out_lo, out_hi + 1)),
            arrival_time=t, tenant=tenant, ttft_deadline=deadline))
    return reqs


def make_straggler_workload(n: int, rate: float, seed: int, long_len: int,
                            long_every: int, chat_lo: int, chat_hi: int,
                            out_lo: int, out_hi: int,
                            deadline: float = 0.0) -> list[Request]:
    """Long-context stragglers beside chat traffic: every
    ``long_every``-th request carries a ``long_len`` prompt (tenant
    ``batch``, no deadline) between short chat requests (tenant ``chat``,
    deadline-bound) — the head-of-line-blocking regime chunked prefill +
    QoS must keep interactive."""
    rng = np.random.default_rng(seed)
    t, reqs = 0.0, []
    for i in range(n):
        t += rng.exponential(1.0 / rate)
        straggler = long_every > 0 and i % long_every == long_every - 1
        plen = long_len if straggler else int(rng.integers(
            chat_lo, chat_hi + 1))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, 512, (plen,)).astype(np.int32),
            max_new_tokens=int(rng.integers(out_lo, out_hi + 1)),
            arrival_time=t,
            tenant="batch" if straggler else "chat",
            ttft_deadline=0.0 if straggler else deadline))
    return reqs


def _adv_run(model, params, args, wl: list[Request], *, qos=None,
             chaos=None, slo: float, num_pages=None, prefill_chunk: int = 0,
             warm_caps: list[int] | None = None) -> dict:
    """One adversarial arm: run, assert the event-stream invariants and
    post-drain allocator conservation, return metrics + goodput-under-SLA
    (tokens/s from requests whose TTFT met the deadline)."""
    eng = ContinuousBatchingEngine(
        model, params, max_slots=args.slots, max_len=args.max_len,
        num_pages=num_pages if num_pages is not None
        else (args.num_pages or None),
        prefill_chunk=prefill_chunk, qos=qos, chaos=chaos)
    eng.warmup(sorted({r.prompt_len for r in wl})
               + (warm_caps or [args.max_len]))
    r = eng.run(wl, GenerationConfig())
    # invariants hold under every hostile workload, not just in tests
    check_event_stream(r["events"])
    alloc = eng.core.sched.alloc
    assert alloc.quarantined_pages == 0, "chaos quarantine leaked"
    assert alloc.free_pages == eng.core.layout.num_pages, \
        "pages leaked after drain"
    r.update(stream_latency_stats(r["events"], wl))
    r["goodput"] = goodput_under_sla(r["requests"], r["wall_s"], slo)
    return r


def run_adversarial(cfg, params, args) -> dict:
    """The hostile-workload scenario suite: each arm runs an SLA-aware
    QoS engine against the FCFS no-QoS baseline on the same workload,
    with **goodput-under-SLA** (tokens/s from requests meeting their
    TTFT deadline) as the headline. The soak arm's QoS-beats-FCFS margin
    is the benchmark's acceptance gate (nonzero rc on regression)."""
    model = get_model(dataclasses.replace(cfg, decode_backend=args.backend))

    # calibrate the SLO off the *unloaded* TTFT: a trickle workload the
    # pool absorbs instantly, p50 TTFT * 4 = the deadline a lightly
    # loaded engine comfortably meets and an overloaded queue blows
    calib = _adv_run(model, params, args,
                     make_workload(max(args.slots, 4), 2.0, args.seed,
                                   args.prompt_lo, args.prompt_hi,
                                   args.out_lo, args.out_hi),
                     slo=float("inf"))
    slo = max(4.0 * calib["ttft_s"]["p50"], 1e-3)
    qos_cfg = QosConfig(ttft_slo=slo)
    out: dict = {"slo_s": slo,
                 "calibration_ttft_p50_s": calib["ttft_s"]["p50"]}

    def ab(name, wl_fn, *, qos=qos_cfg, fcfs_kw=None, qos_kw=None,
           extra=None):
        kw = dict(slo=slo)
        a = _adv_run(model, params, args, wl_fn(),
                     **{**kw, **(fcfs_kw or {})})
        b = _adv_run(model, params, args, wl_fn(), qos=qos,
                     **{**kw, **(qos_kw or {})})
        arm = {
            "fcfs": _strip_requests(a), "qos": _strip_requests(b),
            "goodput_win": b["goodput"]["goodput_tokens_per_s"]
            / max(a["goodput"]["goodput_tokens_per_s"], 1e-9),
        }
        if extra:
            arm.update(extra(a, b))
        print(f"adversarial/{name:12s} goodput fcfs="
              f"{a['goodput']['goodput_tokens_per_s']:8.1f} qos="
              f"{b['goodput']['goodput_tokens_per_s']:8.1f} tok/s "
              f"({arm['goodput_win']:.2f}x)  met-rate "
              f"{a['goodput']['deadline_met_rate']:.2f}->"
              f"{b['goodput']['deadline_met_rate']:.2f}  "
              f"shed={b['n_shed']}")
        out[name] = arm
        return a, b

    # --- sustained-overload soak: a deadline-bound storm far over
    # service capacity, on an undersized pool (preemption churn); FCFS
    # serves everyone late, QoS sheds the doomed tail and keeps the
    # survivors inside the SLA ---
    soak_pages = max(args.slots * 2,
                     (args.prompt_hi + args.out_hi)
                     // cfg.quant.group_size + 2)
    soak_wl = lambda: make_bursty_workload(
        args.adversarial_requests, 2, 0.05, args.seed + 11,
        args.prompt_lo, args.prompt_hi, args.out_lo, args.out_hi,
        deadline=slo)
    ab("soak", soak_wl,
       qos=dataclasses.replace(qos_cfg, pressure_hi=0.85),
       fcfs_kw=dict(num_pages=soak_pages),
       qos_kw=dict(num_pages=soak_pages),
       extra=lambda a, b: {
           "num_pages": soak_pages,
           "preemptions_fcfs": sum(q.preemptions
                                   for q in a["requests"]),
           "preemptions_qos": sum(q.preemptions
                                  for q in b["requests"]),
           "degrade": b.get("qos", {}).get("degrade"),
       })

    # --- bursty Poisson storms: arrival clusters instead of a smooth
    # stream; same A/B, deadlines only meetable near the burst head ---
    burst_wl = lambda: make_bursty_workload(
        args.adversarial_requests, 4, 0.4, args.seed + 13,
        args.prompt_lo, args.prompt_hi, args.out_lo, args.out_hi,
        deadline=slo)
    ab("burst", burst_wl)

    # --- cancellation flood: deterministic chaos storms cancel half the
    # live requests twice mid-run; the stream invariants (no events
    # after cancel, dense ordinals) must survive, and the engine's
    # goodput comes only from the survivors ---
    flood_chaos = lambda: ChaosInjector(ChaosConfig(
        seed=args.seed, cancel_at=(8, 20), cancel_frac=0.5))
    flood_wl = lambda: make_workload(
        args.adversarial_requests, args.rate * 2, args.seed + 17,
        args.prompt_lo, args.prompt_hi, args.out_lo, args.out_hi)
    fa = _adv_run(model, params, args, flood_wl(), slo=slo,
                  chaos=flood_chaos())
    fb = _adv_run(model, params, args, flood_wl(), slo=slo)
    out["cancel_flood"] = {
        "chaos": _strip_requests(fa), "clean": _strip_requests(fb),
        "storm_cancels": fa["chaos"]["storm_cancels"],
    }
    print(f"adversarial/cancel_flood  cancelled="
          f"{fa['n_cancelled']} of {args.adversarial_requests}  "
          f"survivor tok/s={fa['tokens_per_s']:.1f} "
          f"(clean {fb['tokens_per_s']:.1f})")

    # --- 90/10 multi-tenant shared-prefix mix: WFQ must hold the light
    # tenant's TTFT under the heavy tenant's flood ---
    tenant_wl = lambda: make_tenant_workload(
        args.adversarial_requests, args.rate * 2, args.seed + 19,
        args.shared_prefix or 32, args.suffix_lo, args.suffix_hi,
        args.out_lo, args.out_hi, deadline=slo)

    def tenant_ttft(r, tenant):
        ts = [q.t_first_token - q.arrival_time for q in r["requests"]
              if q.tenant == tenant and q.t_first_token is not None]
        return _pct(sorted(ts), 50)

    ab("tenants", tenant_wl,
       qos=dataclasses.replace(qos_cfg, weights={"light": 4.0}),
       extra=lambda a, b: {
           "light_ttft_p50_fcfs_s": tenant_ttft(a, "light"),
           "light_ttft_p50_qos_s": tenant_ttft(b, "light"),
           "tenants_qos": b["qos"]["tenants"],
       })

    # --- long-context stragglers beside chat traffic: chunked prefill +
    # QoS keep the chat class inside its deadline while batch-class
    # stragglers (no deadline) grind through. The SLO recalibrates on
    # the chunked config — per-chunk dispatch overhead shifts the whole
    # unloaded TTFT scale ---
    chunk = max(cfg.quant.group_size * 2, 32)
    calib_chunked = _adv_run(model, params, args,
                             make_workload(max(args.slots, 4), 2.0,
                                           args.seed, args.prompt_lo,
                                           args.prompt_hi, args.out_lo,
                                           args.out_hi),
                             slo=float("inf"), prefill_chunk=chunk)
    slo_chunked = max(4.0 * calib_chunked["ttft_s"]["p50"], 1e-3)
    out["slo_chunked_s"] = slo_chunked
    strag_wl = lambda: make_straggler_workload(
        args.adversarial_requests, args.rate, args.seed + 23,
        long_len=min(args.max_len - args.out_hi, 4 * args.prompt_hi),
        long_every=5, chat_lo=args.prompt_lo, chat_hi=args.prompt_hi,
        out_lo=args.out_lo, out_hi=args.out_hi, deadline=slo_chunked)
    ab("stragglers", strag_wl,
       qos=dataclasses.replace(qos_cfg, ttft_slo=slo_chunked),
       fcfs_kw=dict(prefill_chunk=chunk, slo=slo_chunked),
       qos_kw=dict(prefill_chunk=chunk, slo=slo_chunked),
       extra=lambda a, b: {
           "prefill_chunk": chunk,
           "chat_ttft_p50_fcfs_s": tenant_ttft(a, "chat"),
           "chat_ttft_p50_qos_s": tenant_ttft(b, "chat"),
       })

    out["soak_gate_ok"] = out["soak"]["goodput_win"] > 1.0
    return out


def run_mesh_arm(args) -> int:
    """Internal ``--mesh-arm`` mode: ONE continuous-batching arm on a
    (data x model) mesh, minimal JSON record to ``--json``.

    Runs in its own process so the driver's
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` takes effect —
    device forcing is a process-level switch that must precede jax init.
    """
    import hashlib
    import json

    try:
        d, m = (int(x) for x in args.mesh_shape.split("x"))
    except ValueError:
        raise SystemExit(f"bad --mesh-shape {args.mesh_shape!r}; "
                         "expected e.g. '1x2' (data x model)")
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((d, m), ("data", "model"))
    cfg = reduce_for_smoke(get_config(args.arch))
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    res = run_cb(cfg, params, args, backend=args.backend,
                 max_len=args.max_len, mesh=mesh)
    outs = sorted((r.rid, list(r.out_tokens))
                  for r in res.get("requests", []))
    rec = {
        "devices": jax.device_count(),
        "mesh": {"data": d, "model": m},
        "head_sharded": cfg.num_kv_heads % m == 0,
        "tokens_per_s": res["tokens_per_s"],
        "total_tokens": res["total_tokens"],
        "ttft_s": res["ttft_s"],
        "itl_s": res["itl_s"],
        "decode_step_s_mean": res.get("decode_step_s_mean"),
        # greedy-output fingerprint: the sweep driver asserts it is
        # identical across device counts (sharding must not change tokens)
        "outputs_digest": hashlib.sha256(
            json.dumps(outs).encode()).hexdigest()[:16],
    }
    with open(args.json, "w") as f:
        json.dump(rec, f, indent=2, sort_keys=True)
    return 0


def run_mesh_sweep(args) -> dict:
    """Multi-device serving sweep: tokens/s + TTFT p50/p95 vs device count
    at fixed total pool bytes (same slots/max_len/num_pages every arm; only
    the device count — and thus per-device pool bytes, where kv_heads
    divides the model axis — changes).

    Each count N runs :func:`run_mesh_arm` in a subprocess under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` with a (1, N)
    (data x model) mesh. On CPU the forced "devices" are host threads, so
    this measures sharding *orchestration* overhead and correctness, not a
    speedup — the numbers keep the multi-device decode path tracked across
    PRs. Greedy-output digests must agree across arms.
    """
    import json
    import os
    import subprocess
    import sys
    import tempfile

    arms = []
    for n in args.mesh_sweep:
        env = dict(os.environ)
        flags = [f for f in env.get("XLA_FLAGS", "").split()
                 if not f.startswith("--xla_force_host_platform")]
        flags.append(f"--xla_force_host_platform_device_count={n}")
        env["XLA_FLAGS"] = " ".join(flags)
        env["PYTHONPATH"] = "src" + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        fd, out_path = tempfile.mkstemp(suffix=".json")
        os.close(fd)
        cmd = [sys.executable, "-m", "benchmarks.bench_serving",
               "--mesh-arm", "--mesh-shape", f"1x{n}",
               "--arch", args.arch,
               "--requests", str(args.requests), "--rate", str(args.rate),
               "--slots", str(args.slots), "--max-len", str(args.max_len),
               "--num-pages", str(args.num_pages),
               "--prompt-lo", str(args.prompt_lo),
               "--prompt-hi", str(args.prompt_hi),
               "--out-lo", str(args.out_lo), "--out-hi", str(args.out_hi),
               "--seed", str(args.seed), "--backend", args.backend,
               "--json", out_path]
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                              timeout=1800)
        if proc.returncode != 0:
            print(f"mesh-sweep arm devices={n} FAILED\n"
                  f"{proc.stdout}\n{proc.stderr}")
            os.unlink(out_path)
            continue
        with open(out_path) as f:
            arm = json.load(f)
        os.unlink(out_path)
        arms.append(arm)
        print(f"mesh devices={n:2d} "
              f"head_sharded={str(arm['head_sharded']):5s} "
              f"tok/s={arm['tokens_per_s']:8.1f} "
              f"ttft_p50={arm['ttft_s']['p50'] * 1e3:7.1f}ms "
              f"p95={arm['ttft_s']['p95'] * 1e3:7.1f}ms "
              f"dstep={arm['decode_step_s_mean'] * 1e3:.2f}ms")
    digests = {a["outputs_digest"] for a in arms}
    identical = len(digests) <= 1
    if not identical:
        print("mesh-sweep: greedy outputs DIVERGED across device counts")
    return {"arms": arms, "outputs_identical_across_devices": identical}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--slots", type=int, default=6)
    ap.add_argument("--max-len", type=int, default=192)
    ap.add_argument("--num-pages", type=int, default=0,
                    help="page pool size (0 = fully provisioned)")
    ap.add_argument("--prompt-lo", type=int, default=16)
    ap.add_argument("--prompt-hi", type=int, default=96)
    ap.add_argument("--out-lo", type=int, default=4)
    ap.add_argument("--out-hi", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="paged_fused",
                    help="decode backend for the paged path "
                         "(jnp|gathered|paged_fused|ref|interpret|pallas)")
    ap.add_argument("--sweep", default="",
                    help="comma-separated max_len sweep for the "
                         "decode-step-vs-context scaling arms (e.g. "
                         "'512,2048,4096'; empty = skip)")
    ap.add_argument("--prefill-sweep", default="",
                    help="comma-separated long-prompt lengths for the "
                         "chunked-prefill TTFT A/B arms (jnp vs "
                         "page-native prefill backend, e.g. "
                         "'2048,4096,8192'; empty = skip)")
    ap.add_argument("--prefill-sweep-chunk", type=int, default=256,
                    help="chunk size for the --prefill-sweep arms")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="shared system-prompt length for the prefix-cache "
                         "A/B arm (0 = skip)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="chunked-prefill size for the shared-prefix arms")
    ap.add_argument("--suffix-lo", type=int, default=8)
    ap.add_argument("--suffix-hi", type=int, default=32)
    ap.add_argument("--spec-sweep", default="",
                    help="comma-separated draft-length sweep for the "
                         "speculative-decode A/B arms (ngram proposer, "
                         "e.g. '2,4,8'; empty = skip)")
    ap.add_argument("--spec-gen", type=int, default=192,
                    help="output tokens per request in the spec-sweep "
                         "arms")
    ap.add_argument("--runahead-sweep", default="",
                    help="comma-separated horizon sweep for the run-ahead "
                         "fused-decode A/B arms (e.g. '1,2,4,8'; the "
                         "first entry is the baseline, empty = skip); "
                         "each horizon runs at every --runahead-slots "
                         "count on a decode-bound workload")
    ap.add_argument("--runahead-slots", default="1,4,16",
                    help="comma-separated slot counts for the run-ahead "
                         "sweep grid")
    ap.add_argument("--runahead-gen", type=int, default=64,
                    help="output tokens per request in the run-ahead "
                         "sweep arms")
    ap.add_argument("--adversarial", action="store_true",
                    help="run the hostile-workload scenario suite "
                         "(overload soak, burst storms, cancel floods, "
                         "multi-tenant mix, stragglers) with "
                         "goodput-under-SLA A/B vs the no-QoS FCFS "
                         "baseline")
    ap.add_argument("--adversarial-requests", type=int, default=16,
                    help="requests per adversarial arm")
    ap.add_argument("--mesh-sweep", default="",
                    help="comma-separated device counts for the "
                         "multi-device serving sweep (e.g. '1,2,4'; "
                         "empty = skip). Each count runs the cb arm in a "
                         "subprocess under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N with a "
                         "1xN (data x model) mesh — fixed num_pages, so "
                         "total pool bytes stay constant while per-device "
                         "bytes shrink where kv_heads divides N")
    ap.add_argument("--mesh-shape", default="",
                    help="mesh for the cb arm, e.g. '1x2' (data x model); "
                         "used by the --mesh-arm subprocess mode")
    ap.add_argument("--mesh-arm", action="store_true",
                    help="internal: run ONLY the cb arm under --mesh-shape "
                         "and write a minimal JSON record to --json (the "
                         "--mesh-sweep driver invokes this per device "
                         "count so XLA device forcing precedes jax init)")
    ap.add_argument("--json", default="",
                    help="write machine-readable results to this path")
    args = ap.parse_args(argv)
    args.sweep = [int(x) for x in args.sweep.split(",") if x]
    args.prefill_sweep = [int(x) for x in args.prefill_sweep.split(",") if x]
    args.spec_sweep = [int(x) for x in args.spec_sweep.split(",") if x]
    args.mesh_sweep = [int(x) for x in args.mesh_sweep.split(",") if x]
    args.runahead_sweep = [int(x) for x in args.runahead_sweep.split(",")
                           if x]
    args.runahead_slots = [int(x) for x in args.runahead_slots.split(",")
                           if x]

    if args.mesh_arm:
        return run_mesh_arm(args)

    cfg = reduce_for_smoke(get_config(args.arch))
    # the static arm shares the requested backend (dense path normalizes
    # the paged dispatch names), keeping the cb-vs-static speedup apples
    # to apples
    model = get_model(dataclasses.replace(cfg, decode_backend=args.backend))
    params = model.init(jax.random.PRNGKey(0))

    print(f"# arch={cfg.name} quant={cfg.quant.method} "
          f"backend={args.backend} slots={args.slots} "
          f"requests={args.requests} rate={args.rate}/s")

    # --- continuous batching (requested backend + gathered baseline) ---
    res_cb = run_cb(cfg, params, args, backend=args.backend,
                    max_len=args.max_len)
    # the PR-2 formulation: gather_view copy + dense fused kernel over the
    # full-width table — isolates the structural gather-removal win
    res_base = run_cb(cfg, params, args, backend="gathered",
                      max_len=args.max_len, table_slicing=False)

    # --- static baseline ---
    res_st = run_static(model, params,
                        make_workload(args.requests, args.rate, args.seed,
                                      args.prompt_lo, args.prompt_hi,
                                      args.out_lo, args.out_hi),
                        args.slots, args.max_len)

    def row(name, r):
        extra = ""
        if "mean_page_utilization" in r:
            extra = (f" util={r['mean_page_utilization']:.2f}"
                     f" active={r['mean_active_slots']:.2f}"
                     f" dstep={r['decode_step_s_mean'] * 1e3:.2f}ms"
                     f" preempt={sum(q.preemptions for q in r['requests'])}")
        if "ttft_s" in r:
            extra += (f" ttft_p50={r['ttft_s']['p50'] * 1e3:.1f}ms"
                      f"/p99={r['ttft_s']['p99'] * 1e3:.1f}ms"
                      f" itl_p50={r['itl_s']['p50'] * 1e3:.1f}ms"
                      f"/p99={r['itl_s']['p99'] * 1e3:.1f}ms")
        print(f"{name:12s} tokens={r['total_tokens']:5d} "
              f"wall={r['wall_s']:7.3f}s "
              f"tok/s={r['tokens_per_s']:8.1f} "
              f"p50={r['p50_latency_s']:6.3f}s "
              f"p99={r['p99_latency_s']:6.3f}s{extra}")

    row(f"cb/{args.backend}", res_cb)
    row("cb/gathered", res_base)
    row("static", res_st)
    speedup = res_cb["tokens_per_s"] / max(res_st["tokens_per_s"], 1e-9)
    print(f"speedup(tokens/s cb vs static) = {speedup:.2f}x")
    fused_speedup = res_cb["tokens_per_s"] / max(res_base["tokens_per_s"],
                                                 1e-9)
    print(f"speedup(tokens/s {args.backend} vs gathered) = "
          f"{fused_speedup:.2f}x")

    sweep = run_context_sweep(cfg, params, args) if args.sweep else []
    prefill_sweep = (run_prefill_sweep(cfg, params, args)
                     if args.prefill_sweep else [])
    shared = (run_shared_prefix(cfg, params, args)
              if args.shared_prefix else None)
    spec_sweep = (run_spec_sweep(cfg, params, args)
                  if args.spec_sweep else None)
    runahead_sweep = (run_runahead_sweep(cfg, params, args)
                      if args.runahead_sweep else None)
    adversarial = (run_adversarial(cfg, params, args)
                   if args.adversarial else None)
    mesh_sweep = run_mesh_sweep(args) if args.mesh_sweep else None

    if args.json:
        import json
        payload = {
            "arch": cfg.name,
            "quant": cfg.quant.method,
            "backend": args.backend,
            "workload": {
                "requests": args.requests, "rate": args.rate,
                "slots": args.slots, "max_len": args.max_len,
                "prompt": [args.prompt_lo, args.prompt_hi],
                "out": [args.out_lo, args.out_hi], "seed": args.seed,
            },
            "continuous": _strip_requests(res_cb),
            "gathered_baseline": _strip_requests(res_base),
            "static": _strip_requests(res_st),
            "speedup_cb_vs_static": speedup,
            "speedup_fused_vs_gathered": fused_speedup,
            "context_sweep": sweep,
            "prefill_sweep": prefill_sweep,
            "shared_prefix": shared,
            "spec_sweep": spec_sweep,
            "runahead_sweep": runahead_sweep,
            "adversarial": adversarial,
            "mesh_sweep": mesh_sweep,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    if shared is not None and not shared["outputs_bit_identical"]:
        return 1   # prefix reuse must never change greedy outputs
    if any(not a["outputs_bit_identical"] for a in prefill_sweep):
        return 1   # the fused prefill must never change greedy outputs
    if spec_sweep is not None and not spec_sweep["outputs_bit_identical"]:
        return 1   # speculation must never change greedy outputs
    if runahead_sweep is not None and \
            not runahead_sweep["outputs_bit_identical"]:
        return 1   # run-ahead must never change greedy outputs
    if adversarial is not None and not adversarial["soak_gate_ok"]:
        return 1   # QoS must beat FCFS on deadline-met goodput under
        # sustained overload — the suite's acceptance gate
    if mesh_sweep is not None and \
            not mesh_sweep["outputs_identical_across_devices"]:
        return 1   # sharding must never change greedy outputs
    # when both engines keep up with the Poisson arrivals, tokens/s
    # converges to the offered load for everyone — the continuous-batching
    # win then shows up as per-request latency, not throughput
    ok = (speedup > 1.0
          or res_cb["p50_latency_s"] < res_st["p50_latency_s"])
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
