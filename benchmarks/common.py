"""Shared benchmark utilities: data generators, timing, CSV emission."""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def rope_structured_keys(key, b, h, t, d, outlier_channels=4,
                         rope_base=10000.0, outlier_scale=10.0):
    """Keys with the paper's structure: consistent-magnitude pre-RoPE
    outlier channels in low-frequency rotary pairs, rotated by RoPE."""
    from repro.models.layers import apply_rope
    k1, k2, k3 = jax.random.split(key, 3)
    half = d // 2
    lo = 3 * half // 4
    idx = lo + jax.random.choice(k2, half - lo, (outlier_channels,),
                                 replace=False)
    mean = jnp.zeros((d,))
    signs = jax.random.rademacher(k3, (outlier_channels,), jnp.float32)
    mean = mean.at[idx].set(outlier_scale * signs)
    pre = jax.random.normal(k1, (b, h, t, d)) + mean
    pos = jnp.arange(t, dtype=jnp.int32)
    return apply_rope(pre, pos, rope_base)


def attention_output_error(q, k, k_tilde, v, scale=None):
    """Relative error of softmax(qk)v under key substitution (fp32)."""
    d = q.shape[-1]
    scale = d ** -0.5 if scale is None else scale
    s = jnp.einsum("bhqd,bhtd->bhqt", q * scale, k)
    st = jnp.einsum("bhqd,bhtd->bhqt", q * scale, k_tilde)
    o = jnp.einsum("bhqt,bhtd->bhqd", jax.nn.softmax(s, -1), v)
    ot = jnp.einsum("bhqt,bhtd->bhqd", jax.nn.softmax(st, -1), v)
    return float(jnp.linalg.norm(o - ot) / jnp.linalg.norm(o))


def time_fn(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall-clock microseconds per call (jit'd fn)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}")
