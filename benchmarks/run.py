"""Benchmark entrypoint: one harness per paper table (DESIGN.md §6).

Emits ``name,us_per_call,derived`` CSV rows. Run as:
    PYTHONPATH=src python -m benchmarks.run [--only <substr>]
"""
from __future__ import annotations

import argparse
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="substring filter")
    ap.add_argument("--trace-dir", default="",
                    help="write a jax.profiler trace of the selected "
                         "suites to this directory (host-vs-device "
                         "timeline: dispatch gaps, blocking fetches, "
                         "kernel spans; view with TensorBoard or "
                         "ui.perfetto.dev)")
    args = ap.parse_args()

    from benchmarks import (bench_bitwidth, bench_eviction_compat,
                            bench_group_size, bench_kernel_latency,
                            bench_kv_sensitivity, bench_quant_error,
                            bench_serving, bench_throughput, roofline)

    def serving_json():
        """Small serving run + context sweep -> BENCH_serving.json, so the
        decode-step perf trajectory is tracked across PRs. The CB arms run
        through the streaming event API, so the JSON also records honest
        per-token TTFT / inter-token-latency percentiles."""
        rc = bench_serving.main([
            "--requests", "10", "--slots", "3", "--max-len", "192",
            "--out-lo", "4", "--out-hi", "24",
            "--sweep", "192,512,2048", "--shared-prefix", "96",
            "--prefill-sweep", "2048,4096,8192",
            "--spec-sweep", "2,4,8",
            "--runahead-sweep", "1,2,4,8",
            "--adversarial", "--adversarial-requests", "14",
            "--mesh-sweep", "1,2,4",
            "--json", "BENCH_serving.json"])
        if rc:
            raise RuntimeError(
                "serving regression: continuous batching lost to the "
                "static baseline, prefix reuse / the fused prefill "
                "backend / speculative decode / run-ahead fused decode "
                "changed greedy outputs, QoS lost to FCFS on "
                "deadline-met goodput under the overload soak, or the "
                "mesh sweep's sharded greedy outputs diverged across "
                "device counts")

    suites = [
        ("quant_error(T1)", bench_quant_error.run),
        ("reasoning_proxy(T2/T3)", bench_quant_error.run_reasoning_proxy),
        ("kernel_latency(T4/F3)", bench_kernel_latency.run),
        ("throughput(T4)", bench_throughput.run),
        ("group_size(T5)", bench_group_size.run),
        ("bitwidth(T6)", bench_bitwidth.run),
        ("bitwidth_mixed(KVTuner)", bench_bitwidth.run_mixed_policies),
        ("kv_sensitivity(T7/T9)", bench_kv_sensitivity.run),
        ("eviction(T8)", bench_eviction_compat.run),
        ("serving(CB/paged-fused)", serving_json),
        ("roofline(dryrun)", roofline.run),
    ]
    if args.trace_dir:
        import jax
        jax.profiler.start_trace(args.trace_dir)
    failures = 0
    try:
        for name, fn in suites:
            if args.only and args.only not in name:
                continue
            print(f"== {name} ==")
            t0 = time.monotonic()
            try:
                fn()
            except Exception:  # noqa: BLE001
                failures += 1
                traceback.print_exc()
            print(f"== {name} done in {time.monotonic() - t0:.1f}s ==")
    finally:
        if args.trace_dir:
            import jax
            jax.profiler.stop_trace()
            print(f"wrote jax.profiler trace to {args.trace_dir}")
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
