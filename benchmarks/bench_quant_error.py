"""Table 1/2/3 analog: quantization quality per method at 3/4-bit.

The paper evaluates LongBench/GSM8K accuracy; offline (no datasets/models)
we measure the mechanism itself on RoPE-structured keys:
  * key reconstruction error,
  * attention-output error (the quantity that drives downstream drops),
  * next-token top-1 agreement on a briefly-trained tiny LM (logit proxy
    for the accuracy tables).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import attention_output_error, emit, rope_structured_keys
from repro.core.quantizers import (QuantConfig, decode_keys, encode_keys)

METHODS_4BIT = [
    ("int4", QuantConfig(method="int", key_bits=4)),
    ("zipcache4", QuantConfig(method="zipcache", key_bits=4, group_size=128)),
    ("kivi4", QuantConfig(method="kivi", key_bits=4, group_size=128)),
    ("polar44", QuantConfig(method="polar", rho_bits=4, theta_bits=4,
                            group_size=128)),
]
METHODS_3BIT = [
    ("int3", QuantConfig(method="int", key_bits=3)),
    ("zipcache3", QuantConfig(method="zipcache", key_bits=3, group_size=128)),
    ("kivi2", QuantConfig(method="kivi", key_bits=2, group_size=32)),
    ("polar33", QuantConfig(method="polar", rho_bits=3, theta_bits=3,
                            group_size=128)),
]


def run() -> None:
    key = jax.random.PRNGKey(0)
    b, h, t, d = 2, 4, 2048, 128
    k = rope_structured_keys(key, b, h, t, d)
    q = jax.random.normal(jax.random.PRNGKey(1), (b, h, 8, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, h, t, d))

    for methods, tag in [(METHODS_4BIT, "4bit"), (METHODS_3BIT, "3bit")]:
        for name, cfg in methods:
            kt = decode_keys(encode_keys(k, cfg))
            rec = float(jnp.linalg.norm(k - kt) / jnp.linalg.norm(k))
            att = attention_output_error(q, k, kt, v)
            emit(f"quant_error/{tag}/{name}", 0.0,
                 f"bits={cfg.key_bits_per_element(d):.2f};rec_rel={rec:.4f};"
                 f"attn_rel={att:.4f}")


def run_reasoning_proxy() -> None:
    """Table 2/3 proxy: top-1 agreement + logit KL on a trained tiny LM,
    across generation length (error accumulation, Table 3's concern)."""
    from repro.configs import get_config, reduce_for_smoke
    from repro.data import SyntheticLMDataset
    from repro.models import get_model
    from repro.train.train_step import (StepConfig, init_train_state,
                                        make_train_step)

    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    m = get_model(cfg)
    ds = SyntheticLMDataset(cfg, global_batch=8, seq_len=64, seed=0)
    step = make_train_step(m, None, StepConfig(peak_lr=2e-3, warmup_steps=5,
                                               total_steps=80))
    state = init_train_state(m, jax.random.PRNGKey(0))
    for _ in range(80):
        batch = {kk: jnp.asarray(vv) for kk, vv in ds.next_batch().items()}
        state, _ = step(state, batch)
    params = state.params

    def decode_run(method, horizon=16):
        qcfg = dataclasses.replace(cfg.quant, method=method)
        mm = get_model(dataclasses.replace(cfg, quant=qcfg))
        toks = jnp.asarray(ds.local_batch_np(999)["tokens"])[:, :33]
        st = mm.init_decode_state(toks.shape[0], 96)
        lg, st = mm.prefill(params, {"tokens": toks[:, :32]}, st)
        outs = [lg]
        tok = jnp.argmax(lg, -1)
        dec = jax.jit(mm.decode)
        for _ in range(horizon):
            lg, st = dec(params, st, tok)
            tok = jnp.argmax(lg, -1)
            outs.append(lg)
        return jnp.stack(outs)

    fp = decode_run("none")
    for method in ("polar", "kivi", "int"):
        qx = decode_run(method)
        for lo, hi, tag in [(0, 8, "early"), (8, 17, "late")]:
            agree = float((jnp.argmax(fp[lo:hi], -1) ==
                           jnp.argmax(qx[lo:hi], -1)).mean())
            p = jax.nn.log_softmax(fp[lo:hi].astype(jnp.float32))
            qlp = jax.nn.log_softmax(qx[lo:hi].astype(jnp.float32))
            kl = float(jnp.mean(jnp.sum(jnp.exp(p) * (p - qlp), -1)))
            emit(f"reasoning_proxy/{method}/{tag}", 0.0,
                 f"top1_agree={agree:.3f};kl={kl:.4f}")


if __name__ == "__main__":
    run()
    run_reasoning_proxy()
