"""Table 6 analog: asymmetric (r, t) bitwidth allocation ablation."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import attention_output_error, emit, rope_structured_keys
from repro.core.quantizers import (QuantConfig, decode_polar_keys,
                                   encode_polar_keys)


def run() -> None:
    key = jax.random.PRNGKey(0)
    b, h, t, d = 2, 4, 2048, 128
    k = rope_structured_keys(key, b, h, t, d)
    q = jax.random.normal(jax.random.PRNGKey(1), (b, h, 8, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, h, t, d))
    for r, tb in [(5, 3), (4, 4), (3, 5), (4, 2), (3, 3), (2, 4)]:
        cfg = QuantConfig(method="polar", rho_bits=r, theta_bits=tb,
                          group_size=128)
        kt = decode_polar_keys(encode_polar_keys(k, cfg))
        rec = float(jnp.linalg.norm(k - kt) / jnp.linalg.norm(k))
        att = attention_output_error(q, k, kt, v)
        emit(f"bitwidth/r{r}t{tb}", 0.0,
             f"bits={(r + tb) / 2:.1f};rec_rel={rec:.4f};attn_rel={att:.4f}")
    # beyond-paper variant: fixed (0, 2pi] theta grid — drops the per-group
    # theta stats (saves 16/g bits/element of overhead) at some error cost
    cfg = QuantConfig(method="polar", rho_bits=4, theta_bits=4,
                      group_size=128, theta_stats="fixed")
    kt = decode_polar_keys(encode_polar_keys(k, cfg))
    rec = float(jnp.linalg.norm(k - kt) / jnp.linalg.norm(k))
    att = attention_output_error(q, k, kt, v)
    emit("bitwidth/r4t4_fixed_theta", 0.0,
         f"bits=4.0;rec_rel={rec:.4f};attn_rel={att:.4f}")


if __name__ == "__main__":
    run()
