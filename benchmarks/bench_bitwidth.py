"""Table 6 analog: asymmetric (r, t) bitwidth allocation ablation, plus a
per-layer mixed-policy sweep (KVTuner-style): uniform polar vs
int8-on-the-first-k-layers mixes, printed as an accuracy-vs-avg-bits
frontier."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import attention_output_error, emit, rope_structured_keys
from repro.core import CachePolicy
from repro.core.quantizers import (QuantConfig, decode_keys, encode_keys)


def run() -> None:
    key = jax.random.PRNGKey(0)
    b, h, t, d = 2, 4, 2048, 128
    k = rope_structured_keys(key, b, h, t, d)
    q = jax.random.normal(jax.random.PRNGKey(1), (b, h, 8, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, h, t, d))
    for r, tb in [(5, 3), (4, 4), (3, 5), (4, 2), (3, 3), (2, 4)]:
        cfg = QuantConfig(method="polar", rho_bits=r, theta_bits=tb,
                          group_size=128)
        kt = decode_keys(encode_keys(k, cfg))
        rec = float(jnp.linalg.norm(k - kt) / jnp.linalg.norm(k))
        att = attention_output_error(q, k, kt, v)
        emit(f"bitwidth/r{r}t{tb}", 0.0,
             f"bits={(r + tb) / 2:.1f};rec_rel={rec:.4f};attn_rel={att:.4f}")
    # beyond-paper variant: fixed (0, 2pi] theta grid — drops the per-group
    # theta stats (saves 16/g bits/element of overhead) at some error cost
    cfg = QuantConfig(method="polar", rho_bits=4, theta_bits=4,
                      group_size=128, theta_stats="fixed")
    kt = decode_keys(encode_keys(k, cfg))
    rec = float(jnp.linalg.norm(k - kt) / jnp.linalg.norm(k))
    att = attention_output_error(q, k, kt, v)
    emit("bitwidth/r4t4_fixed_theta", 0.0,
         f"bits=4.0;rec_rel={rec:.4f};attn_rel={att:.4f}")


def run_mixed_policies(num_layers: int = 8) -> None:
    """Accuracy-vs-avg-bits frontier over per-layer CachePolicy mixes.

    Each layer gets its own key distribution (layer-seeded synthetic keys);
    a policy's "accuracy" proxy is the mean attention-output error across
    layers under that layer's QuantConfig, and its cost is
    ``CachePolicy.avg_key_bits`` — the same accounting the serving path
    reports. Mixes: uniform polar at several (r, t), uniform int8, and
    int8 on the first k layers (the KVTuner observation that early layers
    are the sensitive ones) with polar 4+4 on the rest.
    """
    b, h, t, d = 2, 4, 1024, 128
    int8 = QuantConfig(method="int", key_bits=8, group_size=128)
    polar44 = QuantConfig(method="polar", rho_bits=4, theta_bits=4,
                          group_size=128)
    policies: list[tuple[str, CachePolicy]] = [
        ("uniform_polar33", CachePolicy.uniform(
            dataclasses.replace(polar44, rho_bits=3, theta_bits=3))),
        ("uniform_polar44", CachePolicy.uniform(polar44)),
        ("uniform_polar53", CachePolicy.uniform(
            dataclasses.replace(polar44, rho_bits=5, theta_bits=3))),
        ("uniform_int8", CachePolicy.uniform(int8)),
    ]
    for kk in (1, 2, 4):
        policies.append((f"int8_first{kk}_polar44",
                         CachePolicy.first_k(kk, int8, polar44)))

    # per-layer synthetic keys/queries (distinct outlier structure per layer)
    layers = []
    for i in range(num_layers):
        kl = rope_structured_keys(jax.random.PRNGKey(100 + i), b, h, t, d)
        ql = jax.random.normal(jax.random.PRNGKey(200 + i), (b, h, 8, d))
        vl = jax.random.normal(jax.random.PRNGKey(300 + i), (b, h, t, d))
        layers.append((kl, ql, vl))

    err_cache: dict[tuple, float] = {}

    def layer_err(i: int, qc: QuantConfig) -> float:
        ck = (i, qc)
        if ck not in err_cache:
            kl, ql, vl = layers[i]
            kt = decode_keys(encode_keys(kl, qc))
            err_cache[ck] = attention_output_error(ql, kl, kt, vl)
        return err_cache[ck]

    frontier = []
    for name, pol in policies:
        bits = pol.avg_key_bits(num_layers, d)
        err = sum(layer_err(i, pol.layer_config(i))
                  for i in range(num_layers)) / num_layers
        frontier.append((bits, err, name))
    for bits, err, name in sorted(frontier):
        emit(f"bitwidth/mixed/{name}", 0.0,
             f"avg_bits={bits:.3f};attn_rel={err:.4f}")


if __name__ == "__main__":
    run()
    run_mixed_policies()
