"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/<mesh>/<arch>/<shape>.json (produced by
repro.launch.dryrun) and derives, per cell:

  compute_s    = HLO_FLOPs_per_device / peak_FLOPs          (197 TF bf16)
  memory_s     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
  collective_s = collective_wire_bytes_per_device / ICI_bw  (~50 GB/s)

plus MODEL_FLOPS (analytic 6ND / 2ND) vs compiled-FLOPs utilization.
``compiled.cost_analysis()`` on the SPMD module reports per-device values;
collective bytes come from the partitioned-HLO census (per-device payload
x ring factor).
"""
from __future__ import annotations

import json
import os
from typing import Optional

from repro.configs import SHAPES, get_config
from repro.launch.dryrun_lib import HBM_BW, ICI_BW, PEAK_FLOPS, roofline_terms

ART = os.environ.get("DRYRUN_ARTIFACTS", "artifacts/dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic 'useful' FLOPs for the whole step (all devices)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.param_count()
    # exclude the input-embedding TABLE (a gather, ~0 flops) but keep the
    # lm-head matmul (for 256K-vocab archs it IS the dominant matmul); a
    # tied table serves as the lm head, so only the untied case subtracts.
    n_eff = n - (cfg.vocab_size * cfg.d_model if not cfg.tie_embeddings else 0)
    if cfg.family == "moe":
        eff = cfg.moe_d_ff or cfg.d_ff
        routed = cfg.num_experts * 3 * cfg.d_model * eff * cfg.num_layers
        active = (cfg.top_k / cfg.num_experts) * routed
        n_eff = n_eff - routed + active + \
            cfg.num_shared_experts * 3 * cfg.d_model * eff * cfg.num_layers
    tokens = shape.global_batch * shape.seq_len
    kv_span = min(shape.seq_len, cfg.window) if cfg.window else shape.seq_len
    n_attn_layers = cfg.num_layers
    if cfg.family == "hybrid" and cfg.block_pattern:
        n_attn_layers = sum(
            1 for i in range(cfg.num_layers)
            if cfg.block_pattern[i % len(cfg.block_pattern)] == "attn")
    if shape.kind == "train":
        base = 6.0 * n_eff * tokens
        attn = 12.0 * n_attn_layers * cfg.num_heads * cfg.head_dim * \
            shape.global_batch * shape.seq_len * kv_span / 2 \
            if cfg.num_heads else 0.0
        return base + attn
    if shape.kind == "prefill":
        base = 2.0 * n_eff * tokens
        attn = 4.0 * n_attn_layers * cfg.num_heads * cfg.head_dim * \
            shape.global_batch * shape.seq_len * kv_span / 2 \
            if cfg.num_heads else 0.0
        return base + attn
    # decode: one token per sequence
    base = 2.0 * n_eff * shape.global_batch
    attn = (4.0 * n_attn_layers * cfg.num_heads * cfg.head_dim *
            shape.global_batch * kv_span if cfg.num_heads else 0.0)
    return base + attn


def load_cell(mesh_tag: str, arch: str, shape: str) -> Optional[dict]:
    path = os.path.join(ART, mesh_tag, arch, f"{shape}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def analyze(mesh_tag: str = "single_16x16", devices: int = 256) -> list[dict]:
    from repro.configs import ARCH_IDS
    rows = []
    for arch in ARCH_IDS:
        for shape in sorted(SHAPES):
            rec = load_cell(mesh_tag, arch, shape)
            if rec is None:
                continue
            if rec["status"] == "skip":
                rows.append({"arch": arch, "shape": shape, "status": "skip",
                             "reason": rec["reason"]})
                continue
            terms = roofline_terms(rec, devices)
            dom = max(terms, key=terms.get)
            mf = model_flops(arch, shape)
            hlo_global = rec["cost"].get("flops", 0.0) * devices
            util = mf / hlo_global if hlo_global else 0.0
            bound = max(terms.values())
            frac = (terms["compute_s"] / bound) if bound else 0.0
            rows.append({
                "arch": arch, "shape": shape, "status": "ok",
                **{k: v for k, v in terms.items()},
                "dominant": dom.replace("_s", ""),
                "model_flops": mf,
                "hlo_flops_global": hlo_global,
                "useful_ratio": util,
                "roofline_fraction": frac,
                "peak_gib": rec["memory"]["peak_per_device"] / 2 ** 30,
                "fits": rec["memory"]["fits_16g_hbm"],
            })
    return rows


_MITIGATE = {
    "compute": "raise MXU utilization (larger per-device tiles, fuse "
               "elementwise chains, bf16 everywhere)",
    "memory": "cut HBM traffic (quantized cache reads, fuse dequant into "
              "consumers, avoid fp32 spills)",
    "collective": "re-shard to remove the largest all-gather/all-reduce or "
                  "overlap it with compute",
}


def print_table(mesh_tag: str = "single_16x16", devices: int = 256) -> None:
    rows = analyze(mesh_tag, devices)
    print(f"# Roofline [{mesh_tag}] peak={PEAK_FLOPS/1e12:.0f}TF "
          f"hbm={HBM_BW/1e9:.0f}GB/s ici={ICI_BW/1e9:.0f}GB/s")
    hdr = ("arch,shape,compute_s,memory_s,collective_s,dominant,"
           "useful_ratio,roofline_frac,peak_gib,fits,mitigation")
    print(hdr)
    for r in rows:
        if r["status"] == "skip":
            print(f"{r['arch']},{r['shape']},SKIP,,,,,,,,{r['reason'][:50]}")
            continue
        print(f"{r['arch']},{r['shape']},{r['compute_s']:.2e},"
              f"{r['memory_s']:.2e},{r['collective_s']:.2e},{r['dominant']},"
              f"{r['useful_ratio']:.3f},{r['roofline_fraction']:.3f},"
              f"{r['peak_gib']:.2f},{r['fits']},"
              f"\"{_MITIGATE[r['dominant']]}\"")


def run() -> None:
    for tag, dev in [("single_16x16", 256), ("multi_2x16x16", 512)]:
        if os.path.isdir(os.path.join(ART, tag)):
            print_table(tag, dev)


if __name__ == "__main__":
    run()
