"""End-to-end behaviour tests: the paper's pipeline on a real (tiny) model.

Trains a small LM briefly, then verifies the PolarQuant serving claims on
its *learned* key distributions: (1) quantized decode preserves outputs,
(2) key-vs-value sensitivity (paper §D / Table 9).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.data import SyntheticLMDataset
from repro.models import get_model
from repro.train.train_step import StepConfig, init_train_state, make_train_step


@pytest.fixture(scope="module")
def trained():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    m = get_model(cfg)
    ds = SyntheticLMDataset(cfg, global_batch=8, seq_len=64, seed=0)
    step = make_train_step(m, None, StepConfig(peak_lr=2e-3, warmup_steps=5,
                                               total_steps=60))
    state = init_train_state(m, jax.random.PRNGKey(0))
    for _ in range(60):
        batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
        state, metrics = step(state, batch)
    return cfg, m, state.params, ds


def _decode_logits(cfg, params, ds, method, value_bits=0, rho=4, theta=4):
    qcfg = dataclasses.replace(cfg.quant, method=method,
                               value_bits=value_bits,
                               rho_bits=rho, theta_bits=theta)
    mcfg = dataclasses.replace(cfg, quant=qcfg)
    m = get_model(mcfg)
    toks = jnp.asarray(ds.local_batch_np(123)["tokens"])[:, :49]
    state = m.init_decode_state(toks.shape[0], 128)
    lg, state = m.prefill(params, {"tokens": toks[:, :48]}, state)
    outs = [lg]
    for i in range(3):
        lg, state = m.decode(params, state, toks[:, 48])
        outs.append(lg)
    return jnp.stack(outs)


def test_trained_loss_reasonable(trained):
    cfg, m, params, ds = trained
    batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
    loss, _ = m.loss(params, batch)
    assert float(loss) < 6.1  # well below ln(512)=6.24 after 60 steps


def test_polar_decode_preserves_trained_model(trained):
    cfg, m, params, ds = trained
    fp = _decode_logits(cfg, params, ds, "none")
    pq = _decode_logits(cfg, params, ds, "polar")
    agree = float((jnp.argmax(fp, -1) == jnp.argmax(pq, -1)).mean())
    assert agree >= 0.75, agree


def test_key_more_sensitive_than_value(trained):
    """Paper §D / Table 9: quantizing keys hurts more than values."""
    cfg, m, params, ds = trained
    fp = _decode_logits(cfg, params, ds, "none")
    k_only = _decode_logits(cfg, params, ds, "polar", value_bits=0,
                            rho=2, theta=2)
    v_only = _decode_logits(cfg, params, ds, "none", value_bits=4)
    gap_k = float(jnp.linalg.norm(k_only - fp))
    gap_v = float(jnp.linalg.norm(v_only - fp))
    assert gap_v < gap_k, (gap_v, gap_k)
