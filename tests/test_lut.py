"""LUT decode math: equivalence with dequantize-then-matmul (Appendix A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.core.lut import build_angle_table, dequant_qk_scores, lut_qk_scores
from repro.core.quantizers import QuantConfig, encode_polar_keys


@pytest.mark.parametrize("r,t", [(4, 4), (3, 3), (5, 3), (2, 4)])
def test_lut_equals_dequant(r, t):
    key = jax.random.PRNGKey(0)
    k = jax.random.normal(key, (2, 3, 64, 32))
    q = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 32))
    cfg = QuantConfig(method="polar", rho_bits=r, theta_bits=t, group_size=16)
    pk = encode_polar_keys(k, cfg)
    s_lut = lut_qk_scores(q, pk)
    s_deq = dequant_qk_scores(q, pk)
    np.testing.assert_allclose(np.asarray(s_lut), np.asarray(s_deq),
                               rtol=1e-4, atol=1e-4)


def test_angle_table_shape_and_content():
    q = jax.random.normal(jax.random.PRNGKey(2), (2, 1, 16))
    ts = jnp.full((2, 1, 4, 1, 8), 0.3)
    tz = jnp.zeros((2, 1, 4, 1, 8))
    a = build_angle_table(q, ts, tz, theta_bits=3)
    assert a.shape == (2, 1, 4, 8, 8)
    # state s has angle (s + .5) * .3; check one entry by hand
    qx, qy = q[..., :8], q[..., 8:]
    th = (jnp.arange(8) + 0.5) * 0.3 - jnp.pi
    expect = qx[0, 0, 0] * jnp.cos(th[2]) + qy[0, 0, 0] * jnp.sin(th[2])
    np.testing.assert_allclose(float(a[0, 0, 0, 0, 2]), float(expect),
                               rtol=1e-5)


def test_lut_table_is_finite_state():
    """Every LUT score must equal q . center-of-region for its code —
    i.e. only 2^(r+t) distinct dequantized sub-vectors exist per channel."""
    key = jax.random.PRNGKey(3)
    k = jax.random.normal(key, (1, 1, 32, 8))
    cfg = QuantConfig(method="polar", rho_bits=2, theta_bits=2, group_size=32)
    pk = encode_polar_keys(k, cfg)
    from repro.core.quantizers import decode_polar_keys
    kt = decode_polar_keys(pk)
    # per channel pair, count distinct reconstructed (x, y)
    from repro.core.polar import split_pairs
    x, y = split_pairs(kt)
    for j in range(4):
        pts = {(round(float(a), 5), round(float(b), 5))
               for a, b in zip(x[0, 0, :, j], y[0, 0, :, j])}
        assert len(pts) <= 16  # 2^(2+2)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([(4, 4), (3, 3), (3, 5)]))
def test_lut_equivalence_hypothesis(seed, rt):
    r, t = rt
    k = jax.random.normal(jax.random.PRNGKey(seed), (1, 2, 32, 16)) * 3
    q = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 2, 16))
    cfg = QuantConfig(method="polar", rho_bits=r, theta_bits=t, group_size=16)
    pk = encode_polar_keys(k, cfg)
    np.testing.assert_allclose(np.asarray(lut_qk_scores(q, pk)),
                               np.asarray(dequant_qk_scores(q, pk)),
                               rtol=2e-4, atol=2e-4)
