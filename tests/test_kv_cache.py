"""KV cache invariants: streaming parity, ring semantics, masks, values."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.core import QuantConfig, append, decode_attention, init_cache, prefill
from repro.core.kv_cache import position_masks


def _kv(seed, b, h, t, d):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return jax.random.normal(k1, (b, h, t, d)), jax.random.normal(k2, (b, h, t, d))


@pytest.mark.parametrize("method", ["polar", "kivi", "zipcache", "int", "none"])
def test_prefill_equals_streaming(method):
    """Bulk prefill and token-by-token append must agree.

    Polar (floor grid) and fp caches agree bit-exactly; the round-to-nearest
    (midtread) baselines may flip codes at exact .5 boundaries when XLA
    fuses the two paths differently, so they get a one-quantization-step
    tolerance."""
    B, H, d, g, T = 1, 2, 32, 16, 70
    k, v = _kv(0, B, H, T, d)
    cfg = QuantConfig(method=method, group_size=g, key_bits=4)
    ca = prefill(init_cache(cfg, B, H, d, 128), k, v)
    cb = init_cache(cfg, B, H, d, 128)
    ap = jax.jit(append)
    for i in range(T):
        cb = ap(cb, k[:, :, i : i + 1], v[:, :, i : i + 1])
    q = jax.random.normal(jax.random.PRNGKey(9), (B, H * 2, d))
    oa, ob = decode_attention(ca, q), decode_attention(cb, q)
    atol = 2e-6 if method in ("polar", "none") else 1.5e-2
    np.testing.assert_allclose(np.asarray(oa), np.asarray(ob),
                               atol=atol, rtol=1e-5)
    if method == "polar":
        np.testing.assert_array_equal(np.asarray(ca.key_codes),
                                      np.asarray(cb.key_codes))


@pytest.mark.parametrize("method", ["polar", "none"])
def test_ring_window_attention(method):
    """Ring cache == oracle attention over the last `window` tokens."""
    B, H, d, W, T = 1, 2, 32, 64, 200
    k, v = _kv(1, B, H, T, d)
    cfg = QuantConfig(method=method, group_size=16,
                      residual_dtype="float32")
    cache = init_cache(cfg, B, H, d, W, dtype=jnp.float32)
    ap = jax.jit(append)
    for i in range(T):
        cache = ap(cache, k[:, :, i : i + 1], v[:, :, i : i + 1])
    q = jax.random.normal(jax.random.PRNGKey(2), (B, H, d))
    out = decode_attention(cache, q, window=W)
    s = jnp.einsum("bhd,bhtd->bht", q * d ** -0.5, k[:, :, T - W :])
    oracle = jnp.einsum("bht,bhtd->bhd", jax.nn.softmax(s, -1), v[:, :, T - W :])
    tol = 0.35 if method == "polar" else 1e-4
    rel = float(jnp.linalg.norm(out - oracle) / jnp.linalg.norm(oracle))
    assert rel < tol, rel


def test_ring_prefill_matches_append():
    B, H, d, W, T = 1, 1, 16, 32, 100
    k, v = _kv(2, B, H, T, d)
    cfg = QuantConfig(method="polar", group_size=16, residual_dtype="float32")
    ca = prefill(init_cache(cfg, B, H, d, W), k, v)
    cb = init_cache(cfg, B, H, d, W)
    for i in range(T):
        cb = append(cb, k[:, :, i : i + 1], v[:, :, i : i + 1])
    np.testing.assert_array_equal(np.asarray(ca.key_codes),
                                  np.asarray(cb.key_codes))
    q = jax.random.normal(jax.random.PRNGKey(3), (B, H, d))
    np.testing.assert_allclose(
        np.asarray(decode_attention(ca, q, window=W)),
        np.asarray(decode_attention(cb, q, window=W)), atol=1e-6)


def test_quantized_values():
    B, H, d, T = 2, 2, 32, 96
    k, v = _kv(3, B, H, T, d)
    q = jax.random.normal(jax.random.PRNGKey(4), (B, H, d))
    cfg_fp = QuantConfig(method="polar", group_size=32, value_bits=0)
    cfg_q = QuantConfig(method="polar", group_size=32, value_bits=4)
    o_fp = decode_attention(prefill(init_cache(cfg_fp, B, H, d, 128), k, v), q)
    o_q = decode_attention(prefill(init_cache(cfg_q, B, H, d, 128), k, v), q)
    rel = float(jnp.linalg.norm(o_q - o_fp) / jnp.linalg.norm(o_fp))
    assert rel < 0.1, rel


def test_cache_memory_footprint():
    """PolarQuant codes cut key bytes ~4x vs bf16 (plus group stats)."""
    from repro.utils import tree_bytes
    B, H, d, T = 4, 4, 128, 4096
    c_fp = init_cache(QuantConfig(method="none"), B, H, d, T)
    c_pq = init_cache(QuantConfig(method="polar", group_size=128), B, H, d, T)
    key_fp = c_fp.key_codes.size * 2  # fp passthrough stores keys in key_codes
    key_pq = (c_pq.key_codes.size
              + sum(a.size * 4 for a in c_pq.key_scales.values())
              + c_pq.key_residual.size * 2)
    assert key_pq < 0.40 * key_fp  # ~0.31 expected (8/16 phys + stats fp32)


# ---------------------------------------------------------------------------
# position mask properties
# ---------------------------------------------------------------------------


@settings(max_examples=200, deadline=None)
@given(st.integers(0, 400), st.sampled_from([(64, 16, 64), (128, 32, 128),
                                             (64, 16, 0)]))
def test_position_masks_properties(length, cap_g_window):
    cap, g, window = cap_g_window
    if window == 0:
        length = min(length, cap)  # linear-cache contract: length <= capacity
    valid_g, in_res, flushed = position_masks(cap, g, jnp.asarray(length), window)
    valid_g, in_res = np.asarray(valid_g), np.asarray(in_res)
    fl = int(flushed)
    # never both
    assert not (valid_g & in_res).any()
    # residual count == length - flushed (capped at visible slots)
    assert in_res.sum() == min(length - fl, g)
    # grouped valid count == min(flushed, window bound)
    if window:
        expect = max(min(fl, window - (length - fl)), 0)
        assert valid_g.sum() == min(expect, cap)
    else:
        assert valid_g.sum() == min(fl, cap)
