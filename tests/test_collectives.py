"""Int8 error-feedback gradient compression: exactness-over-time property.

Runs in a subprocess with 4 forced host devices (the main test process
must keep seeing 1 device)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.collectives import ef_allreduce_mean

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4,), ("dp",))
    key = jax.random.PRNGKey(0)
    true_acc = np.zeros((64,), np.float32)
    comp_acc = np.zeros((64,), np.float32)
    errors = {"g": jnp.zeros((4, 64), jnp.float32)}
    worst_single = 0.0
    for step in range(30):
        key, k = jax.random.split(key)
        g = jax.random.normal(k, (4, 64)) * (1.0 + step % 3)
        mean, errors = ef_allreduce_mean({"g": g}, errors, mesh, "dp")
        tm = np.asarray(jnp.mean(g, 0))
        cm = np.asarray(mean["g"])
        worst_single = max(worst_single,
                           float(np.linalg.norm(cm - tm) / np.linalg.norm(tm)))
        true_acc += tm
        comp_acc += cm
    # error feedback: the ACCUMULATED compressed mean tracks the true mean
    # far better than any single compressed step (bias is carried forward)
    rel = np.linalg.norm(comp_acc - true_acc) / np.linalg.norm(true_acc)
    print("REL", rel, "WORST", worst_single)
    assert rel < 0.01, rel
    assert rel < worst_single, (rel, worst_single)
    print("OK")
""")


def test_ef_allreduce_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=300)
    assert "OK" in r.stdout, r.stdout + r.stderr
