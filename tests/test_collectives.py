"""Distributed collectives: int8 error-feedback gradient compression
(exactness-over-time) and the online-softmax stats-merge family backing
context-parallel decode (DESIGN.md §17).

Mesh-dependent legs run in a subprocess with 4 forced host devices (the
main test process must keep seeing 1 device); the pure pairwise combiner
is unit-tested in-process."""
import os
import subprocess
import sys
import textwrap

import numpy as np

import jax.numpy as jnp

from repro.core.kv_cache import NEG_INF
from repro.distributed.collectives import (
    combine_softmax_stats, finalize_softmax, softmax_stats,
)

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.collectives import ef_allreduce_mean

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4,), ("dp",))
    key = jax.random.PRNGKey(0)
    true_acc = np.zeros((64,), np.float32)
    comp_acc = np.zeros((64,), np.float32)
    errors = {"g": jnp.zeros((4, 64), jnp.float32)}
    worst_single = 0.0
    for step in range(30):
        key, k = jax.random.split(key)
        g = jax.random.normal(k, (4, 64)) * (1.0 + step % 3)
        mean, errors = ef_allreduce_mean({"g": g}, errors, mesh, "dp")
        tm = np.asarray(jnp.mean(g, 0))
        cm = np.asarray(mean["g"])
        worst_single = max(worst_single,
                           float(np.linalg.norm(cm - tm) / np.linalg.norm(tm)))
        true_acc += tm
        comp_acc += cm
    # error feedback: the ACCUMULATED compressed mean tracks the true mean
    # far better than any single compressed step (bias is carried forward)
    rel = np.linalg.norm(comp_acc - true_acc) / np.linalg.norm(true_acc)
    print("REL", rel, "WORST", worst_single)
    assert rel < 0.01, rel
    assert rel < worst_single, (rel, worst_single)
    print("OK")
""")


def _run_forced_subprocess(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=300)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_ef_allreduce_subprocess():
    _run_forced_subprocess(SCRIPT)


# ---------------------------------------------------------------------------
# Online-softmax stats merge (context-parallel decode's collectives)
# ---------------------------------------------------------------------------


def _ref_softmax_out(scores, values):
    """Direct masked softmax: the single-device answer the merged carries
    must reproduce. Fully-masked rows (all lanes at/below NEG_INF) -> 0."""
    live = np.isfinite(scores) & (scores > -1e29)
    s = np.where(live, scores, -np.inf)
    m = np.max(s, axis=-1, keepdims=True)
    p = np.where(live, np.exp(s - np.where(np.isfinite(m), m, 0.0)), 0.0)
    l = p.sum(-1, keepdims=True)
    out = (p[..., None] * values).sum(-2)
    return np.where(l > 0, out / np.maximum(l, 1e-38), 0.0)


def _stats_case(seed=0, q=5, t=32, d=4):
    """Masked score rows covering the degenerate spectrum: a live row,
    a row masked with -inf, a row masked with the finite NEG_INF sentinel,
    a half-masked row, and a single-survivor row."""
    rng = np.random.default_rng(seed)
    scores = rng.standard_normal((q, t)).astype(np.float32)
    values = rng.standard_normal((q, t, d)).astype(np.float32)
    scores[1, :] = -np.inf
    scores[2, :] = NEG_INF
    scores[3, : t // 2] = NEG_INF
    scores[4, 1:] = -np.inf
    return scores, values


def test_combine_softmax_stats_matches_direct_softmax():
    """Pairwise tree-combining per-block carries == the one-shot softmax,
    with -inf and finite-NEG_INF degenerate blocks contributing exactly
    zero (the 0 * NaN class of bug this guards against)."""
    scores, values = _stats_case()
    ref = _ref_softmax_out(scores, values)
    blocks = [(jnp.asarray(scores[:, i:i + 8]),
               jnp.asarray(values[:, i:i + 8])) for i in range(0, 32, 8)]
    carry = softmax_stats(*blocks[0])
    for b in blocks[1:]:
        carry = combine_softmax_stats(carry, softmax_stats(*b))
    out = np.asarray(finalize_softmax(carry[1], carry[2]))
    assert np.all(np.isfinite(out))
    np.testing.assert_allclose(out, ref, atol=1e-6, rtol=1e-6)
    # the fully-masked queries resolve to exactly zero, not NaN
    assert np.array_equal(out[1], np.zeros_like(out[1]))
    assert np.array_equal(out[2], np.zeros_like(out[2]))


def test_softmax_stats_fully_masked_block_is_zero_mass():
    """A block with no live lanes must yield (l == 0, acc == 0) so it
    merges away — for both -inf and the finite NEG_INF masking."""
    for sentinel in (-np.inf, NEG_INF):
        scores = jnp.full((3, 8), sentinel, jnp.float32)
        values = jnp.ones((3, 8, 4), jnp.float32)
        m, l, acc = softmax_stats(scores, values)
        assert np.array_equal(np.asarray(l), np.zeros((3,)))
        assert np.array_equal(np.asarray(acc), np.zeros((3, 4)))
        out = np.asarray(finalize_softmax(l, acc))
        assert np.array_equal(out, np.zeros((3, 4)))


def test_combine_softmax_stats_is_order_insensitive():
    """The combiner is associative-enough: left-fold vs reversed fold
    agree to fp tolerance (the psum merge relies on this)."""
    scores, values = _stats_case(seed=3)
    blocks = [(jnp.asarray(scores[:, i:i + 8]),
               jnp.asarray(values[:, i:i + 8])) for i in range(0, 32, 8)]
    carries = [softmax_stats(s, v) for s, v in blocks]

    def fold(cs):
        acc = cs[0]
        for c in cs[1:]:
            acc = combine_softmax_stats(acc, c)
        return np.asarray(finalize_softmax(acc[1], acc[2]))

    np.testing.assert_allclose(fold(carries), fold(carries[::-1]),
                               atol=1e-6, rtol=1e-6)


MERGE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.kv_cache import NEG_INF
    from repro.distributed.collectives import (
        allgather_concat, finalize_softmax, merge_softmax_stats,
        shard_map_compat, softmax_stats)
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("cp",))
    rng = np.random.default_rng(0)
    q, t, d = 5, 32, 4
    scores = rng.standard_normal((q, t)).astype(np.float32)
    values = rng.standard_normal((q, t, d)).astype(np.float32)
    # shard 1 fully dead at -inf, shard 2 fully dead at the finite
    # NEG_INF sentinel, for query 0; query 3 dead on EVERY shard
    scores[0, 8:16] = -np.inf
    scores[0, 16:24] = NEG_INF
    scores[3, :] = NEG_INF
    live = np.isfinite(scores) & (scores > -1e29)
    s = np.where(live, scores, -np.inf)
    m = np.max(s, -1, keepdims=True)
    p = np.where(live, np.exp(s - np.where(np.isfinite(m), m, 0.0)), 0.0)
    l = p.sum(-1, keepdims=True)
    ref = np.where(l > 0,
                   (p[..., None] * values).sum(-2) / np.maximum(l, 1e-38),
                   0.0)

    def psum_body(sc, va):
        m, l, acc = softmax_stats(sc, va)
        _, l, acc = merge_softmax_stats(m, l, acc, "cp")
        return finalize_softmax(l, acc)

    out = shard_map_compat(
        psum_body, mesh=mesh, in_specs=(P(None, "cp"), P(None, "cp", None)),
        out_specs=P())(jnp.asarray(scores), jnp.asarray(values))
    out = np.asarray(out)
    assert np.all(np.isfinite(out)), out
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)
    assert np.array_equal(out[3], np.zeros(d, np.float32)), out[3]

    def gather_body(sc, va):
        full_s = allgather_concat(sc, "cp", axis=-1)
        full_v = allgather_concat(va, "cp", axis=-2)
        _, l, acc = softmax_stats(full_s, full_v)
        return finalize_softmax(l, acc), full_s

    out_g, s_g = shard_map_compat(
        gather_body, mesh=mesh,
        in_specs=(P(None, "cp"), P(None, "cp", None)),
        out_specs=(P(), P()))(jnp.asarray(scores), jnp.asarray(values))
    # tiled all-gather reconstructs the row in mesh order, bit-exactly
    assert np.array_equal(np.asarray(s_g), scores)
    np.testing.assert_allclose(np.asarray(out_g), ref, atol=1e-5, rtol=1e-5)
    print("OK")
""")


def test_softmax_merge_collectives_subprocess():
    """merge_softmax_stats / allgather_concat under shard_map on 4 forced
    devices: psum merge matches the direct softmax with degenerate shards
    (-inf AND finite-NEG_INF, plus an all-dead query) contributing zero;
    the tiled all-gather reconstructs rows bit-exactly."""
    _run_forced_subprocess(MERGE_SCRIPT)
