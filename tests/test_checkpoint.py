"""Checkpointing: roundtrip, atomic manifests, retention, elastic restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, extra={"step": 7})
    assert latest_step(str(tmp_path)) == 7
    target = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
    restored, extra = restore_checkpoint(str(tmp_path), 7, target)
    assert extra == {"step": 7}
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_torn_checkpoint_invisible(tmp_path):
    """A step dir without a manifest (preempted mid-save) is never listed."""
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    os.makedirs(tmp_path / "step_0000000009")
    (tmp_path / "step_0000000009" / "shards-00000.npz").write_bytes(b"junk")
    assert latest_step(str(tmp_path)) == 3


def test_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert len(steps) == 2
    assert latest_step(str(tmp_path)) == 4


def test_manifest_records_shapes(tmp_path):
    t = _tree()
    p = save_checkpoint(str(tmp_path), 1, t)
    man = json.load(open(os.path.join(p, "manifest.json")))
    assert man["leaves"]["a"]["shape"] == [8, 16]
    assert man["leaves"]["nested::b"]["dtype"] == "int32"


def test_elastic_restore_across_shardings(tmp_path):
    """Save sharded on an N-device mesh; restore onto a different layout.

    On 1 CPU device this degenerates to replicated<->replicated, but the
    offset-keyed shard format is the same code path the 512-way dry-run
    meshes use; per-shard offsets are exercised in the multi-process branch
    of save_checkpoint."""
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    sharding = jax.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))
    t = {"w": jax.device_put(jnp.arange(32, dtype=jnp.float32), sharding)}
    save_checkpoint(str(tmp_path), 5, t)
    target = {"w": jax.ShapeDtypeStruct((32,), jnp.float32)}
    restored, _ = restore_checkpoint(str(tmp_path), 5, target,
                                     shardings={"w": sharding})
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(32, dtype=np.float32))
