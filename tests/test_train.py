"""Training integration: loss decreases, microbatch equivalence, trainer
fault tolerance (restart resumes exactly)."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.data import SyntheticLMDataset
from repro.models import get_model
from repro.train import Trainer, TrainerConfig
from repro.train.train_step import StepConfig, init_train_state, make_train_step


@pytest.fixture
def tiny():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    return cfg, get_model(cfg)


def test_loss_decreases(tiny, tmp_path):
    cfg, m = tiny
    ds = SyntheticLMDataset(cfg, global_batch=8, seq_len=64, seed=0)
    tc = TrainerConfig(total_steps=40, checkpoint_every=100,
                       checkpoint_dir=str(tmp_path), log_every=100)
    tr = Trainer(m, ds, tc, StepConfig(peak_lr=2e-3, warmup_steps=5,
                                       total_steps=40),
                 log_fn=lambda *_: None)
    res = tr.run()
    first = np.mean(res["losses"][:5])
    last = np.mean(res["losses"][-5:])
    assert last < first - 0.05, (first, last)


def test_microbatch_equivalence(tiny):
    """Grad accumulation over 4 microbatches == single big batch update."""
    cfg, m = tiny
    ds = SyntheticLMDataset(cfg, global_batch=8, seq_len=32, seed=1)
    batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
    outs = {}
    for n in (1, 4):
        step = make_train_step(m, None, StepConfig(peak_lr=1e-3,
                                                   microbatches=n))
        state = init_train_state(m, jax.random.PRNGKey(0))
        state, metrics = step(state, batch)
        outs[n] = (state, metrics)
    p1 = jax.tree_util.tree_leaves(outs[1][0].params)
    p4 = jax.tree_util.tree_leaves(outs[4][0].params)
    for a, b in zip(p1, p4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-3, rtol=5e-3)


def test_trainer_restart_resumes_exactly(tiny, tmp_path):
    cfg, m = tiny
    d = str(tmp_path / "ck")

    def run(total, fresh_dataset=True):
        ds = SyntheticLMDataset(cfg, global_batch=4, seq_len=32, seed=2)
        tc = TrainerConfig(total_steps=total, checkpoint_every=5,
                           checkpoint_dir=d, log_every=100)
        tr = Trainer(m, ds, tc, StepConfig(peak_lr=1e-3),
                     log_fn=lambda *_: None)
        return tr.run(), tr

    res1, _ = run(10)
    res2, tr2 = run(20)               # restores at 10, continues to 20
    assert res2["final_step"] == 20
    assert len(res2["losses"]) == 10  # only steps 11..20 ran

    # uninterrupted 20-step run must match the restarted one exactly
    shutil.rmtree(d)
    res3, tr3 = run(20)
    np.testing.assert_allclose(res3["losses"][10:], res2["losses"],
                               atol=1e-5)


def test_emergency_checkpoint_on_preemption(tiny, tmp_path):
    cfg, m = tiny
    ds = SyntheticLMDataset(cfg, global_batch=4, seq_len=32, seed=3)
    tc = TrainerConfig(total_steps=50, checkpoint_every=1000,
                       checkpoint_dir=str(tmp_path), log_every=100)
    tr = Trainer(m, ds, tc, StepConfig(), log_fn=lambda *_: None)
    tr.init_or_restore()
    tr.ckpt._preempted.set()          # simulate SIGTERM
    res = tr.run()
    assert res["final_step"] < 50     # exited early
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == res["final_step"]


def test_quantized_cache_policy_does_not_affect_training(tiny):
    """cfg.quant only affects serving; train step must be identical."""
    import dataclasses
    cfg, _ = tiny
    cfg_q = dataclasses.replace(
        cfg, quant=dataclasses.replace(cfg.quant, method="none"))
    m1, m2 = get_model(cfg), get_model(cfg_q)
    ds = SyntheticLMDataset(cfg, global_batch=4, seq_len=32, seed=4)
    batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
    p = m1.init(jax.random.PRNGKey(0))
    l1, _ = m1.loss(p, batch)
    l2, _ = m2.loss(p, batch)
    assert float(l1) == float(l2)
