"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantConfig, init_cache, prefill
from repro.core.kv_cache import decode_attention
from repro.kernels import ops
from repro.kernels import ref as R


def _inputs(seed, b, hkv, qh, d, g, gcount, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    k = jax.random.normal(ks[0], (b, hkv, gcount * g, d), dtype)
    q = jax.random.normal(ks[1], (b, hkv, qh, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, gcount * g, d), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# encode kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d,g", [(32, 16), (64, 32), (128, 8)])
@pytest.mark.parametrize("r,t", [(4, 4), (3, 3), (5, 3)])
def test_encode_kernel_exact(d, g, r, t):
    _, k, _ = _inputs(0, 1, 2, 1, d, g, 3)
    out_ref = ops.polar_encode(k, r_bits=r, t_bits=t, group_size=g,
                               backend="ref")
    out_pl = ops.polar_encode(k, r_bits=r, t_bits=t, group_size=g,
                              backend="interpret")
    np.testing.assert_array_equal(np.asarray(out_ref[0]), np.asarray(out_pl[0]))
    for a, b in zip(out_ref[1:], out_pl[1:]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_encode_kernel_dtypes(dtype):
    _, k, _ = _inputs(1, 1, 1, 1, 32, 16, 2, dtype)
    out_ref = ops.polar_encode(k, group_size=16, backend="ref")
    out_pl = ops.polar_encode(k, group_size=16, backend="interpret")
    np.testing.assert_array_equal(np.asarray(out_ref[0]), np.asarray(out_pl[0]))


# ---------------------------------------------------------------------------
# QK-score kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d,g,gcount,qh", [(32, 16, 4, 2), (64, 32, 2, 4),
                                           (128, 16, 2, 1)])
@pytest.mark.parametrize("r,t", [(4, 4), (3, 3)])
def test_qk_kernel_sweep(d, g, gcount, qh, r, t):
    q, k, _ = _inputs(2, 2, 2, qh, d, g, gcount)
    enc = ops.polar_encode(k, r_bits=r, t_bits=t, group_size=g, backend="ref")
    s_ref = ops.polar_qk_scores(q, *enc, r_bits=r, t_bits=t, backend="ref")
    s_pl = ops.polar_qk_scores(q, *enc, r_bits=r, t_bits=t,
                               backend="interpret", block_groups=2)
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_pl),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fused decode-attention kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quantized_values", [False, True])
@pytest.mark.parametrize("length_frac", [0.3, 1.0])
def test_fused_attention_kernel(quantized_values, length_frac):
    b, hkv, qh, d, g, gcount = 2, 2, 4, 32, 16, 4
    q, k, v = _inputs(3, b, hkv, qh, d, g, gcount)
    enc = ops.polar_encode(k, group_size=g, backend="ref")
    length = jnp.asarray(int(gcount * g * length_frac) // g * g, jnp.int32)
    if quantized_values:
        from repro.core.quantizers import encode_values
        qv = encode_values(v, 4)
        vals, vs, vz = qv.codes, qv.scale, qv.zero
    else:
        vals, vs, vz = v, None, None
    o_ref = ops.polar_decode_attention_grouped(
        q, *enc, vals, vs, vz, length, backend="ref")
    o_pl = ops.polar_decode_attention_grouped(
        q, *enc, vals, vs, vz, length, backend="interpret", block_groups=2)
    for a, b_ in zip(o_ref, o_pl):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4)


def test_full_path_matches_core_decode_attention():
    """ops.polar_decode_attention_full == core.decode_attention (the jnp
    serving path) including the fp residual segment."""
    b, hkv, d, g = 1, 2, 32, 16
    t = 3 * g + 7
    cfg = QuantConfig(method="polar", group_size=g, residual_dtype="float32")
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    k = jax.random.normal(ks[0], (b, hkv, t, d))
    v = jax.random.normal(ks[1], (b, hkv, t, d))
    cache = prefill(init_cache(cfg, b, hkv, d, 4 * g, dtype=jnp.float32), k, v)
    q = jax.random.normal(ks[2], (b, hkv * 2, d))
    o_core = decode_attention(cache, q)
    for backend in ("ref", "interpret"):
        o = ops.polar_decode_attention_full(
            q, cache.key_codes, cache.key_scales["rho_scale"],
            cache.key_scales["rho_zero"], cache.key_scales["theta_scale"],
            cache.key_scales["theta_zero"], cache.key_residual,
            cache.value_fp, None, None, cache.length, backend=backend)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_core),
                                   rtol=1e-4, atol=1e-5)


def test_merge_softmax_partials_exact():
    """The associative merge must equal a monolithic softmax."""
    s = jax.random.normal(jax.random.PRNGKey(5), (3, 50))
    v = jax.random.normal(jax.random.PRNGKey(6), (3, 50, 8))
    full = jnp.einsum("bt,btd->bd", jax.nn.softmax(s, -1), v)
    parts = []
    for lo, hi in [(0, 20), (20, 35), (35, 50)]:
        m = jnp.max(s[:, lo:hi], -1)
        p = jnp.exp(s[:, lo:hi] - m[:, None])
        parts.append((jnp.einsum("bt,btd->bd", p, v[:, lo:hi]),
                      m, jnp.sum(p, -1)))
    merged = ops.merge_softmax_partials(parts)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full),
                               rtol=1e-5, atol=1e-6)
