"""Loop-aware HLO static cost model: trip-count multiplication, dot flops,
slicing-aware traffic, collective accounting."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import (HloCostModel, analyze_text,
                                   parse_computations, _type_elems_bytes)


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_multiplies_flops():
    def body(c, w):
        return c @ w, None
    x = jnp.zeros((64, 64))
    ws = jnp.zeros((7, 64, 64))
    txt = _compile(lambda x, ws: jax.lax.scan(body, x, ws)[0], x, ws)
    res = analyze_text(txt)
    expect = 7 * 2 * 64 ** 3
    assert 0.95 * expect <= res["flops"] <= 1.2 * expect, res["flops"]


def test_nested_scans_multiply():
    def inner(c, w):
        return c @ w, None

    def outer(c, _):
        y, _ = jax.lax.scan(inner, c, jnp.zeros((5, 32, 32)))
        return y, None

    x = jnp.zeros((32, 32))
    txt = _compile(lambda x: jax.lax.scan(outer, x, None, length=3)[0], x)
    res = analyze_text(txt)
    expect = 15 * 2 * 32 ** 3
    assert 0.9 * expect <= res["flops"] <= 1.3 * expect, res["flops"]


def test_transcendentals_counted():
    x = jnp.zeros((128, 128))
    txt = _compile(lambda x: jnp.exp(x) + jnp.tanh(x), x)
    res = analyze_text(txt)
    assert res["transcendentals"] == 2 * 128 * 128


def test_dynamic_slice_not_counted_fully():
    """Scan xs slicing must cost the slice, not the whole stacked array."""
    big = jnp.zeros((1000, 64))

    def body(c, i):
        return c + jax.lax.dynamic_slice_in_dim(big, i, 1, 0)[0], None

    txt = _compile(
        lambda: jax.lax.scan(body, jnp.zeros((64,)),
                             jnp.arange(4, dtype=jnp.int32))[0])
    res = analyze_text(txt)
    # 4 iterations x O(small); full-array counting would be ~4 * 256KB
    assert res["hbm_bytes"] < 4 * big.nbytes * 0.5, res["hbm_bytes"]


def test_type_parse():
    assert _type_elems_bytes("bf16[2,3]{1,0}") == (6, 12)
    assert _type_elems_bytes("(f32[4], u8[8])") == (12, 24)
    assert _type_elems_bytes("pred[]") == (1, 1)


def test_comment_stripping_in_tuple_types():
    txt = """
%c (p: s32[]) -> s32[] {
  ROOT %p = s32[] parameter(0)
}
ENTRY %e (a: f32[8]) -> (f32[8], f32[8]) {
  %a = f32[8]{0} parameter(0)
  %m = f32[8]{0} multiply(%a, %a)
  ROOT %t = (f32[8]{0}, /*index=1*/f32[8]{0}) tuple(%m, %a)
}
"""
    comps = parse_computations(txt)
    assert "e" in comps
    kinds = [o.kind for o in comps["e"]]
    assert "multiply" in kinds and "tuple" in kinds
