"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only the dry-run forces 512 devices (in a
subprocess)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def rope_structured_keys(key, b, h, t, d, outlier_channels=4,
                         rope_base=10000.0):
    """Synthetic keys matching the paper's premise (Fig. 1 / KVQuant):
    pre-RoPE outlier channels have CONSISTENT magnitude (large fixed mean,
    small spread) and sit in low-frequency rotary pairs; RoPE rotation then
    sweeps that magnitude across both paired dims (channel-wise outliers
    post-RoPE), while the polar radius stays tight and the angle drifts
    slowly — exactly the structure PolarQuant exploits."""
    import jax.numpy as jnp
    from repro.models.layers import apply_rope
    k1, k2, k3 = jax.random.split(key, 3)
    half = d // 2
    # low-frequency pairs (phi = base^(-2j/d) smallest for j near half-1)
    lo = 3 * half // 4
    idx = lo + jax.random.choice(k2, half - lo, (outlier_channels,),
                                 replace=False)
    mean = jnp.zeros((d,))
    signs = jax.random.rademacher(k3, (outlier_channels,), jnp.float32)
    mean = mean.at[idx].set(10.0 * signs)
    pre = jax.random.normal(k1, (b, h, t, d)) + mean
    pos = jnp.arange(t, dtype=jnp.int32)
    return apply_rope(pre, pos, rope_base)


@pytest.fixture
def structured_keys():
    return rope_structured_keys
