"""Dry-run machinery on a small forced-device mesh (subprocess) + HLO
collective-census parser unit tests."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.dryrun_lib import collective_census, _shape_bytes


def test_shape_bytes():
    assert _shape_bytes("bf16[2,512,128]") == 2 * 512 * 128 * 2
    assert _shape_bytes("f32[16]") == 64
    assert _shape_bytes("(f32[8], u8[4,4])") == 32 + 16
    assert _shape_bytes("pred[]") == 1


def test_collective_census_parses_kinds():
    hlo = """
      %ag = bf16[2,1024]{1,0} all-gather(%x), replica_groups={}
      %ar = f32[512]{0} all-reduce(%y), to_apply=%sum
      %rs.1 = f32[64]{0} reduce-scatter(%z), dimensions={0}
      %a2a = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(%p, %q)
      %cp = u8[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
      %ags = bf16[4,256]{1,0} all-gather-start(%v), replica_groups={}
    """
    c = collective_census(hlo)
    assert c["all-gather"]["count"] == 2
    assert c["all-reduce"]["count"] == 1
    assert c["all-reduce"]["bytes"] == 2 * 512 * 4  # 2x factor
    assert c["reduce-scatter"]["count"] == 1
    assert c["all-to-all"]["bytes"] == 2 * 64 * 4
    assert c["collective-permute"]["count"] == 1
    assert c["total_bytes"] > 0


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.launch import dryrun_lib as lib
    from repro.train.train_step import StepConfig
    from repro.configs.base import ShapeConfig

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    shapes = [ShapeConfig("train_4k", 256, 8, "train"),
              ShapeConfig("decode_32k", 512, 8, "decode")]
    for so in shapes:
        rec = lib.run_cell("tinyllama-1.1b", so.name, mesh, "/tmp/dry_test",
                           "t", StepConfig(), shape_override=so)
        assert rec["status"] == "ok", rec
        assert rec["memory"]["peak_per_device"] > 0
        assert rec["cost"].get("flops", 0) > 0
        assert rec["collectives"]["total_bytes"] > 0
    # skip rule
    import pytest
    rec = lib.run_cell("yi-9b", "long_500k", mesh, "/tmp/dry_test", "t",
                       StepConfig())
    assert rec["status"] == "skip"
    print("OK")
""")


@pytest.mark.slow
def test_dryrun_small_mesh_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=560)
    assert "OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
