"""Optional-hypothesis shim (satellite of the serving PR).

The property tests use hypothesis when it is installed (the ``test`` extra
in pyproject.toml), but the tier-1 suite must collect and run without it.
``pytest.importorskip`` at module level would skip the *whole* file —
including the plain pytest tests — so instead this shim exposes the real
hypothesis API when available and no-op decorators that mark only the
property tests as skipped otherwise.

Usage in a test module::

    from hyp_compat import given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when extra not installed
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped():
                pass  # property test body requires hypothesis

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategy:
        """Stands in for any strategy object/factory at decoration time."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return _Strategy()

    st = _Strategy()
