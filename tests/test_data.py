"""Data pipeline: determinism, resume semantics, host sharding, learnability."""
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.data import SyntheticLMDataset


def _ds(**kw):
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    return SyntheticLMDataset(cfg, global_batch=kw.pop("gb", 8),
                              seq_len=kw.pop("sl", 64), **kw)


def test_deterministic_in_seed_and_step():
    a = _ds(seed=1).local_batch_np(step=5)
    b = _ds(seed=1).local_batch_np(step=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = _ds(seed=2).local_batch_np(step=5)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_resume_replays_exact_stream():
    ds = _ds(seed=3)
    seen = [ds.next_batch()["tokens"] for _ in range(4)]
    ds2 = _ds(seed=3)
    ds2.state.step = 2
    np.testing.assert_array_equal(ds2.next_batch()["tokens"], seen[2])
    np.testing.assert_array_equal(ds2.next_batch()["tokens"], seen[3])


def test_host_sharding_partitions_batch():
    full = _ds(seed=4, process_index=0, process_count=1).local_batch_np(0)
    h0 = _ds(seed=4, gb=8, process_index=0, process_count=2).local_batch_np(0)
    h1 = _ds(seed=4, gb=8, process_index=1, process_count=2).local_batch_np(0)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), full["tokens"])


def test_stream_is_learnable():
    """Markov structure: unigram-context continuation entropy must be well
    below uniform — otherwise training can't show loss decreasing."""
    ds = _ds(seed=5, gb=16, sl=256)
    toks = ds.next_batch()["tokens"]
    from collections import Counter, defaultdict
    ctx = defaultdict(Counter)
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            ctx[int(a)][int(b)] += 1
    top_frac = np.mean([max(v.values()) / sum(v.values())
                        for v in ctx.values() if sum(v.values()) > 3])
    assert top_frac > 0.5, top_frac  # strongly predictable continuations
