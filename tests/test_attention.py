"""Flash attention vs O(T^2) oracle: all mask modes, forward + VJP, GQA."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import flash_attention, reference_attention


def _qkv(seed, b, hq, hkv, tq, tk, d, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, hq, tq, d), dtype),
            jax.random.normal(ks[1], (b, hkv, tk, d), dtype),
            jax.random.normal(ks[2], (b, hkv, tk, d), dtype))


MODES = [("causal", {}), ("full", {}), ("local", {"window": 13})]


@pytest.mark.parametrize("mode,kw", MODES)
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (6, 1)])
def test_forward_matches_reference(mode, kw, hq, hkv):
    q, k, v = _qkv(0, 2, hq, hkv, 50, 50, 32)
    fa = flash_attention(q, k, v, mode=mode, chunk=16, **kw)
    ra = reference_attention(q, k, v, mode=mode, **kw)
    np.testing.assert_allclose(np.asarray(fa), np.asarray(ra), atol=2e-5)


def test_prefix_mode():
    q, k, v = _qkv(1, 2, 4, 2, 40, 40, 16)
    pl = jnp.array([10, 25])
    fa = flash_attention(q, k, v, mode="prefix", prefix_len=pl, chunk=16)
    ra = reference_attention(q, k, v, mode="prefix", prefix_len=pl)
    np.testing.assert_allclose(np.asarray(fa), np.asarray(ra), atol=2e-5)


def test_unpadded_chunks():
    """Tk not a multiple of chunk exercises the padding path."""
    q, k, v = _qkv(2, 1, 2, 2, 37, 53, 16)
    fa = flash_attention(q, k, v, mode="full", chunk=16)
    ra = reference_attention(q, k, v, mode="full")
    np.testing.assert_allclose(np.asarray(fa), np.asarray(ra), atol=2e-5)


@pytest.mark.parametrize("mode,kw", MODES)
def test_gradients_match(mode, kw):
    q, k, v = _qkv(3, 1, 4, 2, 30, 30, 16)

    def loss(fn):
        return lambda q, k, v: jnp.sum(
            fn(q, k, v, mode=mode, **kw) ** 2)

    gf = jax.grad(loss(lambda *a, **k2: flash_attention(*a, chunk=8, **k2)),
                  argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss(reference_attention), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=1e-3)


def test_bf16_inputs():
    q, k, v = _qkv(4, 1, 2, 2, 32, 32, 16, jnp.bfloat16)
    fa = flash_attention(q, k, v, mode="causal", chunk=16)
    ra = reference_attention(q, k, v, mode="causal")
    assert fa.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(fa, np.float32),
                               np.asarray(ra, np.float32), atol=3e-2)


def test_memory_scaling_structure():
    """The jaxpr of the VJP must not capture a (Tq, Tk) residual."""
    q, k, v = _qkv(5, 1, 2, 2, 128, 128, 16)
    vjp_jaxpr = jax.make_jaxpr(
        lambda q, k, v: jax.grad(
            lambda q: jnp.sum(flash_attention(q, k, v, chunk=32)))(q))(q, k, v)
    for eqn_var in vjp_jaxpr.jaxpr.eqns:
        for outvar in eqn_var.outvars:
            shape = getattr(outvar.aval, "shape", ())
            assert not (len(shape) >= 2 and shape[-1] == 128 and
                        shape[-2] == 128 and np.prod(shape) > 128 * 128 * 4), \
                f"full score matrix materialized: {shape}"
