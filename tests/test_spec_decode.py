"""Speculative multi-token decode (DESIGN.md §15).

The load-bearing property is *greedy bit-identity*: with any proposer —
ngram self-speculation, a draft model, an oracle, or an adversarially
wrong one — the engine's outputs must equal plain decode token-for-token
across cache policies, because the verifier accepts exactly the target
argmax prefix and commits through the vanilla append path. Everything
else here guards the machinery around that: span-vs-scan verifier
parity on the committed cache bytes, acceptance boundary cases (all
rejected / all accepted / EOS inside a span), allocator invariants
under cancel and preempt mid-speculation, event ordinal + span
metadata, and the streaming latency semantics of multi-token spans.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import CachePolicy
from repro.core.cache_layout import PagedLayout
from repro.models import get_model
from repro.serve import (
    ContinuousBatchingEngine, EngineCore, GenerationConfig, Request,
    StreamingEngine, stream_latency_stats,
)
from repro.serve.core import TokenEvent
from repro.spec import (
    DraftProposer, NgramProposer, SpecConfig, list_proposers,
    make_proposer, register_proposer,
)
from repro.spec.verify import make_scan_verifier, make_span_verifier
from test_prefix_cache import check_alloc_invariants


@pytest.fixture(scope="module")
def smoke_model():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _policy_cfg(cfg, policy: str):
    if policy == "polar":
        return cfg
    int8 = dataclasses.replace(cfg.quant, method="int", key_bits=8)
    if policy == "int8":
        return dataclasses.replace(cfg, quant=int8)
    # mixed: first layer token-wise int8, the rest grouped polar
    return dataclasses.replace(
        cfg, cache_policy=CachePolicy.first_k(1, int8, cfg.quant))


def _repetitive_requests(cfg, n=4, seed=3, max_new=16):
    """Single-token prompts: greedy continuations tend to fall into
    short cycles, giving the ngram proposer real acceptance."""
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=np.full((36,), rng.randint(0, cfg.vocab_size),
                                   np.int32),
                    max_new_tokens=max_new, arrival_time=i * 0.002)
            for i in range(n)]


def _clone(reqs):
    return [Request(rid=r.rid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens,
                    arrival_time=r.arrival_time) for r in reqs]


def _run(m, params, reqs, spec=None, gen=None, **kw):
    eng = ContinuousBatchingEngine(m, params, spec=spec, **kw)
    eng.warmup([r.prompt_len for r in reqs])
    out = eng.run(_clone(reqs), gen or GenerationConfig())
    return out, {r.rid: list(r.out_tokens) for r in out["requests"]}


# ---------------------------------------------------------------------------
# Greedy bit-identity across proposers and cache policies
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["polar", "int8", "mixed"])
def test_greedy_bit_identical_off_ngram_draft(smoke_model, policy):
    cfg, _, _ = smoke_model
    cfg = _policy_cfg(cfg, policy)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    reqs = _repetitive_requests(cfg)
    kw = dict(max_slots=2, max_len=128)

    _, base = _run(m, params, reqs, **kw)
    out_n, toks_n = _run(m, params, reqs,
                         spec=SpecConfig(mode="ngram", k=4), **kw)
    assert toks_n == base, f"ngram diverged from vanilla ({policy})"
    assert out_n["spec"]["steps"] > 0          # speculation actually ran
    assert out_n["spec"]["accepted_tokens"] > 0

    out_d, toks_d = _run(m, params, reqs,
                         spec=SpecConfig(mode="draft", k=2), **kw)
    assert toks_d == base, f"draft diverged from vanilla ({policy})"
    assert out_d["spec"]["steps"] > 0


# ---------------------------------------------------------------------------
# Span verifier == scan verifier, committed bytes included
# ---------------------------------------------------------------------------


def test_span_scan_verifier_parity(smoke_model):
    """The batched span verifier must reproduce the sequential scan
    verifier bit-for-bit — predictions, acceptance counts, and every
    committed cache byte outside the never-read scratch page — for
    spans inside the slot's current group (the engine's clamp)."""
    cfg, m, params = smoke_model
    g = cfg.quant.group_size
    S, N = 2, 4
    layout = PagedLayout(page_size=g, num_pages=S * N, slots=S,
                         pages_per_slot=N)
    PP = layout.pool_pages
    scan_v = make_scan_verifier(m)
    span_v = make_span_verifier(m)
    rng = np.random.RandomState(0)

    for plen in (33, 47):
        state = m.init_paged_state(layout)
        table = jnp.asarray(
            np.arange(S * N, dtype=np.int32).reshape(S, N))
        tp = -(-plen // g) * g
        toks = np.zeros((S, tp), np.int32)
        toks[:, :plen] = rng.randint(0, cfg.vocab_size, (S, plen))
        nxt = None
        for s in range(S):
            logits, state = m.prefill_paged(
                params, jnp.asarray(toks[s:s + 1]), state,
                jnp.asarray(s, jnp.int32), table[s],
                jnp.asarray(plen, jnp.int32))
            nxt = int(np.asarray(jnp.argmax(logits, -1))[0])
        for q in (1, 2, 3):
            span = np.zeros((S, q), np.int32)
            span[:, 0] = nxt
            if q > 1:
                span[:, 1:] = rng.randint(0, cfg.vocab_size, (S, q - 1))
            args = (jnp.asarray(span), jnp.full((S,), q - 1, jnp.int32),
                    table, jnp.ones((S,), bool))
            p1, n1, c1 = scan_v(params, state, *args)
            p2, n2, c2 = span_v(params, state, *args)
            assert jnp.array_equal(p1, p2), f"preds plen={plen} q={q}"
            assert jnp.array_equal(n1, n2), f"n_acc plen={plen} q={q}"
            for (path, l1), (_, l2) in zip(
                    jax.tree_util.tree_leaves_with_path(c1),
                    jax.tree_util.tree_leaves_with_path(c2)):
                a, b = np.asarray(l1), np.asarray(l2)
                if a.ndim >= 2 and a.shape[1] == PP:
                    a, b = a[:, :PP - 1], b[:, :PP - 1]
                elif a.ndim >= 1 and a.shape[0] == PP:
                    a, b = a[:PP - 1], b[:PP - 1]
                assert np.array_equal(a, b), \
                    f"cache {jax.tree_util.keystr(path)} plen={plen} q={q}"


# ---------------------------------------------------------------------------
# Acceptance boundaries: all-rejected, all-accepted, EOS inside a span
# ---------------------------------------------------------------------------


class _ScriptedProposer(DraftProposer):
    """Proposes a scripted continuation per rid (oracle when fed the
    vanilla outputs, adversarially wrong when fed anything else)."""

    name = "scripted-test"
    script: dict = {}

    def propose(self, req, k):
        s = self.script.get(req.rid, [])
        pos = len(req.out_tokens)
        return [int(t) for t in s[pos:pos + k]]


register_proposer(_ScriptedProposer, overwrite=True)


def test_all_rejected_still_bit_identical(smoke_model):
    cfg, m, params = smoke_model
    reqs = _repetitive_requests(cfg, n=3, max_new=12)
    kw = dict(max_slots=2, max_len=128)
    _, base = _run(m, params, reqs, **kw)
    # every draft is target-argmax + 1 -> guaranteed rejection
    _ScriptedProposer.script = {
        rid: [(t + 1) % cfg.vocab_size for t in t_list]
        for rid, t_list in base.items()}
    out, toks = _run(m, params, reqs,
                     spec=SpecConfig(mode="scripted-test", k=3), **kw)
    assert toks == base
    assert out["spec"]["drafted_tokens"] > 0
    assert out["spec"]["accepted_tokens"] == 0


def test_all_accepted_oracle_proposer(smoke_model):
    cfg, m, params = smoke_model
    reqs = _repetitive_requests(cfg, n=3, max_new=12)
    kw = dict(max_slots=2, max_len=128)
    _, base = _run(m, params, reqs, **kw)
    _ScriptedProposer.script = base     # the target's own continuation
    out, toks = _run(m, params, reqs,
                     spec=SpecConfig(mode="scripted-test", k=3), **kw)
    assert toks == base
    sp = out["spec"]
    assert sp["drafted_tokens"] > 0
    assert sp["accepted_tokens"] == sp["drafted_tokens"]   # rate 1.0
    # oracle spans retire multiple tokens per dispatch
    assert sp["mean_accepted_per_step"] > 1.0


def test_eos_inside_span_truncates(smoke_model):
    """An EOS produced mid-span must end the request exactly where
    vanilla decode would, discarding the span's tail."""
    cfg, m, params = smoke_model
    reqs = _repetitive_requests(cfg, n=3, max_new=16)
    kw = dict(max_slots=2, max_len=128)
    _, base = _run(m, params, reqs, **kw)
    # choose an eos id that appears mid-output for at least one request
    eos = next(t for ts in base.values() for t in ts[2:-2])
    gen = GenerationConfig(eos_id=int(eos))
    _, base_eos = _run(m, params, reqs, gen=gen, **kw)
    assert any(len(base_eos[r]) < len(base[r]) for r in base_eos)
    _ScriptedProposer.script = base
    out, toks = _run(m, params, reqs, gen=gen,
                     spec=SpecConfig(mode="scripted-test", k=4), **kw)
    assert toks == base_eos
    for ts in toks.values():
        assert int(eos) not in ts[:-1]      # nothing emitted past EOS


# ---------------------------------------------------------------------------
# Cancel / preempt mid-speculation + allocator invariants
# ---------------------------------------------------------------------------


def test_cancel_mid_spec_step_allocator_consistent(smoke_model):
    cfg, m, params = smoke_model
    reqs = _repetitive_requests(cfg, n=4, max_new=24)
    eng = ContinuousBatchingEngine(m, params, max_slots=2, max_len=128)
    eng.warmup([r.prompt_len for r in reqs])
    base = eng.run(_clone(reqs), GenerationConfig())
    base_toks = {r.rid: list(r.out_tokens) for r in base["requests"]}

    core = EngineCore(m, params, max_slots=2, max_len=128,
                      spec=SpecConfig(mode="ngram", k=4))
    core.warmup([r.prompt_len for r in reqs])
    stream = StreamingEngine(core, GenerationConfig())
    for r in _clone(reqs):
        stream.submit(r)
    cancelled = False
    steps = 0
    while stream.has_work:
        evs = stream.step()
        steps += 1
        check_alloc_invariants(core.sched.alloc)
        # cancel rid 1 the moment a speculative span lands for it
        if not cancelled and any(
                ev.kind == "token" and ev.rid == 1 and ev.span > 1
                for ev in evs):
            assert stream.cancel(1)
            cancelled = True
            check_alloc_invariants(core.sched.alloc)
        assert steps < 2000
    assert cancelled, "no speculative span ever landed for rid 1"
    out = stream.result()
    done = {r.rid: list(r.out_tokens) for r in out["requests"]}
    assert set(done) == {0, 2, 3}
    for rid, ts in done.items():
        assert ts == base_toks[rid]     # survivors still bit-identical


def test_preempt_mid_spec_recovers_bit_identical(smoke_model):
    """A pool small enough to force recompute-preemption, with spans in
    flight: every request must still finish with vanilla outputs and
    the allocator must stay consistent throughout."""
    cfg, m, params = smoke_model
    g = cfg.quant.group_size
    reqs = _repetitive_requests(cfg, n=4, max_new=40)
    # oversubscribed pool: 3 slots each growing to 3 pages, 6 in the pool
    kw = dict(max_slots=3, max_len=4 * g, num_pages=6)
    _, base = _run(m, params, reqs, **kw)

    core = EngineCore(m, params, spec=SpecConfig(mode="ngram", k=4), **kw)
    core.warmup([r.prompt_len for r in reqs])
    stream = StreamingEngine(core, GenerationConfig())
    for r in _clone(reqs):
        stream.submit(r)
    preempts = 0
    steps = 0
    while stream.has_work:
        for ev in stream.step():
            preempts += ev.kind == "preempt"
        check_alloc_invariants(core.sched.alloc)
        steps += 1
        assert steps < 4000
    assert preempts > 0, "workload never preempted — pool not tight"
    out = stream.result()
    assert {r.rid: list(r.out_tokens) for r in out["requests"]} == base


# ---------------------------------------------------------------------------
# Event stream: ordinals, span metadata, streaming latency semantics
# ---------------------------------------------------------------------------


def test_event_ordinals_and_span_metadata(smoke_model):
    cfg, m, params = smoke_model
    reqs = _repetitive_requests(cfg, n=3, max_new=16)
    core = EngineCore(m, params, max_slots=2, max_len=128,
                      spec=SpecConfig(mode="ngram", k=4))
    core.warmup([r.prompt_len for r in reqs])
    stream = StreamingEngine(core, GenerationConfig())
    for r in _clone(reqs):
        stream.submit(r)
    by_rid: dict = {}
    saw_multi = False
    for ev in stream.events():
        if ev.kind not in ("first_token", "token"):
            continue
        by_rid.setdefault(ev.rid, []).append(ev)
        assert 0 <= ev.span_ix < ev.span
        saw_multi |= ev.span > 1
    assert saw_multi, "no multi-token span retired"
    for rid, evs in by_rid.items():
        assert [e.ordinal for e in evs] == list(range(len(evs)))
        ts = [e.t for e in evs]
        assert all(b >= a for a, b in zip(ts, ts[1:]))   # clock monotone
        for a, b in zip(evs, evs[1:]):
            if b.span_ix > 0:       # same span -> same dispatch stamp
                assert b.t == a.t and b.span == a.span


def test_stream_latency_stats_span_itl():
    """Tokens of one speculative span share a timestamp: the intra-span
    ITL entries must be exactly zero (never negative), and the gap to
    the next dispatch carries the step latency."""
    reqs = [Request(rid=0, prompt=np.zeros((4,), np.int32),
                    max_new_tokens=8, arrival_time=0.0)]
    evs = [TokenEvent("first_token", 0, 1.0, token=7, slot=0,
                      ordinal=0, span=3, span_ix=0)]
    evs += [TokenEvent("token", 0, 1.0, token=7, slot=0, ordinal=i,
                       span=3, span_ix=i) for i in (1, 2)]
    evs.append(TokenEvent("token", 0, 1.5, token=7, slot=0, ordinal=3))
    # replayed/merged streams may carry tiny negative jitter: clamp
    evs.append(TokenEvent("token", 0, 1.5 - 1e-9, token=7, slot=0,
                          ordinal=4))
    lat = stream_latency_stats(evs, reqs)
    assert lat["itl_s"]["n"] == 4
    assert lat["itl_s"]["p50"] == 0.0
    assert min(0.0, lat["itl_s"]["p50"]) == 0.0
    assert lat["itl_s"]["p99"] == pytest.approx(0.5)
    assert lat["ttft_s"]["mean"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Registry + proposer unit checks
# ---------------------------------------------------------------------------


def test_proposer_registry_contents():
    names = list_proposers()
    assert "ngram" in names and "draft" in names


def test_ngram_proposer_incremental_matching():
    spec = SpecConfig(mode="ngram", k=4)
    prop = make_proposer(spec)
    assert isinstance(prop, NgramProposer)
    req = Request(rid=9, prompt=np.array([1, 2, 3, 1, 2], np.int32),
                  max_new_tokens=8, arrival_time=0.0)
    # suffix [1, 2] matched earlier at position 0 -> propose [3, 1, 2]
    got = prop.propose(req, 4)
    assert got[:1] == [3]
    # cap ramps with full acceptance, resets on rejection
    prop.feedback(9, len(got), len(got))
    req.out_tokens.extend(got)
    assert len(prop.propose(req, 4)) >= len(got)
    prop.feedback(9, 2, 0)
    prop.release(9)
    assert prop.propose(req, 0) == []


def test_spec_config_validation():
    with pytest.raises(ValueError):
        SpecConfig(mode="ngram", k=0)
    with pytest.raises(ValueError):
        SpecConfig(mode="ngram", min_ngram=3, max_ngram=2)
    with pytest.raises(KeyError):
        make_proposer(SpecConfig(mode="no-such-proposer"))
