"""MoE dispatch correctness: the sort-free scatter/gather ragged dispatch
must equal the naive dense per-expert oracle when capacity is sufficient."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.models.moe import init_moe, moe_ffn


def _cfg(capacity_factor=8.0, **kw):
    cfg = reduce_for_smoke(get_config("qwen2-moe-a2.7b"))
    return dataclasses.replace(cfg, capacity_factor=capacity_factor, **kw)


def _dense_oracle(params, x, cfg):
    """Route each token to its top-k experts, computed densely."""
    b, t, d = x.shape
    logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, eidx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.norm_topk:
        gates = gates / gates.sum(-1, keepdims=True)
    out = jnp.zeros_like(x, jnp.float32)
    for e in range(cfg.num_experts):
        h = jax.nn.silu(x @ params["wg"][e]) * (x @ params["wu"][e])
        ye = (h @ params["wd"][e]).astype(jnp.float32)
        w = jnp.sum(jnp.where(eidx == e, gates, 0.0), -1)  # (B,T)
        out = out + ye * w[..., None]
    if cfg.num_shared_experts:
        from repro.models.layers import mlp
        out = out + mlp(params["shared"], x, cfg.act).astype(jnp.float32)
    return out.astype(x.dtype)


def test_dispatch_matches_dense_oracle():
    cfg = _cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, aux = moe_ffn(params, x, cfg)
    y_ref = _dense_oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-3)
    assert float(aux) > 0


def test_capacity_drop_degrades_gracefully():
    """Tokens over capacity are dropped (contribute zero), not corrupted."""
    cfg_full = _cfg(capacity_factor=8.0)
    cfg_tight = _cfg(capacity_factor=0.25)
    params = init_moe(jax.random.PRNGKey(2), cfg_full)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, cfg_full.d_model))
    y_full, _ = moe_ffn(params, x, cfg_full)
    y_tight, _ = moe_ffn(params, x, cfg_tight)
    assert bool(jnp.isfinite(y_tight).all())
    # tight capacity must reduce routed output energy (relative to shared)
    if cfg_full.num_shared_experts:
        from repro.models.layers import mlp
        shared = mlp(params["shared"], x, cfg_full.act)
        routed_full = jnp.linalg.norm(y_full - shared)
        routed_tight = jnp.linalg.norm(y_tight - shared)
        assert float(routed_tight) < float(routed_full)


def test_topk_distinct_experts():
    cfg = _cfg()
    params = init_moe(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 16, cfg.d_model))
    logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    _, eidx = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
    e = np.asarray(eidx).reshape(-1, cfg.top_k)
    for row in e:
        assert len(set(row.tolist())) == cfg.top_k
