"""EngineCore step loop + streaming front door (DESIGN.md §13).

Golden parity: the rebuilt ``ContinuousBatchingEngine.run()`` (thin batch
adapter over ``EngineCore.step()``) must reproduce the pre-refactor
monolithic loop bit-identically — same greedy tokens, same page-adoption
decisions, same scheduler metrics — against the frozen oracle in
``cb_reference.py``, on both the classic one-shot path (with preemption)
and the shared-prefix chunked path (the ``bench_serving --shared-prefix``
workload in miniature).

Streaming: token events reconstruct outputs, the clock is monotonic, and
cancellation (queued / mid-prefill / mid-decode, with and without the
prefix cache) always leaves the allocator consistent — cancelled pages
return to the free list or index-only state, never freed under the
index's refcounts — and the freed slot is reusable by the next admission.
"""
import numpy as np
import pytest

import jax

from cb_reference import ReferenceCBEngine
from repro.configs import get_config, reduce_for_smoke
from repro.models import get_model
from repro.serve import (
    ContinuousBatchingEngine, EngineCore, GenerationConfig, Request,
    Scheduler, StreamingEngine, stream_latency_stats,
)
from repro.serve.core import EVENT_KINDS
from test_prefix_cache import check_alloc_invariants


@pytest.fixture(scope="module")
def smoke_model():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _poisson_requests(cfg, n, rate=20.0, seed=0, lo=8, hi=50,
                      max_new=(3, 12)):
    rng = np.random.default_rng(seed)
    t, reqs = 0.0, []
    for i in range(n):
        t += rng.exponential(1.0 / rate)
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, (int(rng.integers(
                lo, hi)),)).astype(np.int32),
            max_new_tokens=int(rng.integers(*max_new)),
            arrival_time=t))
    return reqs


def _shared_prefix_requests(cfg, n=8, rate=20.0, seed=0, prefix_len=96,
                            suffix=(8, 32), out=(4, 24)):
    """bench_serving.make_shared_prefix_workload in miniature: one system
    prompt shared by the whole fleet + short random user suffixes."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, 512, (prefix_len,)).astype(np.int32)
    t, reqs = 0.0, []
    for i in range(n):
        t += rng.exponential(1.0 / rate)
        sfx = rng.integers(0, 512, (int(rng.integers(
            suffix[0], suffix[1] + 1)),)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=np.concatenate([shared, sfx]),
                            max_new_tokens=int(rng.integers(
                                out[0], out[1] + 1)),
                            arrival_time=t))
    return reqs


def _clone(reqs, zero_arrivals=False):
    """Fresh Request objects; ``zero_arrivals`` makes every request
    arrive at t=0, removing the only wall-clock-dependent input to the
    scheduler (arrival pumping) so two runs make identical decisions —
    what the bit-identical parity assertions need."""
    return [Request(rid=r.rid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens,
                    arrival_time=0.0 if zero_arrivals else r.arrival_time)
            for r in reqs]


# the decisions that must survive the refactor bit-identically (wall-clock
# derived metrics — tokens_per_s, latencies — legitimately jitter)
PARITY_KEYS = [
    "total_tokens", "decode_steps", "prefill_tokens_computed",
    "prefill_tokens_skipped", "prefix_hit_rate", "adopted_pages",
    "fresh_pages", "cow_splits", "mean_active_slots",
    "mean_page_utilization", "prefill_chunk", "prefix_cache",
]


def _assert_parity(ref: dict, new: dict):
    for k in PARITY_KEYS:
        assert new[k] == ref[k], f"{k}: {new[k]} != {ref[k]}"
    if "prefix_index" in ref:
        assert new["prefix_index"] == ref["prefix_index"]
    ref_out = {r.rid: (list(r.out_tokens), r.preemptions)
               for r in ref["requests"]}
    new_out = {r.rid: (list(r.out_tokens), r.preemptions)
               for r in new["requests"]}
    assert new_out == ref_out


def test_golden_parity_classic_with_preemption(smoke_model):
    """One-shot prefill path under an oversubscribed pool: admission
    order, decode steps, preemption victims, and greedy tokens all match
    the frozen monolith."""
    cfg, m, params = smoke_model
    g = cfg.quant.group_size
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (g - 2 + 3 * i,)).astype(np.int32),
                    max_new_tokens=40, arrival_time=0.01 * i)
            for i in range(3)]
    kw = dict(max_slots=2, max_len=5 * g, num_pages=4)
    ref = ReferenceCBEngine(m, params, **kw).run(
        _clone(reqs, zero_arrivals=True))
    new = ContinuousBatchingEngine(m, params, **kw).run(
        _clone(reqs, zero_arrivals=True))
    assert sum(r.preemptions for r in ref["requests"]) > 0
    _assert_parity(ref, new)


def test_golden_parity_shared_prefix_chunked(smoke_model):
    """The acceptance workload: chunked prefill + prefix-cache adoption.
    Page-adoption decisions and prefix-hit metrics must be identical."""
    cfg, m, params = smoke_model
    reqs = _shared_prefix_requests(cfg)
    kw = dict(max_slots=3, max_len=192, prefix_cache=True,
              prefill_chunk=32)
    ref = ReferenceCBEngine(m, params, **kw).run(
        _clone(reqs, zero_arrivals=True))
    new = ContinuousBatchingEngine(m, params, **kw).run(
        _clone(reqs, zero_arrivals=True))
    assert ref["adopted_pages"] > 0, "workload must exercise adoption"
    _assert_parity(ref, new)


def test_event_stream_reconstructs_outputs(smoke_model):
    """On a preemption-free run, the token-bearing events replay each
    request's output exactly; the event clock is monotonic and every
    request walks admit -> first_token -> token* -> finish in order."""
    cfg, m, params = smoke_model
    eng = ContinuousBatchingEngine(m, params, max_slots=3, max_len=128)
    out = eng.run(_poisson_requests(cfg, 6))
    events = out["events"]
    assert events and all(ev.kind in EVENT_KINDS for ev in events)
    ts = [ev.t for ev in events]
    assert ts == sorted(ts), "event clock must be monotonic"
    streamed: dict[int, list[int]] = {}
    seen: dict[int, list[str]] = {}
    for ev in events:
        seen.setdefault(ev.rid, []).append(ev.kind)
        if ev.kind in ("first_token", "token"):
            streamed.setdefault(ev.rid, []).append(ev.token)
    for r in out["requests"]:
        assert streamed[r.rid] == list(r.out_tokens)
        kinds = seen[r.rid]
        assert kinds[0] == "admit" and kinds[1] == "first_token"
        assert kinds[-1] == "finish"
    stats = stream_latency_stats(events, out["requests"])
    assert stats["ttft_s"]["n"] == len(out["requests"])
    assert stats["itl_s"]["n"] == out["total_tokens"] - len(out["requests"])
    assert stats["ttft_s"]["p99"] >= stats["ttft_s"]["p50"] >= 0


def test_streaming_engine_matches_batch_run(smoke_model):
    """Submitting the same workload through the streaming front door
    yields the same greedy tokens as the batch adapter (same core, two
    sessions)."""
    cfg, m, params = smoke_model
    eng = ContinuousBatchingEngine(m, params, max_slots=3, max_len=128)
    reqs = _poisson_requests(cfg, 5, seed=4)
    batch = eng.run(_clone(reqs, zero_arrivals=True))
    stream = StreamingEngine(eng)
    for r in _clone(reqs, zero_arrivals=True):
        stream.submit(r)
    streamed: dict[int, list[int]] = {}
    for ev in stream.events():
        if ev.kind in ("first_token", "token"):
            streamed.setdefault(ev.rid, []).append(ev.token)
    assert streamed == {r.rid: list(r.out_tokens)
                        for r in batch["requests"]}
    res = stream.result()
    assert res["total_tokens"] == batch["total_tokens"]
    assert res["decode_steps"] == batch["decode_steps"]


def test_event_stream_preemption_retracts_token(smoke_model):
    """Under preemption, the preempt event carries the retracted token
    and applying the retraction rule (drop the rid's last streamed token)
    reconstructs every request's output exactly."""
    cfg, m, params = smoke_model
    g = cfg.quant.group_size
    rng = np.random.default_rng(1)
    eng = ContinuousBatchingEngine(m, params, max_slots=2, max_len=5 * g,
                                   num_pages=4)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (g - 2,)).astype(np.int32),
                    max_new_tokens=40) for i in range(2)]
    out = eng.run(reqs)
    assert sum(r.preemptions for r in out["requests"]) > 0
    streamed: dict[int, list[int]] = {}
    for ev in out["events"]:
        if ev.kind in ("first_token", "token"):
            streamed.setdefault(ev.rid, []).append(ev.token)
        elif ev.kind == "preempt" and ev.token is not None:
            assert streamed[ev.rid][-1] == ev.token
            streamed[ev.rid].pop()
    assert streamed == {r.rid: list(r.out_tokens)
                        for r in out["requests"]}


# ---------------------------------------------------------------------------
# Cancellation
# ---------------------------------------------------------------------------


def _drive_until(stream, pred, limit=500):
    evs = []
    for _ in range(limit):
        evs.extend(stream.step())
        if pred(evs):
            return evs
    raise AssertionError("condition never reached")


def test_cancel_mid_decode_frees_pages_and_reuses_slot(smoke_model):
    """Cancel a decoding request: its pages return to the free list, the
    allocator stays consistent, and the very next admission reuses the
    freed slot."""
    cfg, m, params = smoke_model
    eng = ContinuousBatchingEngine(m, params, max_slots=2, max_len=128)
    stream = StreamingEngine(eng)
    core = stream.core
    for r in _poisson_requests(cfg, 2, max_new=(30, 31),
                               rate=1e6):  # both arrive ~immediately
        stream.submit(r)
    evs = _drive_until(stream, lambda es: sum(
        1 for e in es if e.rid == 0 and e.kind in ("first_token", "token"))
        >= 3)
    slot0 = next(e.slot for e in evs if e.rid == 0 and e.kind == "admit")
    used_before = core.sched.alloc.used_pages
    assert stream.cancel(0)
    check_alloc_invariants(core.sched.alloc)
    assert core.sched.alloc.used_pages < used_before
    assert 0 not in {r.rid for r in core.completed}
    assert core.cancelled[0].rid == 0
    assert core.cancelled[0].state == "cancelled"
    assert not stream.cancel(0), "double cancel must be a no-op"
    # the freed slot is admissible again immediately
    rid2 = stream.add_request(np.arange(20, dtype=np.int32) % cfg.vocab_size,
                              max_new_tokens=4)
    evs = _drive_until(stream, lambda es: any(
        e.rid == rid2 and e.kind == "finish" for e in es))
    cancel_ev = [e for e in evs if e.kind == "cancel"]
    assert cancel_ev and cancel_ev[0].rid == 0 and cancel_ev[0].slot == slot0
    assert next(e.slot for e in evs
                if e.rid == rid2 and e.kind == "admit") == slot0
    # drain: everything else completes and the pool is fully reclaimed
    list(stream.events())
    check_alloc_invariants(core.sched.alloc)
    assert core.sched.alloc.free_pages == core.layout.num_pages


@pytest.mark.parametrize("prefix_cache", [False, True])
def test_cancel_mid_prefill_chunked(smoke_model, prefix_cache):
    """Cancel between prefill chunks: reserved pages are released (to the
    free list, or to index-only state under the prefix cache) and the
    engine keeps serving."""
    cfg, m, params = smoke_model
    g = cfg.quant.group_size
    core = EngineCore(m, params, max_slots=2, max_len=6 * g,
                      prefill_chunk=g, prefix_cache=prefix_cache)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, (4 * g,)).astype(np.int32)
    core.add_request(Request(rid=0, prompt=prompt, max_new_tokens=6))
    for _ in range(200):
        core.step()
        if core._prefilling and 0 < next(
                iter(core._prefilling.values()))["off"] < 4 * g:
            break
    else:
        raise AssertionError("never caught the request mid-prefill")
    evs = core.cancel(0)
    assert [e.kind for e in evs] == ["cancel"]
    assert not core._prefilling
    check_alloc_invariants(core.sched.alloc)
    if prefix_cache:
        # mid-prefill nothing was registered yet: all pages come back
        assert len(core.prefix) == 0
    assert core.sched.alloc.free_pages == core.layout.num_pages
    # slot is immediately reusable
    core.add_request(Request(rid=1, prompt=prompt[: 2 * g],
                             max_new_tokens=4,
                             arrival_time=core.clock))
    while core.has_work:
        core.step()
    assert [r.rid for r in core.completed] == [1]
    assert core.completed[0].done_tokens == 4
    check_alloc_invariants(core.sched.alloc)


def test_cancel_adopter_keeps_index_pages_live(smoke_model):
    """With the prefix cache on, cancelling a request that adopted shared
    pages must decref them to index-only state — never free them — and a
    later admission re-adopts the same pages into the freed slot."""
    cfg, m, params = smoke_model
    g = cfg.quant.group_size
    core = EngineCore(m, params, max_slots=2, max_len=6 * g,
                      prefix_cache=True, prefill_chunk=g)
    rng = np.random.default_rng(6)
    shared = rng.integers(0, cfg.vocab_size, (3 * g,)).astype(np.int32)

    def req(rid, tail_seed):
        tail = rng.integers(0, cfg.vocab_size, (10,)).astype(np.int32) \
            if tail_seed else np.zeros((0,), np.int32)
        return Request(rid=rid, prompt=np.concatenate([shared, tail]),
                       max_new_tokens=8, arrival_time=core.clock)

    # donor prefills alone and registers the shared prefix
    core.add_request(req(0, 0))
    while core.has_work:
        core.step()
    assert len(core.prefix) > 0
    index_pages = set(core.prefix.pages)

    # adopter admits (hits the index), decodes a little, then cancels
    core.add_request(req(1, 1))
    events = []
    for _ in range(300):
        events.extend(core.step())
        if sum(1 for e in events
               if e.rid == 1 and e.kind in ("first_token", "token")) >= 2:
            break
    adopted_before = core.sched.adopted_pages
    assert adopted_before > 0, "adopter must hit the prefix index"
    core.cancel(1)
    check_alloc_invariants(core.sched.alloc)
    # every indexed page survived the cancel at exactly one ref (index)
    for p in index_pages:
        assert core.sched.alloc.refcount(p) == 1
    # a later admission re-adopts the same pages into the freed slot
    core.add_request(req(2, 1))
    while core.has_work:
        core.step()
    assert core.sched.adopted_pages > adopted_before
    assert [r.rid for r in core.completed if r.rid == 2] == [2]
    check_alloc_invariants(core.sched.alloc)


def test_add_request_rejects_oversized_prompt(smoke_model):
    """An impossible context is rejected at intake (ValueError) instead
    of poisoning the open-loop session when it reaches the queue head."""
    cfg, m, params = smoke_model
    eng = ContinuousBatchingEngine(m, params, max_slots=2, max_len=128)
    stream = StreamingEngine(eng)
    with pytest.raises(ValueError, match="pages_per_slot"):
        stream.add_request(np.zeros(128, np.int32), max_new_tokens=4)
    # the session is unharmed and keeps serving
    rid = stream.add_request(np.zeros(16, np.int32), max_new_tokens=3)
    kinds = [ev.kind for ev in stream.events() if ev.rid == rid]
    assert kinds[-1] == "finish"
    assert [r.rid for r in stream.core.completed] == [rid]


def test_cancel_invalidates_hash_memo_across_rid_reuse():
    """Scheduler-level: cancelling a pending request must drop its
    memoized prefix hashes — a later request reusing the same rid with
    an equal-length but different prompt must not adopt the cancelled
    prompt's pages."""
    from repro.core.cache_layout import PagedLayout, PrefixIndex
    lay = PagedLayout(page_size=4, num_pages=16, slots=2, pages_per_slot=8)
    sched = Scheduler(lay, prefix_index=PrefixIndex(lay, 4),
                      chunk_tokens=4)
    prompt_a = np.arange(12, dtype=np.int32)
    donor = Request(rid=0, prompt=prompt_a)
    sched.submit(donor)
    assert sched.admissible() is donor
    slot = sched.admit(donor)
    sched.register_prefix(slot)     # prompt A's pages enter the index
    sched.finish(slot)
    # rid 5 with prompt A polls admission (hashes memoized), then cancels
    req_a = Request(rid=5, prompt=prompt_a.copy())
    sched.submit(req_a)
    assert sched.admissible() is req_a
    summary = sched.cancel(5)
    assert summary.req is req_a and summary.slot == -1
    assert not summary.was_active and summary.freed_pages == 0
    # rid 5 reused: same length, different tokens — must miss the index
    req_b = Request(rid=5, prompt=np.arange(100, 112, dtype=np.int32))
    sched.submit(req_b)
    assert sched.admissible() is req_b
    sched.admit(req_b)
    assert req_b.prefix_hit_tokens == 0
    check_alloc_invariants(sched.alloc)


def test_nearest_rank_pct_is_nearest_rank():
    from repro.utils import nearest_rank_pct
    assert nearest_rank_pct([], 50) == 0.0
    assert nearest_rank_pct([1.0, 100.0], 50) == 1.0
    assert nearest_rank_pct([1.0, 100.0], 99) == 100.0
    vals = list(range(1, 11))
    assert nearest_rank_pct(vals, 50) == 5    # ceil(0.50*10) = rank 5
    assert nearest_rank_pct(vals, 95) == 10   # ceil(0.95*10) = rank 10
    assert nearest_rank_pct(vals, 0) == 1


def test_cancel_queued_request_never_touches_pool(smoke_model):
    """Cancelling a not-yet-admitted request involves no pages; cancelling
    an unknown rid is a no-op."""
    cfg, m, params = smoke_model
    eng = ContinuousBatchingEngine(m, params, max_slots=2, max_len=128)
    stream = StreamingEngine(eng)
    core = stream.core
    rng = np.random.default_rng(7)
    for i in range(4):   # 2 slots: at least two stay queued at first
        stream.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                       (16,)).astype(np.int32),
            max_new_tokens=12))
    _drive_until(stream, lambda es: any(e.kind == "first_token"
                                        for e in es))
    queued = [r.rid for r in core.sched.pending] + \
             [r.rid for r in core._arrivals]
    assert queued, "test needs a queued request"
    victim = queued[0]
    used = core.sched.alloc.used_pages
    assert stream.cancel(victim)
    assert core.sched.alloc.used_pages == used
    assert not stream.cancel(999)
    rest = [e for e in stream.events()]
    assert {e.rid for e in rest if e.kind == "finish"} == \
        {0, 1, 2, 3} - {victim}
    check_alloc_invariants(core.sched.alloc)
    assert core.sched.alloc.free_pages == core.layout.num_pages


def test_cancel_after_finish_is_noop(smoke_model):
    """Cancelling a rid that already finished must be a documented no-op:
    ``cancel`` returns False, the completed result is untouched, and the
    session keeps serving."""
    cfg, m, params = smoke_model
    eng = ContinuousBatchingEngine(m, params, max_slots=2, max_len=128)
    stream = StreamingEngine(eng)
    rid = stream.add_request(np.zeros(12, np.int32), max_new_tokens=3)
    evs = list(stream.events())
    assert any(e.rid == rid and e.kind == "finish" for e in evs)
    done = [r for r in stream.core.completed if r.rid == rid]
    toks = list(done[0].out_tokens)
    assert not stream.cancel(rid)            # finished rid: no-op
    assert stream.core.sched.cancel(rid) is None   # scheduler agrees
    assert list(done[0].out_tokens) == toks        # result untouched
    assert not stream.core.cancelled
    # and the engine still serves the next request normally
    rid2 = stream.add_request(np.zeros(12, np.int32), max_new_tokens=2)
    kinds = [e.kind for e in stream.events() if e.rid == rid2]
    assert kinds[-1] == "finish"


# ---------------------------------------------------------------------------
# stream_latency_stats degenerate streams (synthetic events)
# ---------------------------------------------------------------------------


def _ev(kind, rid, t, token=None):
    from repro.serve import TokenEvent
    return TokenEvent(kind=kind, rid=rid, t=t, token=token)


def test_latency_stats_all_shed_stream_is_zeroed():
    """A session whose every request was shed/rejected produced no
    tokens: both percentiles blocks must be exact zeros with n=0, never
    NaN."""
    reqs = [Request(rid=i, prompt=np.zeros(4, np.int32),
                    arrival_time=0.01 * i) for i in range(3)]
    events = [_ev("shed", 0, 0.5), _ev("reject", 1, 0.5),
              _ev("shed", 2, 0.6)]
    stats = stream_latency_stats(events, reqs)
    assert stats["ttft_s"] == {"p50": 0.0, "p95": 0.0, "p99": 0.0,
                               "mean": 0.0, "n": 0}
    assert stats["itl_s"]["n"] == 0 and stats["itl_s"]["mean"] == 0.0


def test_latency_stats_single_token_responses_have_no_itl():
    """max_new_tokens=1 fleets have a TTFT per request but zero
    inter-token gaps."""
    reqs = [Request(rid=i, prompt=np.zeros(4, np.int32),
                    arrival_time=float(i)) for i in range(4)]
    events = [_ev("first_token", i, float(i) + 0.25, token=7)
              for i in range(4)]
    stats = stream_latency_stats(events, reqs)
    assert stats["ttft_s"]["n"] == 4
    assert stats["ttft_s"]["p50"] == pytest.approx(0.25)
    assert stats["itl_s"] == {"p50": 0.0, "p95": 0.0, "p99": 0.0,
                              "mean": 0.0, "n": 0}


def test_latency_stats_preempt_retraction_restarts_ttft():
    """A preemption that retracts the only visible token resets the
    client's stream: TTFT is measured to the post-resume first token,
    and no gap across the retraction can go negative."""
    req = Request(rid=0, prompt=np.zeros(4, np.int32), arrival_time=0.0)
    events = [
        _ev("first_token", 0, 1.0, token=5),
        _ev("preempt", 0, 1.5, token=5),    # retracts the whole stream
        _ev("first_token", 0, 4.0, token=5),
        _ev("token", 0, 4.5, token=6),
    ]
    stats = stream_latency_stats(events, [req])
    assert stats["ttft_s"]["n"] == 1
    assert stats["ttft_s"]["p50"] == pytest.approx(4.0)  # post-resume
    assert stats["itl_s"]["n"] == 1
    assert stats["itl_s"]["p50"] == pytest.approx(0.5)
    assert all(v >= 0.0 for v in stats["itl_s"].values())
    # retraction of a rid that never streamed anything must not underflow
    ghost = stream_latency_stats([_ev("preempt", 1, 0.1)], [req])
    assert ghost["ttft_s"]["n"] == 0 and ghost["itl_s"]["n"] == 0
