"""KeyCodec registry + CachePolicy: buffer contracts, golden parity with
the pre-registry implementation, dense-vs-paged parity for every registered
codec, and runtime extensibility with a third-party codec."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CachePolicy, QuantConfig, append, decode_attention, init_cache, prefill,
)
from repro.core import codecs
from repro.core import paged_cache as pg
from repro.core.cache_layout import LinearLayout, PagedLayout, PageAllocator


# ---------------------------------------------------------------------------
# A toy third-party codec, registered at runtime: token-wise symmetric
# 8-bit absmax. Exercises the full extension surface (allocation, encode,
# decode, default dequant-matmul score path) with none of the built-in code.
# ---------------------------------------------------------------------------


class ToyAbsmaxCodec(codecs.KeyCodec):
    name = "toy-absmax"

    def bits_per_element(self, cfg, head_dim):
        return 8.0 + 16.0 / head_dim

    def init_buffers(self, cfg, lead, tokens, head_dim, dtype):
        sdt = jnp.dtype(cfg.scale_dtype)
        return (jnp.zeros((*lead, tokens, head_dim), jnp.uint8),
                {"amax": jnp.zeros((*lead, tokens, 1), sdt)})

    def encode(self, cfg, k):
        a = jnp.maximum(jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1,
                                keepdims=True), 1e-8)
        codes = jnp.clip(jnp.round(k / a * 127.0) + 128.0, 0, 255)
        return codes.astype(jnp.uint8), {
            "amax": a.astype(jnp.dtype(cfg.scale_dtype))}

    def decode(self, cfg, codes, scales, dtype=jnp.float32):
        a = scales["amax"].astype(jnp.float32)
        return ((codes.astype(jnp.float32) - 128.0) / 127.0 * a).astype(dtype)

    # container() inherited: the generic codecs.CodecKeys wrapper


if "toy-absmax" not in codecs.registered_codecs():
    codecs.register_codec(ToyAbsmaxCodec())

ALL_CODECS = sorted(codecs.registered_codecs())
QUANTIZING = [n for n in ALL_CODECS if codecs.get_codec(n).quantizes]


def _cfg(method: str) -> QuantConfig:
    return QuantConfig(method=method, group_size=16, key_bits=8,
                       rho_bits=4, theta_bits=4, residual_dtype="float32")


def _kv(seed, b, h, t, d):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(k1, (b, h, t, d)),
            jax.random.normal(k2, (b, h, t, d)))


# ---------------------------------------------------------------------------
# Registry + buffer contract
# ---------------------------------------------------------------------------


def test_builtin_codecs_registered():
    assert {"none", "int", "kivi", "zipcache", "polar"} <= set(ALL_CODECS)
    with pytest.raises(KeyError, match="unknown key codec"):
        codecs.get_codec("no-such-codec")
    with pytest.raises(ValueError, match="already registered"):
        codecs.register_codec(ToyAbsmaxCodec())


@pytest.mark.parametrize("name", ALL_CODECS)
def test_encode_matches_init_buffer_shapes(name):
    """codec.encode output must drop into codec.init_buffers storage —
    the contract the caches rely on for any registered codec."""
    cfg = _cfg(name)
    codec = codecs.get_codec(name)
    b, h, t, d = 2, 2, 64, 32
    k, _ = _kv(0, b, h, t, d)
    buf_codes, buf_scales = codec.init_buffers(cfg, (b, h), t, d,
                                               jnp.float32)
    codes, scales = codec.encode(cfg, k)
    assert codes.shape == buf_codes.shape
    assert set(scales) == set(buf_scales)
    for key in scales:
        assert scales[key].shape == buf_scales[key].shape, key


@pytest.mark.parametrize("name", QUANTIZING)
def test_codec_roundtrip(name):
    cfg = _cfg(name)
    codec = codecs.get_codec(name)
    k, _ = _kv(1, 2, 2, 64, 32)
    kt = codec.decode(cfg, *codec.encode(cfg, k))
    assert kt.shape == k.shape
    rel = float(jnp.linalg.norm(k - kt) / jnp.linalg.norm(k))
    tol = 0.35 if name == "polar" else 0.02   # 8-bit baselines vs polar 4+4
    assert rel < tol, (name, rel)


@pytest.mark.parametrize("name", ALL_CODECS)
def test_generic_encode_decode_keys_entry_points(name):
    """quantizers.encode_keys/decode_keys must round-trip every registered
    codec — third-party codecs ride the generic CodecKeys container."""
    from repro.core.quantizers import decode_keys, encode_keys

    cfg = _cfg(name)
    k, _ = _kv(8, 2, 2, 64, 32)
    kt = decode_keys(encode_keys(k, cfg))
    assert kt.shape == k.shape
    np.testing.assert_allclose(
        np.asarray(kt),
        np.asarray(codecs.get_codec(name).decode(cfg, *codecs.get_codec(
            name).encode(cfg, k))) if codecs.get_codec(name).quantizes
        else np.asarray(k, np.float32),
        rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("name", ALL_CODECS)
def test_codec_scores_match_dequant_matmul(name):
    """The codec score path (LUT for polar) must agree with the oracle
    dequantize-then-matmul on its own decode output."""
    cfg = _cfg(name)
    codec = codecs.get_codec(name)
    k, _ = _kv(2, 1, 2, 32, 16)
    codes, scales = codec.encode(cfg, k)
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 4, 16))
    s = codec.scores(cfg, q, codes, scales)
    oracle = jnp.einsum("bhqd,bhtd->bhqt", q,
                        codec.decode(cfg, codes, scales))
    np.testing.assert_allclose(np.asarray(s), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Golden parity with the pre-registry implementation
# ---------------------------------------------------------------------------

# Captured from the seed (string-dispatch) implementation at commit
# "PR 1" shapes B,H,d,g,T = 1,2,32,16,70: (key-code sum, sum(out), sum|out|)
# for prefill(64) + 6 appends + decode_attention, fp32, PRNGKey(42)/(7).
_GOLDEN = {
    "polar": (227428, 5.3508195877e+00, 1.8290977478e+01),
    "kivi": (30781, 4.9970455170e+00, 1.9550201416e+01),
    "zipcache": (31099, 4.9251194000e+00, 1.8520610809e+01),
    "int": (33721, 5.0163354874e+00, 1.9066673279e+01),
    "none": (0, 4.9392638206e+00, 1.8867635727e+01),
    "polar+v4": (227428, 5.5629472733e+00, 1.8356626511e+01),
}


@pytest.mark.parametrize("name", sorted(_GOLDEN))
def test_golden_parity_with_pre_registry_implementation(name):
    method, _, v = name.partition("+v")
    value_bits = int(v) if v else 0
    B, H, d, g, T = 1, 2, 32, 16, 70
    k1, k2 = jax.random.split(jax.random.PRNGKey(42))
    k = jax.random.normal(k1, (B, H, T, d))
    v_ = jax.random.normal(k2, (B, H, T, d))
    cfg = QuantConfig(method=method, group_size=g, key_bits=4,
                      value_bits=value_bits, residual_dtype="float32")
    cache = prefill(init_cache(cfg, B, H, d, 128, dtype=jnp.float32),
                    k[:, :, :64], v_[:, :, :64])
    for i in range(64, T):
        cache = append(cache, k[:, :, i : i + 1], v_[:, :, i : i + 1])
    q = jax.random.normal(jax.random.PRNGKey(7), (B, H * 2, d))
    out = decode_attention(cache, q)
    code_sum, out_sum, out_abs = _GOLDEN[name]
    if cache.key_codes.dtype == jnp.uint8:
        assert int(np.asarray(cache.key_codes, np.int64).sum()) == code_sum
    np.testing.assert_allclose(float(out.sum()), out_sum, rtol=1e-6)
    np.testing.assert_allclose(float(jnp.abs(out).sum()), out_abs, rtol=1e-6)


# ---------------------------------------------------------------------------
# Dense vs paged parity for EVERY registered codec (toy included)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_CODECS)
def test_dense_paged_parity(name):
    cfg = _cfg(name)
    B, H, d, g = 1, 2, 32, 16
    layout = PagedLayout(page_size=g, num_pages=12, slots=2, pages_per_slot=6)
    tp, tdec, slot, bucket = 38, 13, 1, 48
    t = tp + tdec
    k, v = _kv(5, B, H, t, d)
    cap = layout.pages_per_slot * g

    dense = prefill(init_cache(cfg, B, H, d, cap, layout=LinearLayout(cap)),
                    k[:, :, :tp], v[:, :, :tp])
    for i in range(tp, t):
        dense = append(dense, k[:, :, i : i + 1], v[:, :, i : i + 1])

    alloc = PageAllocator(layout)
    assert alloc.alloc(slot, layout.pages_for(tp))
    paged = pg.init_paged_cache(cfg, layout, H, d)
    kp = jnp.pad(k[:, :, :tp], ((0, 0), (0, 0), (0, bucket - tp), (0, 0)))
    vp = jnp.pad(v[:, :, :tp], ((0, 0), (0, 0), (0, bucket - tp), (0, 0)))
    paged = pg.paged_prefill(paged, jnp.asarray(slot), alloc.table()[slot],
                             kp, vp, jnp.asarray(tp))
    ap = jax.jit(pg.paged_append)
    for i in range(tp, t):
        ln = int(paged.lengths[slot])
        if ln % g == 0 and alloc.slot_pages(slot) <= ln // g:
            assert alloc.alloc(slot, 1)
        s = layout.slots
        kn = jnp.zeros((s, H, 1, d)).at[slot].set(k[0, :, i : i + 1])
        vn = jnp.zeros((s, H, 1, d)).at[slot].set(v[0, :, i : i + 1])
        active = jnp.zeros((s,), bool).at[slot].set(True)
        paged = ap(paged, kn, vn, alloc.table(), active)

    view = pg.gather_view(paged, alloc.table())
    if codecs.get_codec(name).grouped:
        nfull = int(dense.length) // g
        np.testing.assert_array_equal(
            np.asarray(dense.key_codes)[0, :, :nfull],
            np.asarray(view.key_codes)[slot, :, :nfull])
    elif codecs.get_codec(name).quantizes:
        tlen = int(dense.length)
        np.testing.assert_array_equal(
            np.asarray(dense.key_codes)[0, :, :tlen],
            np.asarray(view.key_codes)[slot, :, :tlen])

    q = jax.random.normal(jax.random.PRNGKey(9), (B, H * 2, d))
    qs = jnp.zeros((layout.slots, H * 2, d)).at[slot].set(q[0])
    o_dense = decode_attention(dense, q)
    o_paged = pg.paged_decode_attention(paged, qs, alloc.table(),
                                        backend="jnp")
    np.testing.assert_allclose(np.asarray(o_dense[0]),
                               np.asarray(o_paged[slot]),
                               atol=2e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# Toy codec end-to-end through make_cache -> decode attention
# ---------------------------------------------------------------------------


def test_third_party_codec_through_make_cache():
    from repro.configs import get_config, reduce_for_smoke
    from repro.models import attn_block as AB

    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    cfg = dataclasses.replace(
        cfg, quant=dataclasses.replace(cfg.quant, method="toy-absmax"),
        dtype="float32")
    cache = AB.make_cache(cfg, batch=2, max_len=96)
    cache_fp = AB.make_cache(
        dataclasses.replace(cfg, quant=dataclasses.replace(
            cfg.quant, method="none")), batch=2, max_len=96)
    h, d = cfg.num_kv_heads, cfg.head_dim
    k, v = _kv(11, 2, h, 70, d)
    cache = prefill(cache, k, v)
    cache_fp = prefill(cache_fp, k, v)
    q = jax.random.normal(jax.random.PRNGKey(12), (2, cfg.num_heads, d))
    out = decode_attention(cache, q)
    ref = decode_attention(cache_fp, q)
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    assert rel < 0.05, rel  # 8-bit absmax keys track the fp cache closely
    assert cfg.quant.key_bits_per_element(d) == 8.0 + 16.0 / d


# ---------------------------------------------------------------------------
# CachePolicy
# ---------------------------------------------------------------------------


def test_cache_policy_segments_and_lookup():
    int8 = QuantConfig(method="int", key_bits=8)
    polar = QuantConfig(method="polar")
    pol = CachePolicy.first_k(2, int8, polar)
    assert pol.layer_config(0) == int8
    assert pol.layer_config(1) == int8
    assert pol.layer_config(5) == polar
    assert pol.segments(6) == ((0, 2, int8), (2, 6, polar))
    assert not pol.is_uniform

    uni = CachePolicy.uniform(polar)
    assert uni.is_uniform
    assert uni.segments(4) == ((0, 4, polar),)

    sparse = CachePolicy.per_layer({1: int8}, polar)
    assert sparse.segments(3) == ((0, 1, polar), (1, 2, int8), (2, 3, polar))


def test_cache_policy_avg_bits_and_group_size():
    int8 = QuantConfig(method="int", key_bits=8, group_size=128)
    polar = QuantConfig(method="polar", rho_bits=4, theta_bits=4,
                        group_size=128)
    pol = CachePolicy.first_k(2, int8, polar)
    avg = pol.avg_key_bits(4, head_dim=128)
    expect = (2 * (8 + 32 / 128) + 2 * 4.25) / 4
    assert abs(avg - expect) < 1e-6
    assert pol.page_group_size() == 128

    bad = CachePolicy.first_k(1, dataclasses.replace(int8, group_size=64),
                              polar)
    with pytest.raises(ValueError, match="one group size"):
        bad.page_group_size()
    assert bad.max_group_size() == 128   # dense buckets use the largest

    small = pol.map(lambda q: dataclasses.replace(q, group_size=32))
    assert small.page_group_size() == 32
    assert small.layer_config(0).method == "int"


def test_mixed_policy_dense_cache_state():
    """Per-layer mixed policy through the dense transformer serving state:
    segment caches carry each layer's own codec buffers."""
    from repro.configs import get_config, reduce_for_smoke
    from repro.models import transformer as TF

    base = reduce_for_smoke(get_config("tinyllama-1.1b"))
    policy = CachePolicy.first_k(
        1, dataclasses.replace(base.quant, method="int", key_bits=8),
        base.quant)
    cfg = dataclasses.replace(base, cache_policy=policy)
    caches = TF.init_decode_caches(cfg, batch=2, max_len=64)
    assert len(caches) == 2                       # int segment + polar segment
    assert caches[0].cfg.method == "int"
    assert caches[1].cfg.method == "polar"
    per_layer = TF.per_layer_cache_bytes(cfg, caches)
    assert len(per_layer) == cfg.num_layers
    assert all(b > 0 for b in per_layer)
