"""Continuous-batching engine: staggered admission, EOS reclamation,
greedy parity with the static engine, oversubscription + preemption,
per-layer mixed-precision cache policies."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import CachePolicy
from repro.models import get_model
from repro.serve import (
    ContinuousBatchingEngine, GenerationConfig, Request, ServeEngine,
)


@pytest.fixture(scope="module")
def smoke_model():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _requests(cfg, n, rng_seed=0, arrival_gap=0.01, lo=8, hi=50,
              max_new=(3, 12)):
    rng = np.random.default_rng(rng_seed)
    return [Request(
        rid=i,
        prompt=rng.integers(0, cfg.vocab_size,
                            (int(rng.integers(lo, hi)),)).astype(np.int32),
        max_new_tokens=int(rng.integers(*max_new)),
        arrival_time=i * arrival_gap) for i in range(n)]


def test_staggered_admission_completes_all(smoke_model):
    cfg, m, params = smoke_model
    eng = ContinuousBatchingEngine(m, params, max_slots=3, max_len=128)
    reqs = _requests(cfg, 7)
    eng.warmup([r.prompt_len for r in reqs])
    out = eng.run(reqs, GenerationConfig())
    assert len(out["requests"]) == 7
    for r in out["requests"]:
        assert r.done_tokens == r.max_new_tokens
        assert r.t_done is not None and r.t_done >= r.arrival_time
    assert out["total_tokens"] == sum(r.max_new_tokens for r in reqs)
    assert out["tokens_per_s"] > 0
    assert 0.0 < out["mean_page_utilization"] <= 1.0
    # later arrivals joined while earlier ones were decoding
    assert out["mean_active_slots"] > 1.0


def test_eos_mid_stream_frees_early(smoke_model):
    """Set eos_id to a token the greedy run actually produces: requests
    must terminate at it and release their slots (total < max budget)."""
    cfg, m, params = smoke_model
    eng = ContinuousBatchingEngine(m, params, max_slots=2, max_len=128)
    reqs = _requests(cfg, 3, max_new=(16, 17))
    out = eng.run(reqs, GenerationConfig())
    produced = [t for r in out["requests"] for t in r.out_tokens[2:-2]]
    eos = int(produced[len(produced) // 2])

    reqs2 = [Request(rid=r.rid, prompt=r.prompt,
                     max_new_tokens=r.max_new_tokens,
                     arrival_time=r.arrival_time) for r in reqs]
    out2 = eng.run(reqs2, GenerationConfig(eos_id=eos))
    assert len(out2["requests"]) == 3
    stopped = [r for r in out2["requests"]
               if r.out_tokens and r.out_tokens[-1] == eos
               and r.done_tokens < r.max_new_tokens]
    assert stopped, "at least one request must stop early at EOS"
    assert out2["total_tokens"] < out["total_tokens"]


def test_greedy_matches_static_engine(smoke_model):
    cfg, m, params = smoke_model
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, (33,)).astype(np.int32)
    n = 10

    static = ServeEngine(m, params, max_len=128).generate(
        {"tokens": prompt[None, :]}, GenerationConfig(max_new_tokens=n))
    eng = ContinuousBatchingEngine(m, params, max_slots=2, max_len=128)
    out = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=n)],
                  GenerationConfig(max_new_tokens=n))
    cb = out["requests"][0].out_tokens
    assert cb == static["tokens"][0][:n].tolist()


def test_page_reuse_across_requests(smoke_model):
    """Pool sized for ~1.5 requests: later requests can only run on pages
    reclaimed from earlier completions."""
    cfg, m, params = smoke_model
    g = cfg.quant.group_size
    eng = ContinuousBatchingEngine(m, params, max_slots=2, max_len=4 * g,
                                   num_pages=6)
    reqs = _requests(cfg, 5, lo=30, hi=60, max_new=(8, 9))
    out = eng.run(reqs, GenerationConfig())
    assert len(out["requests"]) == 5
    assert all(r.done_tokens == r.max_new_tokens for r in out["requests"])


def test_mixed_policy_generates_with_per_layer_bytes(smoke_model):
    """KVTuner-style mixed precision (layer 0 at int8, rest at polar 4+4)
    generates end-to-end under continuous batching; the engine reports
    per-layer cache bytes from the segmented paged state."""
    cfg, m, params = smoke_model
    policy = CachePolicy.first_k(
        1, dataclasses.replace(cfg.quant, method="int", key_bits=8),
        dataclasses.replace(cfg.quant, method="polar", rho_bits=4,
                            theta_bits=4))
    cfg_m = dataclasses.replace(cfg, cache_policy=policy)
    # params are policy-independent: reuse the smoke model's weights
    eng = ContinuousBatchingEngine(get_model(cfg_m), params, max_slots=2,
                                   max_len=128)
    reqs = _requests(cfg, 4)
    out = eng.run(reqs, GenerationConfig())
    assert len(out["requests"]) == 4
    assert all(r.done_tokens == r.max_new_tokens for r in out["requests"])
    per_layer = out["cache_bytes_per_layer"]
    assert len(per_layer) == cfg.num_layers
    # the int8 layer's pool is laid out differently from the polar layers'
    assert per_layer[0] != per_layer[1]
    assert sum(per_layer) == out["cache_bytes"]


def test_uniform_policy_matches_plain_quant(smoke_model):
    """An explicit uniform CachePolicy is the same engine configuration as
    the classic cfg.quant path (greedy token parity)."""
    cfg, m, params = smoke_model
    cfg_p = dataclasses.replace(cfg,
                                cache_policy=CachePolicy.uniform(cfg.quant))
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, (21,)).astype(np.int32)
    outs = []
    for model in (m, get_model(cfg_p)):
        eng = ContinuousBatchingEngine(model, params, max_slots=2,
                                       max_len=128)
        out = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=8)],
                      GenerationConfig(max_new_tokens=8))
        outs.append(out["requests"][0].out_tokens)
    assert outs[0] == outs[1]


def test_oversubscribed_pool_preempts_and_completes(smoke_model):
    """Both slots hit a page boundary with the pool dry: the engine must
    recompute-preempt one request and still finish both."""
    cfg, m, params = smoke_model
    g = cfg.quant.group_size
    rng = np.random.default_rng(1)
    eng = ContinuousBatchingEngine(m, params, max_slots=2, max_len=5 * g,
                                   num_pages=4)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (g - 2,)).astype(np.int32),
                    max_new_tokens=40) for i in range(2)]
    out = eng.run(reqs, GenerationConfig(max_new_tokens=40))
    assert len(out["requests"]) == 2
    assert all(r.done_tokens == 40 for r in out["requests"])
    assert sum(r.preemptions for r in out["requests"]) > 0
