"""Serving engine: generation sanity + quantized-cache memory win."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.models import get_model
from repro.serve import GenerationConfig, ServeEngine


def _engine(method="polar", arch="tinyllama-1.1b", value_bits=0):
    cfg = reduce_for_smoke(get_config(arch))
    cfg = dataclasses.replace(
        cfg, quant=dataclasses.replace(cfg.quant, method=method,
                                       value_bits=value_bits))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, ServeEngine(m, params, max_len=256)


def test_generate_greedy_deterministic():
    cfg, eng = _engine()
    prompts = {"tokens": np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 32)).astype(np.int32)}
    out1 = eng.generate(prompts, GenerationConfig(max_new_tokens=8))
    out2 = eng.generate(prompts, GenerationConfig(max_new_tokens=8))
    np.testing.assert_array_equal(out1["tokens"], out2["tokens"])
    assert out1["tokens"].shape == (2, 8)
    assert out1["tokens_per_s"] > 0


def test_quantized_cache_smaller_than_fp():
    _, eng_fp = _engine("none")
    _, eng_pq = _engine("polar")
    _, eng_pq_v = _engine("polar", value_bits=4)
    prompts = {"tokens": np.zeros((2, 32), np.int32)}
    b_fp = eng_fp.generate(prompts, GenerationConfig(max_new_tokens=2))["cache_bytes"]
    b_pq = eng_pq.generate(prompts, GenerationConfig(max_new_tokens=2))["cache_bytes"]
    b_pqv = eng_pq_v.generate(prompts, GenerationConfig(max_new_tokens=2))["cache_bytes"]
    assert b_pq < b_fp
    assert b_pqv < b_pq


def test_quantized_generation_tracks_fp():
    """Greedy continuations from polar cache should mostly agree with the fp
    cache on a random-init model over a short horizon."""
    cfg, eng_fp = _engine("none")
    _, eng_pq = _engine("polar")
    prompts = {"tokens": np.random.default_rng(1).integers(
        0, cfg.vocab_size, (4, 64)).astype(np.int32)}
    t_fp = eng_fp.generate(prompts, GenerationConfig(max_new_tokens=4))["tokens"]
    t_pq = eng_pq.generate(prompts, GenerationConfig(max_new_tokens=4))["tokens"]
    agree = (t_fp == t_pq).mean()
    assert agree >= 0.5, agree


def test_sampling_modes():
    cfg, eng = _engine()
    prompts = {"tokens": np.zeros((2, 16), np.int32)}
    out = eng.generate(prompts, GenerationConfig(max_new_tokens=4,
                                                 temperature=0.8, top_k=50,
                                                 seed=7))
    assert out["tokens"].shape == (2, 4)
    assert (out["tokens"] >= 0).all() and (out["tokens"] < cfg.vocab_size).all()
