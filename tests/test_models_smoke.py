"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting output shapes + finiteness, plus prefill->decode parity
checks for representative families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduce_for_smoke
from repro.models import get_model

B, T = 2, 96


def _batch(cfg, key, text_plus_one=True):
    text = T - (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    n = text + (1 if text_plus_one else 0)
    batch = {"tokens": jax.random.randint(key, (B, n), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.frontend_dim))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch):
    cfg = reduce_for_smoke(get_config(arch))
    m = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    batch = _batch(cfg, key)

    loss, metrics = jax.jit(lambda p, b: m.loss(p, b))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch

    grads, _ = jax.grad(lambda p: m.loss(p, batch), has_aux=True)(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in
                jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch

    # prefill + 2 decode steps
    state = m.init_decode_state(B, 128)
    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, :-1]
    logits, state = jax.jit(m.prefill)(params, pb, state)
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(logits, -1)
    for _ in range(2):
        logits, state = jax.jit(m.decode)(params, state, tok)
        tok = jnp.argmax(logits, -1)
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "paligemma-3b",
                                  "recurrentgemma-9b", "qwen2-moe-a2.7b"])
def test_decode_matches_forward(arch):
    """Greedy decode over a fp cache must track the teacher-forced forward
    logits (quant='none' isolates the decode-path plumbing)."""
    import dataclasses
    cfg = reduce_for_smoke(get_config(arch))
    # capacity drops differ between bulk prefill (T tokens compete) and
    # step decode (1 token, never drops) — that's routing policy, not a
    # plumbing bug; drop-free capacity isolates the plumbing under test.
    cfg = dataclasses.replace(
        cfg, quant=dataclasses.replace(cfg.quant, method="none"),
        capacity_factor=float(max(cfg.num_experts, 1)))
    m = get_model(cfg)
    key = jax.random.PRNGKey(1)
    params = m.init(key)
    batch = _batch(cfg, key, text_plus_one=False)
    toks = batch["tokens"]
    n_pre, n_dec = 64, 6

    state = m.init_decode_state(B, 128)
    pb = dict(batch)
    pb["tokens"] = toks[:, :n_pre]
    lg, state = m.prefill(params, pb, state)
    outs = [lg]
    for i in range(n_pre, n_pre + n_dec):
        lg, state = m.decode(params, state, toks[:, i])
        outs.append(lg)

    # oracle: full loss-path forward over the same tokens
    full = dict(batch)
    full["tokens"] = toks[:, : n_pre + n_dec + 1]
    _, metrics = m.loss(params, full)  # smoke only
    # teacher-forced logits via prefill of the longer prompt
    state2 = m.init_decode_state(B, 128)
    fb = dict(batch)
    fb["tokens"] = toks[:, : n_pre + n_dec]
    lg2, _ = m.prefill(params, fb, state2)
    np.testing.assert_allclose(np.asarray(outs[-1]), np.asarray(lg2),
                               atol=5e-2, rtol=5e-2)


def test_vlm_prefix_bidirectional():
    """Image patches must attend bidirectionally: permuting patch order
    changes prefix-region hiddens but the causal region stays causal."""
    cfg = reduce_for_smoke(get_config("paligemma-3b"))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(2))
    batch = _batch(cfg, jax.random.PRNGKey(3))
    loss1, _ = m.loss(params, batch)
    # future text tokens must NOT influence earlier losses => changing the
    # last token leaves all but the last-position loss terms equal; here we
    # just check determinism + finiteness of the prefix path.
    loss2, _ = m.loss(params, batch)
    assert float(loss1) == float(loss2)


def test_param_count_sanity():
    """Analytic param_count ~ actual init count for representative archs."""
    for arch, tol in [("tinyllama-1.1b", 0.02), ("yi-9b", 0.02),
                      ("qwen2-moe-a2.7b", 0.05), ("mamba2-2.7b", 0.10)]:
        cfg = get_config(arch)
        small = reduce_for_smoke(cfg)
        m = get_model(small)
        params = m.init(jax.random.PRNGKey(0))
        actual = sum(int(np.prod(p.shape)) for p in
                     jax.tree_util.tree_leaves(params))
        est = small.param_count()
        assert abs(est - actual) / actual < 0.35, (arch, est, actual)


@pytest.mark.parametrize("arch", ["dbrx-132b", "yi-9b", "mamba2-2.7b",
                                  "recurrentgemma-9b"])
def test_full_config_param_count(arch):
    """Published headline sizes: analytic count within 10%."""
    expect = {"dbrx-132b": 132e9, "yi-9b": 8.8e9, "mamba2-2.7b": 2.7e9,
              "recurrentgemma-9b": 9.2e9}[arch]
    n = get_config(arch).param_count()
    assert abs(n - expect) / expect < 0.12, (arch, n / 1e9)
