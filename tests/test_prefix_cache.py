"""Shared-prefix page reuse: allocator refcounts, the PrefixIndex trie,
copy-on-write splits, and engine-level bit-identical reuse (DESIGN.md §12).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hyp_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401
from repro.configs import get_config, reduce_for_smoke
from repro.core import paged_cache as pgc
from repro.core.cache_layout import (
    PageAllocator, PagedLayout, PrefixIndex, token_page_hashes,
)
from repro.core.kv_cache import decode_attention
from repro.core.quantizers import QuantConfig
from repro.models import get_model
from repro.serve import ContinuousBatchingEngine, GenerationConfig, Request
from repro.serve.scheduler import Scheduler


def small_layout(num_pages=8, slots=3, pages_per_slot=4, page_size=4):
    return PagedLayout(page_size=page_size, num_pages=num_pages,
                       slots=slots, pages_per_slot=pages_per_slot)


# ---------------------------------------------------------------------------
# PageAllocator refcounts
# ---------------------------------------------------------------------------


def check_alloc_invariants(alloc: PageAllocator):
    """No leak, no double-free: every page is free exactly once XOR
    referenced; slot mappings + external pins account for every ref."""
    lay = alloc.layout
    free = list(alloc._free)
    assert len(free) == len(set(free)), "page duplicated in the free list"
    slot_refs = np.zeros(lay.num_pages, np.int64)
    for s in range(lay.slots):
        for p in alloc.slot_page_ids(s):
            slot_refs[p] += 1
    for p in range(lay.num_pages):
        ref = alloc.refcount(p)
        assert ref >= 0
        assert (p in free) == (ref == 0), f"page {p} free/ref mismatch"
        assert ref >= slot_refs[p], f"page {p} under-refcounted"
    # conservation: every page accounted for exactly once in free + live
    assert len(free) + int((alloc._ref > 0).sum()) == lay.num_pages


def test_alloc_free_roundtrip_refcounts():
    alloc = PageAllocator(small_layout())
    assert alloc.alloc(0, 3)
    assert alloc.slot_pages(0) == 3
    assert all(alloc.refcount(p) == 1 for p in alloc.slot_page_ids(0))
    check_alloc_invariants(alloc)
    assert alloc.free_slot(0) == 3
    assert alloc.free_pages == 8
    check_alloc_invariants(alloc)


def test_adopt_shares_without_freeing():
    alloc = PageAllocator(small_layout())
    assert alloc.alloc(0, 2)
    pages = alloc.slot_page_ids(0)
    assert alloc.adopt(1, pages)
    assert [alloc.refcount(p) for p in pages] == [2, 2]
    assert alloc.table_np()[1, :2].tolist() == pages
    # freeing the donor keeps the shared pages alive
    assert alloc.free_slot(0) == 0
    assert [alloc.refcount(p) for p in pages] == [1, 1]
    check_alloc_invariants(alloc)
    # last reference frees
    assert alloc.free_slot(1) == 2
    assert alloc.free_pages == 8
    check_alloc_invariants(alloc)


def test_decref_double_free_raises():
    alloc = PageAllocator(small_layout())
    assert alloc.alloc(0, 1)
    page = alloc.page_at(0, 0)
    alloc.free_slot(0)
    with pytest.raises(ValueError, match="double free"):
        alloc.decref(page)
    with pytest.raises(ValueError, match="free page"):
        alloc.incref(page)


def test_cow_splits_only_shared_pages():
    alloc = PageAllocator(small_layout())
    assert alloc.alloc(0, 2)
    pages = alloc.slot_page_ids(0)
    assert alloc.adopt(1, pages)
    # exclusively-owned after the split: no further split
    old, new = alloc.cow(1, 1)
    assert old == pages[1] and new not in pages
    assert alloc.refcount(old) == 1 and alloc.refcount(new) == 1
    assert alloc.page_at(1, 1) == new
    assert alloc.table_np()[1, 1] == new
    assert alloc.cow(1, 1) is None
    # donor untouched
    assert alloc.slot_page_ids(0) == pages
    check_alloc_invariants(alloc)


def test_random_op_soak_never_leaks_or_double_frees():
    """Property soak: arbitrary interleavings of alloc/adopt/free/COW and
    external (index-style) pins preserve the allocator invariants."""
    rng = np.random.default_rng(0)
    lay = small_layout(num_pages=12, slots=4, pages_per_slot=5)
    alloc = PageAllocator(lay)
    pins: list[int] = []   # external refs (the prefix index's holds)
    for _ in range(600):
        op = rng.integers(0, 5)
        slot = int(rng.integers(0, lay.slots))
        if op == 0:
            alloc.alloc(slot, int(rng.integers(1, 3)))
        elif op == 1:
            donor = int(rng.integers(0, lay.slots))
            owned = alloc.slot_page_ids(donor)
            if owned:
                k = int(rng.integers(1, len(owned) + 1))
                alloc.adopt(slot, owned[:k])
        elif op == 2:
            alloc.free_slot(slot)
        elif op == 3:
            owned = alloc.slot_page_ids(slot)
            if owned and alloc.can_alloc(1):
                alloc.cow(slot, int(rng.integers(0, len(owned))))
        elif op == 4:
            if pins and rng.random() < 0.5:
                alloc.decref(pins.pop())
            else:
                live = np.flatnonzero(alloc._ref > 0)
                if len(live):
                    p = int(rng.choice(live))
                    alloc.incref(p)
                    pins.append(p)
        check_alloc_invariants(alloc)
    for p in pins:
        alloc.decref(p)
    for s in range(lay.slots):
        alloc.free_slot(s)
    assert alloc.free_pages == lay.num_pages
    check_alloc_invariants(alloc)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 3),
                          st.integers(1, 4)), max_size=60))
def test_hypothesis_refcount_invariants(ops):
    lay = small_layout(num_pages=10, slots=4, pages_per_slot=4)
    alloc = PageAllocator(lay)
    pinned: list[int] = []
    for op, slot, k in ops:
        if op == 0:
            alloc.alloc(slot, k)
        elif op == 1:
            owned = alloc.slot_page_ids((slot + 1) % lay.slots)
            alloc.adopt(slot, owned[:k])
        elif op == 2:
            alloc.free_slot(slot)
        elif op == 3:
            owned = alloc.slot_page_ids(slot)
            if owned and alloc.can_alloc(1):
                alloc.cow(slot, min(k, len(owned)) - 1)
        elif op == 4:
            owned = alloc.slot_page_ids(slot)
            if owned:
                alloc.incref(owned[0])
                pinned.append(owned[0])
        check_alloc_invariants(alloc)
    for p in pinned:
        alloc.decref(p)
    for s in range(lay.slots):
        alloc.free_slot(s)
    assert alloc.free_pages == lay.num_pages


# ---------------------------------------------------------------------------
# Shard-agnosticism (DESIGN.md §17): head-sharding partitions pool
# *payload* only — the allocator stays host-side and its decisions are a
# pure function of the op sequence, never of the mesh.
# ---------------------------------------------------------------------------


def test_allocator_state_is_host_only():
    """Nothing the mesh could partition: after real alloc/adopt/COW
    traffic the allocator's whole object graph holds no jax arrays."""
    from collections import deque

    alloc = PageAllocator(small_layout())
    assert alloc.alloc(0, 3)
    assert alloc.adopt(1, alloc.slot_page_ids(0)[:2])
    alloc.cow(1, 0)
    seen: set[int] = set()

    def scan(o, depth=0):
        if id(o) in seen or depth > 4:
            return
        seen.add(id(o))
        assert not isinstance(o, jax.Array), \
            f"device array inside PageAllocator state: {type(o)}"
        if isinstance(o, dict):
            vals = list(o.keys()) + list(o.values())
        elif isinstance(o, (list, tuple, set, frozenset, deque)):
            vals = list(o)
        elif hasattr(o, "__dict__"):
            vals = list(vars(o).values())
        else:
            return
        for v in vals:
            scan(v, depth + 1)

    scan(alloc)
    assert isinstance(alloc.table_np(), np.ndarray)


def _replay_alloc_ops(ops, lay):
    alloc = PageAllocator(lay)
    for op, slot, k in ops:
        if op == 0:
            alloc.alloc(slot, k)
        elif op == 1:
            owned = alloc.slot_page_ids((slot + 1) % lay.slots)
            alloc.adopt(slot, owned[:k])
        elif op == 2:
            alloc.free_slot(slot)
        elif op == 3:
            owned = alloc.slot_page_ids(slot)
            if owned and alloc.can_alloc(1):
                alloc.cow(slot, min(k, len(owned)) - 1)
    check_alloc_invariants(alloc)
    return (alloc.table_np().copy(), sorted(alloc._free),
            alloc._ref.copy())


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 3),
                          st.integers(1, 4)), max_size=50))
def test_hypothesis_allocator_is_shard_agnostic(ops):
    """The same op sequence replayed with and without an installed
    sharding context (mesh + ``kv_heads`` rule — what EngineCore installs
    around every dispatch) lands on identical tables, free lists, and
    refcounts: the allocator is shard-agnostic by construction."""
    from repro.distributed import ctx
    from repro.launch.mesh import make_mesh

    lay = small_layout(num_pages=10, slots=4, pages_per_slot=4)
    plain = _replay_alloc_ops(ops, lay)
    mesh = make_mesh((1, 1), ("data", "model"))
    with ctx.use_sharding(mesh, {"kv_heads": "model"}):
        under_mesh = _replay_alloc_ops(ops, lay)
    assert np.array_equal(plain[0], under_mesh[0])
    assert plain[1] == under_mesh[1]
    assert np.array_equal(plain[2], under_mesh[2])


# ---------------------------------------------------------------------------
# PrefixIndex
# ---------------------------------------------------------------------------


def test_token_page_hashes_chain_over_prefix():
    g = 4
    a = np.arange(12, dtype=np.int32)
    b = a.copy()
    b[2] = 99   # differs inside page 0 -> every chain hash differs
    ha, hb = token_page_hashes(a, g), token_page_hashes(b, g)
    assert len(ha) == 3
    assert all(x != y for x, y in zip(ha, hb))
    c = a.copy()
    c[9] = 99   # differs in page 2 only -> pages 0,1 still shared
    hc = token_page_hashes(c, g)
    assert ha[:2] == hc[:2] and ha[2] != hc[2]


def test_index_register_match_and_eos_survival():
    lay = small_layout()
    alloc = PageAllocator(lay)
    idx = PrefixIndex(lay, chunk_tokens=lay.page_size)
    toks = np.arange(3 * lay.page_size, dtype=np.int32)
    alloc.alloc(0, 3)
    pages = alloc.slot_page_ids(0)
    assert idx.register(toks, pages, alloc) == 3
    assert [alloc.refcount(p) for p in pages] == [2, 2, 2]
    # EOS: the slot frees but the indexed pages survive
    alloc.free_slot(0)
    assert [alloc.refcount(p) for p in pages] == [1, 1, 1]
    assert idx.match(toks) == pages
    # longer prompt with the same prefix matches the shared pages
    longer = np.concatenate([toks, np.asarray([7, 8, 9, 10], np.int32)])
    assert idx.match(longer) == pages
    # divergence inside page 1 stops the walk after page 0
    forked = toks.copy()
    forked[lay.page_size + 1] = 501
    assert idx.match(forked) == pages[:1]
    idx.drop_all(alloc)
    assert alloc.free_pages == lay.num_pages


def test_index_evicts_leaf_first_lru():
    lay = small_layout(num_pages=6)
    alloc = PageAllocator(lay)
    idx = PrefixIndex(lay, chunk_tokens=lay.page_size)
    toks = np.arange(3 * lay.page_size, dtype=np.int32)
    alloc.alloc(0, 3)
    pages = alloc.slot_page_ids(0)
    idx.register(toks, pages, alloc)
    alloc.free_slot(0)
    # eviction must pop the deepest page first: page 0/1 still have live
    # children in the trie
    assert idx.evict(alloc, 1) == 1
    assert idx.match(toks) == pages[:2]
    assert alloc.refcount(pages[2]) == 0
    # keep-set protects pages about to be adopted: page 1 is now the only
    # leaf, so nothing is evictable while it is kept (page 0 still has a
    # live child in the trie — never strand reachable descendants)
    assert idx.evict(alloc, 2, keep={pages[1]}) == 0
    assert len(idx) == 2
    # pages pinned elsewhere (refcount > 1) are not evictable either
    alloc.adopt(1, pages[1:2])
    assert idx.evict(alloc, 1) == 0
    alloc.free_slot(1)
    # unprotected again: the chain drains deepest-first
    assert idx.evict(alloc, 2) == 2
    assert len(idx) == 0
    assert alloc.free_pages == lay.num_pages


# ---------------------------------------------------------------------------
# COW split preserves bit-identical decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["polar", "int"])
def test_cow_split_bit_identical_decode(method):
    """Construct genuine partial-tail sharing (slot 1's table aliases the
    donor's pages while its own length ends mid-page), append through a
    COW split, and check (a) the donor's view never changes and (b) the
    sharer's decode stays bit-identical to an unshared replica."""
    g, h, d = 4, 2, 8
    cfg = QuantConfig(method=method, group_size=g, rho_bits=4, theta_bits=4,
                      key_bits=4, value_bits=4)
    lay = small_layout(num_pages=8, slots=3, pages_per_slot=2, page_size=g)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.standard_normal((1, h, 2 * g, d)), jnp.float32)
    vals = jnp.asarray(rng.standard_normal((1, h, 2 * g, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((1, 2, d)), jnp.float32)
    k_new = jnp.asarray(rng.standard_normal((3, h, 1, d)), jnp.float32)
    v_new = jnp.asarray(rng.standard_normal((3, h, 1, d)), jnp.float32)
    r = g // 2                      # sharer's tail ends mid-page
    tl = g + r

    def build(shared: bool):
        alloc = PageAllocator(lay)
        cache = pgc.init_paged_cache(cfg, lay, h, d, dtype=jnp.float32)
        # donor prefills 2 full pages in slot 0
        alloc.alloc(0, 2)
        cache = pgc.paged_prefill(cache, 0, jnp.asarray(alloc.table_np()[0]),
                                  toks, vals, 2 * g)
        # sharer holds [0, g + r): prefill its own replica first so the
        # residual + lengths are right ...
        alloc.alloc(1, 2)
        cache = pgc.paged_prefill(cache, 1, jnp.asarray(alloc.table_np()[1]),
                                  toks, vals, tl)
        if shared:
            # ... then alias its table onto the donor's pages (the value
            # rows it needs are bit-identical by streaming parity)
            alloc.free_slot(1)
            alloc.adopt(1, alloc.slot_page_ids(0))
        return alloc, cache

    def decode(cache, alloc, slot):
        view = pgc.gather_view(cache, jnp.asarray(alloc.table_np()))
        return np.asarray(decode_attention(view, jnp.tile(q, (3, 1, 1))))[slot]

    alloc_s, cache_s = build(shared=True)
    alloc_u, cache_u = build(shared=False)
    assert np.array_equal(decode(cache_s, alloc_s, 1),
                          decode(cache_u, alloc_u, 1))

    donor_before = np.asarray(pgc.gather_view(
        cache_s, jnp.asarray(alloc_s.table_np()[:1])).value_codes
        if cfg.value_bits else pgc.gather_view(
            cache_s, jnp.asarray(alloc_s.table_np()[:1])).value_fp)

    def append(alloc, cache):
        # COW guard before writing into the tail page (pos // g == 1)
        split = alloc.cow(1, tl // g)
        if split is not None:
            cache = pgc.copy_pool_pages(
                cache, jnp.asarray(split[0]), jnp.asarray(split[1]))
        active = np.zeros((3,), bool)
        active[1] = True
        return pgc.paged_append(cache, k_new, v_new,
                                jnp.asarray(alloc.table_np()),
                                jnp.asarray(active))

    cache_s2 = append(alloc_s, cache_s)
    cache_u2 = append(alloc_u, cache_u)
    # the shared tail page must have been split...
    assert alloc_s.page_at(1, 1) != alloc_s.page_at(0, 1)
    # ...the donor's bytes are untouched...
    donor_after = np.asarray(pgc.gather_view(
        cache_s2, jnp.asarray(alloc_s.table_np()[:1])).value_codes
        if cfg.value_bits else pgc.gather_view(
            cache_s2, jnp.asarray(alloc_s.table_np()[:1])).value_fp)
    assert np.array_equal(donor_before, donor_after)
    # ...and the sharer's decode stays bit-identical to the unshared run
    assert np.array_equal(decode(cache_s2, alloc_s, 1),
                          decode(cache_u2, alloc_u, 1))


# ---------------------------------------------------------------------------
# Scheduler adoption policy
# ---------------------------------------------------------------------------


def test_scheduler_adopts_chunk_aligned_and_recomputes_final_chunk():
    lay = small_layout(num_pages=16, slots=2, pages_per_slot=8, page_size=4)
    c = 8   # chunk = 2 pages
    sched = Scheduler(lay, prefix_index=PrefixIndex(lay, c), chunk_tokens=c)
    donor = Request(rid=0, prompt=np.arange(2 * c, dtype=np.int32))
    sched.submit(donor)
    assert sched.admissible() is donor
    slot = sched.admit(donor)
    assert donor.prefix_hit_tokens == 0
    sched.register_prefix(slot)       # both full chunks indexed
    assert len(sched.prefix) == 4     # 4 pages
    done = sched.finish(slot)
    assert done.rid == 0

    # identical prompt: adopt only the FIRST chunk — the chunk holding the
    # last prompt token is always recomputed for live logits
    clone = Request(rid=1, prompt=np.arange(2 * c, dtype=np.int32))
    sched.submit(clone)
    assert sched.admissible() is clone
    slot = sched.admit(clone)
    assert clone.prefix_hit_tokens == c
    adopted = sched.alloc.slot_page_ids(slot)[:2]
    assert [sched.alloc.refcount(p) for p in adopted] == [2, 2]
    sched.finish(slot)

    # longer prompt: both chunks adopted (its last token lives beyond)
    longer = Request(rid=2, prompt=np.arange(2 * c + 3, dtype=np.int32))
    sched.submit(longer)
    assert sched.admissible() is longer
    sched.admit(longer)
    assert longer.prefix_hit_tokens == 2 * c


# ---------------------------------------------------------------------------
# Engine: shared-prefix reuse end to end
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _shared_prefix_requests(cfg, n, prefix_pages=3, seed=0):
    g = cfg.quant.group_size
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, (prefix_pages * g,)).astype(
        np.int32)
    reqs = []
    for i in range(n):
        suffix = rng.integers(0, cfg.vocab_size,
                              (int(rng.integers(5, 20)),)).astype(np.int32)
        # the donor runs alone first (arrival gap >> device time), so its
        # registered pages are matchable by every later admission
        reqs.append(Request(rid=i, prompt=np.concatenate([shared, suffix]),
                            max_new_tokens=5,
                            arrival_time=0.0 if i == 0 else 1e4 + i * 0.01))
    return reqs


def test_prefix_reuse_bit_identical_and_skips_prefill(smoke_model):
    cfg, m, params = smoke_model
    g = cfg.quant.group_size
    outs = {}
    for reuse in (False, True):
        eng = ContinuousBatchingEngine(m, params, max_slots=3,
                                       max_len=8 * g, prefix_cache=reuse,
                                       prefill_chunk=g)
        res = eng.run(_shared_prefix_requests(cfg, 4), GenerationConfig())
        assert len(res["requests"]) == 4
        outs[reuse] = res
    base, reuse = outs[False], outs[True]
    tok = lambda r: {q.rid: q.out_tokens for q in r["requests"]}
    # greedy outputs bit-identical: adopted pages hold the same encoded
    # bytes the baseline recomputes, and adoption is chunk-aligned
    assert tok(base) == tok(reuse)
    # the reuse arm actually skipped prompt prefill work
    assert base["prefill_tokens_skipped"] == 0
    assert reuse["prefill_tokens_skipped"] > 0
    assert reuse["adopted_pages"] > 0
    assert reuse["prefix_hit_rate"] > 0
    assert reuse["prefill_tokens_computed"] < base["prefill_tokens_computed"]
    assert reuse["prefix_pool_bytes_saved"] > 0
    assert reuse["cow_splits"] == 0   # chunk-aligned adoption never appends
    #                                   into a shared page


def test_chunked_prefill_without_sharing_completes(smoke_model):
    """Chunked prefill alone (no prefix cache): all requests complete with
    their full budgets and decode interleaves with prefill."""
    cfg, m, params = smoke_model
    g = cfg.quant.group_size
    eng = ContinuousBatchingEngine(m, params, max_slots=3, max_len=6 * g,
                                   prefill_chunk=g, prefill_budget=g)
    rng = np.random.default_rng(2)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (int(rng.integers(8, 4 * g)),))
                    .astype(np.int32),
                    max_new_tokens=6, arrival_time=i * 0.005)
            for i in range(6)]
    eng.warmup([r.prompt_len for r in reqs])
    out = eng.run(reqs, GenerationConfig())
    assert len(out["requests"]) == 6
    assert all(r.done_tokens == r.max_new_tokens for r in out["requests"])
    assert out["prefill_chunk"] == g
    assert out["prefill_tokens_computed"] >= sum(r.prompt_len for r in reqs)


def test_chunked_engine_greedy_deterministic(smoke_model):
    """The chunked path is deterministic: same workload, same outputs."""
    cfg, m, params = smoke_model
    g = cfg.quant.group_size
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, (2 * g + 5,)).astype(np.int32)
    runs = []
    for _ in range(2):
        eng = ContinuousBatchingEngine(m, params, max_slots=2,
                                       max_len=6 * g, prefill_chunk=g)
        out = eng.run([Request(rid=0, prompt=prompt.copy(),
                               max_new_tokens=8)],
                      GenerationConfig(max_new_tokens=8))
        runs.append(out["requests"][0].out_tokens)
    assert runs[0] == runs[1]


def test_chunk_window_overrunning_row_is_scratch_padded(smoke_model):
    """Regression: with pages_per_slot not a multiple of the chunk pages
    (5 pages, 2-page chunks) the final chunk's static page window overruns
    the table row; dynamic_slice would *clamp* and silently overwrite the
    previous context page. Outputs must not depend on pool capacity."""
    cfg, m, params = smoke_model
    g = cfg.quant.group_size
    rng = np.random.default_rng(11)
    # prompt reaches into the last page: final chunk starts at page 4 of 5
    prompt = rng.integers(0, cfg.vocab_size, (4 * g + 7,)).astype(np.int32)
    outs = []
    for pages_per_slot in (5, 8):
        eng = ContinuousBatchingEngine(m, params, max_slots=2,
                                       max_len=pages_per_slot * g,
                                       prefill_chunk=2 * g)
        out = eng.run([Request(rid=0, prompt=prompt.copy(),
                               max_new_tokens=4)],
                      GenerationConfig(max_new_tokens=4))
        outs.append(out["requests"][0].out_tokens)
    assert outs[0] == outs[1]


def test_prefix_reuse_survives_eviction_pressure(smoke_model):
    """An undersized pool forces index eviction; the engine must still
    complete every request (sharing degrades, never deadlocks)."""
    cfg, m, params = smoke_model
    g = cfg.quant.group_size
    eng = ContinuousBatchingEngine(m, params, max_slots=2, max_len=6 * g,
                                   num_pages=10, prefix_cache=True,
                                   prefill_chunk=g)
    reqs = _shared_prefix_requests(cfg, 5, prefix_pages=2, seed=3)
    out = eng.run(reqs, GenerationConfig())
    assert len(out["requests"]) == 5
    assert all(r.done_tokens == r.max_new_tokens for r in out["requests"])
