"""Mesh-sharded paged serving parity (DESIGN.md §17).

Head-sharded tensor parallelism must be *invisible*: every codec's paged
decode/prefill/append over head-partitioned pools must match the
single-device path bit-identically (per-KV-head attention has no
cross-head math, so partitioning the head axis changes nothing but
placement), GQA head counts that don't divide the mesh axis must fall
back to the replicated path, and the end-to-end engine must produce the
same greedy tokens whether it runs meshless, on a 1-device mesh, or
head-sharded across forced-host devices.

The context-parallel (page-column-sharded) decode reference is held to a
documented fp tolerance instead: its psum merge rescales the per-shard
online-softmax carries, so the reduction order differs from the
single-device softmax (the allgather merge reconstructs the full score
row and is compared at the same tolerance for uniformity; degenerate
shards — padding columns, empty slots — must not poison it).

Two test legs share the ``check_*`` bodies below:

* in-process tests marked ``distributed`` — skipped unless the process
  already sees >= 4 devices (CI's multi-device job sets
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4``);
* tier-1 subprocess tests that force 4 host devices themselves, so the
  parity suite always runs even on a single-device box (same pattern as
  tests/test_collectives.py).
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_for_smoke
from repro.core import paged_cache as pgc
from repro.core.cache_layout import PagedLayout
from repro.core.quantizers import QuantConfig
from repro.distributed import ctx
from repro.distributed import serving as dsrv
from repro.launch.mesh import make_mesh
from repro.models import get_model
from repro.serve import ContinuousBatchingEngine, Request

ROOT = Path(__file__).resolve().parent.parent

multi_device = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")

# the registry sweep: every codec, page == group of 8; one extra polar
# arm exercises quantized values through the sharded paths too
CODEC_CONFIGS = [
    QuantConfig(method="none", group_size=8),
    QuantConfig(method="int", group_size=8),
    QuantConfig(method="kivi", group_size=8),
    QuantConfig(method="zipcache", group_size=8),
    QuantConfig(method="polar", group_size=8),
    QuantConfig(method="polar", group_size=8, value_bits=4),
]


def _tag(cfg: QuantConfig) -> str:
    return f"{cfg.method}+v{cfg.value_bits}"


def build_fragmented_cache(cfg, *, hkv=4, d=16, lens=(37, 0, 21), seed=0):
    """A paged cache populated through real appends over a *permuted*
    (non-monotonic, fragmented) page table: slot 0 ends mid-group (open
    residual), slot 1 is empty, slot 2 is short. Returns (cache, table)."""
    lay = PagedLayout(page_size=8, num_pages=24, slots=len(lens),
                      pages_per_slot=6)
    rng = np.random.default_rng(seed)
    cache = pgc.init_paged_cache(cfg, lay, hkv, d, dtype=jnp.float32)
    table = np.full((lay.slots, lay.pages_per_slot), -1, np.int32)
    perm, off = rng.permutation(lay.num_pages), 0
    for s, ln in enumerate(lens):
        k = -(-ln // lay.page_size)
        table[s, :k] = perm[off:off + k]
        off += k
    table = jnp.asarray(table)
    for t in range(max(lens)):
        active = jnp.asarray([t < ln for ln in lens])
        k_new = jnp.asarray(rng.standard_normal(
            (lay.slots, hkv, 1, d)), jnp.float32)
        v_new = jnp.asarray(rng.standard_normal(
            (lay.slots, hkv, 1, d)), jnp.float32)
        cache = pgc.paged_append(cache, k_new, v_new, table, active)
    return cache, table


def _decode_q(hq=8, d=16, slots=3, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((slots, hq, d)), jnp.float32)


# ---------------------------------------------------------------------------
# check_* bodies (shared by the marked in-process tests and the tier-1
# subprocess leg — each asserts its own device requirement)
# ---------------------------------------------------------------------------


def check_kernel_parity():
    """Registry-wide head-sharded decode == single-device, bitwise, on
    fragmented tables, across head-divisible mesh shapes."""
    assert jax.device_count() >= 4
    for cfg in CODEC_CONFIGS:
        cache, table = build_fragmented_cache(cfg)
        q = _decode_q()
        ref = np.asarray(pgc.paged_decode_attention(cache, q, table,
                                                    backend="jnp"))
        for shape in ((1, 2), (2, 2), (1, 4)):
            mesh = make_mesh(shape, ("data", "model"))
            out = np.asarray(dsrv.sharded_paged_decode_attention(
                cache, q, table, mesh=mesh))
            assert np.array_equal(ref, out), \
                f"{_tag(cfg)} decode diverged on mesh {shape}"


def check_prefill_parity():
    """Head-sharded chunk-prefill attention == single-device, bitwise
    (flushed prefix through the codec score path + fp causal chunk)."""
    assert jax.device_count() >= 2
    mesh = make_mesh((1, 2), ("data", "model"))
    rng = np.random.default_rng(7)
    tc, d, hq, hkv = 16, 16, 8, 4
    for cfg in CODEC_CONFIGS:
        cache, table = build_fragmented_cache(cfg)
        row = table[0]                       # slot 0: 32 flushed + open grp
        q = jnp.asarray(rng.standard_normal((1, hq, tc, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, hkv, tc, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, hkv, tc, d)), jnp.float32)
        start, clen = 32, 13                 # page-aligned, partial chunk
        ref = np.asarray(pgc.paged_prefill_attention(
            cache, q, k, v, row, start, clen, backend="jnp"))
        out = np.asarray(dsrv.sharded_paged_prefill_attention(
            cache, q, k, v, row, start, clen, mesh=mesh))
        assert np.array_equal(ref, out), f"{_tag(cfg)} prefill diverged"


def check_sharded_append_parity():
    """paged_append on a head-partitioned state (GSPMD auto-partitioned
    scatters) leaves every pool leaf bit-identical to the replicated run,
    and keeps the head shardings in place."""
    assert jax.device_count() >= 2
    mesh = make_mesh((1, 2), ("data", "model"))
    rng = np.random.default_rng(11)
    for cfg in (CODEC_CONFIGS[4], CODEC_CONFIGS[5]):   # polar fp/quant vals
        cache, table = build_fragmented_cache(cfg)
        sharded = dsrv.shard_paged_state(cache, mesh)
        for t in range(9):                 # crosses a group-flush boundary
            active = jnp.asarray([True, t % 2 == 0, True])
            k_new = jnp.asarray(rng.standard_normal((3, 4, 1, 16)),
                                jnp.float32)
            v_new = jnp.asarray(rng.standard_normal((3, 4, 1, 16)),
                                jnp.float32)
            cache = pgc.paged_append(cache, k_new, v_new, table, active)
            sharded = pgc.paged_append(sharded, k_new, v_new, table, active)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)), cache, sharded)
        # the head partitioning survived the appends
        kc = sharded.key_codes
        assert "model" in tuple(kc.sharding.spec), \
            f"{_tag(cfg)} lost its head sharding"


def check_gqa_fallback():
    """KV heads not divisible by the mesh axis: placement replicates,
    dispatch takes the plain path, and the math is untouched."""
    assert jax.device_count() >= 4
    mesh = make_mesh((1, 4), ("data", "model"))
    cfg = QuantConfig(method="polar", group_size=8)
    cache, table = build_fragmented_cache(cfg, hkv=2)
    q = _decode_q(hq=4)
    assert dsrv._active_head_axis(cache, 4) == (None, None)  # no ctx
    shardings = dsrv.paged_state_shardings(cache, mesh)
    for s in jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(
                x, jax.sharding.NamedSharding)):
        assert s.spec == jax.sharding.PartitionSpec()
    ref = np.asarray(pgc.paged_decode_attention(cache, q, table,
                                                backend="jnp"))
    out = np.asarray(dsrv.sharded_paged_decode_attention(
        cache, q, table, mesh=mesh))
    assert np.array_equal(ref, out)


def check_context_parallel():
    """Page-column-sharded decode vs the single-device path: psum merge
    within fp tolerance, allgather merge likewise, both finite everywhere
    — including the empty slot and the shards whose columns are all
    padding (the degenerate-carry guard around the finite NEG_INF)."""
    assert jax.device_count() >= 4
    mesh = make_mesh((1, 4), ("data", "model"))
    for cfg in (CODEC_CONFIGS[0], CODEC_CONFIGS[4], CODEC_CONFIGS[5]):
        cache, table = build_fragmented_cache(cfg)
        q = _decode_q()
        ref = np.asarray(pgc.paged_decode_attention(cache, q, table,
                                                    backend="jnp"))
        for merge in ("psum", "allgather"):
            out = np.asarray(dsrv.context_parallel_decode(
                cache, q, table, mesh=mesh, merge=merge))
            assert np.all(np.isfinite(out)), f"{_tag(cfg)}/{merge} not finite"
            np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5,
                                       err_msg=f"{_tag(cfg)}/{merge}")
        # the empty slot's merged softmax has zero mass -> exact zeros
        assert np.array_equal(
            np.asarray(dsrv.context_parallel_decode(
                cache, q, table, mesh=mesh))[1], np.zeros_like(ref[1]))


def _engine_requests(cfg, n=5, seed=3, shared_prefix=0):
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg.vocab_size, (shared_prefix,)).astype(np.int32)
    reqs = []
    for i in range(n):
        sfx = rng.integers(0, cfg.vocab_size,
                           (int(rng.integers(8, 40)),)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=np.concatenate([pre, sfx]),
                            max_new_tokens=int(rng.integers(4, 11)),
                            arrival_time=0.0))
    return reqs


def _engine_outputs(model, params, requests, mesh=None, **kw):
    eng = ContinuousBatchingEngine(model, params, max_slots=3, max_len=128,
                                   mesh=mesh, **kw)
    res = eng.run([Request(rid=r.rid, prompt=r.prompt,
                           max_new_tokens=r.max_new_tokens,
                           arrival_time=0.0) for r in requests])
    return {r.rid: [int(t) for t in r.out_tokens] for r in res["requests"]}


def check_engine_ab():
    """End-to-end greedy A/B: meshless vs head-sharded (1x2) vs GQA
    fallback (1x4), one-shot and chunked+prefix-cache paths — identical
    tokens everywhere."""
    assert jax.device_count() >= 4
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _engine_requests(cfg)
    base = _engine_outputs(model, params, reqs)
    assert base and all(base.values())
    for shape in ((1, 2), (1, 4)):
        mesh = make_mesh(shape, ("data", "model"))
        assert _engine_outputs(model, params, reqs, mesh=mesh) == base, \
            f"engine outputs diverged on mesh {shape}"
    # chunked prefill + shared-prefix adoption under the sharded pools
    reqs_sp = _engine_requests(cfg, shared_prefix=64, seed=5)
    base_sp = _engine_outputs(model, params, reqs_sp,
                              prefill_chunk=64, prefix_cache=True)
    mesh = make_mesh((1, 2), ("data", "model"))
    assert _engine_outputs(model, params, reqs_sp, mesh=mesh,
                           prefill_chunk=64, prefix_cache=True) == base_sp


# ---------------------------------------------------------------------------
# Leg 1: in-process, marked `distributed` (CI multi-device job)
# ---------------------------------------------------------------------------


@multi_device
@pytest.mark.distributed
def test_kernel_parity_all_codecs():
    check_kernel_parity()


@multi_device
@pytest.mark.distributed
def test_prefill_parity_head_sharded():
    check_prefill_parity()


@multi_device
@pytest.mark.distributed
def test_append_parity_on_sharded_pools():
    check_sharded_append_parity()


@multi_device
@pytest.mark.distributed
def test_gqa_nondivisible_falls_back_replicated():
    check_gqa_fallback()


@multi_device
@pytest.mark.distributed
def test_context_parallel_merges_match_reference():
    check_context_parallel()


@multi_device
@pytest.mark.distributed
@pytest.mark.slow
def test_engine_mesh_ab_multidevice():
    check_engine_ab()


# ---------------------------------------------------------------------------
# Leg 2: tier-1 subprocess tests (force 4 host devices themselves)
# ---------------------------------------------------------------------------


def _run_forced(body: str, timeout=600):
    script = ("import os\n"
              'os.environ["XLA_FLAGS"] = '
              '"--xla_force_host_platform_device_count=4"\n'
              "import test_distributed_serving as t\n"
              f"{body}\n"
              'print("PARITY-OK")\n')
    env = {**os.environ,
           "PYTHONPATH": os.pathsep.join(
               [str(ROOT / "src"), str(ROOT / "tests")])}
    env.pop("XLA_FLAGS", None)   # the forced count is set inside the script
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=timeout, cwd=ROOT)
    assert r.returncode == 0 and "PARITY-OK" in r.stdout, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


@pytest.mark.slow
def test_subprocess_kernel_parity_4dev():
    _run_forced("t.check_kernel_parity()\n"
                "t.check_prefill_parity()\n"
                "t.check_sharded_append_parity()")


@pytest.mark.slow
def test_subprocess_fallback_and_context_parallel_4dev():
    _run_forced("t.check_gqa_fallback()\n"
                "t.check_context_parallel()")


@pytest.mark.slow
def test_subprocess_engine_mesh_ab_4dev():
    _run_forced("t.check_engine_ab()")


# ---------------------------------------------------------------------------
# Tier-1 single-device regressions (no forced devices needed)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def test_engine_one_device_mesh_replays_meshless(smoke_model):
    """A mesh-constructed engine on a 1-device mesh takes the full
    shard_map dispatch path (serving_rules maps kv_heads -> "model") and
    must replay the meshless engine bit-identically — the regression that
    keeps EngineCore's mesh=/rules= params load-bearing."""
    cfg, model, params = smoke_model
    reqs = _engine_requests(cfg)
    base = _engine_outputs(model, params, reqs)
    mesh = make_mesh((1, 1), ("data", "model"))
    assert _engine_outputs(model, params, reqs, mesh=mesh) == base


def test_dispatch_honors_sharding_context(smoke_model):
    """The context-aware dispatchers: plain path with no context, the
    sharded path (bitwise-equal here) once a mesh + kv_heads rule is
    installed, and the plain path again when the rule is absent."""
    cfg = QuantConfig(method="polar", group_size=8)
    cache, table = build_fragmented_cache(cfg)
    q = _decode_q()
    ref = np.asarray(pgc.paged_decode_attention(cache, q, table,
                                                backend="jnp"))
    out = np.asarray(dsrv.dispatch_paged_decode_attention(cache, q, table))
    assert np.array_equal(ref, out)
    mesh = make_mesh((1, 1), ("data", "model"))
    with ctx.use_sharding(mesh, {"kv_heads": "model"}):
        assert dsrv._active_head_axis(cache, q.shape[1]) == (mesh, "model")
        out = np.asarray(dsrv.dispatch_paged_decode_attention(
            cache, q, table))
    assert np.array_equal(ref, out)
    with ctx.use_sharding(mesh, {"kv_heads": None}):
        assert dsrv._active_head_axis(cache, q.shape[1]) == (None, None)


def test_serving_rules_keep_seq_unsharded(smoke_model):
    """serving_rules: heads over "model" where divisible, and never the
    training-side "seq": "model" rule (it would fight pool placement)."""
    from repro.distributed.sharding import serving_rules
    cfg, _, _ = smoke_model
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = serving_rules(cfg, mesh, 3)
    assert rules["kv_heads"] == "model"
    assert rules["seq"] is None
