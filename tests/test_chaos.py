"""Deterministic fault injection (DESIGN.md §16): the engine must keep
its invariants — allocator conservation, dense event ordinals,
bit-identical greedy survivors — under every injected failure mode, and
the injector must be **provably inert** when disabled.

The inertness A/B (chaos=None vs an injector with an empty schedule) is
the acceptance bar for the whole seam: the chaos hook may not perturb a
healthy engine by even one token.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_config, reduce_for_smoke
from repro.core.cache_layout import PageAllocator, PagedLayout
from repro.models import get_model
from repro.serve import (
    ChaosConfig, ChaosError, ChaosInjector, ContinuousBatchingEngine,
    GenerationConfig, Request, check_event_stream,
)
from test_prefix_cache import check_alloc_invariants


@pytest.fixture(scope="module")
def smoke_model():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _workload(cfg, n, seed=0, plen=(8, 40), max_new=6, gap=0.002):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, (int(
                        rng.integers(*plen)),)).astype(np.int32),
                    max_new_tokens=max_new, arrival_time=i * gap)
            for i in range(n)]


def _toks(res):
    return {r.rid: list(r.out_tokens) for r in res["requests"]}


# --- config + injector units ----------------------------------------------


def test_chaos_config_parse_spec_string():
    cfg = ChaosConfig.parse("exhaust@8,slow@5:0.05,cancel@12:0.5,"
                            "proposer@0.3", seed=7)
    assert cfg.exhaust_at == (8,) and cfg.slow_at == (5,)
    assert cfg.cancel_at == (12,) and cfg.cancel_frac == 0.5
    assert cfg.slow_s == 0.05 and cfg.proposer_fail_rate == 0.3
    assert cfg.seed == 7
    assert ChaosConfig.parse("exhaust@4:9").exhaust_steps == 9
    with pytest.raises(ValueError):
        ChaosConfig.parse("meteor@3")
    with pytest.raises(ValueError):
        ChaosConfig(cancel_frac=1.5)


def test_injector_is_deterministic_across_resets():
    cfg = ChaosConfig(seed=11, cancel_at=(3,), cancel_frac=0.5,
                      proposer_fail_rate=0.5)
    inj = ChaosInjector(cfg)
    v1 = inj.pick_victims(list(range(10)), 0.5)
    fails1 = []
    for _ in range(20):
        try:
            inj.maybe_fail_proposer()
            fails1.append(False)
        except ChaosError:
            fails1.append(True)
    inj.reset()
    assert inj.pick_victims(list(range(10)), 0.5) == v1
    fails2 = []
    for _ in range(20):
        try:
            inj.maybe_fail_proposer()
            fails2.append(False)
        except ChaosError:
            fails2.append(True)
    assert fails1 == fails2
    assert inj.pick_victims([], 0.9) == []         # no victims, no crash
    assert len(ChaosInjector(cfg).pick_victims([7], 0.01)) == 1  # >= 1


def test_allocator_quarantine_preserves_invariants():
    lay = PagedLayout(page_size=4, num_pages=8, slots=2, pages_per_slot=4)
    alloc = PageAllocator(lay)
    assert alloc.alloc(0, 2)
    taken = alloc.quarantine(alloc.free_pages)
    assert taken == 6 and alloc.free_pages == 0
    assert alloc.quarantined_pages == 6
    check_alloc_invariants(alloc)       # quarantine = a legal external pin
    assert alloc.quarantine(3) == 0     # nothing left to take
    assert alloc.release_quarantine() == 6
    assert alloc.free_pages == 6 and alloc.quarantined_pages == 0
    check_alloc_invariants(alloc)


# --- engine-level failure modes -------------------------------------------


def test_chaos_disabled_and_empty_schedule_are_bit_identical(smoke_model):
    """chaos=None and an injector that never fires must both match the
    plain engine token-for-token and metric-for-metric."""
    cfg, m, params = smoke_model
    plain = ContinuousBatchingEngine(m, params, max_slots=2, max_len=64,
                                     num_pages=8)
    r0 = plain.run(_workload(cfg, 6), GenerationConfig())
    empty = ContinuousBatchingEngine(
        m, params, max_slots=2, max_len=64, num_pages=8,
        chaos=ChaosInjector(ChaosConfig(proposer_fail_rate=0.0)))
    r1 = empty.run(_workload(cfg, 6), GenerationConfig())
    assert _toks(r0) == _toks(r1)
    assert r0["decode_steps"] == r1["decode_steps"]
    assert r0["total_tokens"] == r1["total_tokens"]
    assert r1["chaos"] == {"exhausts": 0, "slow_steps": 0,
                           "cancel_storms": 0, "storm_cancels": 0,
                           "proposer_faults": 0, "proposer_calls": 0}


def test_forced_exhaustion_recovers_with_invariants(smoke_model):
    """Quarantining every free page mid-run forces the stall/preempt
    path; once the quarantine lifts, every request still completes and
    the allocator balances to the page."""
    cfg, m, params = smoke_model
    eng = ContinuousBatchingEngine(
        m, params, max_slots=2, max_len=64, num_pages=6,
        chaos=ChaosInjector(ChaosConfig(exhaust_at=(4,), exhaust_steps=3,
                                        seed=1)))
    res = eng.run(_workload(cfg, 5, seed=3), GenerationConfig())
    assert res["chaos"]["exhausts"] == 1
    assert len(res["requests"]) == 5          # everyone survived
    check_event_stream(res["events"])
    check_alloc_invariants(eng.core.sched.alloc)
    assert eng.core.sched.alloc.quarantined_pages == 0
    assert eng.core.sched.alloc.free_pages == eng.core.layout.num_pages


def test_exhaustion_with_empty_slots_spins_not_dies(smoke_model):
    """Regression: when a quarantine leaves the engine with pending work,
    no active slots, and no future arrivals, it must spin until the
    scheduled release — not raise the 'num_pages too small' error meant
    for genuinely undersized pools (found driving the launcher with
    --chaos exhaust@N on a drained queue)."""
    cfg, m, params = smoke_model
    rng = np.random.default_rng(21)
    eng = ContinuousBatchingEngine(
        m, params, max_slots=1, max_len=64, num_pages=3,
        chaos=ChaosInjector(ChaosConfig(exhaust_at=(2,), exhaust_steps=6,
                                        seed=3)))
    # both arrive at t=0; the 1-slot engine holds req 1 pending while the
    # quarantine (cycle 2) grabs the pages req 1 will need after req 0's
    # early finish
    reqs = [Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, (20,))
                    .astype(np.int32), max_new_tokens=2),
            Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, (60,))
                    .astype(np.int32), max_new_tokens=3)]
    res = eng.run(reqs, GenerationConfig())
    assert res["chaos"]["exhausts"] == 1
    assert sorted(r.rid for r in res["requests"]) == [0, 1]
    check_event_stream(res["events"])
    check_alloc_invariants(eng.core.sched.alloc)
    assert eng.core.sched.alloc.quarantined_pages == 0
    assert eng.core.sched.alloc.free_pages == eng.core.layout.num_pages


def test_cancel_storm_survivors_bit_identical(smoke_model):
    """A storm cancels half the live requests mid-run; on a
    preemption-free pool the survivors' greedy outputs must equal the
    clean run's token-for-token (cancellation frees pages, it never
    perturbs another slot's cache)."""
    cfg, m, params = smoke_model
    clean = ContinuousBatchingEngine(m, params, max_slots=2, max_len=64)
    r0 = clean.run(_workload(cfg, 6, seed=5), GenerationConfig())
    stormy = ContinuousBatchingEngine(
        m, params, max_slots=2, max_len=64,
        chaos=ChaosInjector(ChaosConfig(cancel_at=(6,), cancel_frac=0.5,
                                        seed=2)))
    r1 = stormy.run(_workload(cfg, 6, seed=5), GenerationConfig())
    assert r1["chaos"]["cancel_storms"] == 1 and r1["n_cancelled"] > 0
    survivors = _toks(r1)
    baseline = _toks(r0)
    assert survivors                        # the storm spared someone
    for rid, toks in survivors.items():
        assert toks == baseline[rid], f"survivor rid {rid} diverged"
    terminal = check_event_stream(r1["events"])
    cancelled = {r.rid for r in r1["cancelled_requests"]}
    assert {rid for rid, k in terminal.items() if k == "cancel"} == \
        cancelled
    assert len(survivors) + len(cancelled) == 6
    check_alloc_invariants(stormy.core.sched.alloc)
    assert stormy.core.sched.alloc.free_pages == \
        stormy.core.layout.num_pages


def test_proposer_faults_degrade_to_plain_decode(smoke_model):
    """Every proposer call raising must cost speculation, never
    correctness: outputs stay bit-identical to the spec-off baseline and
    the faults are counted."""
    from repro.spec import SpecConfig
    cfg, m, params = smoke_model
    plain = ContinuousBatchingEngine(m, params, max_slots=2, max_len=64)
    r0 = plain.run(_workload(cfg, 4, seed=7, max_new=8),
                   GenerationConfig())
    faulty = ContinuousBatchingEngine(
        m, params, max_slots=2, max_len=64,
        spec=SpecConfig(mode="ngram", k=4),
        chaos=ChaosInjector(ChaosConfig(proposer_fail_rate=1.0, seed=4)))
    r1 = faulty.run(_workload(cfg, 4, seed=7, max_new=8),
                    GenerationConfig())
    assert _toks(r0) == _toks(r1)
    assert r1["proposer_faults"] > 0
    assert r1["spec"]["drafted_tokens"] == 0   # nothing ever verified
    check_event_stream(r1["events"])


def test_slow_steps_only_stretch_the_clock(smoke_model):
    cfg, m, params = smoke_model
    mk = lambda chaos: ContinuousBatchingEngine(
        m, params, max_slots=2, max_len=64, chaos=chaos)
    r0 = mk(None).run(_workload(cfg, 4, seed=9), GenerationConfig())
    slow = ChaosInjector(ChaosConfig(slow_at=(2, 3, 4), slow_s=0.5))
    r1 = mk(slow).run(_workload(cfg, 4, seed=9), GenerationConfig())
    assert r1["chaos"]["slow_steps"] == 3
    assert _toks(r0) == _toks(r1)              # tokens untouched
    assert r1["wall_s"] >= r0["wall_s"] + 1.4  # ~3 x 0.5s injected
    check_event_stream(r1["events"])


def test_streaming_cancel_storm_under_prefix_cache(smoke_model):
    """Storms + prefix sharing: cancelled slots decref adopted pages
    under the index's pins; the allocator must balance and the index
    survive for later adoptions."""
    cfg, m, params = smoke_model
    g = cfg.quant.group_size
    rng = np.random.default_rng(13)
    shared = rng.integers(0, cfg.vocab_size, (g,)).astype(np.int32)
    reqs = [Request(rid=i,
                    prompt=np.concatenate([shared, rng.integers(
                        0, cfg.vocab_size, (6,)).astype(np.int32)]),
                    max_new_tokens=5,
                    arrival_time=0.0 if i == 0 else 0.05 + i * 0.002)
            for i in range(6)]
    eng = ContinuousBatchingEngine(
        m, params, max_slots=2, max_len=64, prefix_cache=True,
        prefill_chunk=g,
        chaos=ChaosInjector(ChaosConfig(cancel_at=(5, 9),
                                        cancel_frac=0.5, seed=6)))
    res = eng.run(reqs, GenerationConfig())
    assert res["chaos"]["cancel_storms"] == 2
    check_event_stream(res["events"])
    check_alloc_invariants(eng.core.sched.alloc)
    assert eng.core.sched.alloc.quarantined_pages == 0
    # completed + cancelled account for every request exactly once
    assert len(res["requests"]) + res["n_cancelled"] == 6
