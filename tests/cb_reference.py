"""Frozen pre-refactor ``ContinuousBatchingEngine.run()`` — the golden
parity oracle for the EngineCore decomposition (DESIGN.md §13).

This is a verbatim snapshot of the monolithic ``run()`` loop as it stood
before the step-loop refactor (one closed ``while`` owning admission,
chunked prefill, decode, sampling, preemption, and the clock). It drives
the *live* Scheduler/PageAllocator/PrefixIndex — those were not part of
the refactor — so any behavioral drift the decomposition introduces in
greedy tokens, page-adoption decisions, or scheduling metrics shows up as
a diff against this oracle on the same workload, on any platform (both
engines run in the same process against the same weights).

Do not "improve" this file: its value is that it does not change.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache_layout import PagedLayout, PrefixIndex
from repro.distributed import ctx
from repro.models.registry import Model
from repro.serve.core import GenerationConfig, _sample
from repro.serve.scheduler import Request, Scheduler
from repro.utils import cdiv, pow2_bucket, tree_bytes as _tree_bytes


class ReferenceCBEngine:
    """Pre-refactor continuous-batching engine (closed-loop ``run()``)."""

    def __init__(self, model: Model, params, *, max_slots: int = 4,
                 max_len: int = 256, num_pages: Optional[int] = None,
                 mesh=None, rules: Optional[dict] = None,
                 table_slicing: bool = True, prefix_cache: bool = False,
                 prefill_chunk: int = 0, prefill_budget: int = 0):
        if model.decode_paged is None:
            raise ValueError(
                f"family {model.cfg.family!r} has no paged decode path")
        self.model = model
        self.params = params
        self.mesh = mesh
        self.rules = rules
        self.table_slicing = table_slicing
        g = model.cfg.policy.page_group_size()
        pages_per_slot = cdiv(max_len, g)
        if num_pages is None:
            num_pages = max_slots * pages_per_slot
        self.layout = PagedLayout(page_size=g, num_pages=num_pages,
                                  slots=max_slots,
                                  pages_per_slot=pages_per_slot)
        self.prefix_cache = bool(prefix_cache)
        chunk = int(prefill_chunk)
        if self.prefix_cache and chunk == 0:
            chunk = 2 * g
        if chunk:
            chunk = cdiv(chunk, g) * g
        self.prefill_chunk = chunk
        self.prefill_budget = int(prefill_budget) if prefill_budget else chunk
        self._prefill = jax.jit(model.prefill_paged)
        if chunk:
            self._prefill_chunk = jax.jit(model.prefill_paged_chunk,
                                          donate_argnums=(2,))
        if model.copy_pages is not None:
            self._copy_pages = jax.jit(model.copy_pages, donate_argnums=(0,))
        self._decode = jax.jit(model.decode_paged, donate_argnums=(1,))
        self._sample = jax.jit(_sample, static_argnames=("gen",))

    def _decode_widths(self) -> list[int]:
        n = self.layout.pages_per_slot
        if not self.table_slicing:
            return [n]
        widths, w = [], 1
        while w < n:
            widths.append(w)
            w *= 2
        widths.append(n)
        return widths

    def _step_width(self, pages_needed: int) -> int:
        if not self.table_slicing:
            return self.layout.pages_per_slot
        for w in self._decode_widths():
            if w >= pages_needed:
                return w
        return self.layout.pages_per_slot

    def _ctx(self):
        if self.mesh is not None and self.rules is not None:
            return ctx.use_sharding(self.mesh, self.rules)
        import contextlib
        return contextlib.nullcontext()

    def _bucket(self, prompt_len: int) -> int:
        return min(pow2_bucket(prompt_len, self.layout.page_size),
                   self.layout.tokens_per_slot)

    def run(self, requests: list[Request],
            gen: Optional[GenerationConfig] = None) -> dict:
        gen = gen if gen is not None else GenerationConfig()
        prefix = (PrefixIndex(self.layout, self.prefill_chunk)
                  if self.prefix_cache else None)
        sched = Scheduler(self.layout, prefix_index=prefix,
                          chunk_tokens=self.prefill_chunk)
        state = self.model.init_paged_state(self.layout)
        s = self.layout.slots
        g = self.layout.page_size
        next_tok = np.zeros((s,), np.int32)
        lengths = np.zeros((s,), np.int64)
        eff_max: dict[int, int] = {}
        admit_seq: dict[int, int] = {}
        prefilling: dict[int, dict] = {}
        n_admitted = 0
        clock = 0.0
        key = jax.random.PRNGKey(gen.seed)
        arrivals = deque(sorted(requests, key=lambda r: r.arrival_time))
        completed: list[Request] = []
        util, active_hist, step_times = [], [], []
        steps = 0
        prefill_computed = 0
        prefill_skipped = 0
        cow_splits = 0

        def finish(slot: int):
            req = sched.active[slot]
            req.t_done = clock
            eff_max.pop(req.rid, None)
            completed.append(sched.finish(slot))

        def take_first_token(slot: int, tok0: int, tl: int):
            req = sched.active[slot]
            if req.t_admitted is None:
                req.t_admitted = req.t_first_token = clock
            req.out_tokens.append(tok0)
            next_tok[slot] = tok0
            lengths[slot] = tl
            if (gen.eos_id >= 0 and tok0 == gen.eos_id) or \
                    req.done_tokens >= eff_max[req.rid]:
                finish(slot)

        with self._ctx():
            while arrivals or sched.has_work:
                while arrivals and arrivals[0].arrival_time <= clock:
                    sched.submit(arrivals.popleft())

                if not sched.has_work:
                    clock = max(clock, arrivals[0].arrival_time)
                    continue

                while (req := sched.admissible()) is not None:
                    slot = sched.admit(req)
                    admit_seq[slot] = n_admitted
                    n_admitted += 1
                    ctx_toks = req.context_tokens()
                    tl = len(ctx_toks)
                    eff_max[req.rid] = req.done_tokens + min(
                        req.max_new_tokens - req.done_tokens,
                        self.layout.tokens_per_slot - tl + 1)
                    if self.prefill_chunk:
                        prefilling[slot] = {"ctx": ctx_toks,
                                            "off": req.prefix_hit_tokens}
                        lengths[slot] = req.prefix_hit_tokens
                        prefill_skipped += req.prefix_hit_tokens
                        continue
                    toks = np.zeros((1, self._bucket(tl)), np.int32)
                    toks[0, :tl] = ctx_toks
                    t0 = time.monotonic()
                    logits, state = self._prefill(
                        self.params, jnp.asarray(toks), state,
                        jnp.asarray(slot, jnp.int32),
                        sched.alloc.table()[slot],
                        jnp.asarray(tl, jnp.int32))
                    key, sub = jax.random.split(key)
                    tok = self._sample(logits, sub, gen)
                    tok0 = int(jax.block_until_ready(tok)[0])
                    clock += time.monotonic() - t0
                    prefill_computed += tl
                    take_first_token(slot, tok0, tl)

                progressed = False
                budget = self.prefill_budget
                while budget > 0 and prefilling:
                    slot = min(prefilling, key=admit_seq.__getitem__)
                    cur = prefilling[slot]
                    ctx_toks, off = cur["ctx"], cur["off"]
                    tl = len(ctx_toks)
                    c = self.prefill_chunk
                    clen = min(c, tl - off)
                    toks = np.zeros((1, c), np.int32)
                    toks[0, :clen] = ctx_toks[off:off + clen]
                    t0 = time.monotonic()
                    logits, state = self._prefill_chunk(
                        self.params, jnp.asarray(toks), state,
                        jnp.asarray(slot, jnp.int32),
                        sched.alloc.table()[slot],
                        jnp.asarray(off, jnp.int32),
                        jnp.asarray(clen, jnp.int32))
                    progressed = True
                    budget -= clen
                    prefill_computed += clen
                    cur["off"] = off + clen
                    lengths[slot] = off + clen
                    if cur["off"] >= tl:
                        key, sub = jax.random.split(key)
                        tok = self._sample(logits, sub, gen)
                        tok0 = int(jax.block_until_ready(tok)[0])
                        clock += time.monotonic() - t0
                        del prefilling[slot]
                        sched.register_prefix(slot)
                        take_first_token(slot, tok0, tl)
                    else:
                        jax.block_until_ready(logits)
                        clock += time.monotonic() - t0

                if not sched.active:
                    if sched.pending and sched.admissible() is None:
                        if arrivals:
                            clock = max(clock, arrivals[0].arrival_time)
                            continue
                        raise RuntimeError(
                            "pool cannot fit a single pending request "
                            "(num_pages too small)")
                    continue

                stalled = set(sched.ensure_pages(lengths,
                                                 skip=prefilling.keys()))
                step_slots = [sl for sl in sched.active
                              if sl not in stalled and sl not in prefilling]

                if step_slots and (self.prefix_cache or cow_splits):
                    safe = []
                    for sl in step_slots:
                        pidx = int(lengths[sl]) // g
                        if (pidx < sched.alloc.slot_pages(sl) and
                                sched.alloc.refcount(
                                    sched.alloc.page_at(sl, pidx)) > 1):
                            if not sched.alloc.can_alloc(1):
                                sched.reclaim(1)
                            if not sched.alloc.can_alloc(1):
                                stalled.add(sl)
                                continue
                            src, dst = sched.alloc.cow(sl, pidx)
                            state = self._copy_pages(
                                state, jnp.asarray(src, jnp.int32),
                                jnp.asarray(dst, jnp.int32))
                            cow_splits += 1
                        safe.append(sl)
                    step_slots = safe

                if not step_slots:
                    if progressed:
                        continue
                    victim = max(sched.active, key=admit_seq.__getitem__)
                    vreq = sched.active[victim]
                    if vreq.preemptions >= 64:
                        raise RuntimeError(
                            "request thrashing on preemption — pool too "
                            "small to finish any request")
                    assert victim not in prefilling
                    if vreq.out_tokens:
                        vreq.out_tokens.pop()
                    eff_max.pop(vreq.rid, None)
                    sched.preempt(victim)
                    continue
                mask = np.zeros((s,), bool)
                mask[step_slots] = True
                w = self._step_width(
                    max(int(lengths[sl]) // self.layout.page_size + 1
                        for sl in step_slots))
                t0 = time.monotonic()
                logits, state = self._decode(
                    self.params, state, jnp.asarray(next_tok),
                    sched.alloc.table()[:, :w], jnp.asarray(mask))
                key, sub = jax.random.split(key)
                toks = np.asarray(
                    jax.block_until_ready(self._sample(logits, sub, gen)))
                step_s = time.monotonic() - t0
                clock += step_s
                steps += 1
                step_times.append(step_s)
                util.append(sched.utilization())
                active_hist.append(len(step_slots))

                for sl in step_slots:
                    lengths[sl] += 1
                    req = sched.active[sl]
                    t = int(toks[sl])
                    req.out_tokens.append(t)
                    next_tok[sl] = t
                    if (gen.eos_id >= 0 and t == gen.eos_id) or \
                            req.done_tokens >= eff_max[req.rid]:
                        finish(sl)

        total_tokens = sum(r.done_tokens for r in completed)
        lats = sorted(r.latency() for r in completed)

        def pct(p):
            if not lats:
                return 0.0
            return lats[min(int(p / 100 * len(lats)), len(lats) - 1)]

        res = {
            "requests": completed,
            "total_tokens": total_tokens,
            "wall_s": clock,
            "tokens_per_s": total_tokens / max(clock, 1e-9),
            "p50_latency_s": pct(50),
            "p99_latency_s": pct(99),
            "decode_steps": steps,
            "decode_step_s_mean": float(np.mean(step_times)) if step_times
            else 0.0,
            "decode_step_s_p50": float(np.median(step_times)) if step_times
            else 0.0,
            "decode_backend": self.model.cfg.decode_backend,
            "mean_active_slots": float(np.mean(active_hist)) if active_hist
            else 0.0,
            "mean_page_utilization": float(np.mean(util)) if util else 0.0,
            "cache_bytes": _tree_bytes(state),
            "cache_bytes_per_layer": (
                self.model.cache_layer_bytes(state)
                if self.model.cache_layer_bytes else None),
            "prefill_chunk": self.prefill_chunk,
            "prefix_cache": self.prefix_cache,
            "prefill_tokens_computed": prefill_computed,
            "prefill_tokens_skipped": prefill_skipped,
            "prefix_hit_rate": prefill_skipped / max(
                prefill_skipped + prefill_computed, 1),
            "adopted_pages": sched.adopted_pages,
            "fresh_pages": sched.fresh_pages,
            "cow_splits": cow_splits,
        }
        if prefix is not None:
            from repro.core import paged_cache as pgc
            page_bytes = sum(pgc.pool_page_bytes(c) for c in state)
            res["pool_page_bytes"] = page_bytes
            res["prefix_pool_bytes_saved"] = sched.adopted_pages * page_bytes
            res["prefix_index"] = {
                "entries": len(prefix), "queries": prefix.queries,
                "evictions": prefix.evictions,
            }
        return res
