"""Page-native fused prefill: parity of paged_prefill_attention with the
gathered jnp reference across every registered codec, GQA + fragmented
non-monotonic page tables, width-sliced rows, fresh-vs-adopted prefix
pages, the rem == 0 misaligned-residual invariant across a chunked
prefill -> decode sequence at exact page multiples, and bit-identical
greedy outputs from the continuous-batching engine under
prefill_backend=paged_fused."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import QuantConfig, codecs
from repro.core import paged_cache as pg
from repro.core.cache_layout import PagedLayout
from repro.models import get_model
from repro.serve import ContinuousBatchingEngine, GenerationConfig, Request

H, d, g = 2, 32, 16
QPK = 2                      # GQA: query heads per kv head
TC = 16                      # chunk bucket (tokens)
LAYOUT = PagedLayout(page_size=g, num_pages=24, slots=3, pages_per_slot=6)
# fragmented, non-monotonic row: pages land wherever the allocator found
# free slots, and the kernel must visit them in *logical* order anyway
ROW = (9, 0, 5, 1, 2, 3)


def _cfg(method: str, value_bits: int = 0) -> QuantConfig:
    return QuantConfig(method=method, group_size=g, key_bits=8,
                       value_bits=value_bits, rho_bits=4, theta_bits=4,
                       residual_dtype="float32")


def _prefix_cache(cfg, start=3 * g, row=ROW, seed=0, slot=0, cache=None):
    """Prefill a ``start``-token prefix (page-aligned) into a fragmented
    row; returns (cache, row, start)."""
    cache = cache if cache is not None else pg.init_paged_cache(
        cfg, LAYOUT, H, d)
    row = jnp.asarray(row, jnp.int32)
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    k = jax.random.normal(ks[0], (1, H, start, d))
    v = jax.random.normal(ks[1], (1, H, start, d))
    cache = pg.paged_prefill(cache, jnp.asarray(slot), row, k, v,
                             jnp.asarray(start))
    return cache, row, jnp.asarray(start, jnp.int32)


def _chunk(seed=7, tc=TC):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, H * QPK, tc, d))
    k = jax.random.normal(ks[1], (1, H, tc, d))
    v = jax.random.normal(ks[2], (1, H, tc, d))
    return q, k, v


# ---------------------------------------------------------------------------
# Parity: page-native dispatch vs the gathered jnp reference, whole registry
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(codecs.registered_codecs()))
def test_paged_fused_prefill_matches_jnp_reference(name):
    """paged_prefill_attention(backend="paged_fused") must agree with the
    gathered jnp reference for every registered codec — page-native walk
    for codecs with the capability, gathered fallback for the rest."""
    cfg = _cfg(name)
    cache, row, start = _prefix_cache(cfg)
    q, kc, vc = _chunk()
    clen = jnp.asarray(13, jnp.int32)    # ragged chunk: tail is padding
    o_ref = pg.paged_prefill_attention(cache, q, kc, vc, row, start, clen,
                                       backend="jnp")
    o_fused = pg.paged_prefill_attention(cache, q, kc, vc, row, start, clen,
                                         backend="paged_fused")
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_fused),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("value_bits", [0, 4])
def test_polar_jnp_oracle_is_bit_identical(value_bits):
    """The page-walking jnp oracle reorders no float ops relative to the
    gathered reference — outputs are bit-identical, which is what lets the
    engine flip prefill_backend without perturbing greedy decoding."""
    cfg = _cfg("polar", value_bits=value_bits)
    cache, row, start = _prefix_cache(cfg)
    q, kc, vc = _chunk()
    clen = jnp.asarray(13, jnp.int32)
    o_jnp = pg.paged_prefill_attention(cache, q, kc, vc, row, start, clen,
                                       backend="jnp")
    o_ref = pg.paged_prefill_attention(cache, q, kc, vc, row, start, clen,
                                       backend="ref")
    np.testing.assert_array_equal(np.asarray(o_jnp), np.asarray(o_ref))


@pytest.mark.parametrize("value_bits", [0, 4])
def test_polar_pallas_kernel_parity_interpret(value_bits):
    """Interpret-mode Pallas (kernel body on CPU CI) vs the gathered
    reference, quantized and fp values."""
    cfg = _cfg("polar", value_bits=value_bits)
    cache, row, start = _prefix_cache(cfg)
    q, kc, vc = _chunk()
    clen = jnp.asarray(13, jnp.int32)
    o_jnp = pg.paged_prefill_attention(cache, q, kc, vc, row, start, clen,
                                       backend="jnp")
    o_k = pg.paged_prefill_attention(cache, q, kc, vc, row, start, clen,
                                     backend="interpret")
    np.testing.assert_allclose(np.asarray(o_jnp), np.asarray(o_k),
                               atol=2e-5, rtol=1e-4)


def test_width_sliced_row_matches_full_row():
    """The engine buckets the table row to the pages covering the live
    prefix; masked lanes contribute exactly-0.0 probability, so slicing is
    numerically equivalent (to reduction-order rounding — the contraction
    width changes, so exact bit layout may differ by ~1 ulp)."""
    cfg = _cfg("polar", value_bits=4)
    cache, row, start = _prefix_cache(cfg)
    q, kc, vc = _chunk()
    clen = jnp.asarray(TC, jnp.int32)
    full = pg.paged_prefill_attention(cache, q, kc, vc, row, start, clen,
                                      backend="ref")
    sliced = pg.paged_prefill_attention(cache, q, kc, vc, row[:4], start,
                                        clen, backend="ref")
    np.testing.assert_allclose(np.asarray(full), np.asarray(sliced),
                               atol=1e-6, rtol=1e-5)


def test_fresh_vs_adopted_prefix_pages_identical():
    """Shared-prefix adoption: a slot whose row points at pages *another*
    slot's prefill wrote must score the prefix identically to a slot that
    recomputed the same prefix into fresh pages (same bytes -> same
    bits)."""
    cfg = _cfg("polar", value_bits=4)
    cache, row_a, start = _prefix_cache(cfg, seed=0, slot=0)
    # same prefix content, fresh pages, different slot
    row_b = (14, 20, 7, 11, 12, 13)
    cache, row_b, _ = _prefix_cache(cfg, row=row_b, seed=0, slot=1,
                                    cache=cache)
    q, kc, vc = _chunk()
    clen = jnp.asarray(TC, jnp.int32)
    o_fresh = pg.paged_prefill_attention(cache, q, kc, vc, row_b, start,
                                         clen, backend="ref")
    # adoption == pointing the row at the original writer's pages
    o_adopted = pg.paged_prefill_attention(cache, q, kc, vc, row_a, start,
                                           clen, backend="ref")
    np.testing.assert_array_equal(np.asarray(o_fresh), np.asarray(o_adopted))


def test_start_zero_first_chunk():
    """First chunk of a prompt: no prefix pages live, pure fp causal."""
    cfg = _cfg("polar")
    cache = pg.init_paged_cache(cfg, LAYOUT, H, d)
    row = jnp.asarray(ROW, jnp.int32)
    q, kc, vc = _chunk()
    z = jnp.asarray(0, jnp.int32)
    clen = jnp.asarray(TC, jnp.int32)
    o_jnp = pg.paged_prefill_attention(cache, q, kc, vc, row, z, clen,
                                       backend="jnp")
    o_ref = pg.paged_prefill_attention(cache, q, kc, vc, row, z, clen,
                                       backend="ref")
    o_k = pg.paged_prefill_attention(cache, q, kc, vc, row, z, clen,
                                     backend="interpret")
    np.testing.assert_array_equal(np.asarray(o_jnp), np.asarray(o_ref))
    np.testing.assert_allclose(np.asarray(o_jnp), np.asarray(o_k),
                               atol=2e-5, rtol=1e-4)


def test_unknown_backend_rejected():
    cfg = _cfg("polar")
    cache, row, start = _prefix_cache(cfg)
    q, kc, vc = _chunk()
    with pytest.raises(ValueError, match="unknown paged prefill backend"):
        pg.paged_prefill_attention(cache, q, kc, vc, row, start,
                                   jnp.asarray(1, jnp.int32),
                                   backend="cuda")


# ---------------------------------------------------------------------------
# rem == 0: the misaligned-residual invariant at exact page multiples
# ---------------------------------------------------------------------------


def test_rem_zero_residual_garbage_never_visible():
    """When a prefill chunk ends exactly on a page boundary (rem == 0),
    paged_prefill's clamped dynamic_slice writes *misaligned garbage* into
    key_residual (src/repro/core/paged_cache.py, res_lo clamp). The
    invariant: that garbage is dead — every later read is either masked by
    lengths or overwritten before becoming visible. Poisoning the residual
    after each rem == 0 chunk must not change a single output bit across a
    chunked prefill -> decode sequence at exact page multiples."""
    cfg = _cfg("polar", value_bits=4)

    def poison(cache):
        return dataclasses.replace(
            cache, key_residual=jnp.full_like(cache.key_residual, 1e9))

    row = jnp.asarray(ROW, jnp.int32)
    slot = jnp.asarray(0)
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    chunks = [(jax.random.normal(ks[2 * i], (1, H, g, d)),
               jax.random.normal(ks[2 * i + 1], (1, H, g, d)))
              for i in range(2)]
    q, kc, vc = _chunk(seed=11, tc=g)

    outs = []
    for arm in ("clean", "poisoned"):
        cache = pg.init_paged_cache(cfg, LAYOUT, H, d)
        arm_out = []
        for i, (k, v) in enumerate(chunks):       # two chunks of exactly g
            start = jnp.asarray(i * g, jnp.int32)
            arm_out.append(pg.paged_prefill_attention(
                cache, q, k, v, row, start, jnp.asarray(g, jnp.int32),
                backend="ref"))
            cache = pg.paged_prefill(cache, slot, row, k, v,
                                     jnp.asarray(g), start=start)
            if arm == "poisoned":
                cache = poison(cache)             # rem == 0: garbage anyway
        # decode step: append one token, attend over the whole slot
        k1 = jax.random.normal(ks[4], (LAYOUT.slots, H, 1, d))
        v1 = jax.random.normal(ks[5], (LAYOUT.slots, H, 1, d))
        table = jnp.tile(row[None], (LAYOUT.slots, 1))
        active = jnp.asarray([True, False, False])
        cache = pg.paged_append(cache, k1, v1, table, active)
        qd = jax.random.normal(jax.random.PRNGKey(9),
                               (LAYOUT.slots, H * QPK, d))
        for be in ("jnp", "paged_fused"):
            arm_out.append(pg.paged_decode_attention(cache, qd, table,
                                                     backend=be))
        outs.append(arm_out)

    for o_clean, o_poisoned in zip(*outs):
        np.testing.assert_array_equal(np.asarray(o_clean),
                                      np.asarray(o_poisoned))


# ---------------------------------------------------------------------------
# Engine: bit-identical greedy outputs with prefill_backend=paged_fused
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_cfg_params():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def test_cb_engine_paged_fused_prefill_bit_identical(smoke_cfg_params):
    """Shared-prefix chunked-prefill workload under the CB engine: flipping
    prefill_backend jnp -> paged_fused (page-native kernel + width-sliced
    table rows) must leave every greedy output token bit-identical."""
    cfg0, params = smoke_cfg_params
    rng = np.random.default_rng(5)
    shared = rng.integers(0, cfg0.vocab_size, (96,)).astype(np.int32)
    tails = [rng.integers(0, cfg0.vocab_size,
                          (int(rng.integers(8, 40)),)).astype(np.int32)
             for _ in range(5)]

    def _reqs():  # fresh Requests per arm: the engine mutates them
        return [Request(rid=i, prompt=np.concatenate([shared, tails[i]]),
                        max_new_tokens=6,
                        arrival_time=0.0 if i == 0 else 1.0 + 0.01 * i)
                for i in range(5)]

    results = {}
    for pb in ("jnp", "paged_fused"):
        cfg = dataclasses.replace(cfg0, decode_backend="paged_fused",
                                  prefill_backend=pb)
        eng = ContinuousBatchingEngine(
            get_model(cfg), params, max_slots=3, max_len=192,
            prefill_chunk=32, prefix_cache=True)
        out = eng.run(_reqs(), GenerationConfig(max_new_tokens=6))
        assert out["prefill_backend"] == pb
        results[pb] = {r.rid: list(r.out_tokens) for r in out["requests"]}
    assert results["jnp"] == results["paged_fused"]
