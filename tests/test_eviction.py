"""SnapKV-style eviction: selection sanity + composition with PolarQuant."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.eviction import snapkv_select


def test_keeps_observation_window():
    b, h, t, d, w = 1, 2, 128, 16, 16
    k = jax.random.normal(jax.random.PRNGKey(0), (b, h, t, d))
    q_obs = jax.random.normal(jax.random.PRNGKey(1), (b, h, w, d))
    mask = snapkv_select(q_obs, k, budget=48, obs_window=w)
    assert mask.shape == (b, h, t)
    # observation window always kept
    assert bool(mask[:, :, t - w :].all())
    # budget respected
    assert int(mask.sum(-1).max()) <= 48


def test_selects_high_attention_tokens():
    b, h, t, d, w = 1, 1, 64, 8, 8
    k = jax.random.normal(jax.random.PRNGKey(2), (b, h, t, d)) * 0.05
    # token 7 strongly attended: align it with the observation queries
    q_obs = jax.random.normal(jax.random.PRNGKey(3), (b, h, w, d))
    k = k.at[:, :, 7].set(q_obs.mean(axis=2) * 10)
    mask = snapkv_select(q_obs, k, budget=16, obs_window=w)
    assert bool(mask[0, 0, 7])


def test_eviction_error_decreases_with_budget():
    b, h, t, d, w = 1, 2, 256, 32, 16
    k = jax.random.normal(jax.random.PRNGKey(4), (b, h, t, d))
    v = jax.random.normal(jax.random.PRNGKey(5), (b, h, t, d))
    q = jax.random.normal(jax.random.PRNGKey(6), (b, h, 4, d))
    q_obs = jax.random.normal(jax.random.PRNGKey(7), (b, h, w, d))

    def attn(mask=None):
        s = jnp.einsum("bhqd,bhtd->bhqt", q * d ** -0.5, k)
        if mask is not None:
            s = jnp.where(mask[:, :, None, :], s, -1e30)
        return jnp.einsum("bhqt,bhtd->bhqd", jax.nn.softmax(s, -1), v)

    full = attn()
    errs = []
    for budget in (32, 128, 224):
        o = attn(snapkv_select(q_obs, k, budget, w))
        errs.append(float(jnp.linalg.norm(o - full)))
    assert errs[0] > errs[1] > errs[2], errs
