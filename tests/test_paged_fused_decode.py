"""Page-native fused decode: parity with the gathered reference across
every registered codec, fragmented/non-monotonic page tables, scratch-page
masking, width-sliced tables, mixed per-layer policies under the
continuous-batching engine, and decode-state donation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantConfig, codecs
from repro.core import paged_cache as pg
from repro.core.cache_layout import PagedLayout, PageAllocator
from repro.utils import tree_bytes

H, d, g = 2, 32, 16
LAYOUT = PagedLayout(page_size=g, num_pages=24, slots=3, pages_per_slot=6)


def _cfg(method: str, value_bits: int = 0) -> QuantConfig:
    return QuantConfig(method=method, group_size=g, key_bits=8,
                       value_bits=value_bits, rho_bits=4, theta_bits=4,
                       residual_dtype="float32")


def _fill_slots(cfg, layout=LAYOUT, lengths=(9, 38, 64), alloc=None):
    """Prefill each slot to its length (heterogeneous, residuals included)."""
    alloc = alloc or PageAllocator(layout)
    cache = pg.init_paged_cache(cfg, layout, H, d)
    for slot, tp in enumerate(lengths):
        assert alloc.alloc(slot, layout.pages_for(max(tp, 1)))
        bucket = -(-tp // g) * g
        ks = jax.random.split(jax.random.PRNGKey(slot), 2)
        k = jax.random.normal(ks[0], (1, H, bucket, d))
        v = jax.random.normal(ks[1], (1, H, bucket, d))
        cache = pg.paged_prefill(cache, jnp.asarray(slot),
                                 alloc.table()[slot], k, v, jnp.asarray(tp))
    return cache, alloc


def _q(seed=7, slots=3):
    return jax.random.normal(jax.random.PRNGKey(seed), (slots, H * 2, d))


# ---------------------------------------------------------------------------
# Parity: page-native dispatch vs the gathered reference, whole registry
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(codecs.registered_codecs()))
def test_paged_fused_matches_gathered_reference(name):
    """paged_decode_attention(backend="paged_fused") must agree with the
    gathered jnp reference for every registered codec — page-native kernel
    for codecs with the capability, gathered fallback for the rest."""
    cfg = _cfg(name)
    cache, alloc = _fill_slots(cfg)
    q = _q()
    o_ref = pg.paged_decode_attention(cache, q, alloc.table(), backend="jnp")
    o_fused = pg.paged_decode_attention(cache, q, alloc.table(),
                                        backend="paged_fused")
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_fused),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("value_bits", [0, 4])
@pytest.mark.parametrize("backend", ["paged_fused", "interpret"])
def test_polar_page_native_kernel_parity(backend, value_bits):
    """The page-table-walking kernel (jnp page walk AND interpret-mode
    Pallas, so CPU CI exercises the kernel body) vs the gathered dense
    path, heterogeneous per-slot lengths + quantized values."""
    cfg = _cfg("polar", value_bits=value_bits)
    cache, alloc = _fill_slots(cfg)
    q = _q()
    o_ref = pg.paged_decode_attention(cache, q, alloc.table(), backend="jnp")
    o = pg.paged_decode_attention(cache, q, alloc.table(), backend=backend)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o),
                               atol=2e-5, rtol=1e-4)


def test_gathered_backend_still_runs_dense_fused_path():
    """backend="gathered" keeps the PR-2 formulation alive for A/B."""
    cfg = _cfg("polar")
    cache, alloc = _fill_slots(cfg)
    q = _q()
    o_ref = pg.paged_decode_attention(cache, q, alloc.table(), backend="jnp")
    o = pg.paged_decode_attention(cache, q, alloc.table(), backend="gathered")
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o),
                               atol=2e-5, rtol=1e-4)


def test_unknown_backend_rejected():
    cfg = _cfg("polar")
    cache, alloc = _fill_slots(cfg)
    with pytest.raises(ValueError, match="unknown paged decode backend"):
        pg.paged_decode_attention(cache, _q(), alloc.table(),
                                  backend="warp-drive")


# ---------------------------------------------------------------------------
# Fragmented / non-monotonic page tables + scratch-page masking
# ---------------------------------------------------------------------------


def test_fragmented_non_monotonic_table_parity():
    """Slots admitted onto recycled pages (table rows out of pool order):
    page-native and gathered paths must both match bit-for-bit semantics."""
    lay = PagedLayout(page_size=g, num_pages=10, slots=3, pages_per_slot=6)
    cfg = _cfg("polar", value_bits=4)
    # alloc/free churn: the free list wraps, so new rows interleave fresh
    # and recycled page ids
    alloc = PageAllocator(lay)
    assert alloc.alloc(0, 4)          # pages 0..3
    assert alloc.alloc(1, 3)          # pages 4..6
    assert alloc.alloc(2, 2)          # pages 7..8
    alloc.free_slot(0)                # free list: [9, 0, 1, 2, 3]
    alloc.free_slot(2)                # free list: [9, 0, 1, 2, 3, 7, 8]
    cache = pg.init_paged_cache(cfg, lay, H, d)
    for slot, tp in [(0, 40), (2, 25)]:   # rows [9, 0, 1] and [2, 3]
        assert alloc.alloc(slot, lay.pages_for(tp))
        bucket = -(-tp // g) * g
        ks = jax.random.split(jax.random.PRNGKey(10 + slot), 2)
        k = jax.random.normal(ks[0], (1, H, bucket, d))
        v = jax.random.normal(ks[1], (1, H, bucket, d))
        cache = pg.paged_prefill(cache, jnp.asarray(slot),
                                 alloc.table()[slot], k, v, jnp.asarray(tp))
    rows = alloc.table_np()
    assert (np.diff(rows[0][rows[0] != lay.scratch_page]) < 0).any(), \
        "fixture should produce a non-monotonic row"
    q = _q()
    o_ref = pg.paged_decode_attention(cache, q, alloc.table(), backend="jnp")
    for backend in ("paged_fused", "interpret"):
        o = pg.paged_decode_attention(cache, q, alloc.table(),
                                      backend=backend)
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o),
                                   atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("backend", ["jnp", "paged_fused", "interpret"])
def test_scratch_page_masked_at_page_granularity(backend):
    """Regression (fragmented pool): a poisoned scratch page — NaN stats
    and value rows, the worst stale garbage masked writes could leave —
    must not leak into any slot's output. gather_view now masks unassigned
    entries at *page* granularity before scoring; the page-native kernel
    never dereferences the scratch page at all."""
    cfg = _cfg("polar", value_bits=0)
    cache, alloc = _fill_slots(cfg, lengths=(9, 38, 64))
    clean = pg.paged_decode_attention(cache, _q(), alloc.table(),
                                      backend="jnp")
    sp = LAYOUT.scratch_page
    bad = jnp.nan
    poisoned = dataclasses.replace(
        cache,
        key_scales={k: v.at[sp].set(bad) for k, v in cache.key_scales.items()},
        value_fp=cache.value_fp.at[sp].set(bad))
    out = pg.paged_decode_attention(poisoned, _q(), alloc.table(),
                                    backend=backend)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(clean), np.asarray(out),
                               atol=2e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# Width-sliced page tables (engine decode buckets)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "paged_fused", "interpret"])
def test_width_sliced_table_matches_full(backend):
    """Slicing the table to the live pages (the engines' pow2 width
    buckets) must not change the result — only the read volume."""
    cfg = _cfg("polar", value_bits=4)
    cache, alloc = _fill_slots(cfg, lengths=(9, 38, 64))
    q = _q()
    full = pg.paged_decode_attention(cache, q, alloc.table(),
                                     backend=backend)
    live = max(LAYOUT.pages_for(t) for t in (9, 38, 64))   # 4 of 6 pages
    sliced = pg.paged_decode_attention(cache, q, alloc.table()[:, :live],
                                       backend=backend)
    np.testing.assert_allclose(np.asarray(full), np.asarray(sliced),
                               atol=2e-5, rtol=1e-4)


def test_append_with_sliced_table():
    """paged_append must address pages through a width-sliced table too
    (clamped group index; inactive slots land on scratch)."""
    cfg = _cfg("polar")
    cache, alloc = _fill_slots(cfg, lengths=(9, 38, 64))
    w = max(LAYOUT.pages_for(t + 1) for t in (9, 38, 64))
    s = LAYOUT.slots
    kn = jax.random.normal(jax.random.PRNGKey(0), (s, H, 1, d))
    active = jnp.ones((s,), bool)
    a_full = pg.paged_append(cache, kn, kn, alloc.table(), active)
    a_sliced = pg.paged_append(cache, kn, kn, alloc.table()[:, :w], active)
    for x, y in zip(jax.tree_util.tree_leaves(a_full),
                    jax.tree_util.tree_leaves(a_sliced)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Model + engine integration (per-segment dispatch, donation)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    from repro.configs import get_config, reduce_for_smoke
    from repro.models import get_model
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def test_model_decode_paged_backend_parity(smoke_model):
    """decode_paged logits agree across jnp / paged_fused / interpret —
    the cfg-driven dispatch reaches the page-native kernel."""
    from repro.models import get_model
    cfg, m, params = smoke_model
    lay = PagedLayout(page_size=cfg.quant.group_size, num_pages=8, slots=2,
                      pages_per_slot=4)
    logits = {}
    for be in ("jnp", "paged_fused", "interpret"):
        mb = get_model(dataclasses.replace(cfg, decode_backend=be))
        alloc = PageAllocator(lay)
        assert alloc.alloc(0, 2) and alloc.alloc(1, 1)
        state = mb.init_paged_state(lay)
        rng = np.random.default_rng(0)
        for slot, tl in [(0, 40), (1, 17)]:
            toks = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                            (1, 64)).astype(np.int32))
            _, state = mb.prefill_paged(params, toks, state,
                                        jnp.asarray(slot, jnp.int32),
                                        alloc.table()[slot],
                                        jnp.asarray(tl, jnp.int32))
        lg, _ = mb.decode_paged(params, state,
                                jnp.asarray([3, 5], jnp.int32),
                                alloc.table(), jnp.ones((2,), bool))
        logits[be] = np.asarray(lg)
    np.testing.assert_allclose(logits["jnp"], logits["paged_fused"],
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(logits["paged_fused"], logits["interpret"],
                               atol=1e-4, rtol=1e-4)


def test_mixed_policy_paged_fused_engine(smoke_model):
    """first_k mixed policy under continuous batching with
    decode_backend="paged_fused": the polar segment runs page-native, the
    int8 segment takes the gathered fallback — requests must complete."""
    from repro.core import CachePolicy
    from repro.models import get_model
    from repro.serve import ContinuousBatchingEngine, GenerationConfig, Request
    cfg, m, params = smoke_model
    policy = CachePolicy.first_k(
        1, dataclasses.replace(cfg.quant, method="int", key_bits=8),
        dataclasses.replace(cfg.quant, method="polar"))
    cfg_m = dataclasses.replace(cfg, cache_policy=policy,
                                decode_backend="paged_fused")
    eng = ContinuousBatchingEngine(get_model(cfg_m), params, max_slots=2,
                                   max_len=128)
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        (int(rng.integers(8, 50)),)
                                        ).astype(np.int32),
                    max_new_tokens=6, arrival_time=i * 0.01)
            for i in range(4)]
    out = eng.run(reqs, GenerationConfig())
    assert len(out["requests"]) == 4
    assert all(r.done_tokens == 6 for r in out["requests"])
    assert out["decode_backend"] == "paged_fused"
    assert out["decode_step_s_mean"] > 0.0


def test_decode_state_donated_no_per_step_copy(smoke_model):
    """Both engines donate the decode state: the compiled step aliases the
    cache buffers in place of copying them, and the only fresh allocation
    per step is logits-sized — asserted via memory_analysis/cost_analysis
    on the exact jitted callables the engines run."""
    from repro.serve import ContinuousBatchingEngine, ServeEngine
    cfg, m, params = smoke_model

    # --- paged engine ---
    eng = ContinuousBatchingEngine(m, params, max_slots=2, max_len=128)
    state = m.init_paged_state(eng.layout)
    s = eng.layout.slots
    args = (params, state, jnp.zeros((s,), jnp.int32),
            jnp.zeros((s, eng.layout.pages_per_slot), jnp.int32),
            jnp.zeros((s,), bool))
    compiled = eng.core._decode.lower(*args).compile()
    ma = compiled.memory_analysis()
    state_bytes = tree_bytes(state)
    assert ma.alias_size_in_bytes >= 0.9 * state_bytes
    fresh_out = ma.output_size_in_bytes - ma.alias_size_in_bytes
    assert fresh_out < max(1 << 20, 0.1 * state_bytes)
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    assert ca.get("bytes accessed", 0.0) > 0.0   # sanity: analysis populated

    # --- dense engine ---
    dense = ServeEngine(m, params, max_len=128)
    dstate = m.init_decode_state(2, 128)
    compiled = dense._decode.lower(
        params, dstate, jnp.zeros((2,), jnp.int32)).compile()
    ma = compiled.memory_analysis()
    dbytes = tree_bytes(dstate)
    assert ma.alias_size_in_bytes >= 0.9 * dbytes
    assert (ma.output_size_in_bytes - ma.alias_size_in_bytes
            < max(1 << 20, 0.1 * dbytes))
