"""Pallas prefill flash-attention kernel vs the jnp reference (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import reference_attention
from repro.kernels.flash_prefill import flash_prefill


def _qkv(seed, b, h, hkv, tq, tk, d, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, h, tq, d), dtype),
            jax.random.normal(ks[1], (b, hkv, tk, d), dtype),
            jax.random.normal(ks[2], (b, hkv, tk, d), dtype))


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 2), (2, 1)])
def test_matches_reference(causal, h, hkv):
    q, k, v = _qkv(0, 1, h, hkv, 64, 64, 32)
    out = flash_prefill(q, k, v, causal=causal, q_blk=16, k_blk=16)
    ref = reference_attention(q, k, v, mode="causal" if causal else "full")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_unpadded_lengths():
    q, k, v = _qkv(1, 1, 2, 2, 50, 70, 16)
    out = flash_prefill(q, k, v, causal=False, q_blk=16, k_blk=32)
    ref = reference_attention(q, k, v, mode="full")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_bf16():
    q, k, v = _qkv(2, 1, 2, 2, 32, 32, 32, jnp.bfloat16)
    out = flash_prefill(q, k, v, causal=True, q_blk=16, k_blk=16)
    ref = reference_attention(q, k, v, mode="causal")
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)
