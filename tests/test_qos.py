"""SLA-aware admission control (DESIGN.md §16): weighted fair queueing,
tenant token budgets, TTFT-deadline shedding, bounded-queue backpressure,
and graceful degradation.

Policy-layer tests run host-side against ``repro.serve.qos`` and the
Scheduler directly (no model, no jax); engine-level tests drive the
smoke model through the streaming front door and assert the explicit
``shed``/``reject`` events — a QoS engine must never hang silently.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_config, reduce_for_smoke
from repro.core.cache_layout import PagedLayout
from repro.models import get_model
from repro.serve import (
    ContinuousBatchingEngine, GenerationConfig, QosConfig, Request,
    Scheduler, StreamingEngine, check_event_stream, goodput_under_sla,
)
from repro.serve.core import REJECTED, SHED
from repro.serve.qos import (
    DegradeController, QosState, RateEstimator, request_cost,
)
from test_prefix_cache import check_alloc_invariants


@pytest.fixture(scope="module")
def smoke_model():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _req(rid, *, plen=8, tenant="default", arrival=0.0, deadline=0.0,
         max_new=4):
    return Request(rid=rid, prompt=np.arange(plen, dtype=np.int32),
                   max_new_tokens=max_new, arrival_time=arrival,
                   tenant=tenant, ttft_deadline=deadline)


# --- config + primitives ---------------------------------------------------


def test_qos_config_validation():
    with pytest.raises(ValueError):
        QosConfig(max_pending=-1)
    with pytest.raises(ValueError):
        QosConfig(ttft_slo=-0.5)
    with pytest.raises(ValueError):
        QosConfig(pressure_hi=0.5, pressure_lo=0.9)
    with pytest.raises(ValueError):
        QosConfig(weights={"a": 0.0})
    cfg = QosConfig(tenant_budget=100.0)
    assert cfg.burst == 200.0          # default burst = 2x budget
    assert QosConfig(tenant_budget=100.0, tenant_burst=50.0).burst == 50.0


def test_rate_estimator_ewma():
    est = RateEstimator()
    assert est.rate is None            # no projection before any sample
    est.observe(100, 1.0)
    assert est.rate == pytest.approx(100.0)
    est.observe(300, 1.0)              # EWMA pulls toward the new sample
    assert 100.0 < est.rate < 300.0
    est.observe(0, 0.0)                # degenerate sample ignored
    assert est.rate is not None


def test_token_bucket_budget_and_burst():
    st = QosState(QosConfig(tenant_budget=100.0))
    ts = st.tenant("a")
    assert ts.can_afford(150)          # bucket starts full (= burst 200)
    ts.charge(180)
    assert not ts.can_afford(150)      # 20 left, cost 150 > bucket
    st.refill(1.0)                     # +100 tokens after 1s engine time
    assert ts.can_afford(100)
    # a cost above burst is payable at a full bucket (min(cost, burst)):
    # one giant request must not starve forever
    big = QosState(QosConfig(tenant_budget=10.0))
    assert big.tenant("b").can_afford(10_000)


def test_wfq_admission_order_least_attained_first():
    st = QosState(QosConfig(weights={"heavy": 1.0, "light": 1.0}))
    st.tenant("heavy").committed_tokens = 1000
    pending = [_req(0, tenant="heavy"), _req(1, tenant="heavy"),
               _req(2, tenant="light")]
    order = st.admission_order(pending)
    assert [r.rid for r in order] == [2, 0, 1]   # light first, FCFS ties
    # weights scale attained service: heavy at weight 4 halves back in
    st2 = QosState(QosConfig(weights={"heavy": 4.0}))
    st2.tenant("heavy").committed_tokens = 100
    st2.tenant("light").committed_tokens = 100
    order2 = st2.admission_order([_req(0, tenant="heavy"),
                                  _req(1, tenant="light")])
    assert [r.rid for r in order2] == [0, 1]     # 100/4 < 100/1


def test_budget_filter_excludes_broke_tenants():
    st = QosState(QosConfig(tenant_budget=10.0))
    st.tenant("broke").bucket = 0.0
    pending = [_req(0, tenant="broke"), _req(1, tenant="flush")]
    assert [r.rid for r in st.admission_order(pending)] == [1]


def test_scheduler_wfq_vs_fcfs():
    lay = PagedLayout(page_size=4, num_pages=16, slots=2, pages_per_slot=4)
    heavy_first = [_req(0, tenant="heavy"), _req(1, tenant="light")]
    # FCFS (qos=None): strictly head-of-queue
    s0 = Scheduler(lay)
    for r in heavy_first:
        s0.submit(r)
    assert s0.admissible().rid == 0
    # WFQ: the starved light tenant jumps the queue
    st = QosState(QosConfig())
    st.tenant("heavy").committed_tokens = 500
    s1 = Scheduler(lay, qos=st)
    for r in [_req(0, tenant="heavy"), _req(1, tenant="light")]:
        s1.submit(r)
    got = s1.admissible()
    assert got.rid == 1
    slot = s1.admit(got)                  # non-head admit must not corrupt
    assert s1.active[slot].rid == 1
    assert [r.rid for r in s1.pending] == [0]
    assert st.tenant("light").committed_tokens == request_cost(got)
    check_alloc_invariants(s1.alloc)


def test_unmeetable_projection_and_blown_deadlines():
    st = QosState(QosConfig(ttft_slo=1.0))
    # blown: clock already past arrival + deadline (no rate needed)
    blown = _req(0, arrival=0.0, deadline=1.0)
    doomed = st.unmeetable([blown], clock=2.0, prefill_rate=None)
    assert [(r.rid, why) for r, why in doomed] == [(0, "deadline_blown")]
    # projection: 3 requests x 100-token contexts at 100 tok/s; the
    # third's ETA = (backlog 200 + own 100)/100 = 3s > its 1s deadline
    reqs = [_req(i, plen=100, arrival=0.0, deadline=10.0 if i < 2 else 1.0)
            for i in range(3)]
    doomed = st.unmeetable(reqs, clock=0.0, prefill_rate=100.0)
    assert [(r.rid, why) for r, why in doomed] == \
        [(2, "deadline_unmeetable")]
    # without a rate measurement the projection is disabled
    assert st.unmeetable(reqs, clock=0.0, prefill_rate=None) == []
    # shed_late=False disables shedding entirely
    off = QosState(QosConfig(ttft_slo=1.0, shed_late=False))
    assert off.unmeetable([blown], clock=2.0, prefill_rate=None) == []


def test_degrade_hysteresis_and_knobs():
    cfg = QosConfig(pressure_hi=0.9, pressure_lo=0.5,
                    hysteresis_up=3, hysteresis_down=4)
    d = DegradeController(cfg)
    for _ in range(2):                      # 2 hot cycles: not enough
        assert d.update(0.95, False) == 0
    assert d.update(0.95, False) == 1       # 3rd consecutive: downshift
    assert d.spec_k(8) == 4 and d.prefill_budget(64) == 32
    assert not d.evict_ahead
    for _ in range(3):
        d.update(0.95, False)
    assert d.level == 2 and d.evict_ahead   # sustained: next level
    # the dead zone (between lo and hi) resets the hot streak but is not
    # calm — the level holds
    d.update(0.7, False)
    assert d.level == 2
    for _ in range(3):
        assert d.update(0.2, False) == 2    # calm, but < hysteresis_down
    assert d.update(0.2, False) == 1        # 4th calm cycle: recover
    # a preemption is pressure regardless of utilization
    d2 = DegradeController(cfg)
    for _ in range(3):
        d2.update(0.0, True)
    assert d2.level == 1
    # level 3 turns speculation off and floors the budget
    d3 = DegradeController(cfg)
    for _ in range(9):
        d3.update(1.0, False)
    assert d3.level == 3
    assert d3.spec_k(8) == 0 and d3.prefill_budget(4) == 1
    assert d3.stats()["downshifts"] == 3


def test_goodput_under_sla_metric():
    met = _req(0, deadline=1.0)
    met.t_first_token, met.out_tokens = 0.5, [1, 2, 3]
    late = _req(1, arrival=0.0, deadline=1.0)
    late.t_first_token, late.out_tokens = 2.0, [1, 2, 3, 4]
    never = _req(2, deadline=1.0)           # no first token at all
    g = goodput_under_sla([met, late, never], wall_s=2.0)
    assert g["good_tokens"] == 3 and g["deadline_met_requests"] == 1
    assert g["deadline_missed_requests"] == 2
    assert g["goodput_tokens_per_s"] == pytest.approx(1.5)
    # no deadline anywhere: everything completed counts
    free = _req(3)
    free.t_first_token, free.out_tokens = 5.0, [1]
    assert goodput_under_sla([free], 1.0)["deadline_met_rate"] == 1.0


# --- engine-level: explicit events, never a silent hang --------------------


def test_bounded_queue_rejects_with_event(smoke_model):
    cfg, m, params = smoke_model
    eng = ContinuousBatchingEngine(
        m, params, max_slots=2, max_len=64,
        qos=QosConfig(max_pending=2))
    stream = StreamingEngine(eng)
    rids = [stream.add_request(np.arange(8, dtype=np.int32),
                               max_new_tokens=3) for _ in range(4)]
    # intake 3 and 4 arrive over the bounded queue: explicit rejects
    # surface on the very first pull, ahead of any step events
    first = stream.step()
    pre = [ev for ev in first if ev.kind == "reject"]
    assert [ev.rid for ev in pre] == rids[2:]
    assert all(ev.reason == "queue_full" for ev in pre)
    assert first[:2] == pre
    events = first + list(stream.events())
    terminal = check_event_stream(events)
    assert [terminal[r] for r in rids] == \
        ["finish", "finish", "reject", "reject"]
    res = stream.result()
    assert res["n_rejected"] == 2
    assert all(r.state == REJECTED for r in res["rejected_requests"])
    assert res["qos"]["rejected"] == 2
    # cancelling a rejected rid is the documented no-op
    assert stream.cancel(rids[2]) is False
    check_alloc_invariants(eng.core.sched.alloc)


def test_deadline_shed_emits_events_and_frees_nothing(smoke_model):
    cfg, m, params = smoke_model
    eng = ContinuousBatchingEngine(
        m, params, max_slots=2, max_len=64, qos=QosConfig(ttft_slo=1e-4))
    stream = StreamingEngine(eng)
    rng = np.random.default_rng(0)
    # a two-slot engine swallowing 8 near-simultaneous arrivals under a
    # microscopic deadline: the queue tail must shed, not serve late
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, (16,))
                    .astype(np.int32),
                    max_new_tokens=4, arrival_time=i * 1e-5)
            for i in range(8)]
    for r in reqs:
        stream.submit(r)
    events = list(stream.events())
    terminal = check_event_stream(events)
    res = stream.result()
    assert res["n_shed"] > 0
    sheds = [ev for ev in events if ev.kind == "shed"]
    assert {ev.reason for ev in sheds} <= \
        {"deadline_blown", "deadline_unmeetable"}
    assert all(r.state == SHED for r in res["shed_requests"])
    assert res["qos"]["prefill_rate_est"] is not None
    # every request reached exactly one terminal state
    assert sorted(terminal) == list(range(8))
    assert res["n_shed"] + len(res["requests"]) == 8
    check_alloc_invariants(eng.core.sched.alloc)
    assert eng.core.sched.alloc.free_pages == eng.core.layout.num_pages


def test_degrade_engages_under_pool_pressure(smoke_model):
    cfg, m, params = smoke_model
    g = cfg.quant.group_size
    # an oversubscribed pool: 3 slots contending for barely more pages
    # than one request needs keeps utilization pinned above pressure_hi
    pages = (48 + 8) // g + 3
    qos = QosConfig(pressure_hi=0.6, pressure_lo=0.3, hysteresis_up=2)
    eng = ContinuousBatchingEngine(
        m, params, max_slots=3, max_len=64, num_pages=pages, qos=qos)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, (24,))
                    .astype(np.int32),
                    max_new_tokens=8, arrival_time=i * 1e-3)
            for i in range(6)]
    res = eng.run(reqs, GenerationConfig())
    deg = res["qos"]["degrade"]
    assert deg["downshifts"] > 0            # pressure engaged the ladder
    assert deg["peak_level"] >= 1
    assert len(res["requests"]) == 6        # degraded, but everyone done
    check_alloc_invariants(eng.core.sched.alloc)


def test_default_qos_config_outputs_match_plain_engine(smoke_model):
    """A bare ``QosConfig()`` on a single-tenant unchunked workload
    changes accounting, not behavior: no deadlines to shed, no budgets
    to filter, equal attained service keeps FCFS order — greedy outputs
    must match the qos=None engine exactly."""
    cfg, m, params = smoke_model

    def wl():
        rng = np.random.default_rng(2)
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, (12,))
                        .astype(np.int32),
                        max_new_tokens=5, arrival_time=i * 0.002)
                for i in range(5)]

    plain = ContinuousBatchingEngine(m, params, max_slots=2, max_len=64)
    r1 = plain.run(wl(), GenerationConfig())
    qos = ContinuousBatchingEngine(m, params, max_slots=2, max_len=64,
                                   qos=QosConfig())
    r2 = qos.run(wl(), GenerationConfig())
    toks = lambda r: {q.rid: list(q.out_tokens) for q in r["requests"]}
    assert toks(r1) == toks(r2)
    assert "qos" not in r1 and "chaos" not in r1
    assert r2["qos"]["tenants"]["default"]["admitted"] == 5


def test_idle_engine_jumps_clock_to_bucket_refill(smoke_model):
    """Regression: with the pool idle and the queue head blocked only by
    its tenant's token bucket, the simulated clock (and thus every
    refill) would freeze — the engine must jump to the next affordable
    time instead of dying with the 'num_pages too small' error (found
    driving the launcher with --tenant-budget on a drained pool)."""
    cfg, m, params = smoke_model
    rng = np.random.default_rng(31)
    eng = ContinuousBatchingEngine(
        m, params, max_slots=2, max_len=64,
        qos=QosConfig(tenant_budget=10.0))   # burst 20 = one request
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, (16,))
                    .astype(np.int32), max_new_tokens=4)
            for i in range(2)]               # cost 20 each
    res = eng.run(reqs, GenerationConfig())
    assert sorted(r.rid for r in res["requests"]) == [0, 1]
    # rid 1 had to wait out a full bucket refill (20 tokens / 10 tok/s)
    assert res["wall_s"] >= 1.9
    assert res["qos"]["tenants"]["default"]["admitted"] == 2
