"""NTK RoPE scaling (paper Appendix C): PolarQuant is insensitive to the
RoPE base / NTK context extension — the polar premise (rotation preserves
radius) holds for any frequency configuration."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.core import polar
from repro.core.quantizers import (QuantConfig, decode_polar_keys,
                                   encode_polar_keys)
from repro.models import get_model
from repro.models.layers import apply_rope, rope_frequencies


def test_ntk_scaling_lowers_frequencies():
    f1 = rope_frequencies(64, 10000.0)
    f2 = rope_frequencies(64, 10000.0, ntk_scale=4.0)
    assert float(f2[1:].max()) < float(f1[1:].max())
    np.testing.assert_allclose(float(f2[0]), 1.0)  # first freq unscaled


def test_radius_invariance_any_base():
    """The paper's core invariant under every RoPE configuration."""
    pre = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 64, 32)) + 5.0
    pos = jnp.arange(64, dtype=jnp.int32)
    for base, scale in [(10000.0, 1.0), (500000.0, 1.0), (1e6, 1.0),
                        (10000.0, 4.0)]:
        post = apply_rope(pre, pos, base, scale)
        r_pre, _ = polar.to_polar(pre)
        r_post, _ = polar.to_polar(post)
        np.testing.assert_allclose(np.asarray(r_pre), np.asarray(r_post),
                                   atol=1e-4)


def test_quant_error_stable_across_bases(structured_keys):
    errs = []
    for base in (10000.0, 500000.0, 1000000.0):
        k = structured_keys(jax.random.PRNGKey(1), 2, 2, 512, 64,
                            rope_base=base)
        cfg = QuantConfig(method="polar", group_size=128)
        kt = decode_polar_keys(encode_polar_keys(k, cfg))
        errs.append(float(jnp.linalg.norm(k - kt) / jnp.linalg.norm(k)))
    assert max(errs) < 1.6 * min(errs), errs


def test_model_with_ntk_scaling_runs():
    cfg = reduce_for_smoke(get_config("tinyllama-1.1b"))
    cfg = dataclasses.replace(cfg, rope_ntk_scale=2.0)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 65), 0,
                                          cfg.vocab_size)}
    loss, _ = m.loss(params, batch)
    assert bool(jnp.isfinite(loss))
    state = m.init_decode_state(2, 128)
    lg, state = m.prefill(params, {"tokens": batch["tokens"][:, :64]}, state)
    assert bool(jnp.isfinite(lg).all())
