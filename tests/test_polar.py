"""Polar transform unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.core import polar


@pytest.mark.parametrize("pairing", ["half", "adjacent"])
def test_roundtrip(pairing):
    k = jax.random.normal(jax.random.PRNGKey(0), (3, 5, 64))
    rho, theta = polar.to_polar(k, pairing)
    back = polar.from_polar(rho, theta, pairing)
    np.testing.assert_allclose(np.asarray(back), np.asarray(k), atol=1e-5)


def test_theta_range():
    k = jax.random.normal(jax.random.PRNGKey(1), (100, 32))
    _, theta = polar.to_polar(k)
    assert float(theta.min()) >= 0.0
    assert float(theta.max()) <= 2 * np.pi + 1e-6


def test_rho_nonnegative_and_magnitude():
    k = jax.random.normal(jax.random.PRNGKey(2), (10, 16))
    rho, _ = polar.to_polar(k)
    assert float(rho.min()) >= 0.0
    x, y = polar.split_pairs(k)
    np.testing.assert_allclose(np.asarray(rho ** 2), np.asarray(x ** 2 + y ** 2),
                               rtol=1e-5)


def test_rope_preserves_radius(structured_keys):
    """The paper's core observation: RoPE rotation is magnitude-preserving,
    so pre- and post-RoPE radii are identical per pair."""
    from repro.models.layers import apply_rope
    key = jax.random.PRNGKey(3)
    pre = jax.random.normal(key, (2, 2, 64, 32))
    pos = jnp.arange(64, dtype=jnp.int32)
    post = apply_rope(pre, pos, 10000.0)
    rho_pre, _ = polar.to_polar(pre)
    rho_post, _ = polar.to_polar(post)
    np.testing.assert_allclose(np.asarray(rho_pre), np.asarray(rho_post),
                               atol=1e-4)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                min_size=8, max_size=8))
def test_roundtrip_hypothesis(vals):
    k = jnp.asarray(vals, jnp.float32)[None]
    rho, theta = polar.to_polar(k)
    back = polar.from_polar(rho, theta)
    # fp error of the trig roundtrip scales with the PAIR norm (a tiny
    # component next to a huge one is only recoverable to |pair| * eps)
    x, y = polar.split_pairs(k)
    pair_norm = np.asarray(jnp.sqrt(x * x + y * y))
    tol = 1e-5 + 5e-7 * np.concatenate([pair_norm, pair_norm], -1)
    err = np.abs(np.asarray(back) - np.asarray(k))
    assert (err <= tol).all(), (err, tol)


def test_pairings_differ_but_consistent():
    k = jnp.arange(8, dtype=jnp.float32)[None]
    xh, yh = polar.split_pairs(k, "half")
    xa, ya = polar.split_pairs(k, "adjacent")
    assert not np.allclose(np.asarray(xh), np.asarray(xa))
    np.testing.assert_allclose(
        np.asarray(polar.merge_pairs(xh, yh, "half")), np.asarray(k))
    np.testing.assert_allclose(
        np.asarray(polar.merge_pairs(xa, ya, "adjacent")), np.asarray(k))
