"""Paged cache: dense-layout parity, page-allocator reuse, masked writes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    QuantConfig, append, decode_attention, init_cache, prefill,
)
from repro.core import paged_cache as pg
from repro.core.cache_layout import LinearLayout, PagedLayout, PageAllocator

B, H, d, g = 1, 2, 32, 16
LAYOUT = PagedLayout(page_size=g, num_pages=20, slots=4, pages_per_slot=8)


def _tokens(seed, t):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(k1, (B, H, t, d)),
            jax.random.normal(k2, (B, H, t, d)))


def _fill_pair(cfg, tp, tdec, slot=2, bucket=48, seed=0):
    """Same token stream into a dense linear cache and a paged slot."""
    t = tp + tdec
    k, v = _tokens(seed, t)
    cap = LAYOUT.pages_per_slot * g

    dense = prefill(init_cache(cfg, B, H, d, cap, layout=LinearLayout(cap)),
                    k[:, :, :tp], v[:, :, :tp])
    for i in range(tp, t):
        dense = append(dense, k[:, :, i : i + 1], v[:, :, i : i + 1])

    alloc = PageAllocator(LAYOUT)
    assert alloc.alloc(slot, LAYOUT.pages_for(tp))
    paged = pg.init_paged_cache(cfg, LAYOUT, H, d)
    kp = jnp.pad(k[:, :, :tp], ((0, 0), (0, 0), (0, bucket - tp), (0, 0)))
    vp = jnp.pad(v[:, :, :tp], ((0, 0), (0, 0), (0, bucket - tp), (0, 0)))
    paged = pg.paged_prefill(paged, jnp.asarray(slot), alloc.table()[slot],
                             kp, vp, jnp.asarray(tp))
    ap = jax.jit(pg.paged_append)
    for i in range(tp, t):
        ln = int(paged.lengths[slot])
        if ln % g == 0 and alloc.slot_pages(slot) <= ln // g:
            assert alloc.alloc(slot, 1)
        s = LAYOUT.slots
        kn = jnp.zeros((s, H, 1, d)).at[slot].set(k[0, :, i : i + 1])
        vn = jnp.zeros((s, H, 1, d)).at[slot].set(v[0, :, i : i + 1])
        active = jnp.zeros((s,), bool).at[slot].set(True)
        paged = ap(paged, kn, vn, alloc.table(), active)
    return dense, paged, alloc, slot, k, v


@pytest.mark.parametrize("method,value_bits", [
    ("polar", 0), ("polar", 4), ("kivi", 0), ("zipcache", 0),
    ("int", 0), ("none", 0),
])
def test_paged_matches_dense(method, value_bits):
    """Prefill + appends crossing a page boundary: bit-identical codes and
    matching decode attention between the dense and paged layouts."""
    cfg = QuantConfig(method=method, group_size=g, key_bits=4,
                      value_bits=value_bits)
    # prompt 38 = 2 full groups + 6 residual; 13 appends cross slot 48
    dense, paged, alloc, slot, _, _ = _fill_pair(cfg, 38, 13)

    view = pg.gather_view(paged, alloc.table())
    if method in ("polar", "kivi", "zipcache"):
        nfull = int(dense.length) // g
        np.testing.assert_array_equal(
            np.asarray(dense.key_codes)[0, :, :nfull],
            np.asarray(view.key_codes)[slot, :, :nfull])

    q = jax.random.normal(jax.random.PRNGKey(9), (B, H * 2, d))
    qs = jnp.zeros((LAYOUT.slots, H * 2, d)).at[slot].set(q[0])
    o_dense = decode_attention(dense, q)
    o_paged = pg.paged_decode_attention(paged, qs, alloc.table(),
                                        backend="jnp")
    np.testing.assert_allclose(np.asarray(o_dense[0]),
                               np.asarray(o_paged[slot]),
                               atol=2e-5, rtol=1e-4)


def test_paged_prefill_exact_group_multiple():
    """rem == 0 prefill (empty residual) then appends starting a new group."""
    cfg = QuantConfig(method="polar", group_size=g)
    dense, paged, alloc, slot, _, _ = _fill_pair(cfg, 32, 5, bucket=32)
    q = jax.random.normal(jax.random.PRNGKey(3), (B, H * 2, d))
    qs = jnp.zeros((LAYOUT.slots, H * 2, d)).at[slot].set(q[0])
    o_dense = decode_attention(dense, q)
    o_paged = pg.paged_decode_attention(paged, qs, alloc.table(),
                                        backend="jnp")
    np.testing.assert_allclose(np.asarray(o_dense[0]),
                               np.asarray(o_paged[slot]),
                               atol=2e-5, rtol=1e-4)


def test_append_leaves_inactive_slots_untouched():
    """A fully-masked append may only dirty the scratch page: every real
    pool page, the residuals, and the lengths are bit-unchanged."""
    cfg = QuantConfig(method="polar", group_size=g)
    _, paged, alloc, slot, _, _ = _fill_pair(cfg, 38, 3)
    s = LAYOUT.slots
    kn = jax.random.normal(jax.random.PRNGKey(0), (s, H, 1, d))
    out = pg.paged_append(paged, kn, kn, alloc.table(),
                          jnp.zeros((s,), bool))

    def real(x):  # strip the scratch page from pool buffers
        return x[: LAYOUT.num_pages] if x.shape[0] == LAYOUT.pool_pages else x

    before = jax.tree_util.tree_leaves(paged)
    after = jax.tree_util.tree_leaves(out)
    for a, b in zip(before, after):
        np.testing.assert_array_equal(np.asarray(real(a)),
                                      np.asarray(real(b)))


def test_page_allocator_reuse_after_free():
    """Freed pages go back to the pool and get handed to new requests."""
    lay = PagedLayout(page_size=16, num_pages=6, slots=3, pages_per_slot=4)
    alloc = PageAllocator(lay)
    assert alloc.alloc(0, 3)
    pages0 = set(alloc.table_np()[0, :3].tolist())
    assert alloc.alloc(1, 2)
    assert alloc.used_pages == 5 and alloc.free_pages == 1
    # all-or-nothing: 2 pages requested, 1 free
    assert not alloc.alloc(2, 2)
    assert alloc.used_pages == 5

    assert alloc.free_slot(0) == 3
    assert (alloc.table_np()[0] == lay.scratch_page).all()
    assert alloc.free_pages == 4

    assert alloc.alloc(2, 4)
    pages2 = set(alloc.table_np()[2].tolist())
    assert pages0 < pages2  # recycled pages reappear in the new request
    assert alloc.utilization() == 1.0


def test_allocator_respects_pages_per_slot():
    lay = PagedLayout(page_size=16, num_pages=16, slots=2, pages_per_slot=3)
    alloc = PageAllocator(lay)
    assert alloc.alloc(0, 3)
    assert not alloc.alloc(0, 1)   # row full even though the pool isn't
    assert alloc.free_pages == 13
